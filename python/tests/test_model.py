"""L2 model tests: shapes, genome alignment, trainability, quant effect."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

B = 8


def _batch(seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (B, model.IMG, model.IMG, model.IN_CH), jnp.float32)
    y = jax.random.randint(ky, (B,), 0, model.NUM_CLASSES, jnp.int32)
    return x, y


def _q(bits):
    return jnp.full((model.NUM_LAYERS,), float(bits), jnp.float32)


def test_arch_matches_paper_genome():
    assert model.NUM_LAYERS == 28
    assert 2 * model.NUM_LAYERS == 56  # paper: 56-integer string
    kinds = [k for k, *_ in model.ARCH]
    assert kinds[0] == "conv"
    assert kinds[-1] == "fc"
    assert kinds[1:-1:2] == ["dw"] * 13
    assert kinds[2:-1:2] == ["pw"] * 13


def test_param_vector_layout():
    spec = model.PARAM_SPEC
    # offsets are contiguous and ordered
    off = 0
    for name, shape, o in spec:
        assert o == off, name
        size = int(np.prod(shape))
        off += size
    assert off == model.PARAM_SIZE
    assert model.PARAM_SIZE < 600_000  # CPU-trainable (DESIGN.md §3)


def test_forward_shapes_and_finiteness():
    p = model.init_params(0)
    x, _ = _batch()
    logits = model.forward(p, x, _q(8), _q(8))
    assert logits.shape == (B, model.NUM_CLASSES)
    assert np.isfinite(np.asarray(logits)).all()


def test_loss_decreases_when_overfitting_one_batch():
    p = model.init_params(0)
    x, y = _batch(1)
    qa, qw = _q(8), _q(8)
    step = jax.jit(model.train_step)
    first = None
    loss = None
    for i in range(30):
        p, loss = step(p, x, y, qa, qw, jnp.float32(0.05))
        if i == 0:
            first = float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))


def test_eval_step_counts():
    p = model.init_params(0)
    x, y = _batch(2)
    correct, loss = model.eval_step(p, x, y, _q(8), _q(8))
    assert 0.0 <= float(correct) <= B
    assert float(correct) == int(float(correct))
    assert np.isfinite(float(loss))


def test_low_bitwidth_hurts_loss():
    """2-bit everywhere must be substantially worse than 8-bit on a
    trained-ish model (train briefly at 8 bit, compare eval losses)."""
    p = model.init_params(0)
    x, y = _batch(3)
    step = jax.jit(model.train_step)
    for _ in range(15):
        p, _ = step(p, x, y, _q(8), _q(8), jnp.float32(0.05))
    _, l8 = model.eval_step(p, x, y, _q(8), _q(8))
    _, l2 = model.eval_step(p, x, y, _q(2), _q(2))
    assert float(l2) > float(l8), (float(l2), float(l8))


def test_per_layer_bitwidths_are_independent():
    """Changing one layer's q changes the output; others' stay same."""
    p = model.init_params(1)
    x, _ = _batch(4)
    base = model.forward(p, x, _q(8), _q(8))
    qa = np.full(model.NUM_LAYERS, 8.0, np.float32)
    qa[5] = 2.0
    out = model.forward(p, x, jnp.asarray(qa), _q(8))
    assert not np.allclose(np.asarray(base), np.asarray(out))


def test_pallas_and_ref_paths_agree(monkeypatch):
    """The USE_PALLAS=0 ablation path computes the same function."""
    p = model.init_params(2)
    x, _ = _batch(5)
    qa, qw = _q(5), _q(3)
    out_pallas = model.forward(p, x, qa, qw)

    monkeypatch.setattr(model, "USE_PALLAS", False)
    out_ref = model.forward(p, x, qa, qw)
    np.testing.assert_allclose(
        np.asarray(out_pallas), np.asarray(out_ref), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("seed", [0, 1])
def test_init_deterministic(seed):
    a = model.init_params(seed)
    b = model.init_params(seed)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
