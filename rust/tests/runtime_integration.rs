//! PJRT runtime integration: load the AOT artifacts and execute the
//! train/eval steps from Rust. When `make artifacts` has run, the real
//! artifacts are used; otherwise a deterministic stub bundle is
//! generated on the fly (`runtime::write_stub_artifacts`) and executed
//! by the stub backend — so this suite runs in CI with no Python
//! toolchain and still pins the full Runtime/TrainSession/QatAccuracy
//! contract (shapes, determinism, loss descent, bit-width
//! degradation, memoization).

use qmap::data::SyntheticDataset;
use qmap::quant::QuantConfig;
use qmap::runtime::qat::{QatAccuracy, QatBudget};
use qmap::runtime::{default_artifact_dir, write_stub_artifacts, Runtime};

/// PJRT handles are not Sync, so each test compiles its own runtime
/// (cheap on the stub; a few seconds per test on a real client). The
/// stub bundle is written exactly once per process — tests run in
/// parallel, and `fs::write` truncates before writing, so a per-test
/// rewrite would race another test's `Runtime::load` mid-truncation.
fn load_rt() -> Runtime {
    let dir = default_artifact_dir();
    if dir.join("model_meta.json").exists() {
        return Runtime::load(dir).expect("artifacts present but stale — run `make artifacts`");
    }
    static STUB_DIR: std::sync::OnceLock<std::path::PathBuf> = std::sync::OnceLock::new();
    let stub = STUB_DIR.get_or_init(|| {
        let mut d = std::env::temp_dir();
        d.push(format!("qmap_stub_artifacts_{}", std::process::id()));
        write_stub_artifacts(&d).expect("stub artifacts");
        d
    });
    Runtime::load(stub).expect("stub artifact bundle must load")
}

#[test]
fn artifacts_load_and_metadata_is_consistent() {
    let rt = &load_rt();
    assert_eq!(rt.meta.num_layers, 28, "MobileNetV1 genome length");
    assert_eq!(rt.init_params.len(), rt.meta.param_size);
    assert!(rt.meta.batch > 0 && rt.meta.img > 0);
    assert!(!rt.platform().is_empty());
}

#[test]
fn eval_step_runs_and_is_deterministic() {
    let rt = &load_rt();
    let data = SyntheticDataset::new(1);
    let b = data.batch(rt.meta.batch, 0);
    let l = rt.meta.num_layers;
    let qa = vec![8.0f32; l];
    let qw = vec![8.0f32; l];
    let (c1, l1) = rt.eval_step(&rt.init_params, &b.x, &b.y, &qa, &qw).unwrap();
    let (c2, l2) = rt.eval_step(&rt.init_params, &b.x, &b.y, &qa, &qw).unwrap();
    assert_eq!(c1, c2);
    assert_eq!(l1, l2);
    assert!(c1 >= 0.0 && c1 <= rt.meta.batch as f32);
    assert!(l1.is_finite() && l1 > 0.0);
}

#[test]
fn train_step_changes_params_and_loss_is_finite() {
    let rt = &load_rt();
    let data = SyntheticDataset::new(2);
    let b = data.batch(rt.meta.batch, 0);
    let l = rt.meta.num_layers;
    let qa = vec![8.0f32; l];
    let qw = vec![8.0f32; l];
    let mut params = rt.init_params.clone();
    let loss = rt.train_step(&mut params, &b.x, &b.y, &qa, &qw, 0.05).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let changed = params
        .iter()
        .zip(&rt.init_params)
        .filter(|(a, b)| a != b)
        .count();
    assert!(
        changed > params.len() / 10,
        "only {changed}/{} params moved",
        params.len()
    );
}

#[test]
fn short_training_reduces_loss() {
    let rt = &load_rt();
    let data = SyntheticDataset::new(3);
    let mut first = None;
    let mut last = 0.0f32;
    QatAccuracy::pretrain(rt, &data, 8, 30, 0.05, |_, loss| {
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    })
    .unwrap();
    let first = first.unwrap();
    assert!(
        last < first,
        "loss did not fall over 30 steps: {first} -> {last}"
    );
}

#[test]
fn lower_bitwidths_execute_and_degrade_gracefully() {
    // the same artifact serves every genome: bit-widths are runtime
    // inputs. 2-bit inference must run, and (untrained) should not be
    // *better* than 8-bit by a large margin.
    let rt = &load_rt();
    let data = SyntheticDataset::new(4);
    let params = QatAccuracy::pretrain(rt, &data, 8, 60, 0.05, |_, _| {}).unwrap();
    let l = rt.meta.num_layers;
    let eval_at = |bits: f32| {
        let qa = vec![bits; l];
        let qw = vec![bits; l];
        let mut correct = 0.0;
        for i in 0..4 {
            let b = data.batch(rt.meta.batch, 10_000 + i);
            let (c, _) = rt.eval_step(&params, &b.x, &b.y, &qa, &qw).unwrap();
            correct += c;
        }
        correct / (4.0 * rt.meta.batch as f32)
    };
    let a8 = eval_at(8.0);
    let a2 = eval_at(2.0);
    assert!(
        a8 >= a2 - 0.05,
        "8-bit ({a8}) should not lose to 2-bit ({a2}) after 8-bit training"
    );
}

#[test]
fn qat_accuracy_memoizes_genomes() {
    let rt = &load_rt();
    let data = SyntheticDataset::new(5);
    let mut qat = QatAccuracy::new(
        rt,
        data,
        rt.init_params.clone(),
        QatBudget {
            finetune_steps: 2,
            eval_batches: 1,
            lr: 0.02,
        },
    );
    let g = QuantConfig::uniform(rt.meta.num_layers, 6);
    let t0 = std::time::Instant::now();
    let a1 = qat.evaluate(&g).unwrap();
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    let a2 = qat.evaluate(&g).unwrap();
    let warm = t1.elapsed();
    assert_eq!(a1, a2);
    assert!(
        warm < cold / 10,
        "memo hit not fast: cold {cold:?}, warm {warm:?}"
    );
}

#[test]
fn shape_mismatches_are_rejected() {
    let rt = &load_rt();
    let l = rt.meta.num_layers;
    let qa = vec![8.0f32; l];
    let bad_qw = vec![8.0f32; l + 1];
    let data = SyntheticDataset::new(6);
    let b = data.batch(rt.meta.batch, 0);
    assert!(rt.eval_step(&rt.init_params, &b.x, &b.y, &qa, &bad_qw).is_err());
    let bad_params = vec![0.0f32; 10];
    assert!(rt.eval_step(&bad_params, &b.x, &b.y, &qa, &qa).is_err());
    let bad_x = vec![0.0f32; 7];
    assert!(rt.eval_step(&rt.init_params, &bad_x, &b.y, &qa, &qa).is_err());
}
