//! Table II: reduction in memory energy (Δ_em) and relative accuracy
//! change (Δ_acc) vs the uniform 8-bit implementation, for the Uniform /
//! Naïve / Proposed strategies × {MobileNetV1, V2} × {Eyeriss, Simba}.
//!
//! Paper shape to reproduce:
//!   * Uniform finds large savings only at large accuracy loss;
//!   * Naïve recovers accuracy but saves less than Proposed;
//!   * Proposed reaches the deepest savings at >= 0 accuracy delta
//!     (paper headline: up to -63% memory energy at +0.1% accuracy on
//!     Eyeriss/MobileNetV1; "up to 37% energy savings without any
//!     accuracy drop" across the whole-energy axis);
//!   * savings on Eyeriss > Simba (its memory subsystem dominates).
//!
//! Run: `cargo bench --bench table2_summary`.

use qmap::coordinator::experiments::{table2_summary, Table2Row};
use qmap::coordinator::RunConfig;
use qmap::report;
use std::time::Instant;

fn main() {
    let rc = RunConfig::from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2)
    });
    let per_cell = 4; // representative trade-offs per cell, as the paper prints
    println!("=== Table II: Δ memory-energy / Δ accuracy vs uniform-8 ===");
    let t0 = Instant::now();
    let rows = table2_summary(&rc, per_cell);
    let dt = t0.elapsed();

    let fmt: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.arch.clone(),
                r.network.clone(),
                r.strategy.to_string(),
                format!("{:+.1}%", r.delta_mem * 100.0),
                format!("{:+.1}%", r.delta_acc * 100.0),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(&["arch", "network", "strategy", "Δ_em", "Δ_acc"], &fmt)
    );

    // shape checks
    // the paper's Table II "no drop" cells sit within +-0.5% of the
    // reference; accept 0.5% here (the proxy adds evaluation noise)
    let best_saving_no_drop = |arch: &str, strat: &str| {
        rows.iter()
            .filter(|r| r.arch == arch && r.strategy == strat && r.delta_acc >= -0.005)
            .map(|r: &Table2Row| -r.delta_mem)
            .fold(f64::NEG_INFINITY, f64::max)
    };
    let mut ok = true;
    for arch in ["eyeriss", "simba"] {
        let p = best_saving_no_drop(arch, "proposed");
        let n = best_saving_no_drop(arch, "naive");
        let u = best_saving_no_drop(arch, "uniform");
        println!(
            "\n{arch}: best memory saving at no accuracy drop — proposed {:.1}%, naive {:.1}%, uniform {:.1}%",
            p * 100.0,
            n * 100.0,
            u * 100.0
        );
        // at laptop budgets the two NSGA-II arms are within run-to-run
        // noise of each other; flag only decisive (>5pp) inversions
        if p < n - 0.05 {
            ok = false;
            println!("shape violation: {arch} naive beat proposed decisively");
        }
        if p < u - 0.05 {
            ok = false;
            println!("shape violation: {arch} uniform beat proposed decisively");
        }
    }
    let e = best_saving_no_drop("eyeriss", "proposed");
    println!(
        "\nheadline (Eyeriss, proposed, no acc drop): -{:.1}% memory energy (paper: up to -63% at +0.1%)",
        e * 100.0
    );
    println!(
        "paper shape (proposed >= naive >= uniform at no-drop): {}",
        if ok && e > 0.25 { "REPRODUCED" } else { "MISMATCH" }
    );

    let csv_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.arch.clone(),
                r.network.clone(),
                r.strategy.to_string(),
                format!("{:.6}", r.delta_mem),
                format!("{:.6}", r.delta_acc),
            ]
        })
        .collect();
    let path = report::write_results(
        "table2_summary.csv",
        &report::csv(&["arch", "network", "strategy", "delta_mem", "delta_acc"], &csv_rows),
    );
    println!("[{dt:.2?}] wrote {}", path.display());
}
