//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path. Python never runs at request time — the
//! artifacts in `artifacts/` are produced once by `make artifacts`
//! (`python/compile/aot.py`) and this module is the only consumer.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT): HLO *text* is
//! the interchange format because jax>=0.5 serialized protos use 64-bit
//! instruction ids that this XLA rejects (see /opt/xla-example/README.md).

pub mod qat;

use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `model_meta.json` manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub model: String,
    pub num_layers: usize,
    pub param_size: usize,
    pub batch: usize,
    pub img: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub use_pallas: bool,
}

impl ModelMeta {
    pub fn from_json(src: &str) -> Result<Self> {
        let v = parse(src).map_err(|e| anyhow!("model_meta.json: {e}"))?;
        let need = |k: &str| -> Result<usize> {
            v.get(k)
                .as_usize()
                .ok_or_else(|| anyhow!("manifest missing '{k}'"))
        };
        Ok(ModelMeta {
            model: v
                .get("model")
                .as_str()
                .ok_or_else(|| anyhow!("manifest missing 'model'"))?
                .to_string(),
            num_layers: need("num_layers")?,
            param_size: need("param_size")?,
            batch: need("batch")?,
            img: need("img")?,
            in_ch: need("in_ch")?,
            num_classes: need("num_classes")?,
            use_pallas: matches!(v.get("use_pallas"), Json::Bool(true)),
        })
    }
}

/// A compiled artifact bundle: PJRT client + train/eval executables +
/// initial parameters.
pub struct Runtime {
    client: xla::PjRtClient,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    pub meta: ModelMeta,
    pub init_params: Vec<f32>,
}

impl Runtime {
    /// Load `model_meta.json`, `{train,eval}_step.hlo.txt` and
    /// `params_init.bin` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let meta_src = std::fs::read_to_string(dir.join("model_meta.json"))
            .with_context(|| format!("reading {}/model_meta.json (run `make artifacts`)", dir.display()))?;
        let meta = ModelMeta::from_json(&meta_src)?;

        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        let train = Self::compile(&client, &dir.join("train_step.hlo.txt"))?;
        let eval = Self::compile(&client, &dir.join("eval_step.hlo.txt"))?;

        let raw = std::fs::read(dir.join("params_init.bin"))
            .with_context(|| "reading params_init.bin")?;
        if raw.len() != meta.param_size * 4 {
            bail!(
                "params_init.bin: expected {} bytes, got {}",
                meta.param_size * 4,
                raw.len()
            );
        }
        let init_params: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        Ok(Runtime {
            client,
            train,
            eval,
            meta,
            init_params,
        })
    }

    fn compile(client: &xla::PjRtClient, path: &PathBuf) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str).map_err(to_anyhow)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(to_anyhow)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn x_literal(&self, x: &[f32]) -> Result<xla::Literal> {
        let m = &self.meta;
        xla::Literal::vec1(x)
            .reshape(&[m.batch as i64, m.img as i64, m.img as i64, m.in_ch as i64])
            .map_err(to_anyhow)
    }

    /// One SGD step. `params` is updated in place; returns the
    /// post-step loss on the same batch (an extra forward pass — the
    /// train artifact returns only `new_params`, see aot.py).
    ///
    /// Convenience wrapper that round-trips `params` through the host;
    /// hot loops should use [`Runtime::train_session`], which keeps the
    /// parameters resident on the PJRT device between steps.
    pub fn train_step(
        &self,
        params: &mut Vec<f32>,
        x: &[f32],
        y: &[i32],
        qa: &[f32],
        qw: &[f32],
        lr: f32,
    ) -> Result<f32> {
        self.check_shapes(params, x, y, qa, qw)?;
        let mut sess = self.train_session(params)?;
        sess.step(x, y, qa, qw, lr)?;
        let (_, loss) = sess.eval(x, y, qa, qw)?;
        *params = sess.params_to_host()?;
        Ok(loss)
    }

    /// Start a device-resident training session from a host checkpoint.
    pub fn train_session(&self, params: &[f32]) -> Result<TrainSession<'_>> {
        if params.len() != self.meta.param_size {
            bail!(
                "params: expected {} values, got {}",
                self.meta.param_size,
                params.len()
            );
        }
        // the host-to-device copy is asynchronous: the literal must stay
        // alive until the first sync point (see `in_flight`)
        let lit = xla::Literal::vec1(params);
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(to_anyhow)?;
        Ok(TrainSession {
            rt: self,
            params: buf,
            in_flight: (Vec::new(), vec![lit]),
            steps_since_sync: 0,
        })
    }

    /// Evaluate one batch. Returns (correct_count, mean_loss).
    pub fn eval_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        qa: &[f32],
        qw: &[f32],
    ) -> Result<(f32, f32)> {
        self.check_shapes(params, x, y, qa, qw)?;
        let args = vec![
            xla::Literal::vec1(params),
            self.x_literal(x)?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(qa),
            xla::Literal::vec1(qw),
        ];
        let result = self.eval.execute::<xla::Literal>(&args).map_err(to_anyhow)?;
        Self::unpack_eval(&result[0])
    }

    fn unpack_eval(outs: &[xla::PjRtBuffer]) -> Result<(f32, f32)> {
        // the eval artifact returns a (correct, loss) tuple in one buffer
        // (this PJRT does not untuple roots)
        if outs.len() != 1 {
            bail!("eval_step: expected 1 tuple output, got {}", outs.len());
        }
        let out = outs[0].to_literal_sync().map_err(to_anyhow)?;
        let (correct, loss) = out.to_tuple2().map_err(to_anyhow)?;
        Ok((
            correct.get_first_element::<f32>().map_err(to_anyhow)?,
            loss.get_first_element::<f32>().map_err(to_anyhow)?,
        ))
    }

    fn check_shapes(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        qa: &[f32],
        qw: &[f32],
    ) -> Result<()> {
        let m = &self.meta;
        if params.len() != m.param_size {
            bail!("params: expected {} values, got {}", m.param_size, params.len());
        }
        let want_x = m.batch * m.img * m.img * m.in_ch;
        if x.len() != want_x {
            bail!("x: expected {} values, got {}", want_x, x.len());
        }
        if y.len() != m.batch {
            bail!("y: expected {} labels, got {}", m.batch, y.len());
        }
        if qa.len() != m.num_layers || qw.len() != m.num_layers {
            bail!(
                "qa/qw: expected {} entries, got {}/{}",
                m.num_layers,
                qa.len(),
                qw.len()
            );
        }
        Ok(())
    }
}

/// A training loop whose parameters live on the PJRT device: each
/// [`TrainSession::step`] feeds the previous step's `new_params` output
/// buffer straight back into `execute_b`, so only the batch (and the
/// scalar loss) cross the host boundary (§Perf: ~2x per step on CPU
/// PJRT vs. the Literal round-trip).
pub struct TrainSession<'rt> {
    rt: &'rt Runtime,
    params: xla::PjRtBuffer,
    /// Operands (device buffers + host literals) of every dispatch
    /// since the last sync point. PJRT CPU executes — and performs the
    /// host-to-device literal copies — asynchronously, and the host
    /// loop can enqueue many steps ahead of the device queue; freeing
    /// an argument buffer or a Literal a deferred copy still reads
    /// corrupts the heap (observed as `literal.size_bytes() ==
    /// b->size()` CHECK failures). Everything is retained here and
    /// released at sync points ([`TrainSession::sync`], `eval`,
    /// `params_to_host`), which `step` inserts automatically every
    /// [`SYNC_INTERVAL`] dispatches.
    in_flight: (Vec<xla::PjRtBuffer>, Vec<xla::Literal>),
    steps_since_sync: u32,
}

/// Dispatches between automatic sync points in [`TrainSession::step`]:
/// bounds in-flight operand memory (~1.7 MB/step) while amortizing the
/// ~0.85 MB params read-back a sync costs to ~53 KB/step.
const SYNC_INTERVAL: u32 = 16;

impl TrainSession<'_> {
    /// One SGD step. The updated parameters replace the session's
    /// device buffer; nothing crosses back to the host. (The train
    /// artifact intentionally has no loss output — use
    /// [`TrainSession::eval`] to sample a loss curve.)
    pub fn step(&mut self, x: &[f32], y: &[i32], qa: &[f32], qw: &[f32], lr: f32) -> Result<()> {
        let rt = self.rt;
        let host_args = [
            rt.x_literal(x)?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(qa),
            xla::Literal::vec1(qw),
            xla::Literal::scalar(lr),
        ];
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(6);
        for lit in &host_args {
            bufs.push(
                rt.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(to_anyhow)?,
            );
        }
        let args: Vec<&xla::PjRtBuffer> = std::iter::once(&self.params)
            .chain(bufs.iter())
            .collect();
        let mut result = rt.train.execute_b(&args).map_err(to_anyhow)?;
        let outs = &mut result[0];
        if outs.len() != 1 {
            bail!("train_step: expected 1 output (new_params), got {}", outs.len());
        }
        let old_params = std::mem::replace(&mut self.params, outs.swap_remove(0));
        // keep this dispatch's operands (incl. the consumed params
        // buffer) alive until the next sync point
        self.in_flight.0.extend(bufs);
        self.in_flight.0.push(old_params);
        self.in_flight.1.extend(host_args);
        self.steps_since_sync += 1;
        if self.steps_since_sync >= SYNC_INTERVAL {
            self.sync()?;
        }
        Ok(())
    }

    /// Block until all in-flight dispatches have drained, then release
    /// their retained operands.
    pub fn sync(&mut self) -> Result<()> {
        // reading the params buffer back forces completion of the whole
        // dependency chain (every step writes params)
        let _ = self.params.to_literal_sync().map_err(to_anyhow)?;
        self.in_flight.0.clear();
        self.in_flight.1.clear();
        self.steps_since_sync = 0;
        Ok(())
    }

    /// Evaluate a batch against the session's current parameters.
    pub fn eval(&mut self, x: &[f32], y: &[i32], qa: &[f32], qw: &[f32]) -> Result<(f32, f32)> {
        let rt = self.rt;
        let host_args = [
            rt.x_literal(x)?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(qa),
            xla::Literal::vec1(qw),
        ];
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(5);
        for lit in &host_args {
            bufs.push(
                rt.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(to_anyhow)?,
            );
        }
        let args: Vec<&xla::PjRtBuffer> = std::iter::once(&self.params)
            .chain(bufs.iter())
            .collect();
        let result = rt.eval.execute_b(&args).map_err(to_anyhow)?;
        let out = Runtime::unpack_eval(&result[0])?;
        // unpack_eval synced on the eval output, which depends on the
        // whole params chain: all retained operands are now drained
        self.in_flight.0.clear();
        self.in_flight.1.clear();
        self.steps_since_sync = 0;
        Ok(out)
    }

    /// Copy the current parameters back to the host.
    pub fn params_to_host(&mut self) -> Result<Vec<f32>> {
        let lit = self.params.to_literal_sync().map_err(to_anyhow)?;
        self.in_flight.0.clear();
        self.in_flight.1.clear();
        self.steps_since_sync = 0;
        lit.to_vec::<f32>().map_err(to_anyhow)
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("{e}")
}

/// Locate the repo's artifact directory: `$QMAP_ARTIFACTS` or
/// `artifacts/` relative to the workspace root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("QMAP_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let src = r#"{"model":"scaled_mobilenet_v1","num_layers":28,
            "param_size":100,"batch":32,"img":32,"in_ch":3,
            "num_classes":10,"use_pallas":true}"#;
        let m = ModelMeta::from_json(src).unwrap();
        assert_eq!(m.num_layers, 28);
        assert_eq!(m.batch, 32);
        assert!(m.use_pallas);
    }

    #[test]
    fn manifest_missing_field_errors() {
        assert!(ModelMeta::from_json("{}").is_err());
        assert!(ModelMeta::from_json("not json").is_err());
    }

    #[test]
    fn load_missing_dir_fails_with_hint() {
        match Runtime::load("/nonexistent/path") {
            Ok(_) => panic!("expected load failure"),
            Err(err) => assert!(format!("{err:#}").contains("make artifacts")),
        }
    }

    // Runtime execution tests live in rust/tests/runtime_integration.rs
    // (they need `make artifacts` to have run).
}
