//! The work-stealing executor behind the engine.
//!
//! One [`Pool`] owns the process-wide core budget: `budget - 1` worker
//! threads plus the submitting thread, which always participates in the
//! fan-outs it starts. Work lives in per-worker deques (owners push and
//! pop the back, LIFO; thieves take the front, FIFO) plus a global FIFO
//! injector for top-level submissions — the classic Chase–Lev shape,
//! built from plain `std` primitives (`Mutex`/`Condvar`/atomics) because
//! no external crates are available offline.
//!
//! The pool deliberately has no opinion about *what* runs: it executes
//! erased `FnOnce` tasks. Determinism is the caller's property — the
//! engine's jobs write results keyed by job id and merge in index order,
//! so steal order and worker count never show up in the output (see
//! `engine::driver`).
//!
//! Nesting is supported and is how adaptive shard-splitting works: a
//! task already running on a worker may call [`Pool::run_scoped`] again;
//! its subtasks go to that worker's own deque, where idle workers steal
//! them while the owner drains the rest itself.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A pool task with its borrows erased to `'static` by
/// [`Pool::run_scoped`] — sound because that call does not return until
/// every task it submitted has completed.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// A task that may borrow data from the submitting scope.
pub type ScopedTask<'scope> = Box<dyn FnOnce() + Send + 'scope>;

struct Shared {
    /// Global FIFO: top-level (non-worker) submissions land here.
    injector: Mutex<VecDeque<Task>>,
    /// Per-worker deques: owner pushes/pops the back, thieves steal the
    /// front.
    locals: Vec<Mutex<VecDeque<Task>>>,
    /// Workers currently parked on the condvar.
    idle: AtomicUsize,
    shutdown: AtomicBool,
    /// Submitters notify under this lock and parked workers re-check the
    /// queues under it before sleeping, so no wakeup is ever lost.
    gate: Mutex<()>,
    cv: Condvar,
    executed: AtomicU64,
    steals: AtomicU64,
}

thread_local! {
    /// `(pool identity, worker index)` when the current thread is a pool
    /// worker — lets nested fan-outs target the worker's own deque.
    static WORKER: std::cell::Cell<Option<(usize, usize)>> =
        std::cell::Cell::new(None);
}

fn shared_id(s: &Arc<Shared>) -> usize {
    Arc::as_ptr(s) as usize
}

impl Shared {
    fn has_work(&self) -> bool {
        if !self.injector.lock().unwrap().is_empty() {
            return true;
        }
        self.locals.iter().any(|q| !q.lock().unwrap().is_empty())
    }

    /// Worker `me`'s next task: own back (LIFO), then the injector,
    /// then steal a neighbour's front (FIFO).
    fn find_task(&self, me: usize) -> Option<Task> {
        if let Some(t) = self.locals[me].lock().unwrap().pop_back() {
            return Some(t);
        }
        if let Some(t) = self.injector.lock().unwrap().pop_front() {
            return Some(t);
        }
        for k in 1..self.locals.len() {
            let victim = (me + k) % self.locals.len();
            if let Some(t) = self.locals[victim].lock().unwrap().pop_front() {
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }
}

fn worker_loop(shared: Arc<Shared>, me: usize) {
    WORKER.with(|w| w.set(Some((shared_id(&shared), me))));
    loop {
        if let Some(t) = shared.find_task(me) {
            t();
            continue;
        }
        let guard = shared.gate.lock().unwrap();
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        if shared.has_work() {
            drop(guard);
            continue;
        }
        shared.idle.fetch_add(1, Ordering::SeqCst);
        let guard = shared.cv.wait(guard).unwrap();
        shared.idle.fetch_sub(1, Ordering::SeqCst);
        drop(guard);
    }
}

/// One fan-out in flight: the scope's tasks in claimable slots, the
/// claim counter, and the completion latch. Helpers — idle workers that
/// popped a stub, and the submitting thread itself — call [`help`]:
/// claim a slot index, take the task, run it, complete the latch.
/// Nobody executing inside a scope ever runs a *different* scope's
/// tasks, so nesting depth is bounded by real nesting (generation →
/// job → shards), never by queue contents.
///
/// [`help`]: ScopeState::help
struct ScopeState {
    slots: Vec<Mutex<Option<Task>>>,
    next: AtomicUsize,
    latch: Latch,
    shared: Arc<Shared>,
}

impl ScopeState {
    fn help(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::SeqCst);
            if i >= self.slots.len() {
                break;
            }
            // fetch_add hands out each index exactly once, so the slot
            // is always occupied; the Option guards double-execution
            // anyway
            if let Some(t) = self.slots[i].lock().unwrap().take() {
                self.shared.executed.fetch_add(1, Ordering::Relaxed);
                let r = catch_unwind(AssertUnwindSafe(t));
                self.latch.complete(r.err());
            }
        }
    }
}

/// Completion latch for one `run_scoped` fan-out; also carries the first
/// captured panic so it can be re-raised on the submitting thread.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            remaining: Mutex::new(n),
            cv: Condvar::new(),
            panic: Mutex::new(None),
        }
    }

    fn complete(&self, panic: Option<Box<dyn std::any::Any + Send + 'static>>) {
        if let Some(p) = panic {
            let mut slot = self.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.cv.wait(r).unwrap();
        }
        drop(r);
        if let Some(p) = self.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }
}

/// Work-stealing thread pool with a fixed concurrency budget.
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    budget: usize,
}

impl Pool {
    /// A pool with a total concurrency budget of `budget` threads
    /// (`0` = all available cores): the submitting thread participates
    /// in every fan-out it starts, so `budget - 1` worker threads are
    /// spawned. `budget == 1` spawns nothing and executes every task
    /// inline on the caller — a true serial baseline.
    pub fn new(budget: usize) -> Pool {
        let budget = if budget == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            budget
        };
        let workers = budget - 1;
        let shared = Arc::new(Shared {
            injector: Mutex::new(VecDeque::new()),
            locals: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            idle: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            gate: Mutex::new(()),
            cv: Condvar::new(),
            executed: AtomicU64::new(0),
            steals: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|me| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("qmap-engine-{me}"))
                    .spawn(move || worker_loop(shared, me))
                    .expect("spawn engine worker")
            })
            .collect();
        Pool {
            shared,
            handles,
            budget,
        }
    }

    /// The total concurrency budget (worker threads + the caller).
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Workers parked right now. Advisory only — it drives the
    /// shard-split *execution* heuristic, never the decomposition, so
    /// results cannot depend on it.
    pub fn idle_workers(&self) -> usize {
        self.shared.idle.load(Ordering::Relaxed)
    }

    /// Tasks executed so far (workers + helping submitters).
    pub fn tasks_executed(&self) -> u64 {
        self.shared.executed.load(Ordering::Relaxed)
    }

    /// Tasks a worker took from another worker's deque.
    pub fn steals(&self) -> u64 {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Execute every task to completion across the pool's workers plus
    /// the calling thread; returns once all are done. Tasks may borrow
    /// from the caller's stack: the borrow is erased to `'static`
    /// internally, which is sound because this function neither returns
    /// nor unwinds until every submitted task has completed. A panic
    /// inside a task is captured, the remaining tasks still run, and
    /// the first panic is re-raised here.
    ///
    /// The scope's tasks sit in claimable slots; what goes on the
    /// queues are cheap helper *stubs* (one per pool worker, capped by
    /// the task count) that claim slots until none remain. Called from
    /// a pool worker (a nested fan-out, e.g. a job splitting into
    /// mapper shards), the stubs land on that worker's own deque where
    /// idle workers steal them; the caller claims the rest itself, so
    /// completion never depends on any stub actually running.
    pub fn run_scoped<'scope>(&self, tasks: Vec<ScopedTask<'scope>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        // SAFETY: the lifetime erasure is sound because `latch.wait()`
        // at the end of this function blocks until all `n` tasks have
        // completed (the caller's `help` claims every slot no stub got
        // to), so every `'scope` borrow outlives its task's execution.
        // Leftover stubs executed after this scope ends only see empty
        // slots.
        let slots: Vec<Mutex<Option<Task>>> = tasks
            .into_iter()
            .map(|t| {
                Mutex::new(Some(unsafe {
                    std::mem::transmute::<ScopedTask<'scope>, Task>(t)
                }))
            })
            .collect();
        let state = Arc::new(ScopeState {
            slots,
            next: AtomicUsize::new(0),
            latch: Latch::new(n),
            shared: Arc::clone(&self.shared),
        });
        let stubs = n.saturating_sub(1).min(self.shared.locals.len());
        if stubs > 0 {
            let me = WORKER
                .with(|w| w.get())
                .filter(|&(pool, _)| pool == shared_id(&self.shared))
                .map(|(_, idx)| idx);
            {
                let mut helpers: Vec<Task> = Vec::with_capacity(stubs);
                for _ in 0..stubs {
                    let st = Arc::clone(&state);
                    helpers.push(Box::new(move || st.help()));
                }
                match me {
                    Some(idx) => self.shared.locals[idx].lock().unwrap().extend(helpers),
                    None => self.shared.injector.lock().unwrap().extend(helpers),
                }
            }
            let _g = self.shared.gate.lock().unwrap();
            if stubs == 1 {
                self.shared.cv.notify_one();
            } else {
                self.shared.cv.notify_all();
            }
        }
        state.help();
        state.latch.wait();
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let _g = self.shared.gate.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn scoped<'a>(f: impl FnOnce() + Send + 'a) -> ScopedTask<'a> {
        Box::new(f)
    }

    #[test]
    fn runs_every_task_once() {
        for budget in [1usize, 2, 4, 8] {
            let pool = Pool::new(budget);
            let counter = AtomicUsize::new(0);
            let tasks: Vec<ScopedTask> = (0..200)
                .map(|_| {
                    scoped(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect();
            pool.run_scoped(tasks);
            assert_eq!(counter.load(Ordering::Relaxed), 200, "budget={budget}");
        }
    }

    #[test]
    fn tasks_can_borrow_the_callers_stack() {
        let pool = Pool::new(4);
        let data: Vec<u64> = (0..64).collect();
        let slots: Vec<Mutex<u64>> = (0..64).map(|_| Mutex::new(0)).collect();
        {
            let data = &data;
            let slots = &slots;
            let tasks: Vec<ScopedTask> = (0..64)
                .map(|i| scoped(move || *slots[i].lock().unwrap() = data[i] * 3))
                .collect();
            pool.run_scoped(tasks);
        }
        for (i, s) in slots.iter().enumerate() {
            assert_eq!(*s.lock().unwrap(), i as u64 * 3);
        }
    }

    #[test]
    fn nested_fanout_completes() {
        let pool = Pool::new(4);
        let total = AtomicUsize::new(0);
        {
            let pool_ref = &pool;
            let total = &total;
            let outer: Vec<ScopedTask> = (0..8)
                .map(|_| {
                    scoped(move || {
                        let inner: Vec<ScopedTask> = (0..8)
                            .map(|_| {
                                scoped(move || {
                                    total.fetch_add(1, Ordering::Relaxed);
                                })
                            })
                            .collect();
                        pool_ref.run_scoped(inner);
                    })
                })
                .collect();
            pool.run_scoped(outer);
        }
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn pool_survives_many_fanouts() {
        let pool = Pool::new(3);
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            {
                let sum = &sum;
                let tasks: Vec<ScopedTask> =
                    (0..10).map(|i| scoped(move || {
                        sum.fetch_add(i, Ordering::Relaxed);
                    })).collect();
                pool.run_scoped(tasks);
            }
            assert_eq!(sum.load(Ordering::Relaxed), 45, "round {round}");
        }
        assert!(pool.tasks_executed() >= 500);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn task_panic_propagates_to_submitter() {
        let pool = Pool::new(2);
        let tasks: Vec<ScopedTask> = vec![
            scoped(|| {}),
            scoped(|| panic!("boom")),
            scoped(|| {}),
        ];
        pool.run_scoped(tasks);
    }

    #[test]
    fn panic_does_not_kill_the_pool() {
        let pool = Pool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_scoped(vec![scoped(|| panic!("first"))]);
        }));
        assert!(r.is_err());
        // the pool still executes later fan-outs
        let ok = AtomicUsize::new(0);
        {
            let ok = &ok;
            pool.run_scoped((0..20).map(|_| scoped(move || {
                ok.fetch_add(1, Ordering::Relaxed);
            })).collect());
        }
        assert_eq!(ok.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn budget_one_is_inline() {
        let pool = Pool::new(1);
        assert_eq!(pool.budget(), 1);
        assert_eq!(pool.idle_workers(), 0);
        let tid = std::thread::current().id();
        let ran_on = Mutex::new(None);
        {
            let ran_on = &ran_on;
            pool.run_scoped(vec![scoped(move || {
                *ran_on.lock().unwrap() = Some(std::thread::current().id());
            })]);
        }
        assert_eq!(*ran_on.lock().unwrap(), Some(tid));
    }
}
