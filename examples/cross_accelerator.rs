//! Cross-accelerator portability study (paper Fig. 6's
//! "Proposed for Simba" arm, expanded both directions).
//!
//! Question: how much do you lose by optimizing a quantization for the
//! WRONG accelerator? We run the hardware-aware search against Eyeriss
//! and against Simba, then price both genomes on both machines.
//!
//! Run: `cargo run --release --example cross_accelerator`

use qmap::accuracy::{AccuracyModel, ProxyAccuracy, ProxyParams};
use qmap::arch::presets;
use qmap::baselines::{proposed_search, Candidate};
use qmap::coordinator::RunConfig;
use qmap::engine::Engine;
use qmap::eval::evaluate_network;
use qmap::mapper::cache::MapperCache;
use qmap::quant::QuantConfig;
use qmap::report;
use qmap::workload::models;

fn main() {
    let layers = models::mobilenet_v2();
    let mut rc = RunConfig::fast();
    rc.nsga.generations = 8;

    let eyeriss = presets::eyeriss();
    let simba = presets::simba();
    let engine = Engine::new(rc.threads);
    let cache_e = MapperCache::new();
    let cache_s = MapperCache::new();
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());

    println!("=== cross-accelerator study: MobileNetV2, Eyeriss <-> Simba ===\n");

    // native searches
    let on_eyeriss = proposed_search(
        &engine, &eyeriss, &layers, &mut acc, &cache_e, &rc.mapper, &rc.nsga, |_, _| {},
    );
    let on_simba = proposed_search(
        &engine, &simba, &layers, &mut acc, &cache_s, &rc.mapper, &rc.nsga, |_, _| {},
    );

    // references
    let u8g = QuantConfig::uniform(layers.len(), 8);
    let ref_e = evaluate_network(&eyeriss, &layers, &u8g, &cache_e, &rc.mapper).unwrap();
    let ref_s = evaluate_network(&simba, &layers, &u8g, &cache_s, &rc.mapper).unwrap();
    let ref_acc = acc.accuracy(&u8g);

    // best candidate at no accuracy drop, per search, per eval target
    let best_on = |cands: &[Candidate],
                   target: &qmap::arch::Arch,
                   cache: &MapperCache,
                   ref_edp: f64|
     -> Option<f64> {
        cands
            .iter()
            .filter(|c| c.accuracy >= ref_acc - 0.002)
            .filter_map(|c| {
                evaluate_network(target, &layers, &c.genome, cache, &rc.mapper)
                    .map(|e| e.edp / ref_edp)
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    };

    let ee = best_on(&on_eyeriss, &eyeriss, &cache_e, ref_e.edp);
    let es = best_on(&on_eyeriss, &simba, &cache_s, ref_s.edp);
    let se = best_on(&on_simba, &eyeriss, &cache_e, ref_e.edp);
    let ss = best_on(&on_simba, &simba, &cache_s, ref_s.edp);

    let fmt = |x: Option<f64>| {
        x.map(|v| format!("{:.3} ({:+.1}%)", v, (v - 1.0) * 100.0))
            .unwrap_or_else(|| "-".into())
    };
    print!(
        "{}",
        report::table(
            &["searched for \\ priced on", "Eyeriss (EDP rel u8)", "Simba (EDP rel u8)"],
            &[
                vec!["Eyeriss".into(), fmt(ee), fmt(es)],
                vec!["Simba".into(), fmt(se), fmt(ss)],
            ]
        )
    );

    // the paper's claim: the native diagonal should be the best column-wise
    let native_wins_e = match (ee, se) {
        (Some(native), Some(cross)) => native <= cross,
        _ => false,
    };
    let native_wins_s = match (ss, es) {
        (Some(native), Some(cross)) => native <= cross,
        _ => false,
    };
    println!(
        "\nnative search beats cross search on Eyeriss: {native_wins_e}, on Simba: {native_wins_s}"
    );
    println!(
        "paper shape (optimizing for the target accelerator wins): {}",
        if native_wins_e || native_wins_s { "REPRODUCED" } else { "MISMATCH" }
    );

    // how different are the genomes the two machines prefer?
    let mean_bits = |cands: &[Candidate]| -> (f64, f64) {
        let picks: Vec<&Candidate> = cands
            .iter()
            .filter(|c| c.accuracy >= ref_acc - 0.002)
            .collect();
        if picks.is_empty() {
            return (f64::NAN, f64::NAN);
        }
        let n = (picks.len() * picks[0].genome.layers.len()) as f64;
        let a = picks
            .iter()
            .flat_map(|c| c.genome.layers.iter().map(|&(a, _)| a as f64))
            .sum::<f64>()
            / n;
        let w = picks
            .iter()
            .flat_map(|c| c.genome.layers.iter().map(|&(_, w)| w as f64))
            .sum::<f64>()
            / n;
        (a, w)
    };
    let (ea, ew) = mean_bits(&on_eyeriss);
    let (sa, sw) = mean_bits(&on_simba);
    println!("\nmean (qa, qw) preferred: Eyeriss-opt ({ea:.2}, {ew:.2}), Simba-opt ({sa:.2}, {sw:.2})");
    println!("different memory subsystems prefer different bit allocations — the synergy effect.");
}
