//! Table I: the number of exhaustively enumerated *valid* mappings and
//! the minimum-EDP mapping for MobileNet conv layer #2 (the first
//! depthwise layer) under six bit-width settings, on Eyeriss and Simba.
//!
//! Paper shape to reproduce:
//!   * mapping count grows monotonically as (qa, qw, qo) shrink,
//!   * Simba admits far more mappings than Eyeriss,
//!   * min EDP falls with bit-width,
//!   * qw-only reduction (8,4,8)->(8,2,8) helps counts only slightly;
//!     shrinking the activations too helps much more.
//!
//! Run: `cargo bench --bench table1_mappings`. QMAP_PROFILE=full lifts
//! the enumeration cap so the counts are exact (unbounded).

use qmap::coordinator::experiments::table1_mappings;
use qmap::report;
use std::time::Instant;

fn main() {
    let limit = match std::env::var("QMAP_PROFILE").as_deref() {
        Ok("fast") => 20_000,
        // "exact" is intractable for Simba's mapspace in a laptop budget;
        // 2M is far above the paper's largest count (133,568) and enough
        // to expose the relative ordering the paper reports.
        Ok("full") => 2_000_000,
        _ => 400_000,
    };
    println!("=== Table I: exhaustive valid-mapping counts, MobileNet dw-conv #2 ===");
    let t0 = Instant::now();
    let rows = table1_mappings(limit);
    let dt = t0.elapsed();

    let fmt_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}, {}, {}", r.setting.0, r.setting.1, r.setting.2),
                r.arch.clone(),
                format!(
                    "{}{}",
                    r.valid_mappings,
                    if r.truncated { "+ (capped)" } else { "" }
                ),
                format!("{:.3e}", r.min_edp),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(
            &["qa, qw, qo", "arch", "valid mappings", "min EDP [J*cyc]"],
            &fmt_rows
        )
    );

    // shape checks vs the paper
    let count = |arch: &str, s: (u8, u8, u8)| {
        rows.iter()
            .find(|r| r.arch == arch && r.setting == s)
            .map(|r| r.valid_mappings)
            .unwrap_or(0)
    };
    let edp = |arch: &str, s: (u8, u8, u8)| {
        rows.iter()
            .find(|r| r.arch == arch && r.setting == s)
            .map(|r| r.min_edp)
            .unwrap_or(f64::NAN)
    };
    let mut ok = true;
    let any_capped = rows.iter().any(|r| r.truncated);
    if any_capped {
        println!(
            "\nnote: counts hit the {limit} cap — count-shape checks skipped \
             (run with QMAP_PROFILE=full for exact counts)"
        );
    }
    for arch in ["eyeriss", "simba"] {
        let seq = [
            (16u8, 16u8, 16u8),
            (8, 8, 8),
            (8, 4, 8),
            (8, 2, 8),
            (4, 4, 4),
            (2, 2, 2),
        ];
        if !any_capped {
            for w in seq.windows(2) {
                if count(arch, w[1]) < count(arch, w[0]) {
                    ok = false;
                    println!("shape violation: {arch} {:?} -> {:?} count fell", w[0], w[1]);
                }
            }
        }
        if !(edp(arch, (2, 2, 2)) < edp(arch, (16, 16, 16))) {
            ok = false;
            println!("shape violation: {arch} min EDP did not fall 16b->2b");
        }
    }
    if !any_capped && count("simba", (8, 8, 8)) <= count("eyeriss", (8, 8, 8)) {
        ok = false;
        println!("shape violation: Simba should admit more mappings than Eyeriss");
    }
    println!(
        "\npaper shape (counts grow as bits shrink; Simba >> Eyeriss; EDP falls): {}",
        if ok { "REPRODUCED" } else { "MISMATCH" }
    );

    let csv_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.arch.clone(),
                format!("{}", r.setting.0),
                format!("{}", r.setting.1),
                format!("{}", r.setting.2),
                r.valid_mappings.to_string(),
                r.truncated.to_string(),
                format!("{:.6}", r.min_edp),
            ]
        })
        .collect();
    let path = report::write_results(
        "table1_mappings.csv",
        &report::csv(
            &["arch", "qa", "qw", "qo", "valid_mappings", "truncated", "min_edp"],
            &csv_rows,
        ),
    );
    println!("[{dt:.2?}] wrote {}", path.display());
}
