//! §Perf: micro/meso benchmarks of the L3 hot paths. Not a paper
//! artifact — this is the before/after harness for the performance pass
//! recorded in EXPERIMENTS.md §Perf.
//!
//!   * mapper throughput, naive vs context path: candidate draws priced
//!     per second (draw + validity + nest analysis + energy model). The
//!     naive loop reproduces the pre-refactor hot path with the same
//!     functions it used (`random_mapping`/`check`/`analyze`/
//!     `estimate`), so the speedup is measured in one environment;
//!   * the staged batch evaluator (`run_shard`) on the identical
//!     stream — block draws, spatial pre-check cascade, fused
//!     check+analyze over survivors — with its per-stage cost split
//!     and reject rates (`batch_speedup_x` is floor-guarded);
//!   * sharded single-layer characterization scaling,
//!   * full-network characterization latency (28 workloads × target
//!     valid mappings), cold and warm cache,
//!   * cache hit latency on the lock-striped cache,
//!   * engine scaling: population evaluation through the work-stealing
//!     `engine::driver` at 1/2/4/8 workers (1 worker = the serial
//!     baseline the parallel runs are bit-identical to; acceptance bar:
//!     >= 2x at 4 workers).
//!
//! Run: `cargo bench --bench perf_hotpath` (QMAP_PROFILE=fast for the
//! CI smoke: smaller draw budgets, same row structure). Writes the
//! machine-readable trajectory record to `BENCH_perf.json` at the
//! repository root.
//!
//! Both throughput numbers and their ratio are recorded so the >= 3x
//! acceptance bar of the hot-path refactor stays auditable across PRs.

use qmap::arch::presets;
use qmap::energy::estimate_into;
use qmap::engine::checkpoint::SearchIdent;
use qmap::engine::{driver, Checkpointer, Engine, SchedPolicy};
use qmap::eval::evaluate_network;
use qmap::mapper::cache::MapperCache;
use qmap::mapper::{self, EvalContext, MapperConfig};
use qmap::mapping::mapspace::MapSpace;
use qmap::mapping::{check, LayerContext};
use qmap::nest::analyze_into;
use qmap::nsga::{Individual, NsgaConfig, SearchState};
use qmap::quant::{LayerQuant, QuantConfig};
use qmap::util::json::Json;
use qmap::util::rng::Rng;
use qmap::workload::{models, ConvLayer};
use std::time::Instant;

fn time<R>(label: &str, f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("{label:<58} {:>10.3} ms", dt * 1e3);
    (r, dt)
}

fn main() {
    // validate QMAP_PROFILE (and fail loudly on typos) even though this
    // bench derives its own fixed budgets from the profile name
    let _ = qmap::coordinator::RunConfig::from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2)
    });
    let fast = matches!(std::env::var("QMAP_PROFILE").as_deref(), Ok("fast"));
    println!(
        "=== §Perf: L3 hot-path benchmarks{} ===\n",
        if fast { " (fast profile)" } else { "" }
    );
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cfg = MapperConfig {
        // the paper's budget; /10 for the CI smoke
        valid_target: if fast { 200 } else { 2_000 },
        max_draws: if fast { 200_000 } else { 2_000_000 },
        seed: 42,
        shards: 1,
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // 1. raw mapper throughput on the paper's dw-conv layer:
    //    (a) the pre-refactor path, reproduced with the naive per-draw
    //        functions it used (allocates on every draw);
    //    (b) the allocation-free LayerContext/EvalContext path.
    let layer = &layers[1];
    let q = LayerQuant { qa: 8, qw: 8, qo: 8 }.canonical(arch.word_bits, arch.bit_packing);
    let space = MapSpace::of(&arch);
    #[allow(non_snake_case)]
    let PIPELINE_DRAWS: u64 = if fast { 40_000 } else { 200_000 };

    let (naive_priced, dt_naive) = time(
        &format!("mapper: naive draw+check+analyze+estimate x {PIPELINE_DRAWS}"),
        || {
            let mut rng = Rng::new(42);
            let mut priced = 0u64;
            for _ in 0..PIPELINE_DRAWS {
                let m = space.random_mapping(layer, &mut rng);
                if check(&arch, layer, &q, &m).is_err() {
                    continue;
                }
                let nest = qmap::nest::analyze(&arch, layer, &m);
                let est = qmap::energy::estimate(&arch, layer, &q, &nest);
                std::hint::black_box(est.edp());
                priced += 1;
            }
            priced
        },
    );
    let naive_rate = PIPELINE_DRAWS as f64 / dt_naive;
    println!("  -> {naive_priced} valid priced, {naive_rate:.0} candidates/s/core (naive)");

    let ((ctx_priced, ctx_best_bits), dt_ctx) = time(
        &format!("mapper: ctx   draw+check+analyze+estimate x {PIPELINE_DRAWS}"),
        || {
            let lctx = LayerContext::new(&arch, layer, &q);
            let mut ectx = EvalContext::for_arch(&arch);
            let mut rng = Rng::new(42);
            let mut priced = 0u64;
            let mut best: Option<f64> = None;
            for _ in 0..PIPELINE_DRAWS {
                space.random_mapping_into(&lctx, &mut rng, &mut ectx.fbuf, &mut ectx.mapping);
                if lctx.check(&ectx.mapping, &mut ectx.ext).is_err() {
                    continue;
                }
                analyze_into(&lctx, &ectx.mapping, &mut ectx.ext, &mut ectx.nest);
                estimate_into(&lctx, &ectx.nest, &mut ectx.est);
                let edp = ectx.est.edp();
                std::hint::black_box(edp);
                if best.map_or(true, |b| edp < b) {
                    best = Some(edp);
                }
                priced += 1;
            }
            (priced, best.map(f64::to_bits))
        },
    );
    let ctx_rate = PIPELINE_DRAWS as f64 / dt_ctx;
    let speedup = ctx_rate / naive_rate.max(1e-12);
    assert_eq!(
        naive_priced, ctx_priced,
        "naive and ctx paths must price identical candidate streams"
    );
    // `mappings_per_sec_*` = VALID mappings priced per second (the
    // historical meaning of the key); `candidates_per_sec_*` = raw
    // draws per second including invalid candidates. Both paths walk
    // the identical candidate stream, so the two ratios agree.
    let naive_valid_rate = naive_priced as f64 / dt_naive;
    let ctx_valid_rate = ctx_priced as f64 / dt_ctx;
    println!("  -> {ctx_priced} valid priced, {ctx_rate:.0} candidates/s/core (ctx)");
    println!("  -> hot-path speedup {speedup:.2}x (target >= 3x)");

    // 1c. the staged batch evaluator (`run_shard`: block draws, spatial
    //     pre-check cascade, fused check+analyze over survivors) on the
    //     identical candidate stream — the same seed with an unbounded
    //     valid target walks exactly the draws of row 1b, so valid
    //     count and winning EDP must agree bit-for-bit.
    let lctx = LayerContext::new(&arch, layer, &q);
    let spec = mapper::ShardSpec {
        seed: 42,
        valid_target: u64::MAX,
        max_draws: PIPELINE_DRAWS,
    };
    let (batch_out, dt_batch) = time(
        &format!("mapper: batch draw+cascade+analyze+estimate x {PIPELINE_DRAWS}"),
        || mapper::run_shard(&space, &lctx, &spec),
    );
    assert_eq!(
        batch_out.valid(),
        ctx_priced,
        "batched and scalar paths must accept identical candidate streams"
    );
    assert_eq!(batch_out.draws(), PIPELINE_DRAWS);
    assert_eq!(
        batch_out.best_edp().map(f64::to_bits),
        ctx_best_bits,
        "batched winner must be bit-identical to the scalar winner"
    );
    let batch_rate = PIPELINE_DRAWS as f64 / dt_batch;
    let batch_speedup = batch_rate / ctx_rate.max(1e-12);
    println!("  -> {} valid priced, {batch_rate:.0} candidates/s/core (batched)", batch_out.valid());
    println!("  -> batch speedup {batch_speedup:.2}x over the scalar ctx path");

    // 1c'. the admissible-bound pruning win (PR 10): the same stream
    //      through the reference cascade with the bound stage compiled
    //      out (`run_shard_unpruned`). Pruning is provably
    //      result-invariant — bit-identity asserted here in-run — so
    //      the ratio is pure work saved (pricings skipped because the
    //      lower bound already matched or beat the reigning winner).
    let (unpruned_out, dt_unpruned) = time(
        &format!("mapper: unpruned reference cascade x {PIPELINE_DRAWS}"),
        || mapper::run_shard_unpruned(&space, &lctx, &spec),
    );
    assert_eq!(
        unpruned_out, batch_out,
        "bound pruning must be invisible in the shard outcome"
    );
    let guided_speedup = dt_unpruned / dt_batch.max(1e-12);
    println!("  -> pruned vs unpruned speedup {guided_speedup:.2}x (bit-identical outcomes)");

    // 1d. per-stage cost split of the staged pipeline, measured inside
    //     the evaluator itself: `run_shard_timed` runs the identical
    //     stream through the stage-timing observer (draw / check /
    //     price), so the split prices exactly the code row 1c executed
    //     — bit-identity asserted — instead of re-simulating the
    //     stages as cumulative prefixes.
    let (
        stage_draw_ms,
        stage_check_ms,
        stage_bound_ms,
        stage_price_ms,
        reject_rate,
        spatial_reject_rate,
        bound_prune_rate,
    ) = {
        let (timed_out, tstats) = mapper::run_shard_timed(&space, &lctx, &spec);
        assert_eq!(
            timed_out, batch_out,
            "the stage-timing observer must not perturb the evaluator"
        );
        assert_eq!(tstats.stats.draws(), PIPELINE_DRAWS);
        assert_eq!(tstats.stats.valid, ctx_priced, "cascade must accept the same stream");
        (
            tstats.draw_ns as f64 / 1e6,
            tstats.check_ns as f64 / 1e6,
            tstats.bound_ns as f64 / 1e6,
            tstats.price_ns as f64 / 1e6,
            1.0 - tstats.stats.valid as f64 / PIPELINE_DRAWS as f64,
            tstats.stats.spatial_rejects as f64 / PIPELINE_DRAWS as f64,
            tstats.bound_prune_rate(),
        )
    };
    println!(
        "  -> stage split: draw {stage_draw_ms:.1} ms, check {stage_check_ms:.1} ms, \
         bound {stage_bound_ms:.1} ms, price {stage_price_ms:.1} ms; reject rate {:.1}% \
         ({:.1}% spatial); bound pruned {:.1}% of accepted",
        reject_rate * 1e2,
        spatial_reject_rate * 1e2,
        bound_prune_rate * 1e2
    );

    // 2. random-search characterization of one layer (2000 valid),
    //    1 shard vs all-core sharding
    let cache = MapperCache::new();
    let (_, dt2) = time("mapper: random search, 1 layer, 2000 valid, 1 shard", || {
        cache.evaluate(&arch, layer, &q, &cfg)
    });
    println!("  -> {:.0} layer-characterizations/s possible", 1.0 / dt2);
    let sharded_cfg = MapperConfig { shards: threads, ..cfg };
    let (_, dt2s) = time(
        &format!("mapper: random search, 1 layer, 2000 valid, {threads} shards"),
        || mapper::search(&arch, layer, &q, &sharded_cfg),
    );
    let shard_scaling = dt2 / dt2s.max(1e-12);
    println!("  -> sharded speedup {shard_scaling:.1}x on {threads} shards");

    // 3. full MobileNetV1 characterization, cold vs warm cache
    let cache2 = MapperCache::new();
    let qc = QuantConfig::uniform(layers.len(), 8);
    let (r_cold, dt_cold) = time("network: MobileNetV1 cold-cache characterization", || {
        evaluate_network(&arch, &layers, &qc, &cache2, &cfg)
    });
    assert!(r_cold.is_some());
    let (_, dt_warm) = time("network: MobileNetV1 warm-cache (identical genome)", || {
        evaluate_network(&arch, &layers, &qc, &cache2, &cfg)
    });
    println!(
        "  -> warm/cold speedup {:.0}x; warm per-genome {:.1} µs",
        dt_cold / dt_warm.max(1e-12),
        dt_warm * 1e6
    );

    // 3b. persistent store warm-start: seed a store file from one
    //     characterization (write-behind appends on every fresh
    //     search), then measure what a brand-new process pays — reopen
    //     + index the store, and a store-backed characterization with
    //     a completely cold in-memory cache — against the true cold
    //     run above. Bit-identity of the store-served result is
    //     asserted, and `warm_start_speedup_x` is floor-guarded.
    let store_dir =
        std::env::temp_dir().join(format!("qmap_bench_store_{}", std::process::id()));
    std::fs::create_dir_all(&store_dir).unwrap();
    let dir_str = store_dir.to_str().unwrap().to_string();
    {
        let seeded = MapperCache::new();
        seeded.set_backing(
            qmap::mapper::store::open_search_store(&dir_str, &arch, &cfg).expect("seed store"),
        );
        assert!(evaluate_network(&arch, &layers, &qc, &seeded, &cfg).is_some());
    }
    let (pstore, dt_open) = time("store: reopen + index persistent mapper store", || {
        qmap::mapper::store::open_search_store(&dir_str, &arch, &cfg).expect("reopen store")
    });
    let store_open_ms = dt_open * 1e3;
    assert!(!pstore.is_empty(), "seeding characterization must have appended records");
    let cache3 = MapperCache::new();
    cache3.set_backing(pstore);
    let (r_store, dt_store) = time("network: MobileNetV1 store-backed, cold process", || {
        evaluate_network(&arch, &layers, &qc, &cache3, &cfg)
    });
    let warm_start_speedup_x = dt_cold / dt_store.max(1e-12);
    let (c, s) = (r_cold.as_ref().unwrap(), r_store.as_ref().unwrap());
    assert_eq!(c.edp.to_bits(), s.edp.to_bits(), "store-served edp must be bit-identical");
    assert_eq!(
        c.energy_pj.to_bits(),
        s.energy_pj.to_bits(),
        "store-served energy must be bit-identical"
    );
    println!(
        "  -> store open {store_open_ms:.1} ms; warm-start speedup {warm_start_speedup_x:.1}x \
         (store-backed cold process vs cold search)"
    );
    let _ = std::fs::remove_dir_all(&store_dir);

    // 4. cache hit latency (single layer, striped cache)
    let (_, dth) = time("cache: single-workload hit x 100k", || {
        for _ in 0..100_000 {
            std::hint::black_box(cache2.evaluate(&arch, layer, &q, &cfg));
        }
    });
    let cache_hit_ns = dth * 1e9 / 1e5;
    println!("  -> {cache_hit_ns:.0} ns per hit");

    // 5. engine scaling: one genome population through the
    //    work-stealing engine at 1/2/4/8 workers. The 1-worker engine
    //    IS the serial baseline (inline execution), and every row is
    //    bit-identical to it by construction — this is the
    //    engine-vs-naive scaling record.
    let pop_n = if fast { 24 } else { 64 };
    let mut rng = Rng::new(7);
    let genomes: Vec<QuantConfig> = (0..pop_n)
        .map(|_| {
            let mut g = QuantConfig::uniform(layers.len(), 8);
            for l in g.layers.iter_mut() {
                l.0 = 2 + rng.below(7) as u8;
                l.1 = 2 + rng.below(7) as u8;
            }
            g
        })
        .collect();
    let mut engine_rows: Vec<(usize, f64)> = Vec::new();
    let mut reference: Option<Vec<Option<f64>>> = None;
    let mut worker_counts: Vec<usize> = vec![1, 2, 4, 8];
    if !worker_counts.contains(&threads) {
        worker_counts.push(threads);
    }
    for &w in &worker_counts {
        let engine = Engine::new(w);
        let fresh = MapperCache::new();
        let (evals, dt) = time(
            &format!("engine: {pop_n} genomes, {w} worker(s), cold cache"),
            || driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &fresh, &cfg),
        );
        let edps: Vec<Option<f64>> = evals.iter().map(|e| e.as_ref().map(|e| e.edp)).collect();
        match reference.take() {
            None => reference = Some(edps),
            Some(r) => {
                assert_eq!(r, edps, "engine results must be bit-identical at {w} workers");
                reference = Some(r);
            }
        }
        engine_rows.push((w, dt));
        let st = engine.stats();
        println!(
            "  -> jobs {}, splits {}, tasks {}, steals {}",
            st.jobs, st.splits, st.tasks, st.steals
        );
    }
    // 6. generation tail under FIFO vs priority scheduling at 4
    //    workers: tail = time between the job queue running dry (last
    //    job claimed) and the last job finishing. Priority order
    //    (largest effective draw budget first) plus tail-mode shard
    //    splitting is the fix for the idle-workers-at-the-tail problem
    //    FIFO leaves; both runs must stay bit-identical to the serial
    //    reference.
    let (tail_fifo_ms, tail_prio_ms, fifo_ms, prio_ms) = {
        let run = |label: &str, policy: SchedPolicy| {
            let engine = Engine::new(4).with_sched_policy(policy);
            let fresh = MapperCache::new();
            let (evals, dt) = time(
                &format!("engine: {pop_n} genomes, 4 workers, {label} order, cold cache"),
                || driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &fresh, &cfg),
            );
            let edps: Vec<Option<f64>> =
                evals.iter().map(|e| e.as_ref().map(|e| e.edp)).collect();
            if let Some(r) = &reference {
                assert_eq!(r, &edps, "{label} scheduling must be bit-identical");
            }
            let tail = engine.stats().last_tail_ms;
            println!("  -> generation tail {tail:.1} ms ({label})");
            (tail, dt * 1e3)
        };
        let (tf, f) = run("fifo", SchedPolicy::Fifo);
        let (tp, p) = run("priority", SchedPolicy::Priority);
        (tf, tp, f, p)
    };
    // clamp both tails to 1 ms before the ratio: a sub-millisecond
    // tail means "no measurable tail either way", and the ratio should
    // read ~1x instead of exploding (or collapsing) on timer noise —
    // the regression guard floors this row
    let tail_improvement = tail_fifo_ms.max(1.0) / tail_prio_ms.max(1.0);
    println!("  -> tail improvement {tail_improvement:.2}x (priority vs fifo)");

    // 7. distributed loopback: the same population through
    //    `Engine::distributed` over an in-process `qmap worker`
    //    (TCP on 127.0.0.1), at pipeline depth 1 (the PR 3
    //    one-in-flight baseline) and at the default windowed depth.
    //    Asserts bit-identity with the local rows — the distributed
    //    seam's acceptance bar — and records the protocol's overhead
    //    next to the local timings.
    let pipeline_depth = 4usize;
    let run_loopback = |label: &str, depth: usize| {
        // the worker-side outcome cache is process-global; with it on,
        // the second row would be served from the first row's outcomes
        // and the comparison would measure cache hits, not pipelining —
        // disable it for BOTH rows so the ratio isolates the window
        let opts = qmap::engine::WorkerOptions {
            disable_outcome_cache: true,
            ..qmap::engine::WorkerOptions::default()
        };
        let addr = qmap::engine::remote::spawn_local_worker(opts).expect("loopback worker");
        let engine = Engine::distributed(2, vec![addr]).with_pipeline_depth(depth);
        let fresh = MapperCache::new();
        let (evals, dt) = time(
            &format!("engine: {pop_n} genomes, distributed loopback, {label}, cold cache"),
            || driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &fresh, &cfg),
        );
        let edps: Vec<Option<f64>> = evals.iter().map(|e| e.as_ref().map(|e| e.edp)).collect();
        if let Some(r) = &reference {
            assert_eq!(
                r, &edps,
                "distributed loopback results must be bit-identical to local ({label})"
            );
        }
        let st = engine.stats();
        println!(
            "  -> remote jobs {}, requeued specs {}, lost workers {}",
            st.remote_jobs, st.requeued_specs, st.lost_workers
        );
        dt * 1e3
    };
    let dist_ms = run_loopback("single in-flight batch", 1);
    let pipelined_ms = run_loopback("pipelined window", pipeline_depth);
    let pipeline_speedup = dist_ms / pipelined_ms.max(1e-9);
    println!("  -> pipelined loopback speedup {pipeline_speedup:.2}x at depth {pipeline_depth}");

    // 8. checkpoint cost: the pre-journal per-generation snapshot
    //    rewrote the whole cache (O(cache)); the append-only journal
    //    writes one frame per new entry plus an fsync'd generation
    //    mark (O(new)). Measured on a synthetic cache large enough for
    //    the difference to dominate (the first save IS the full
    //    rewrite, so it doubles as the snapshot-cost measurement).
    let (ck_full_ms, ck_append_ms, ck_entries) = {
        let n_entries: usize = if fast { 20_000 } else { 100_000 };
        let mut dump = String::from("{\"entries\":[");
        for i in 0..n_entries {
            if i > 0 {
                dump.push(',');
            }
            dump.push_str(&format!(
                "{{\"key\":\"{i:016x}\",\"mappable\":true,\"energy_pj\":1.0,\
                 \"memory_energy_pj\":0.5,\"cycles\":2.0,\"edp\":3.0,\
                 \"valid_mappings\":4,\"breakdown\":[0.25,0.25,0.0],\
                 \"mac_energy_pj\":0.5}}"
            ));
        }
        dump.push_str("]}");
        let big = MapperCache::new();
        assert_eq!(big.load_json(&dump).expect("synthetic dump"), n_entries);
        let st = SearchState {
            generation: 1,
            pop: vec![Individual {
                genome: QuantConfig::uniform(4, 8),
                objectives: qmap::objective::ObjectiveVec::raw(vec![1.0, 2.0]),
            }],
            rng: Rng::new(1),
        };
        let toy_arch = presets::toy();
        let ident = SearchIdent::new(
            &toy_arch,
            4,
            &qmap::objective::ObjectiveSpec::default(),
            &cfg,
            &NsgaConfig::default(),
        );
        let mut path = std::env::temp_dir();
        path.push(format!("qmap_bench_journal_{}.jsonl", std::process::id()));
        let path = path.to_string_lossy().into_owned();
        let ckpt = Checkpointer::new(path.as_str());
        let (r, dt_full) = time(
            &format!("checkpoint: full snapshot write, {n_entries} cache entries"),
            || ckpt.save(&st, &big, &ident),
        );
        r.expect("snapshot save");
        // a handful of real inserts between generation boundaries
        let tiny = MapperConfig {
            valid_target: 1,
            max_draws: 200,
            seed: 1,
            shards: 1,
        };
        for k in 0..16u64 {
            big.evaluate(
                &toy_arch,
                &ConvLayer::fc("fc", 16, 10 + k),
                &LayerQuant::uniform(8),
                &tiny,
            );
        }
        let (r, dt_app) = time("checkpoint: journal append, 16 new entries", || {
            ckpt.save(&st, &big, &ident)
        });
        r.expect("journal append");
        let _ = std::fs::remove_file(&path);
        (dt_full * 1e3, dt_app * 1e3, n_entries)
    };
    let checkpoint_speedup = ck_full_ms / ck_append_ms.max(1e-9);
    println!(
        "  -> journal append {checkpoint_speedup:.0}x cheaper than the {ck_entries}-entry snapshot"
    );

    // 9. objective-space cost (the typed k-objective refactor):
    //    (a) the NSGA-II internals — environmental selection over a
    //        synthetic population at k=2 vs k=3 (dominance and
    //        crowding are O(k); the ratio guards against an
    //        accidentally superlinear k-objective path);
    //    (b) one full 3-objective generation end-to-end — the same
    //        genome population through the driver plus spec
    //        evaluation, bit-identity with the 2-objective engine
    //        rows asserted (the spec must never change what the
    //        mapper computes).
    let (nsga2_ms, nsga3_ms, obj3_gen_ms) = {
        use qmap::objective::{ObjectiveSpec, ObjectiveVec};
        let select_time = |k: usize| -> f64 {
            let mut r = Rng::new(0x0B1 ^ k as u64);
            let pop: Vec<Individual> = (0..256)
                .map(|_| Individual {
                    genome: QuantConfig::uniform(4, 8),
                    objectives: ObjectiveVec::raw((0..k).map(|_| r.f64()).collect()),
                })
                .collect();
            let t0 = Instant::now();
            let mut kept = 0usize;
            for _ in 0..100 {
                kept += qmap::nsga::environmental_select(pop.clone(), 128).len();
            }
            std::hint::black_box(kept);
            t0.elapsed().as_secs_f64() * 1e3
        };
        let n2 = select_time(2);
        let n3 = select_time(3);
        println!(
            "nsga: environmental selection x100, |pop|=256        k=2 {n2:>8.1} ms, k=3 {n3:>8.1} ms"
        );
        let spec = ObjectiveSpec::parse("error,energy,weight_words").expect("3-objective spec");
        let engine = Engine::new(4).with_objectives(spec);
        let fresh = MapperCache::new();
        let (objs, dt) = time(
            &format!("engine: {pop_n} genomes, 3-objective generation, cold cache"),
            || {
                let evals =
                    driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &fresh, &cfg);
                let objs: Vec<_> = evals
                    .iter()
                    .map(|e| spec.evaluate(e.as_ref(), 0.9))
                    .collect();
                (evals, objs)
            },
        );
        let (evals, objs) = objs;
        assert_eq!(objs.len(), genomes.len());
        assert!(objs.iter().all(|o| o.len() == 3));
        // the objective spec is identity-only on the hardware side:
        // the mapper results must match the 2-objective engine rows
        let edps: Vec<Option<f64>> = evals.iter().map(|e| e.as_ref().map(|e| e.edp)).collect();
        if let Some(r) = &reference {
            assert_eq!(r, &edps, "3-objective run must not perturb mapper results");
        }
        (n2, n3, dt * 1e3)
    };
    let nsga_k3_vs_k2_x = nsga2_ms / nsga3_ms.max(1e-9);
    println!(
        "  -> k=3 selection costs {:.2}x of k=2 (ratio floor-guarded)",
        1.0 / nsga_k3_vs_k2_x.max(1e-9)
    );

    // 10. trace overhead: the same population through the engine with a
    //     JSONL trace attached vs detached (best of two runs each, after
    //     a shared warmup). The recorder is observation-only —
    //     bit-identity asserted — and must stay cheap: this row is
    //     CEILING-guarded (`trace_overhead_pct` in BENCH_baseline.json),
    //     so event emission can never creep into the hot path unnoticed.
    let trace_overhead_pct = {
        let run_once = || {
            let engine = Engine::new(4);
            let fresh = MapperCache::new();
            let t0 = Instant::now();
            let evals = driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &fresh, &cfg);
            (evals, t0.elapsed().as_secs_f64())
        };
        let _ = run_once(); // warmup: neither row pays first-touch costs
        let (off_evals, t_off_a) = run_once();
        let (_, t_off_b) = run_once();
        let t_off = t_off_a.min(t_off_b);
        let mut tpath = std::env::temp_dir();
        tpath.push(format!("qmap_bench_trace_{}.jsonl", std::process::id()));
        let tpath = tpath.to_string_lossy().into_owned();
        qmap::obs::trace_to(&tpath).expect("attach trace");
        let (on_evals, t_on_a) = run_once();
        let (_, t_on_b) = run_once();
        let t_on = t_on_a.min(t_on_b);
        qmap::obs::trace_close();
        let off_edps: Vec<Option<f64>> =
            off_evals.iter().map(|e| e.as_ref().map(|e| e.edp)).collect();
        let on_edps: Vec<Option<f64>> =
            on_evals.iter().map(|e| e.as_ref().map(|e| e.edp)).collect();
        assert_eq!(off_edps, on_edps, "tracing must be observation-only");
        if let Some(r) = &reference {
            assert_eq!(r, &on_edps, "traced run must match the engine rows");
        }
        let _ = std::fs::remove_file(&tpath);
        let pct = (t_on / t_off.max(1e-9) - 1.0) * 100.0;
        println!(
            "engine: {pop_n} genomes, trace attached vs detached        on {:.1} ms, off {:.1} ms",
            t_on * 1e3,
            t_off * 1e3
        );
        println!("  -> trace overhead {pct:+.1}% (ceiling-guarded)");
        pct
    };

    let t_1w = engine_rows[0].1;
    for &(w, dt) in &engine_rows {
        println!("  -> engine speedup at {w} workers: {:.2}x", t_1w / dt.max(1e-12));
    }
    let engine_4w = engine_rows
        .iter()
        .find(|&&(w, _)| w == 4)
        .map(|&(_, dt)| t_1w / dt.max(1e-12))
        .unwrap_or(1.0);
    let pop64 = engine_rows
        .iter()
        .find(|&&(w, _)| w == threads)
        .map(|&(_, dt)| t_1w / dt.max(1e-12))
        .unwrap_or(engine_4w);
    println!("  -> engine speedup {engine_4w:.2}x at 4 workers (target >= 2x)");

    // summary + machine-readable record for the perf trajectory
    println!("\nsummary:");
    println!("  nsga_select_2obj_ms          = {nsga2_ms:.1}");
    println!("  nsga_select_3obj_ms          = {nsga3_ms:.1}");
    println!("  nsga_k3_vs_k2_x              = {nsga_k3_vs_k2_x:.2}");
    println!("  objectives3_generation_ms    = {obj3_gen_ms:.1}");
    println!("  mappings_per_sec_core        = {ctx_valid_rate:.0}");
    println!("  mappings_per_sec_core_naive  = {naive_valid_rate:.0}");
    println!("  candidates_per_sec_core      = {ctx_rate:.0}");
    println!("  candidates_per_sec_core_naive= {naive_rate:.0}");
    println!("  hotpath_speedup_x            = {speedup:.2}");
    println!("  batch_candidates_per_sec_core= {batch_rate:.0}");
    println!("  batch_speedup_x              = {batch_speedup:.2}");
    println!("  guided_speedup_x             = {guided_speedup:.2}");
    println!("  bound_prune_rate             = {bound_prune_rate:.3}");
    println!("  stage_draw_ms                = {stage_draw_ms:.1}");
    println!("  stage_check_ms               = {stage_check_ms:.1}");
    println!("  stage_bound_ms               = {stage_bound_ms:.1}");
    println!("  stage_price_ms               = {stage_price_ms:.1}");
    println!("  reject_rate                  = {reject_rate:.3}");
    println!("  spatial_reject_rate          = {spatial_reject_rate:.3}");
    println!("  shard_scaling_x              = {shard_scaling:.2}");
    println!("  network_cold_ms              = {:.1}", dt_cold * 1e3);
    println!("  network_warm_us              = {:.1}", dt_warm * 1e6);
    println!("  store_open_ms                = {store_open_ms:.2}");
    println!("  warm_start_speedup_x         = {warm_start_speedup_x:.1}");
    println!("  cache_hit_ns                 = {cache_hit_ns:.0}");
    println!("  engine_speedup_4w_x          = {engine_4w:.2}");
    println!("  pop64_speedup_x              = {pop64:.1}");
    println!("  tail_fifo_ms                 = {tail_fifo_ms:.1}");
    println!("  tail_priority_ms             = {tail_prio_ms:.1}");
    println!("  tail_improvement_x           = {tail_improvement:.2}");
    println!("  distributed_loopback_ms      = {dist_ms:.1}");
    println!("  pipelined_loopback_ms        = {pipelined_ms:.1}");
    println!("  pipeline_speedup_x           = {pipeline_speedup:.2}");
    println!("  checkpoint_snapshot_ms       = {ck_full_ms:.1}");
    println!("  checkpoint_journal_ms        = {ck_append_ms:.1}");
    println!("  checkpoint_speedup_x         = {checkpoint_speedup:.1}");
    println!("  trace_overhead_pct           = {trace_overhead_pct:.1}");

    let record = Json::obj(vec![
        ("bench", Json::Str("perf_hotpath".into())),
        ("profile", Json::Str(if fast { "fast".into() } else { "default".into() })),
        ("pipeline_draws", Json::Num(PIPELINE_DRAWS as f64)),
        // valid mappings priced per second (naive twin measured in the
        // same run on the same candidate stream)
        ("mappings_per_sec_core", Json::Num(ctx_valid_rate)),
        ("mappings_per_sec_core_naive", Json::Num(naive_valid_rate)),
        // raw candidate draws per second, invalid draws included
        ("candidates_per_sec_core", Json::Num(ctx_rate)),
        ("candidates_per_sec_core_naive", Json::Num(naive_rate)),
        ("hotpath_speedup_x", Json::Num(speedup)),
        // the staged batch evaluator (run_shard) over the identical
        // stream: block draws + spatial pre-check cascade + fused
        // check/analyze over survivors (bit-identity asserted above),
        // with the per-stage cost split and the cascade's reject rates
        ("batch_candidates_per_sec_core", Json::Num(batch_rate)),
        ("batch_speedup_x", Json::Num(batch_speedup)),
        // the admissible-bound pruning stage (PR 10): pruned cascade vs
        // the pruning-compiled-out reference on the identical stream
        // (bit-identity asserted above; floor-guarded), plus the
        // fraction of accepted candidates whose pricing it skipped
        ("guided_speedup_x", Json::Num(guided_speedup)),
        ("bound_prune_rate", Json::Num(bound_prune_rate)),
        ("stage_draw_ms", Json::Num(stage_draw_ms)),
        ("stage_check_ms", Json::Num(stage_check_ms)),
        ("stage_bound_ms", Json::Num(stage_bound_ms)),
        ("stage_price_ms", Json::Num(stage_price_ms)),
        ("reject_rate", Json::Num(reject_rate)),
        ("spatial_reject_rate", Json::Num(spatial_reject_rate)),
        ("shard_scaling_x", Json::Num(shard_scaling)),
        ("threads", Json::Num(threads as f64)),
        ("network_cold_ms", Json::Num(dt_cold * 1e3)),
        ("network_warm_us", Json::Num(dt_warm * 1e6)),
        // persistent store tier: open+index cost of the seeded store
        // and the store-backed cold-process characterization vs the
        // true cold run (bit-identity asserted above; floor-guarded)
        ("store_open_ms", Json::Num(store_open_ms)),
        ("warm_start_speedup_x", Json::Num(warm_start_speedup_x)),
        ("cache_hit_ns", Json::Num(cache_hit_ns)),
        // engine scaling rows: population evaluation through
        // engine::driver at each worker count (1 = serial baseline)
        (
            "engine_rows",
            Json::Arr(
                engine_rows
                    .iter()
                    .map(|&(w, dt)| {
                        Json::obj(vec![
                            ("workers", Json::Num(w as f64)),
                            ("ms", Json::Num(dt * 1e3)),
                            ("speedup_x", Json::Num(t_1w / dt.max(1e-12))),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("engine_population", Json::Num(pop_n as f64)),
        ("engine_speedup_4w_x", Json::Num(engine_4w)),
        ("pop64_speedup_x", Json::Num(pop64)),
        // generation tail (last-job-finish minus queue-dry) at 4
        // workers, FIFO vs priority injection (bit-identity asserted)
        ("tail_fifo_ms", Json::Num(tail_fifo_ms)),
        ("tail_priority_ms", Json::Num(tail_prio_ms)),
        ("tail_improvement_x", Json::Num(tail_improvement)),
        ("tail_fifo_total_ms", Json::Num(fifo_ms)),
        ("tail_priority_total_ms", Json::Num(prio_ms)),
        // same population through Engine::distributed over a loopback
        // qmap worker (bit-identity asserted above): depth 1 is the
        // PR 3 single-in-flight baseline, the pipelined row keeps a
        // window of batches per connection
        ("distributed_loopback_ms", Json::Num(dist_ms)),
        ("pipelined_loopback_ms", Json::Num(pipelined_ms)),
        ("pipeline_depth", Json::Num(pipeline_depth as f64)),
        ("pipeline_speedup_x", Json::Num(pipeline_speedup)),
        // per-generation checkpoint cost: full-cache snapshot rewrite
        // vs append-only journal (16 new entries + one fsync'd mark)
        ("checkpoint_entries", Json::Num(ck_entries as f64)),
        ("checkpoint_snapshot_ms", Json::Num(ck_full_ms)),
        ("checkpoint_journal_ms", Json::Num(ck_append_ms)),
        ("checkpoint_speedup_x", Json::Num(checkpoint_speedup)),
        // the typed objective space: k-objective NSGA internals cost
        // (k=2 vs k=3 environmental selection; the guarded ratio
        // catches an accidentally superlinear k path) and one full
        // 3-objective generation (bit-identity with the 2-objective
        // rows asserted above)
        ("nsga_select_2obj_ms", Json::Num(nsga2_ms)),
        ("nsga_select_3obj_ms", Json::Num(nsga3_ms)),
        ("nsga_k3_vs_k2_x", Json::Num(nsga_k3_vs_k2_x)),
        ("objectives3_generation_ms", Json::Num(obj3_gen_ms)),
        // cost of an attached JSONL trace on a full generation
        // (bit-identity asserted above; ceiling-guarded)
        ("trace_overhead_pct", Json::Num(trace_overhead_pct)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_perf.json");
    match std::fs::write(path, record.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
