//! FSM model of the append-only checkpoint journal
//! (`engine::checkpoint::Checkpointer` + the `MapperCache` insert
//! queue): insert frames, fsync'd generation marks, compaction,
//! torn-tail crashes, and resume.
//!
//! Two kinds of cache key keep the scope finite while still separating
//! "frames" from "entries" (the distinction compaction exists for):
//! the **churn** key (re-inserted repeatedly — every insert queues a
//! frame, the cache stays at one entry, which is what trips the
//! `appended > slack + 2 * entries` trigger) and a bounded pool of
//! **fresh** keys, each used once. Crash events (`tear` with a torn
//! tail, `crash` without) drop the process side — cache, pending
//! queue, appender — and `resume` rebuilds it from the file exactly
//! the way [`Checkpointer::load`](crate::engine::Checkpointer::load)
//! does: replayed insert frames re-arm the compaction accounting, a
//! torn tail leaves the appender unarmed so the next save rewrites
//! the file whole.

use super::Fsm;

/// The generation the initial checkpoint is saved at (shared with the
/// conformance SUT in `tests/model_conformance.rs`).
pub const INIT_GEN: u8 = 3;

pub struct JournalModel {
    /// Compaction slack, mirrored by the SUT's `with_compact_slack`.
    pub slack: u8,
    /// Distinct single-use fresh keys available to `insert_fresh`.
    pub fresh_pool: u8,
    /// Highest generation `save` may write (bounds the scope).
    pub max_gen: u8,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct JournalState {
    // --- the file ---
    /// Generations of the complete marks on file, in order.
    pub marks: Vec<u8>,
    /// Complete insert frames on file (duplicates count: every insert
    /// queues a frame).
    pub file_inserts: u8,
    /// Distinct fresh keys with at least one frame on file.
    pub file_fresh: u8,
    /// The churn key has at least one frame on file.
    pub file_has_dup: bool,
    /// The final line is incomplete (crash mid-append).
    pub torn: bool,
    // --- the process ---
    /// Crashed/stopped; only `resume` applies.
    pub down: bool,
    /// Appender armed (next save appends; unarmed saves rewrite).
    pub armed: bool,
    /// Insert frames appended since the last full write — replayed
    /// frames count too on resume, exactly like `load`.
    pub appended: u8,
    /// Distinct fresh keys in the live cache.
    pub live_fresh: u8,
    /// The churn key is in the live cache.
    pub live_has_dup: bool,
    /// Queued-but-unsaved frames for the churn key.
    pub pending_dup: u8,
    /// Queued-but-unsaved frames for fresh keys (each a distinct key).
    pub pending_fresh: u8,
    /// Fresh keys handed out so far (never reused, even across a
    /// crash that loses their frames).
    pub used_fresh: u8,
    /// Generation the next save writes.
    pub next_gen: u8,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JournalEvent {
    /// Re-insert the churn key: one more queued frame, same entry.
    InsertDup,
    /// Insert a never-used key: one queued frame, one new entry.
    InsertFresh,
    /// Checkpoint at the next generation: append queued frames + one
    /// mark (then maybe compact), or rewrite whole if unarmed.
    Save,
    /// Process stops with the file intact (graceful or kill between
    /// appends).
    Crash,
    /// Process dies mid-append: the final mark line is cut short.
    Tear,
    /// Start a new process and load the journal.
    Resume,
}

impl JournalModel {
    fn entries(s: &JournalState) -> u8 {
        s.live_fresh + u8::from(s.live_has_dup)
    }

    fn drop_process(n: &mut JournalState) {
        n.down = true;
        n.armed = false;
        n.appended = 0;
        n.live_fresh = 0;
        n.live_has_dup = false;
        n.pending_dup = 0;
        n.pending_fresh = 0;
    }
}

impl Fsm for JournalModel {
    type State = JournalState;
    type Event = JournalEvent;

    fn name(&self) -> String {
        "journal".to_string()
    }

    fn initial(&self) -> JournalState {
        // the scope starts just after the first save of a run: journal
        // enabled, appender armed, one mark, empty cache
        JournalState {
            marks: vec![INIT_GEN],
            file_inserts: 0,
            file_fresh: 0,
            file_has_dup: false,
            torn: false,
            down: false,
            armed: true,
            appended: 0,
            live_fresh: 0,
            live_has_dup: false,
            pending_dup: 0,
            pending_fresh: 0,
            used_fresh: 0,
            next_gen: INIT_GEN + 1,
        }
    }

    fn events(&self, s: &JournalState) -> Vec<JournalEvent> {
        let mut evs = Vec::new();
        if s.down {
            if !s.marks.is_empty() {
                evs.push(JournalEvent::Resume);
            }
            return evs;
        }
        evs.push(JournalEvent::InsertDup);
        if s.used_fresh < self.fresh_pool {
            evs.push(JournalEvent::InsertFresh);
        }
        if s.next_gen <= self.max_gen {
            evs.push(JournalEvent::Save);
        }
        evs.push(JournalEvent::Crash);
        // tearing cuts the file's final line — always the latest mark,
        // since every save ends with one. Keep a complete mark to
        // resume from (a journal with none refuses to load).
        if !s.torn && s.marks.len() >= 2 {
            evs.push(JournalEvent::Tear);
        }
        evs
    }

    fn step(&self, s: &JournalState, e: &JournalEvent) -> JournalState {
        let mut n = s.clone();
        match e {
            JournalEvent::InsertDup => {
                if !s.down {
                    n.live_has_dup = true;
                    n.pending_dup += 1;
                }
            }
            JournalEvent::InsertFresh => {
                if !s.down && s.used_fresh < self.fresh_pool {
                    n.live_fresh += 1;
                    n.pending_fresh += 1;
                    n.used_fresh += 1;
                }
            }
            JournalEvent::Save => {
                if s.down || s.next_gen > self.max_gen {
                    return n;
                }
                let gen = s.next_gen;
                let entries = Self::entries(s);
                if s.armed {
                    let frames = s.pending_dup + s.pending_fresh;
                    n.file_inserts += frames;
                    n.file_fresh += s.pending_fresh;
                    n.file_has_dup |= s.pending_dup > 0;
                    n.marks.push(gen);
                    n.appended += frames;
                    n.pending_dup = 0;
                    n.pending_fresh = 0;
                    if n.appended > self.slack + 2 * entries {
                        // compaction: full rewrite — header, one frame
                        // per live entry, one mark
                        n.marks = vec![gen];
                        n.file_inserts = entries;
                        n.file_fresh = s.live_fresh;
                        n.file_has_dup = s.live_has_dup;
                        n.appended = 0;
                    }
                } else {
                    // unarmed (first save after a torn resume): the
                    // whole file is rewritten and the appender re-arms
                    n.marks = vec![gen];
                    n.file_inserts = entries;
                    n.file_fresh = s.live_fresh;
                    n.file_has_dup = s.live_has_dup;
                    n.appended = 0;
                    n.pending_dup = 0;
                    n.pending_fresh = 0;
                    n.armed = true;
                    n.torn = false;
                }
                n.next_gen = gen + 1;
            }
            JournalEvent::Crash => {
                if !s.down {
                    Self::drop_process(&mut n);
                }
            }
            JournalEvent::Tear => {
                if !s.down && !s.torn && s.marks.len() >= 2 {
                    n.marks.pop();
                    n.torn = true;
                    Self::drop_process(&mut n);
                }
            }
            JournalEvent::Resume => {
                if s.down && !s.marks.is_empty() {
                    n.down = false;
                    // load replays every complete insert frame into a
                    // fresh cache...
                    n.live_fresh = s.file_fresh;
                    n.live_has_dup = s.file_has_dup;
                    // ...and re-arms the appender unless the tail is
                    // torn, counting the replayed frames toward the
                    // next compaction check
                    n.armed = !s.torn;
                    n.appended = if s.torn { 0 } else { s.file_inserts };
                    n.pending_dup = 0;
                    n.pending_fresh = 0;
                    n.next_gen = *s.marks.last().expect("non-empty") + 1;
                }
            }
        }
        n
    }

    fn invariant(&self, s: &JournalState) -> Result<(), String> {
        if s.armed && s.torn {
            return Err("appender armed over a torn tail".to_string());
        }
        if s.armed && s.down {
            return Err("appender armed with no process".to_string());
        }
        if s.marks.windows(2).any(|w| w[0] >= w[1]) {
            return Err(format!("marks not strictly increasing: {:?}", s.marks));
        }
        let file_distinct = s.file_fresh + u8::from(s.file_has_dup);
        if s.file_inserts < file_distinct {
            return Err(format!(
                "{} insert frames cannot cover {file_distinct} distinct keys",
                s.file_inserts
            ));
        }
        if s.live_fresh > self.fresh_pool || s.file_fresh > self.fresh_pool {
            return Err("fresh keys exceed the pool".to_string());
        }
        if s.pending_fresh > s.live_fresh {
            return Err("a queued fresh frame must have a live entry".to_string());
        }
        Ok(())
    }

    fn show_event(&self, e: &JournalEvent) -> String {
        match e {
            JournalEvent::InsertDup => "insert_dup",
            JournalEvent::InsertFresh => "insert_fresh",
            JournalEvent::Save => "save",
            JournalEvent::Crash => "crash",
            JournalEvent::Tear => "tear",
            JournalEvent::Resume => "resume",
        }
        .to_string()
    }

    fn parse_event(&self, line: &str) -> Option<JournalEvent> {
        match line {
            "insert_dup" => Some(JournalEvent::InsertDup),
            "insert_fresh" => Some(JournalEvent::InsertFresh),
            "save" => Some(JournalEvent::Save),
            "crash" => Some(JournalEvent::Crash),
            "tear" => Some(JournalEvent::Tear),
            "resume" => Some(JournalEvent::Resume),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{explore, replay, Budget};
    use JournalEvent::*;

    fn model() -> JournalModel {
        JournalModel {
            slack: 0,
            fresh_pool: 2,
            max_gen: 8,
        }
    }

    #[test]
    fn journal_model_explores_exhaustively() {
        let cov = explore(&model(), &Budget::new(12, 500_000)).expect("no violation");
        assert!(cov.complete, "small scope must be exhausted");
        assert!(cov.deepest >= 10, "got depth {}", cov.deepest);
    }

    /// The satellite scenario, as a pinned model trace: churn until a
    /// save compacts, append one more generation, tear mid-append,
    /// resume — the resumed state must sit on the last complete mark
    /// with the appender unarmed.
    #[test]
    fn tear_right_after_compaction_resumes_from_the_compacted_mark() {
        let m = model();
        let trace = [
            InsertDup, Save, // gen 4: appended 1, entries 1 → no compact
            InsertDup, Save, // gen 5: appended 2 → no compact
            InsertDup, Save, // gen 6: appended 3 > 0 + 2·1 → compact
            InsertDup, Save, // gen 7: appends onto the compacted file
            Tear,    // cut gen 7's mark line
            Resume,  // back up from the compacted mark
        ];
        let s = replay(&m, &trace).expect("invariant holds along the trace");
        assert!(s.torn, "the tail stays torn until the next save");
        assert!(!s.armed, "a torn resume leaves the appender unarmed");
        assert_eq!(s.marks, vec![6], "resumes from the compaction's mark");
        assert_eq!(s.next_gen, 7, "the torn generation is re-run");
        assert!(s.live_has_dup, "replayed insert frames rebuild the cache");
        // and the next save heals the file whole
        let healed = m.step(&s, &Save);
        assert!(healed.armed && !healed.torn);
        assert_eq!(healed.marks, vec![7]);
        assert_eq!(healed.file_inserts, 1, "one frame per live entry");
    }

    #[test]
    fn journal_grammar_round_trips() {
        let m = model();
        for ev in [InsertDup, InsertFresh, Save, Crash, Tear, Resume] {
            let s = m.show_event(&ev);
            assert_eq!(m.parse_event(&s), Some(ev), "grammar: {s}");
        }
        assert_eq!(m.parse_event("compact"), None);
    }
}
