//! Quickstart: evaluate a quantized MobileNetV1 on the Eyeriss model.
//!
//! Demonstrates the core public API in ~5 minutes of reading:
//!   1. pick an accelerator preset (or parse your own text spec),
//!   2. pick a network layer table,
//!   3. describe a mixed-precision quantization (the paper's genome),
//!   4. run the mapping engine per layer and aggregate,
//!   5. inspect the best mapping Timeloop-style.
//!
//! Run: `cargo run --release --example quickstart`

use qmap::arch::presets;
use qmap::eval::evaluate_network;
use qmap::mapper::{self, MapperConfig};
use qmap::mapper::cache::MapperCache;
use qmap::quant::{LayerQuant, QuantConfig};
use qmap::workload::models;

fn main() {
    // 1. the accelerator: Eyeriss-like, 168 PEs, 16-bit words,
    //    bit-packing enabled (the paper's Timeloop extension)
    let arch = presets::eyeriss();
    println!(
        "accelerator: {} ({} PEs, {}-bit words, bit-packing {})",
        arch.name,
        arch.total_pes(),
        arch.word_bits,
        if arch.bit_packing { "on" } else { "off" }
    );

    // 2. the workload: full-size MobileNetV1 layer table (28 layers)
    let layers = models::mobilenet_v1();
    println!("network: MobileNetV1, {} quantizable layers", layers.len());

    // 3. two quantizations: uniform 8-bit, and a mixed-precision genome
    //    that spends bits where the early layers need them
    let uniform8 = QuantConfig::uniform(layers.len(), 8);
    let mut mixed = QuantConfig::uniform(layers.len(), 8);
    for (i, l) in mixed.layers.iter_mut().enumerate() {
        // keep first/last at 8/8; taper the middle to 4-6 bits
        *l = match i {
            0 => (8, 8),
            i if i + 1 == layers.len() => (8, 8),
            i if i < 6 => (8, 6),
            i if i < 14 => (6, 4),
            _ => (4, 4),
        };
    }

    // 4. characterize both through the mapping engine (cached, so shared
    //    workloads across genomes are only mapped once)
    let cache = MapperCache::new();
    let cfg = MapperConfig::default(); // 2000 valid mappings per workload
    let e8 = evaluate_network(&arch, &layers, &uniform8, &cache, &cfg)
        .expect("uniform-8 must map");
    let em = evaluate_network(&arch, &layers, &mixed, &cache, &cfg)
        .expect("mixed genome must map");

    println!("\n                       uniform 8-bit    mixed-precision");
    println!(
        "total energy   [uJ]    {:>12.2}    {:>12.2}  ({:+.1}%)",
        e8.energy_pj / 1e6,
        em.energy_pj / 1e6,
        (em.energy_pj / e8.energy_pj - 1.0) * 100.0
    );
    println!(
        "memory energy  [uJ]    {:>12.2}    {:>12.2}  ({:+.1}%)",
        e8.memory_energy_pj / 1e6,
        em.memory_energy_pj / 1e6,
        (em.memory_energy_pj / e8.memory_energy_pj - 1.0) * 100.0
    );
    println!(
        "latency     [cycles]   {:>12.0}    {:>12.0}  ({:+.1}%)",
        e8.cycles,
        em.cycles,
        (em.cycles / e8.cycles - 1.0) * 100.0
    );
    println!(
        "EDP        [J*cycles]  {:>12.3e}    {:>12.3e}  ({:+.1}%)",
        e8.edp,
        em.edp,
        (em.edp / e8.edp - 1.0) * 100.0
    );
    println!(
        "weight words           {:>12}    {:>12}  ({:+.1}%)",
        e8.weight_words,
        em.weight_words,
        (em.weight_words as f64 / e8.weight_words as f64 - 1.0) * 100.0
    );

    // 5. look at one layer's best mapping in detail (Timeloop-style nest)
    let layer = &layers[1]; // the paper's "conv layer #2" (depthwise)
    let q = LayerQuant { qa: 4, qw: 4, qo: 4 };
    let r = mapper::search(&arch, layer, &q, &cfg);
    println!(
        "\nbest mapping for '{}' at (qa,qw,qo)=(4,4,4): {} valid of {} draws",
        layer.name, r.valid, r.draws
    );
    if let (Some(est), Some(m)) = (r.best, r.best_mapping) {
        print!("{}", m.render(&arch));
        println!(
            "energy {:.1} nJ, {:.0} cycles, EDP {:.3e}, PEs used {}/{}",
            est.energy_pj / 1e3,
            est.cycles,
            est.edp(),
            m.pes_used(),
            arch.total_pes()
        );
    }

    println!(
        "\ncache: {} workloads characterized, {} hits / {} misses",
        cache.len(),
        cache.hits(),
        cache.misses()
    );
    println!("\nnext: cargo run --release --example e2e_search   (full QAT-in-the-loop search)");
}
