//! Analytical loop-nest analysis: per-level, per-tensor access counting.
//!
//! This is the Timeloop-style model (tile footprints + temporal-reuse
//! discounting + spatial multicast/reduction) that turns a mapping into
//! memory traffic, from which energy and latency follow.
//!
//! Model summary, per tensor `t`:
//! * The *keeper chain* is the subsequence of levels that store `t`
//!   (bypassed levels pass traffic through); DRAM is always a keeper.
//! * A keeper `k`'s tile is refetched from its parent keeper every time a
//!   loop above `k` changes an index relevant to `t`. Iterations of the
//!   innermost contiguous block of `t`-irrelevant temporal loops above
//!   `k` reuse the resident tile (this is where the loop permutation
//!   matters); once any relevant loop with factor > 1 intervenes, all
//!   outer loops force refetches.
//! * Spatial fanout replicates read tiles to children; a multicast
//!   network delivers one parent read to all children sharing the tile
//!   (discount = product of spatial factors over `t`-irrelevant dims).
//!   For outputs the same factor models the spatial reduction tree.
//! * The innermost keeper additionally serves one operand access per MAC
//!   (read for weights/inputs; read+write for the accumulated output).
//!
//! All traffic is kept in *elements* here; the energy layer converts to
//! memory words using the bit-packing factors (see `crate::energy`).

use crate::arch::Arch;
use crate::mapping::{LayerContext, Mapping};
use crate::workload::{ConvLayer, Tensor, TENSORS};

/// Element-granular access counts for one (level, tensor) slot.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct Accesses {
    /// Elements read out of this level (serving children / drains up).
    pub reads: f64,
    /// Elements written into this level (fills from parent / partial-sum
    /// updates from below).
    pub writes: f64,
}

impl Accesses {
    pub fn total(&self) -> f64 {
        self.reads + self.writes
    }
}

/// Full nest-analysis result.
#[derive(Debug, Clone)]
pub struct NestAnalysis {
    /// `[level][tensor]` element traffic.
    pub accesses: Vec<[Accesses; 3]>,
    /// Total multiply-accumulates.
    pub macs: u64,
    /// MAC lanes actually used (product of spatial factors).
    pub pes_used: u64,
}

impl NestAnalysis {
    /// An empty result to be filled by [`analyze_into`] (scratch-buffer
    /// construction for the allocation-free hot path).
    pub fn empty() -> Self {
        NestAnalysis {
            accesses: Vec::new(),
            macs: 0,
            pes_used: 0,
        }
    }
}

/// Number of times the tile of `t` held at level `k` is (re)loaded,
/// walking every temporal loop above `k` from innermost to outermost.
fn reloads(layer: &ConvLayer, mapping: &Mapping, k: usize, t: Tensor) -> f64 {
    let mut reload = 1.0;
    let mut contiguous = true; // still in the innermost irrelevant block
    for lv in (k + 1)..mapping.levels.len() {
        let lm = &mapping.levels[lv];
        for &d in &lm.perm {
            let f = lm.temporal[d.index()];
            if f == 1 {
                continue;
            }
            if contiguous && !layer.is_relevant(t, d) {
                continue; // temporal reuse: resident tile survives
            }
            contiguous = false;
            reload *= f as f64;
        }
    }
    reload
}

/// Multicast (for reads) / spatial-reduction (for outputs) discount on
/// the networks between child keeper `k` and parent keeper `pk`:
/// product of spatial factors over `t`-irrelevant dims on levels whose
/// network supports multicast.
fn multicast_discount(
    arch: &Arch,
    layer: &ConvLayer,
    mapping: &Mapping,
    k: usize,
    pk: usize,
    t: Tensor,
) -> f64 {
    let mut mc = 1.0;
    for lv in (k + 1)..=pk {
        if !arch.levels[lv].multicast {
            continue;
        }
        for d in crate::workload::DIMS {
            let s = mapping.levels[lv].spatial[d.index()];
            if s > 1 && !layer.is_relevant(t, d) {
                mc *= s as f64;
            }
        }
    }
    mc
}

/// Per-instance tile footprint of `t` at level `lv`, in elements.
fn tile_elems(layer: &ConvLayer, mapping: &Mapping, lv: usize, t: Tensor) -> f64 {
    let mut tile = mapping.tile_extents(lv);
    for d in 0..7 {
        tile[d] = tile[d].min(layer.dims[d]);
    }
    layer.tile_elements(t, &tile) as f64
}

/// Run the analysis for a valid mapping.
pub fn analyze(arch: &Arch, layer: &ConvLayer, mapping: &Mapping) -> NestAnalysis {
    let nl = arch.levels.len();
    let mut acc = vec![[Accesses::default(); 3]; nl];
    let macs = layer.macs();

    for t in TENSORS {
        // keeper chain (innermost first; DRAM guaranteed last)
        let keepers: Vec<usize> = (0..nl).filter(|&i| arch.levels[i].keeps_tensor(t)).collect();
        debug_assert!(!keepers.is_empty());

        // compute-level operand service at the innermost keeper
        let k0 = keepers[0];
        match t {
            Tensor::Outputs => {
                acc[k0][t.index()].reads += macs as f64;
                acc[k0][t.index()].writes += macs as f64;
            }
            _ => acc[k0][t.index()].reads += macs as f64,
        }

        // inter-level traffic along the keeper chain
        for w in keepers.windows(2) {
            let (k, pk) = (w[0], w[1]);
            let tile = tile_elems(layer, mapping, k, t);
            let inst = mapping.instances(k) as f64;
            let rl = reloads(layer, mapping, k, t);
            let fills = tile * inst * rl;
            let mc = multicast_discount(arch, layer, mapping, k, pk, t);
            let full = layer.tensor_elements(t) as f64;

            match t {
                Tensor::Outputs => {
                    // partial sums drain upward; spatial reduction merges
                    // contributions from sibling children
                    let up = fills / mc;
                    acc[pk][t.index()].writes += up;
                    // revisited output tiles are re-read from the parent
                    // (all but the compulsory first visit)
                    acc[pk][t.index()].reads += (up - full).max(0.0);
                    // the child reads each drained tile out of its buffer
                    acc[k][t.index()].reads += fills;
                }
                _ => {
                    acc[pk][t.index()].reads += fills / mc;
                    acc[k][t.index()].writes += fills;
                }
            }
        }
    }

    NestAnalysis {
        accesses: acc,
        macs,
        pes_used: mapping.pes_used(),
    }
}

/// Allocation-free, table-driven [`analyze`]: identical math in the same
/// order (bit-identical results — asserted by
/// `tests/hotpath_equivalence.rs`), but keeper chains and relevance come
/// from the precomputed [`LayerContext`], cumulative tile extents are
/// computed once into the `ext` scratch buffer, and the result is
/// written into `out` without reallocating in steady state.
pub fn analyze_into(
    lctx: &LayerContext,
    mapping: &Mapping,
    ext: &mut Vec<[u64; 7]>,
    out: &mut NestAnalysis,
) {
    lctx.fill_extents(mapping, ext);
    analyze_core(lctx, mapping, |k, t| lctx.tile_elems_at(t, &ext[k]) as f64, out);
}

/// [`analyze_into`] for a candidate that already passed
/// [`LayerContext::check_tiles_into`]: the exact per-(level, tensor)
/// tile footprints the checker recorded into its `elems` slab
/// (`lv * 3 + tensor`, kept pairs below DRAM) are reused, skipping the
/// redundant extent re-fill and tile-size recomputation the
/// `check` → `analyze_into` sequence used to pay per survivor.
/// Bit-identical to [`analyze_into`]: the footprints are the same
/// `u64`s `tile_elems_at` produces (every child keeper is a kept level
/// below DRAM, so the checker's capacity pass covers all of them), and
/// every f64 operation runs in the same order.
pub fn analyze_prefilled(
    lctx: &LayerContext,
    mapping: &Mapping,
    elems: &[u64],
    out: &mut NestAnalysis,
) {
    debug_assert_eq!(elems.len(), lctx.num_levels * 3);
    analyze_core(lctx, mapping, |k, t| elems[k * 3 + t.index()] as f64, out);
}

/// Shared body of [`analyze_into`] / [`analyze_prefilled`]; `tile`
/// yields the tile footprint (elements, as f64) of tensor `t` at keeper
/// level `k`.
fn analyze_core<F: Fn(usize, Tensor) -> f64>(
    lctx: &LayerContext,
    mapping: &Mapping,
    tile_at: F,
    out: &mut NestAnalysis,
) {
    let nl = lctx.num_levels;
    out.accesses.clear();
    out.accesses.resize(nl, [Accesses::default(); 3]);
    out.macs = lctx.macs;
    out.pes_used = mapping.pes_used();
    let macs = lctx.macs;

    for t in TENSORS {
        let ti = t.index();
        let keepers = &lctx.keepers[ti];
        debug_assert!(!keepers.is_empty());

        // compute-level operand service at the innermost keeper
        let k0 = keepers[0];
        match t {
            Tensor::Outputs => {
                out.accesses[k0][ti].reads += macs as f64;
                out.accesses[k0][ti].writes += macs as f64;
            }
            _ => out.accesses[k0][ti].reads += macs as f64,
        }

        // inter-level traffic along the keeper chain
        for w in keepers.windows(2) {
            let (k, pk) = (w[0], w[1]);
            let tile = tile_at(k, t);
            let inst = mapping.instances(k) as f64;
            let rl = reloads_ctx(lctx, mapping, k, t);
            let fills = tile * inst * rl;
            let mc = multicast_discount_ctx(lctx, mapping, k, pk, t);
            let full = lctx.tensor_elems[ti] as f64;

            match t {
                Tensor::Outputs => {
                    // partial sums drain upward; spatial reduction merges
                    // contributions from sibling children
                    let up = fills / mc;
                    out.accesses[pk][ti].writes += up;
                    // revisited output tiles are re-read from the parent
                    // (all but the compulsory first visit)
                    out.accesses[pk][ti].reads += (up - full).max(0.0);
                    // the child reads each drained tile out of its buffer
                    out.accesses[k][ti].reads += fills;
                }
                _ => {
                    out.accesses[pk][ti].reads += fills / mc;
                    out.accesses[k][ti].writes += fills;
                }
            }
        }
    }
}

/// [`reloads`] with the relevance test replaced by a bitmask lookup
/// (same multiplication order, same result).
fn reloads_ctx(lctx: &LayerContext, mapping: &Mapping, k: usize, t: Tensor) -> f64 {
    let mut reload = 1.0;
    let mut contiguous = true; // still in the innermost irrelevant block
    for lv in (k + 1)..mapping.levels.len() {
        let lm = &mapping.levels[lv];
        for &d in &lm.perm {
            let f = lm.temporal[d.index()];
            if f == 1 {
                continue;
            }
            if contiguous && !lctx.is_relevant(t, d) {
                continue; // temporal reuse: resident tile survives
            }
            contiguous = false;
            reload *= f as f64;
        }
    }
    reload
}

/// [`multicast_discount`] on the precomputed multicast table.
fn multicast_discount_ctx(
    lctx: &LayerContext,
    mapping: &Mapping,
    k: usize,
    pk: usize,
    t: Tensor,
) -> f64 {
    let mut mc = 1.0;
    for lv in (k + 1)..=pk {
        if !lctx.multicast[lv] {
            continue;
        }
        for d in crate::workload::DIMS {
            let s = mapping.levels[lv].spatial[d.index()];
            if s > 1 && !lctx.is_relevant(t, d) {
                mc *= s as f64;
            }
        }
    }
    mc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;
    use crate::mapping::{check, Mapping};
    use crate::quant::LayerQuant;
    use crate::workload::{ConvLayer, Dim};

    fn layer() -> ConvLayer {
        ConvLayer::conv("t", 4, 8, 3, 8, 1)
    }

    /// all loops at DRAM (worst case: no on-chip reuse via tiling)
    fn dram_heavy(l: &ConvLayer, nl: usize) -> Mapping {
        let mut m = Mapping::unit(nl);
        for d in 0..7 {
            m.levels[nl - 1].temporal[d] = l.dims[d];
        }
        m
    }

    #[test]
    fn conservation_lower_bounds() {
        // every tensor must cross DRAM at least once: DRAM reads >= tensor
        // footprint for W/I; DRAM writes >= footprint for O.
        let a = toy();
        let l = layer();
        let m = dram_heavy(&l, a.levels.len());
        check(&a, &l, &LayerQuant::uniform(8), &m).unwrap();
        let r = analyze(&a, &l, &m);
        let dram = a.levels.len() - 1;
        assert!(r.accesses[dram][0].reads >= l.tensor_elements(Tensor::Weights) as f64);
        assert!(r.accesses[dram][1].reads >= l.tensor_elements(Tensor::Inputs) as f64);
        assert!(r.accesses[dram][2].writes >= l.tensor_elements(Tensor::Outputs) as f64);
    }

    #[test]
    fn compute_level_serves_macs() {
        let a = toy();
        let l = layer();
        let m = dram_heavy(&l, a.levels.len());
        let r = analyze(&a, &l, &m);
        // innermost level keeps all three tensors in `toy`
        assert!(r.accesses[0][0].reads >= l.macs() as f64);
        assert!(r.accesses[0][2].writes >= l.macs() as f64);
        assert_eq!(r.macs, l.macs());
    }

    #[test]
    fn weight_stationary_reduces_dram_weight_reads() {
        // Two mappings: (a) weight-relevant loop innermost at DRAM above a
        // weight tile, (b) weight-irrelevant loop (P) innermost. In (b)
        // the weight tile is reused across P iterations -> fewer refetches.
        let a = toy();
        let l = layer();
        let nl = a.levels.len();

        // tile: full weights at spad? too big; keep K,C,R,S inner at buf.
        let mut m = Mapping::unit(nl);
        // inner level: one output pixel, full filter for one (k)
        m.levels[0].temporal[Dim::C.index()] = 4;
        m.levels[0].temporal[Dim::R.index()] = 3;
        m.levels[0].temporal[Dim::S.index()] = 3;
        // buf level: K at temporal
        m.levels[1].temporal[Dim::K.index()] = 8;
        // DRAM: P, Q loops
        m.levels[2].temporal[Dim::P.index()] = 8;
        m.levels[2].temporal[Dim::Q.index()] = 8;

        let q = LayerQuant::uniform(2); // small so capacity passes
        // (a) P,Q outermost but no irrelevant-inner discount change at
        //     DRAM for weights: perm with P first (irrelevant to W inner)
        let mut ma = m.clone();
        ma.levels[2].perm = [Dim::P, Dim::Q, Dim::N, Dim::K, Dim::C, Dim::R, Dim::S];
        check(&a, &l, &q, &ma).unwrap();
        let ra = analyze(&a, &l, &ma);

        // (b) same loops, but a relevant dummy? there are no relevant
        // loops at DRAM; both P and Q are irrelevant to weights, so the
        // whole DRAM level is one contiguous irrelevant block -> weights
        // fetched exactly once.
        let w_fp = l.tensor_elements(Tensor::Weights) as f64;
        assert_eq!(ra.accesses[2][0].reads, w_fp);

        // now force refetch: move K to DRAM, ordered outside P
        let mut mb = Mapping::unit(nl);
        mb.levels[0].temporal[Dim::C.index()] = 4;
        mb.levels[0].temporal[Dim::R.index()] = 3;
        mb.levels[0].temporal[Dim::S.index()] = 3;
        mb.levels[2].temporal[Dim::K.index()] = 8;
        mb.levels[2].temporal[Dim::P.index()] = 8;
        mb.levels[2].temporal[Dim::Q.index()] = 8;
        // innermost at DRAM: K (relevant) then P,Q outside -> P,Q re-runs
        // K sequence -> weights refetched P*Q times
        mb.levels[2].perm = [Dim::K, Dim::P, Dim::Q, Dim::N, Dim::C, Dim::R, Dim::S];
        check(&a, &l, &q, &mb).unwrap();
        let rb = analyze(&a, &l, &mb);
        assert!(rb.accesses[2][0].reads >= 64.0 * w_fp * 0.99,
            "expected ~{} got {}", 64.0 * w_fp, rb.accesses[2][0].reads);

        // permutation with P,Q innermost (irrelevant block) then K:
        // weights fetched only K-times total (once per k tile) = footprint
        let mut mc = mb.clone();
        mc.levels[2].perm = [Dim::P, Dim::Q, Dim::K, Dim::N, Dim::C, Dim::R, Dim::S];
        let rc = analyze(&a, &l, &mc);
        assert!(rc.accesses[2][0].reads < rb.accesses[2][0].reads / 10.0);
    }

    #[test]
    fn multicast_discounts_parent_reads() {
        // spatial K at buf level: input tiles are identical across K
        // children -> multicast serves them with one GLB read each.
        let a = toy(); // buf: fanout 4, multicast, dims {K,C,P}
        let l = layer();
        let nl = a.levels.len();
        let mut m = dram_heavy(&l, nl);
        m.levels[1].spatial[Dim::K.index()] = 4;
        m.levels[2].temporal[Dim::K.index()] = 2;
        let q = LayerQuant::uniform(4);
        check(&a, &l, &q, &m).unwrap();
        let with_spatial = analyze(&a, &l, &m);

        let m_nospatial = dram_heavy(&l, nl);
        let base = analyze(&a, &l, &m_nospatial);
        // input reads at buf level (serving spads) should not exceed the
        // non-spatial case by the fanout factor; with multicast the
        // parent-read side stays equal while 4 children are fed.
        assert!(with_spatial.accesses[1][1].reads <= base.accesses[1][1].reads * 1.01);
        assert_eq!(with_spatial.pes_used, 4);
    }

    #[test]
    fn outputs_write_up_once_when_reduction_inner() {
        let a = toy();
        let l = layer();
        let m = dram_heavy(&l, a.levels.len());
        let r = analyze(&a, &l, &m);
        let dram = a.levels.len() - 1;
        let o_fp = l.tensor_elements(Tensor::Outputs) as f64;
        // canonical perm [N,K,C,R,S,P,Q]: C,R,S (reduction) are NOT the
        // innermost block... N=1,K relevant. With K innermost (factor 8),
        // contiguous breaks immediately -> drains = K*C*R*S*P*Q... the
        // precise value depends on perm; we only assert the lower bound
        // and that re-reads = writes - footprint.
        assert!(r.accesses[dram][2].writes >= o_fp);
        assert!(
            (r.accesses[dram][2].reads - (r.accesses[dram][2].writes - o_fp)).abs() < 1e-6
        );
    }

    #[test]
    fn reuse_invariance_dram_traffic_at_least_footprint() {
        // property-ish: for random valid mappings, DRAM traffic never
        // drops below compulsory traffic
        use crate::mapping::mapspace::MapSpace;
        use crate::util::rng::Rng;
        let a = toy();
        let l = layer();
        let space = MapSpace::of(&a);
        let mut rng = Rng::new(42);
        let q = LayerQuant::uniform(8);
        let mut tested = 0;
        for _ in 0..500 {
            let m = space.random_mapping(&l, &mut rng);
            if check(&a, &l, &q, &m).is_err() {
                continue;
            }
            tested += 1;
            let r = analyze(&a, &l, &m);
            let dram = a.levels.len() - 1;
            assert!(r.accesses[dram][0].reads + 1e-9 >= l.tensor_elements(Tensor::Weights) as f64);
            assert!(r.accesses[dram][1].reads + 1e-9 >= l.tensor_elements(Tensor::Inputs) as f64);
            assert!(r.accesses[dram][2].writes + 1e-9 >= l.tensor_elements(Tensor::Outputs) as f64);
            // and macs served at innermost keepers
            assert!(r.accesses[0][0].reads + 1e-9 >= l.macs() as f64);
        }
        assert!(tested > 5, "too few valid samples: {tested}");
    }
}
