//! Timeloop-style mapspace constraints.
//!
//! Timeloop never enumerates the raw cross product of all ordered
//! factorizations: every shipped architecture config carries a
//! *constraints* file that pins which problem dims each storage level
//! may iterate temporally (and which may be mapped spatially — that part
//! lives in [`crate::arch::Level::spatial_dims`]). The paper's Table I
//! counts ("11,778 valid mappings on Eyeriss at 16 bit") are counts of
//! such a *constrained* mapspace; without constraints the raw space for
//! the same layer is ~10^8 and the reported numbers would be
//! meaningless.
//!
//! A [`MapConstraints`] is one entry per storage level, each listing the
//! dims permitted to carry a temporal factor > 1 at that level (`None`
//! = unconstrained). [`MapSpace::enumerate_valid_with`] consumes it to
//! prune factorization choices *before* recursion, which is also what
//! makes exhaustive enumeration tractable.

use crate::arch::Arch;
use crate::workload::{Dim, DIMS};

/// Per-level temporal-dim whitelist.
#[derive(Debug, Clone, Default)]
pub struct LevelConstraint {
    /// Dims allowed a temporal factor > 1 at this level.
    /// `None` = all dims allowed.
    pub temporal_dims: Option<Vec<Dim>>,
}

impl LevelConstraint {
    pub fn any() -> Self {
        LevelConstraint { temporal_dims: None }
    }
    pub fn only(dims: &[Dim]) -> Self {
        LevelConstraint {
            temporal_dims: Some(dims.to_vec()),
        }
    }
    pub fn allows(&self, d: Dim) -> bool {
        match &self.temporal_dims {
            None => true,
            Some(ds) => ds.contains(&d),
        }
    }
}

/// A full constraint set: one [`LevelConstraint`] per storage level
/// (innermost first, same order as [`Arch::levels`]).
#[derive(Debug, Clone)]
pub struct MapConstraints {
    pub levels: Vec<LevelConstraint>,
}

impl MapConstraints {
    /// No constraints (the raw mapspace).
    pub fn none(num_levels: usize) -> Self {
        MapConstraints {
            levels: vec![LevelConstraint::any(); num_levels],
        }
    }

    /// Eyeriss row-stationary discipline (mirrors the `eyeriss_like`
    /// constraints of the Timeloop exercises): the PE scratchpad runs
    /// the MAC-feeding loops over the filter window and a channel
    /// sliver; the global buffer iterates output tiles; DRAM carries
    /// whatever remains (unconstrained).
    pub fn eyeriss() -> Self {
        MapConstraints {
            levels: vec![
                // pe_spad: filter window + output-column reuse
                LevelConstraint::only(&[Dim::R, Dim::S, Dim::Q]),
                // shared_glb: output tiles + channel blocking
                LevelConstraint::only(&[Dim::P, Dim::Q, Dim::C, Dim::K, Dim::N]),
                // dram: free
                LevelConstraint::any(),
            ],
        }
    }

    /// Simba weight-stationary-ish discipline: lane registers hold a
    /// weight sliver (no temporal loops beyond the window), PE buffers
    /// block channels/outputs, the global buffer tiles outputs and
    /// batches, DRAM is free.
    pub fn simba() -> Self {
        MapConstraints {
            levels: vec![
                // lane_reg: innermost reuse over the filter window only
                LevelConstraint::only(&[Dim::R, Dim::S]),
                // pe_buf: channel/filter blocking
                LevelConstraint::only(&[Dim::C, Dim::K]),
                // global_buf: output/batch tiling
                LevelConstraint::only(&[Dim::P, Dim::Q, Dim::N]),
                // dram: free
                LevelConstraint::any(),
            ],
        }
    }

    /// The constraint set an architecture ships with (by preset name),
    /// falling back to the unconstrained space.
    pub fn for_arch(arch: &Arch) -> Self {
        match arch.name.as_str() {
            "eyeriss" => Self::eyeriss(),
            "simba" => Self::simba(),
            _ => Self::none(arch.levels.len()),
        }
    }

    /// Is `factor` at temporal slot `level` for dim `d` permitted?
    pub fn allows_temporal(&self, level: usize, d: Dim, factor: u64) -> bool {
        factor == 1 || self.levels.get(level).map_or(true, |lc| lc.allows(d))
    }

    /// Filter an ordered factorization `fs` (layout: `num_levels`
    /// temporal slots then spatial slots) for dim `d`.
    pub fn allows_factorization(&self, num_levels: usize, d: Dim, fs: &[u64]) -> bool {
        (0..num_levels).all(|lv| self.allows_temporal(lv, d, fs[lv]))
    }

    /// Sanity-check against an architecture.
    pub fn validate(&self, arch: &Arch) -> Result<(), String> {
        if self.levels.len() != arch.levels.len() {
            return Err(format!(
                "constraints cover {} levels, arch has {}",
                self.levels.len(),
                arch.levels.len()
            ));
        }
        // the top level must be able to absorb every dim, or some layer
        // sizes become unmappable
        if let Some(ds) = &self.levels.last().unwrap().temporal_dims {
            for d in DIMS {
                if !ds.contains(&d) {
                    return Err(format!("top level must allow dim {d:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn presets_validate_against_archs() {
        MapConstraints::eyeriss().validate(&presets::eyeriss()).unwrap();
        MapConstraints::simba().validate(&presets::simba()).unwrap();
        MapConstraints::none(3).validate(&presets::eyeriss()).unwrap();
    }

    #[test]
    fn factor_one_always_allowed() {
        let c = MapConstraints::eyeriss();
        for d in DIMS {
            for lv in 0..3 {
                assert!(c.allows_temporal(lv, d, 1));
            }
        }
    }

    #[test]
    fn eyeriss_spad_rejects_channel_loops() {
        let c = MapConstraints::eyeriss();
        assert!(!c.allows_temporal(0, Dim::C, 2));
        assert!(!c.allows_temporal(0, Dim::K, 4));
        assert!(c.allows_temporal(0, Dim::R, 3));
        assert!(c.allows_temporal(2, Dim::C, 64)); // DRAM free
    }

    #[test]
    fn factorization_filter() {
        let c = MapConstraints::eyeriss();
        // 3 temporal slots + 1 spatial slot; C may only tile at GLB/DRAM
        assert!(c.allows_factorization(3, Dim::C, &[1, 2, 4, 4]));
        assert!(!c.allows_factorization(3, Dim::C, &[2, 1, 1, 16]));
        // spatial slot content is not this struct's concern
        assert!(c.allows_factorization(3, Dim::C, &[1, 1, 1, 32]));
    }

    #[test]
    fn for_arch_lookup() {
        assert!(MapConstraints::for_arch(&presets::eyeriss()).levels[0]
            .temporal_dims
            .is_some());
        let mut a = presets::eyeriss();
        a.name = "custom".into();
        assert!(MapConstraints::for_arch(&a).levels[0].temporal_dims.is_none());
    }

    #[test]
    fn mismatched_level_count_rejected() {
        assert!(MapConstraints::none(2).validate(&presets::simba()).is_err());
    }

    #[test]
    fn top_level_must_be_free() {
        let mut c = MapConstraints::eyeriss();
        c.levels[2] = LevelConstraint::only(&[Dim::P]);
        assert!(c.validate(&presets::eyeriss()).is_err());
    }
}
