//! The mapper: searches the mapspace of one workload for the best
//! mapping under a quantization setting.
//!
//! Mirrors the paper's Timeloop configuration: "random search with
//! termination condition set to finding 2000 valid mappings per
//! workload", the best mapping selected by minimum EDP. A per-workload
//! result cache (the paper's §III-A caching mechanism) makes repeated
//! NSGA-II evaluations of similar genomes cheap.

pub mod cache;
pub mod gamma;

use crate::arch::Arch;
use crate::energy::{estimate_into, Estimate};
use crate::mapping::mapspace::MapSpace;
use crate::mapping::{LayerContext, Mapping};
use crate::nest::{analyze_into, NestAnalysis};
use crate::quant::LayerQuant;
use crate::util::rng::Rng;
use crate::workload::ConvLayer;

/// Mapper configuration.
#[derive(Debug, Clone, Copy)]
pub struct MapperConfig {
    /// Stop after this many *valid* mappings have been evaluated
    /// (paper: 2000).
    pub valid_target: u64,
    /// Hard cap on candidate draws (valid or not), to bound pathological
    /// workloads where validity is rare.
    pub max_draws: u64,
    /// RNG seed (combined with a workload hash for determinism).
    pub seed: u64,
    /// Parallel search shards for one workload (0 = one per available
    /// core). Targets and draw budgets split across shards; each shard
    /// derives its own seed from (seed, workload hash, shard index), so
    /// results are deterministic for a fixed (seed, shards) pair.
    pub shards: usize,
}

impl Default for MapperConfig {
    fn default() -> Self {
        MapperConfig {
            valid_target: 2000,
            max_draws: 400_000,
            seed: 0x51AB5EED,
            shards: 1,
        }
    }
}

/// Reusable per-thread scratch for the allocation-free hot path: one
/// candidate `Mapping`, the factorization slot buffer, the cumulative
/// tile-extent buffer, and the nest/estimate output slots. Build once
/// per (thread, workload) and reuse across candidate draws — the
/// steady-state loop performs zero heap allocations per draw.
pub struct EvalContext {
    pub mapping: Mapping,
    pub fbuf: Vec<u64>,
    pub ext: Vec<[u64; 7]>,
    pub nest: NestAnalysis,
    pub est: Estimate,
}

impl EvalContext {
    pub fn for_arch(arch: &Arch) -> Self {
        let space = MapSpace::of(arch);
        Self::with_dims(arch.levels.len(), space.slots())
    }

    pub fn with_dims(num_levels: usize, slots: usize) -> Self {
        EvalContext {
            mapping: Mapping::unit(num_levels),
            fbuf: vec![1; slots],
            ext: Vec::with_capacity(num_levels),
            nest: NestAnalysis::empty(),
            est: Estimate::empty(),
        }
    }
}

/// Outcome of a mapper search on one workload.
#[derive(Debug, Clone)]
pub struct MapperResult {
    /// Best (minimum-EDP) estimate found; `None` if no valid mapping.
    pub best: Option<Estimate>,
    /// The mapping achieving `best`.
    pub best_mapping: Option<Mapping>,
    /// Number of valid mappings encountered.
    pub valid: u64,
    /// Number of candidates drawn.
    pub draws: u64,
}

/// One shard's slice of a search: its derived seed and its share of the
/// valid-mapping target and draw budget. The full decomposition of a
/// workload search is [`shard_plan`]; it is a pure function of the
/// `MapperConfig` and the workload, never of how the shards end up
/// being executed — which is what lets `engine::driver` run the same
/// shards on a work-stealing pool and still merge to bit-identical
/// results.
#[derive(Debug, Clone, Copy)]
pub struct ShardSpec {
    pub seed: u64,
    pub valid_target: u64,
    pub max_draws: u64,
}

/// Per-shard search outcome. Opaque outside the mapper: produced by
/// [`run_shard`], consumed (in shard-index order) by [`merge_shards`].
pub struct ShardOutcome {
    /// (EDP, estimate, mapping) of the shard's winner.
    best: Option<(f64, Estimate, Mapping)>,
    valid: u64,
    draws: u64,
}

/// The deterministic decomposition of one workload search into shards:
/// `effective_shards(cfg)` entries, each with a seed derived from
/// `(base_seed, shard index)` and an even split of the valid-mapping
/// target and draw budget (remainders to the lowest indices). One shard
/// reproduces the single-threaded candidate stream exactly.
pub fn shard_plan(cfg: &MapperConfig, base_seed: u64) -> Vec<ShardSpec> {
    let n = effective_shards(cfg) as u64;
    (0..n)
        .map(|i| ShardSpec {
            seed: base_seed ^ i.wrapping_mul(0x9E3779B97F4A7C15),
            valid_target: cfg.valid_target / n + u64::from(i < cfg.valid_target % n),
            max_draws: cfg.max_draws / n + u64::from(i < cfg.max_draws % n),
        })
        .collect()
}

/// One shard of the random search: draws candidates through the
/// allocation-free context path until its share of the valid-mapping
/// target (or draw budget) is exhausted. Within a shard the first
/// strictly-lower EDP wins, so the result is deterministic in the seed.
pub fn run_shard(space: &MapSpace, lctx: &LayerContext, spec: &ShardSpec) -> ShardOutcome {
    let (seed, valid_target, max_draws) = (spec.seed, spec.valid_target, spec.max_draws);
    let mut ctx = EvalContext::with_dims(lctx.num_levels, space.slots());
    let mut rng = Rng::new(seed);
    let mut best: Option<(f64, Estimate, Mapping)> = None;
    let mut valid = 0u64;
    let mut draws = 0u64;

    while valid < valid_target && draws < max_draws {
        draws += 1;
        space.random_mapping_into(lctx, &mut rng, &mut ctx.fbuf, &mut ctx.mapping);
        if lctx.check(&ctx.mapping, &mut ctx.ext).is_err() {
            continue;
        }
        valid += 1;
        analyze_into(lctx, &ctx.mapping, &mut ctx.ext, &mut ctx.nest);
        estimate_into(lctx, &ctx.nest, &mut ctx.est);
        let edp = ctx.est.edp();
        match &mut best {
            Some((b, be, bm)) => {
                if edp < *b {
                    *b = edp;
                    be.copy_from(&ctx.est);
                    bm.copy_from(&ctx.mapping);
                }
            }
            None => best = Some((edp, ctx.est.clone(), ctx.mapping.clone())),
        }
    }

    ShardOutcome { best, valid, draws }
}

/// Deterministic merge of shard outcomes: iterate in shard-index order,
/// keep the first strictly-minimum EDP (ties go to the lowest shard
/// index), and sum the counters. Order-independent of how the shards
/// were *executed*, so work-stealing execution merges identically to
/// sequential execution.
pub fn merge_shards(outcomes: Vec<ShardOutcome>) -> MapperResult {
    let mut valid = 0u64;
    let mut draws = 0u64;
    let mut best: Option<(f64, Estimate, Mapping)> = None;
    for r in outcomes {
        valid += r.valid;
        draws += r.draws;
        if let Some((edp, est, m)) = r.best {
            if best.as_ref().map_or(true, |(b, _, _)| edp < *b) {
                best = Some((edp, est, m));
            }
        }
    }
    match best {
        Some((_, est, m)) => MapperResult {
            best: Some(est),
            best_mapping: Some(m),
            valid,
            draws,
        },
        None => MapperResult {
            best: None,
            best_mapping: None,
            valid,
            draws,
        },
    }
}

/// Resolve the configured shard count (0 = auto) and cap it so no shard
/// is left without a share of the valid-mapping target.
pub fn effective_shards(cfg: &MapperConfig) -> usize {
    let s = if cfg.shards == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.shards
    };
    s.max(1).min(cfg.valid_target.clamp(1, 1024) as usize)
}

/// Random-search the mapspace of `(layer, q)` on `arch`.
///
/// Bit-widths are canonicalized to their packing-equivalence class first
/// (see [`LayerQuant::canonical`]): the engine's capacity and energy
/// models depend on `q` only through the pack factor, so equivalent
/// settings must explore identical mapspaces (and share cache entries).
///
/// With `cfg.shards > 1` the valid-mapping target and draw budget split
/// across that many threads, each with a seed derived from
/// `(cfg.seed, workload, shard index)`, and the shard minima merge by
/// minimum EDP with ties resolved to the lowest shard index (within a
/// shard the strict `<` keeps the earliest winner) — deterministic for
/// a fixed (seed, shards) pair. `shards == 1` reproduces the
/// single-threaded candidate stream exactly.
pub fn search(arch: &Arch, layer: &ConvLayer, q: &LayerQuant, cfg: &MapperConfig) -> MapperResult {
    let q = &q.canonical(arch.word_bits, arch.bit_packing);
    let space = MapSpace::of(arch);
    let lctx = LayerContext::new(arch, layer, q);
    let specs = shard_plan(cfg, cfg.seed ^ workload_hash(layer, q));

    let outcomes: Vec<ShardOutcome> = if specs.len() <= 1 {
        specs.iter().map(|s| run_shard(&space, &lctx, s)).collect()
    } else {
        // standalone parallel path (scoped threads). Under the engine
        // the same specs run as work-stealing pool subtasks instead —
        // see `engine::driver::search_on_engine` — and merge to the
        // same result.
        let mut slots: Vec<Option<ShardOutcome>> = specs.iter().map(|_| None).collect();
        std::thread::scope(|sc| {
            for (spec, slot) in specs.iter().zip(slots.iter_mut()) {
                let space = &space;
                let lctx = &lctx;
                sc.spawn(move || {
                    *slot = Some(run_shard(space, lctx, spec));
                });
            }
        });
        slots.into_iter().map(|r| r.expect("shard completed")).collect()
    };

    merge_shards(outcomes)
}

/// Stable 64-bit hash of a workload + quantization (cache key and seed
/// derivation). FNV-1a over the canonical fields.
pub fn workload_hash(layer: &ConvLayer, q: &LayerQuant) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut feed = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for &d in &layer.dims {
        feed(d);
    }
    feed(layer.stride.0);
    feed(layer.stride.1);
    feed(layer.kind as u64);
    feed(q.qa as u64);
    feed(q.qw as u64);
    feed(q.qo as u64);
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{eyeriss, toy};
    use crate::workload::ConvLayer;

    #[test]
    fn finds_valid_mappings_on_toy() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let cfg = MapperConfig {
            valid_target: 200,
            max_draws: 100_000,
            seed: 1,
            shards: 1,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert!(r.valid >= 200);
        assert!(r.best.is_some());
        assert!(r.best.unwrap().edp() > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let cfg = MapperConfig {
            valid_target: 100,
            max_draws: 50_000,
            seed: 7,
            shards: 1,
        };
        let q = LayerQuant::uniform(4);
        let r1 = search(&a, &l, &q, &cfg);
        let r2 = search(&a, &l, &q, &cfg);
        assert_eq!(r1.best.map(|e| e.edp()), r2.best.map(|e| e.edp()));
        assert_eq!(r1.valid, r2.valid);
    }

    #[test]
    fn sharded_search_is_deterministic() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(4);
        for shards in [2usize, 4] {
            let cfg = MapperConfig {
                valid_target: 120,
                max_draws: 60_000,
                seed: 7,
                shards,
            };
            let r1 = search(&a, &l, &q, &cfg);
            let r2 = search(&a, &l, &q, &cfg);
            assert_eq!(
                r1.best.as_ref().map(|e| e.edp().to_bits()),
                r2.best.as_ref().map(|e| e.edp().to_bits()),
                "shards={shards}"
            );
            assert_eq!(r1.valid, r2.valid);
            assert_eq!(r1.draws, r2.draws);
            assert!(r1.valid >= 120, "shards={shards} valid={}", r1.valid);
            assert_eq!(r1.best_mapping, r2.best_mapping);
        }
    }

    #[test]
    fn sharded_targets_sum_to_config() {
        // draws split exactly: on a never-valid workload every shard
        // exhausts its share and the totals reassemble the budget
        let a = toy();
        let l = ConvLayer::conv("t", 97, 89, 1, 13, 1); // awkward primes
        let cfg = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 2_001, // deliberately not divisible by shards
            seed: 5,
            shards: 4,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert_eq!(r.draws, 2_001);
    }

    #[test]
    fn lower_bits_find_lower_edp_on_eyeriss() {
        // the synergy effect end-to-end through the mapper
        let a = eyeriss();
        let l = ConvLayer::dw("dw2", 32, 3, 112, 1);
        let cfg = MapperConfig {
            valid_target: 300,
            max_draws: 300_000,
            seed: 3,
            shards: 1,
        };
        let e16 = search(&a, &l, &LayerQuant::uniform(16), &cfg);
        let e4 = search(&a, &l, &LayerQuant::uniform(4), &cfg);
        let b16 = e16.best.expect("16b should map").edp();
        let b4 = e4.best.expect("4b should map").edp();
        assert!(b4 < b16, "edp4={b4} edp16={b16}");
    }

    #[test]
    fn hash_distinguishes_quant_and_shape() {
        let l1 = ConvLayer::conv("a", 4, 8, 3, 8, 1);
        let l2 = ConvLayer::conv("b", 8, 8, 3, 8, 1);
        let q8 = LayerQuant::uniform(8);
        let q4 = LayerQuant::uniform(4);
        assert_ne!(workload_hash(&l1, &q8), workload_hash(&l1, &q4));
        assert_ne!(workload_hash(&l1, &q8), workload_hash(&l2, &q8));
        // name does NOT affect the key: same shape+q hits the same cache
        let l1b = ConvLayer::conv("other_name", 4, 8, 3, 8, 1);
        assert_eq!(workload_hash(&l1, &q8), workload_hash(&l1b, &q8));
    }

    #[test]
    fn impossible_workload_returns_none() {
        // single PE spad of 16 words can't hold even one weight at 16b if
        // we also forbid DRAM-resident loops? Actually DRAM-heavy always
        // works; make a level-0 mandatory overflow by using a huge R so
        // that any unit tile... unit tiles always fit. So instead: check
        // that max_draws bounds the search on a workload with rare
        // validity rather than hanging.
        let a = toy();
        let l = ConvLayer::conv("t", 97, 89, 1, 13, 1); // awkward primes
        let cfg = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 2_000,
            seed: 5,
            shards: 1,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert_eq!(r.draws, 2_000);
    }
}
