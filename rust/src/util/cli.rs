//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the program name and subcommand).
    /// `flag_names` lists options that take no value.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    a.flags.push(body.to_string());
                } else {
                    i += 1;
                    let v = raw
                        .get(i)
                        .ok_or_else(|| format!("option --{body} expects a value"))?;
                    a.options.insert(body.to_string(), v.clone());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = Args::parse(
            &v(&["pos1", "--n", "32", "--arch=eyeriss", "--verbose", "pos2"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, v(&["pos1", "pos2"]));
        assert_eq!(a.usize_or("n", 0), 32);
        assert_eq!(a.str_or("arch", ""), "eyeriss");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&v(&["--n"]), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&v(&[]), &[]).unwrap();
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("p", 0.5), 0.5);
    }
}
