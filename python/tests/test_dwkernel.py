"""Pallas depthwise fake-quant conv vs the pure-jnp oracle.

Same contract as test_kernel.py: hypothesis sweeps over shapes, strides
and bit-widths; directed edge cases around channel-block boundaries and
degenerate tensors.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qdwconv import _qdwconv_impl, qdwconv
from compile.kernels.ref import ref_qdwconv

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, lo=-3.0, hi=3.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


def assert_matches_ref(x, w, qa, qw, stride=1, **kw):
    got = _qdwconv_impl(x, w, jnp.float32(qa), jnp.float32(qw), stride=stride, **kw)
    want = ref_qdwconv(x, w, jnp.float32(qa), jnp.float32(qw), stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(3, 12),
    c=st.integers(1, 20),
    r=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    qa=st.integers(2, 8),
    qw=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shape_stride_bit_sweep(b, h, c, r, stride, qa, qw, seed):
    x = _rand(seed, (b, h, h, c))
    w = _rand(seed + 1, (r, r, c))
    assert_matches_ref(x, w, qa, qw, stride=stride, block_c=8)


@pytest.mark.parametrize("c", [1, 7, 8, 9, 128, 130])
def test_channel_block_boundaries(c):
    """Padding/slicing around the BLOCK_C lane edge must be exact."""
    x = _rand(11, (2, 6, 6, c))
    w = _rand(12, (3, 3, c))
    assert_matches_ref(x, w, 4, 4, block_c=8)


@pytest.mark.parametrize("stride", [1, 2])
def test_odd_spatial_with_stride(stride):
    """SAME padding on odd H/W (the 112->56 MobileNet transitions)."""
    x = _rand(13, (1, 7, 9, 4))
    w = _rand(14, (3, 3, 4))
    assert_matches_ref(x, w, 6, 3, stride=stride)


def test_constant_tensor_no_nan():
    x = jnp.ones((1, 5, 5, 3), jnp.float32)
    w = jnp.zeros((3, 3, 3), jnp.float32)
    out = _qdwconv_impl(x, w, jnp.float32(2), jnp.float32(2))
    assert np.isfinite(np.asarray(out)).all()


def test_ste_gradients_flow_and_bits_get_none():
    x = _rand(21, (1, 6, 6, 4))
    w = _rand(22, (3, 3, 4))

    def loss(xx, ww, qa, qw):
        return jnp.sum(qdwconv(xx, ww, qa, qw, 1) ** 2)

    gx, gw, gqa, gqw = jax.grad(loss, argnums=(0, 1, 2, 3))(
        x, w, jnp.float32(4), jnp.float32(4)
    )
    assert np.abs(np.asarray(gx)).sum() > 0, "no gradient reached x"
    assert np.abs(np.asarray(gw)).sum() > 0, "no gradient reached w"
    np.testing.assert_allclose(np.asarray(gqa), 0.0)
    np.testing.assert_allclose(np.asarray(gqw), 0.0)


def test_quantization_coarsens_output():
    """2-bit weights must change the output vs 8-bit (sanity that the
    quantizer is actually in the compute path)."""
    x = _rand(31, (1, 8, 8, 8))
    w = _rand(32, (3, 3, 8))
    o8 = _qdwconv_impl(x, w, jnp.float32(8), jnp.float32(8))
    o2 = _qdwconv_impl(x, w, jnp.float32(8), jnp.float32(2))
    assert float(jnp.abs(o8 - o2).max()) > 1e-3
