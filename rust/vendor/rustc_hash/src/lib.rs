//! Minimal vendored FxHash: the multiply-rotate hash used throughout
//! rustc, exposed with the same names as the crates.io `rustc-hash`
//! crate (`FxHashMap`, `FxHashSet`, `FxHasher`). Vendored so the
//! workspace builds in offline environments; not cryptographic, not
//! DoS-resistant — exactly like the original, it trades both for speed
//! on short integer-ish keys.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// A `HashMap` using [`FxHasher`].
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
/// A `HashSet` using [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;
/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The FxHash state: `h = rotl5(h) ^ word, then h *= SEED` per word.
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            let mut buf = [0u8; 8];
            buf.copy_from_slice(c);
            self.add(u64::from_le_bytes(buf));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }
    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add(n as u64);
    }
    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }
    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }
    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works() {
        let mut m: FxHashMap<u64, &str> = FxHashMap::default();
        m.insert(1, "one");
        m.insert(u64::MAX, "max");
        assert_eq!(m.get(&1), Some(&"one"));
        assert_eq!(m.get(&u64::MAX), Some(&"max"));
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn hasher_is_deterministic() {
        let h = |bytes: &[u8]| {
            let mut hh = FxHasher::default();
            hh.write(bytes);
            hh.finish()
        };
        assert_eq!(h(b"abc"), h(b"abc"));
        assert_ne!(h(b"abc"), h(b"abd"));
        assert_ne!(h(b"abcdefgh"), h(b"abcdefg"));
    }

    #[test]
    fn set_works() {
        let mut s: FxHashSet<u32> = FxHashSet::default();
        s.insert(7);
        assert!(s.contains(&7));
        assert!(!s.contains(&8));
    }
}
