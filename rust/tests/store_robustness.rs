//! Robustness and end-to-end properties of the persistent mapper-cache
//! store (`mapper::store`, `qmap search --cache-dir`):
//!
//! * fuzzed store files — truncated, bit-flipped, garbage-spliced,
//!   garbage-tailed — must never panic: open either refuses cleanly or
//!   serves the undamaged records (cold fallback, never corruption);
//! * two OS processes appending to one store concurrently lose nothing
//!   and tear nothing (the `O_APPEND` whole-record invariant);
//! * through the real binary: a warm `--cache-dir` run's Pareto front
//!   is byte-identical to the cold run's and to a storeless serial run,
//!   for both the 2-objective default and a 3-objective spec (which
//!   shares the store — identity excludes objectives);
//! * a store whose header claims a different identity is a loud
//!   refusal, never a silent cold start or reuse.
//!
//! Honors `QMAP_PROP_SEED` / `QMAP_PROP_CASES` for replay.

use qmap::mapper::store::{CacheStore, HEADER_LEN};
use qmap::util::prop::check_with_rng;
use qmap::util::Fnv1a;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::process::Command;

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qmap_storerob_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ------------------------------------------------------------- fuzzing

#[test]
fn mutilated_store_files_never_panic_and_degrade_to_cold() {
    let dir = tmp_dir("fuzz");
    let path = dir.join("fuzz.qstore");
    check_with_rng(
        0x57A6,
        40,
        |r| (r.range(1, 12), r.range(0, 16)),
        |&(slots, n), r| {
            // build a healthy store of n records, then mutilate it
            let _ = std::fs::remove_file(&path);
            {
                let s = CacheStore::open(&path, 0xF00D, slots).map_err(|e| e.to_string())?;
                for k in 0..n as u64 {
                    let payload: Vec<u64> = (0..slots as u64).map(|j| k * 100 + j).collect();
                    s.append(k, k % 3, &payload);
                }
            }
            let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
            match r.range(0, 3) {
                // truncation anywhere, header included
                0 => bytes.truncate(r.range(0, bytes.len() - 1)),
                // single bit flip anywhere
                1 => {
                    let b = r.range(0, bytes.len() - 1);
                    bytes[b] ^= 1 << r.range(0, 7);
                }
                // splice a run of garbage over a random region
                2 => {
                    let start = r.range(0, bytes.len() - 1);
                    let len = r.range(1, 64).min(bytes.len() - start);
                    for b in &mut bytes[start..start + len] {
                        *b = r.below(256) as u8;
                    }
                }
                // garbage tail (a crashed appender's worst case)
                _ => bytes.extend((0..r.range(1, 200)).map(|_| r.below(256) as u8)),
            }
            std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
            let opened = catch_unwind(AssertUnwindSafe(|| {
                match CacheStore::open(&path, 0xF00D, slots) {
                    // clean refusal = the caller starts cold
                    Err(_) => 0usize,
                    Ok(s) => {
                        // surviving records must still be well-formed
                        for k in 0..n as u64 + 2 {
                            if let Some((_, p)) = s.lookup(k) {
                                assert_eq!(p.len(), slots);
                            }
                        }
                        s.len()
                    }
                }
            }));
            match opened {
                Err(_) => Err("panicked on a mutilated store file".into()),
                Ok(len) if len <= n => Ok(()),
                Ok(len) => Err(format!("{len} records resurrected from a store of {n}")),
            }
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ----------------------------------------------- multi-process appends

/// Hidden helper, not a real test: when `QMAP_STORE_CHILD` is set this
/// appends `count` records starting at `base` to the store at `path`
/// (the concurrent test below re-invokes the test binary with
/// `--exact` to get genuinely separate OS processes). A normal test
/// run sees the variable unset and returns immediately.
#[test]
fn helper_child_appender() {
    let Ok(spec) = std::env::var("QMAP_STORE_CHILD") else { return };
    let mut it = spec.split('|');
    let path = PathBuf::from(it.next().unwrap());
    let base: u64 = it.next().unwrap().parse().unwrap();
    let count: u64 = it.next().unwrap().parse().unwrap();
    let s = CacheStore::open(&path, 0xC0FFEE, 2).unwrap();
    for k in 0..count {
        s.append(base + k, 1, &[base + k, (base + k) * 3]);
    }
}

#[test]
fn concurrent_processes_append_without_loss_or_tearing() {
    let dir = tmp_dir("mproc");
    let path = dir.join("shared.qstore");
    let exe = std::env::current_exe().unwrap();
    let n = 200u64;
    let bases = [0u64, 1 << 20];
    let children: Vec<_> = bases
        .iter()
        .map(|&base| {
            Command::new(&exe)
                .args(["helper_child_appender", "--exact", "--nocapture"])
                .env("QMAP_STORE_CHILD", format!("{}|{base}|{n}", path.display()))
                .spawn()
                .expect("spawn child appender")
        })
        .collect();
    for mut c in children {
        assert!(c.wait().unwrap().success(), "child appender failed");
    }
    let s = CacheStore::open(&path, 0xC0FFEE, 2).unwrap();
    assert_eq!(s.skipped(), 0, "interleaved appends must never tear a record");
    assert_eq!(s.len(), 2 * n as usize, "every append from both processes is visible");
    for &base in &bases {
        for k in 0..n {
            let key = base + k;
            assert_eq!(s.lookup(key), Some((1, &[key, key * 3][..])), "key {key}");
        }
    }
    // exactly 2n whole records on disk: nothing duplicated, nothing torn
    let stride = (3 + 2) * 8;
    let file_len = std::fs::metadata(&path).unwrap().len() as usize;
    assert_eq!(file_len, HEADER_LEN + 2 * n as usize * stride);
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------- end-to-end through the binary

/// Run `qmap search` on the toy arch over a small 3-layer net, serial
/// threads, fast profile. Returns (stdout, stderr).
fn run_search(net: &Path, objectives: Option<&str>, cache_dir: Option<&Path>) -> (String, String) {
    let mut c = Command::new(env!("CARGO_BIN_EXE_qmap"));
    c.args(["search", "--arch", "toy", "--profile", "fast"])
        .arg("--net")
        .arg(net)
        .args(["--gens", "2", "--pop", "6", "--offspring", "4", "--threads", "1"])
        .env_remove("QMAP_CACHE_DIR")
        .env_remove("QMAP_OBJECTIVES")
        .env_remove("QMAP_PROFILE")
        .env_remove("QMAP_WORKERS");
    if let Some(o) = objectives {
        c.args(["--objectives", o]);
    }
    if let Some(d) = cache_dir {
        c.arg("--cache-dir").arg(d);
    }
    let out = c.output().expect("run qmap search");
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(out.status.success(), "qmap search failed:\n{stderr}");
    (String::from_utf8(out.stdout).expect("utf8 stdout"), stderr)
}

fn write_tiny_net(dir: &Path) -> PathBuf {
    let net = dir.join("tiny.qnet");
    std::fs::write(
        &net,
        "c1 conv(c=3, k=8, r=3, p=8)\nd1 dw(ch=8, r=3, p=8)\nc2 conv(c=8, k=16, r=1, p=4)\n",
    )
    .unwrap();
    net
}

/// Hits reported by the end-of-run `store_summary` stderr line.
fn summary_hits(stderr: &str) -> u64 {
    let line = stderr
        .lines()
        .find(|l| l.contains("cache store:") && l.contains("hit"))
        .unwrap_or_else(|| panic!("no store summary in stderr:\n{stderr}"));
    let hits = line.split("cache store:").nth(1).unwrap();
    hits.trim().split_whitespace().next().unwrap().parse().unwrap()
}

#[test]
fn warm_front_is_bit_identical_to_cold_and_serial() {
    let dir = tmp_dir("warmcold");
    let net = write_tiny_net(&dir);
    let store = dir.join("store");

    let (serial, _) = run_search(&net, None, None);
    let (cold, cold_err) = run_search(&net, None, Some(&store));
    let (warm, warm_err) = run_search(&net, None, Some(&store));
    assert!(cold_err.contains("cache store"), "cold run must report the store:\n{cold_err}");
    assert_eq!(serial, cold, "a cold --cache-dir run must not move the front");
    assert_eq!(cold, warm, "a warm --cache-dir run must be byte-identical to cold");
    assert!(summary_hits(&warm_err) > 0, "warm run served nothing from the store:\n{warm_err}");

    // the 3-objective front shares the same store (identity excludes
    // objectives — mapper results are objective-independent) and must
    // also be byte-identical to its storeless serial twin
    let axes = Some("error,energy,weight_words");
    let (serial3, _) = run_search(&net, axes, None);
    let (warm3, warm3_err) = run_search(&net, axes, Some(&store));
    assert_eq!(serial3, warm3, "3-objective warm front must equal the serial front");
    assert!(
        summary_hits(&warm3_err) > 0,
        "3-objective run must warm-start from the 2-objective store:\n{warm3_err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn foreign_identity_store_is_refused_loudly() {
    let dir = tmp_dir("refusal");
    let net = write_tiny_net(&dir);
    let store = dir.join("store");
    let (_, _) = run_search(&net, None, Some(&store));
    let qstore = std::fs::read_dir(&store)
        .unwrap()
        .filter_map(Result::ok)
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|e| e == "qstore"))
        .expect("search created a .qstore file");

    // rewrite the header to claim a different identity, with a valid
    // checksum — the file is structurally sound, just foreign
    let mut bytes = std::fs::read(&qstore).unwrap();
    bytes[8] ^= 0xFF;
    let mut f = Fnv1a::new();
    f.write(&bytes[..24]);
    bytes[24..32].copy_from_slice(&f.finish().to_le_bytes());
    std::fs::write(&qstore, &bytes).unwrap();

    let mut c = Command::new(env!("CARGO_BIN_EXE_qmap"));
    c.args(["search", "--arch", "toy", "--profile", "fast"])
        .arg("--net")
        .arg(&net)
        .args(["--gens", "1", "--pop", "4", "--offspring", "2", "--threads", "1"])
        .arg("--cache-dir")
        .arg(&store)
        .env_remove("QMAP_CACHE_DIR")
        .env_remove("QMAP_PROFILE");
    let out = c.output().expect("run qmap search");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "a foreign-identity store must be a refusal");
    assert!(
        stderr.contains("does not match this run's identity") && stderr.contains("refusing"),
        "refusal must name the mismatch:\n{stderr}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
