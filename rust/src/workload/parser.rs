//! Parser for network layer tables as text (`.qnet`): the workload-side
//! analogue of the accelerator text specification. One layer per line:
//!
//! ```text
//! # name  kind       N  K    C    R  S  P   Q   strideH strideW
//! conv1   conv       1  32   3    3  3  112 112 2 2
//! dw1     depthwise  1  32   1    3  3  112 112 1 1
//! pw1     conv       1  64   32   1  1  112 112 1 1
//! fc      conv       1  1000 1024 1  1  1   1   1 1
//! ```
//!
//! Shorthand lines are also accepted:
//!
//! ```text
//! conv1 conv(c=3, k=32, r=3, p=112, stride=2)
//! dw1   dw(ch=32, r=3, p=112)
//! pw1   pw(c=32, k=64, p=112)
//! fc    fc(c=1024, k=1000)
//! ```

use super::{ConvLayer, LayerKind};

/// Parse a `.qnet` source into a layer table.
pub fn parse_net(src: &str) -> Result<Vec<ConvLayer>, String> {
    let mut layers = Vec::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let layer = parse_line(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        layers.push(layer);
    }
    if layers.is_empty() {
        return Err("no layers in network spec".into());
    }
    Ok(layers)
}

fn parse_line(line: &str) -> Result<ConvLayer, String> {
    // shorthand form: "<name> <helper>(k=v, ...)"
    if let Some(open) = line.find('(') {
        let close = line.rfind(')').ok_or("missing ')'")?;
        let head: Vec<&str> = line[..open].split_whitespace().collect();
        if head.len() != 2 {
            return Err(format!("want '<name> <kind>(...)', got '{line}'"));
        }
        let (name, helper) = (head[0], head[1]);
        let kv = parse_kv(&line[open + 1..close])?;
        let get = |k: &str| -> Result<u64, String> {
            kv.iter()
                .find(|(key, _)| key == k)
                .map(|&(_, v)| v)
                .ok_or(format!("{helper}: missing '{k}'"))
        };
        let opt = |k: &str, default: u64| -> u64 {
            kv.iter().find(|(key, _)| key == k).map(|&(_, v)| v).unwrap_or(default)
        };
        return match helper {
            "conv" => Ok(ConvLayer::conv(
                name,
                get("c")?,
                get("k")?,
                opt("r", 3),
                get("p")?,
                opt("stride", 1),
            )),
            "dw" => Ok(ConvLayer::dw(name, get("ch")?, opt("r", 3), get("p")?, opt("stride", 1))),
            "pw" => Ok(ConvLayer::pw(name, get("c")?, get("k")?, get("p")?)),
            "fc" => Ok(ConvLayer::fc(name, get("c")?, get("k")?)),
            other => Err(format!("unknown layer helper '{other}'")),
        };
    }

    // long form: name kind N K C R S P Q sh sw
    let f: Vec<&str> = line.split_whitespace().collect();
    if f.len() != 11 {
        return Err(format!("want 11 fields (name kind N K C R S P Q sh sw), got {}", f.len()));
    }
    let kind = match f[1] {
        "conv" | "standard" => LayerKind::Standard,
        "depthwise" | "dw" => LayerKind::Depthwise,
        other => return Err(format!("unknown kind '{other}'")),
    };
    let num = |i: usize| -> Result<u64, String> {
        f[i].parse().map_err(|_| format!("bad number '{}'", f[i]))
    };
    Ok(ConvLayer::new(
        f[0],
        kind,
        num(2)?,
        num(3)?,
        num(4)?,
        num(5)?,
        num(6)?,
        num(7)?,
        num(8)?,
        (num(9)?, num(10)?),
    ))
}

fn parse_kv(s: &str) -> Result<Vec<(String, u64)>, String> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (k, v) = part.split_once('=').ok_or(format!("bad 'k=v' pair '{part}'"))?;
        out.push((
            k.trim().to_string(),
            v.trim().parse().map_err(|_| format!("bad number '{v}'"))?,
        ));
    }
    Ok(out)
}

/// Load a layer table from a file path.
pub fn load_net(path: &str) -> Result<Vec<ConvLayer>, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse_net(&src)
}

/// Render a layer table back to the long text form (round-trippable).
pub fn render_net(layers: &[ConvLayer]) -> String {
    let mut out = String::from("# name kind N K C R S P Q strideH strideW\n");
    for l in layers {
        let kind = match l.kind {
            LayerKind::Standard => "conv",
            LayerKind::Depthwise => "depthwise",
        };
        out.push_str(&format!(
            "{} {} {} {} {} {} {} {} {} {} {}\n",
            l.name,
            kind,
            l.dims[0],
            l.dims[1],
            l.dims[2],
            l.dims[3],
            l.dims[4],
            l.dims[5],
            l.dims[6],
            l.stride.0,
            l.stride.1
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models;

    #[test]
    fn long_form_roundtrip_mobilenets() {
        for net in [models::mobilenet_v1(), models::mobilenet_v2()] {
            let text = render_net(&net);
            let back = parse_net(&text).unwrap();
            assert_eq!(back, net);
        }
    }

    #[test]
    fn shorthand_matches_helpers() {
        let src = "\
# a MobileNet-ish stem
conv1 conv(c=3, k=32, r=3, p=112, stride=2)
dw1   dw(ch=32, r=3, p=112)
pw1   pw(c=32, k=64, p=112)
fc    fc(c=1024, k=1000)
";
        let net = parse_net(src).unwrap();
        assert_eq!(net[0], ConvLayer::conv("conv1", 3, 32, 3, 112, 2));
        assert_eq!(net[1], ConvLayer::dw("dw1", 32, 3, 112, 1));
        assert_eq!(net[2], ConvLayer::pw("pw1", 32, 64, 112));
        assert_eq!(net[3], ConvLayer::fc("fc", 1024, 1000));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let net = parse_net("\n# only a comment\nfc fc(c=8, k=4)\n\n").unwrap();
        assert_eq!(net.len(), 1);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_net("fc fc(c=8, k=4)\nbogus line here\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn missing_field_rejected() {
        assert!(parse_net("pw1 pw(c=32, p=14)").unwrap_err().contains("missing 'k'"));
        assert!(parse_net("x conv 1 2 3").unwrap_err().contains("11 fields"));
    }

    #[test]
    fn empty_spec_rejected() {
        assert!(parse_net("# nothing\n").is_err());
    }
}
