//! Accuracy models for quantized CNNs.
//!
//! Two evaluators implement [`AccuracyModel`]:
//!
//! * [`ProxyAccuracy`] — a fast analytical surrogate based on per-layer
//!   quantization-noise sensitivity with QAT-recovery terms. Used by the
//!   large benchmark sweeps where the paper burned 48 GPU-hours per run
//!   (DESIGN.md §3 substitution). Its constants are *calibrated* against
//!   real QAT measurements produced by the runtime evaluator.
//! * `QatAccuracy` (in `crate::runtime`) — real quantization-aware
//!   fine-tuning of the scaled MobileNet through the AOT-compiled JAX
//!   train/eval steps, executed via PJRT. Used by the E2E example.
//!
//! The proxy's structure follows the standard SQNR argument: a per-tensor
//! asymmetric b-bit quantizer has noise power ~ 4^-b; layer sensitivity
//! varies with position and kind (stem/classifier and depthwise layers
//! tolerate quantization worst — the known MobileNet result); QAT with
//! more epochs recovers a larger fraction of the loss, and starting from
//! a QAT-8 checkpoint recovers more than starting from FP32 (paper
//! Fig. 3a/3c).

use crate::quant::QuantConfig;
use crate::workload::{ConvLayer, LayerKind, Tensor};

/// Anything that can score a quantization genome with a top-1 accuracy
/// in `[0, 1]`.
pub trait AccuracyModel {
    fn accuracy(&mut self, qc: &QuantConfig) -> f64;
    /// Human-readable identifier (for experiment records).
    fn name(&self) -> &'static str;
}

/// Which pre-trained checkpoint QAT fine-tuning starts from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitModel {
    /// FP32-trained checkpoint (paper: 77.26% top-1 for MobileNetV1).
    Fp32,
    /// 8-bit QAT checkpoint — "already accustomed to the effects of
    /// quantization", recovers better (paper Fig. 3a).
    Qat8,
}

/// Tunable constants of the proxy (see `calibrate`).
#[derive(Debug, Clone, Copy)]
pub struct ProxyParams {
    /// Accuracy of the unquantized reference model.
    pub base_accuracy: f64,
    /// Chance-level accuracy (1/#classes).
    pub chance: f64,
    /// Global penalty scale (the main calibration knob).
    pub scale: f64,
    /// Weight-noise vs activation-noise relative weight.
    pub weight_share: f64,
    /// QAT fine-tuning epochs `e`.
    pub epochs: u32,
    pub init: InitModel,
}

impl Default for ProxyParams {
    fn default() -> Self {
        ProxyParams {
            base_accuracy: 0.7726, // paper's MobileNetV1 on ImageNet-100
            chance: 0.01,
            scale: 1.6,
            weight_share: 0.55,
            epochs: 10,
            init: InitModel::Qat8,
        }
    }
}

/// Analytical accuracy surrogate.
#[derive(Debug, Clone)]
pub struct ProxyAccuracy {
    pub params: ProxyParams,
    /// Per-layer sensitivities, derived from the layer table.
    sensitivities: Vec<f64>,
}

impl ProxyAccuracy {
    pub fn new(layers: &[ConvLayer], params: ProxyParams) -> Self {
        ProxyAccuracy {
            params,
            sensitivities: layer_sensitivities(layers),
        }
    }

    /// Quantization noise power of a b-bit per-tensor quantizer,
    /// normalized to 1.0 at 2 bits: 4^(2-b).
    fn eps(bits: u8) -> f64 {
        4f64.powi(2 - bits.min(16) as i32)
    }

    /// Fraction of quantization damage *not* recovered by QAT.
    fn residual(&self) -> f64 {
        let e = self.params.epochs as f64;
        let init_boost = match self.params.init {
            InitModel::Fp32 => 1.0,
            InitModel::Qat8 => 0.55, // QAT-8 checkpoint recovers more
        };
        // more epochs -> more recovery, saturating
        init_boost * (0.25 + 0.75 / (1.0 + 0.35 * e))
    }

    /// Total residual penalty of a genome (the quantity calibration
    /// scales).
    pub fn penalty(&self, qc: &QuantConfig) -> f64 {
        assert_eq!(qc.len(), self.sensitivities.len());
        let ws = self.params.weight_share;
        let mut p = 0.0;
        for (i, s) in self.sensitivities.iter().enumerate() {
            let lq = qc.layer(i);
            p += s * (ws * Self::eps(lq.qw) + (1.0 - ws) * Self::eps(lq.qa));
        }
        p * self.residual() * self.params.scale
    }
}

impl AccuracyModel for ProxyAccuracy {
    fn accuracy(&mut self, qc: &QuantConfig) -> f64 {
        let p = self.penalty(qc);
        let acc = self.params.chance
            + (self.params.base_accuracy - self.params.chance) * (-p).exp();
        // deterministic per-genome jitter (~training noise, +-0.25%)
        let mut h: u64 = 0x9E3779B97F4A7C15;
        for &(a, w) in &qc.layers {
            h = h.wrapping_mul(0x100000001b3) ^ ((a as u64) << 8 | w as u64);
        }
        let jitter = ((h >> 11) as f64 / (1u64 << 53) as f64 - 0.5) * 0.005;
        (acc + jitter).clamp(self.params.chance, 1.0)
    }

    fn name(&self) -> &'static str {
        "proxy"
    }
}

/// Per-layer sensitivity heuristic: stem and classifier are brittle,
/// depthwise layers are brittle (few parameters, no redundancy),
/// big pointwise layers are robust. Normalized to sum to 1.
pub fn layer_sensitivities(layers: &[ConvLayer]) -> Vec<f64> {
    let n = layers.len();
    let mut s: Vec<f64> = layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let params = l.tensor_elements(Tensor::Weights) as f64;
            // fewer parameters -> less redundancy -> more sensitive
            let size_term = 1.0 / params.powf(0.35);
            let kind_term = match l.kind {
                LayerKind::Depthwise => 2.2,
                LayerKind::Standard => 1.0,
            };
            let pos_term = if i == 0 || i == n - 1 { 2.5 } else { 1.0 };
            size_term * kind_term * pos_term
        })
        .collect();
    let total: f64 = s.iter().sum();
    for v in &mut s {
        *v /= total;
    }
    s
}

/// Fit the proxy's global `scale` so its predictions match measured
/// (genome, accuracy) pairs in a least-squares sense (1-D golden-section
/// search; the remaining constants keep their structural defaults).
pub fn calibrate(
    proxy: &mut ProxyAccuracy,
    measurements: &[(QuantConfig, f64)],
) -> f64 {
    let loss = |scale: f64, proxy: &ProxyAccuracy| -> f64 {
        let mut p = proxy.clone();
        p.params.scale = scale;
        measurements
            .iter()
            .map(|(qc, measured)| {
                let pred = p.clone().accuracy(qc);
                (pred - measured).powi(2)
            })
            .sum()
    };
    let (mut lo, mut hi) = (0.01f64, 50.0f64);
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    for _ in 0..60 {
        let a = hi - phi * (hi - lo);
        let b = lo + phi * (hi - lo);
        if loss(a, proxy) < loss(b, proxy) {
            hi = b;
        } else {
            lo = a;
        }
    }
    let best = (lo + hi) / 2.0;
    proxy.params.scale = best;
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::mobilenet_v1;

    fn proxy() -> ProxyAccuracy {
        ProxyAccuracy::new(&mobilenet_v1(), ProxyParams::default())
    }

    #[test]
    fn monotone_in_bits() {
        let mut p = proxy();
        let accs: Vec<f64> = (2..=8)
            .map(|q| p.accuracy(&QuantConfig::uniform(28, q)))
            .collect();
        for w in accs.windows(2) {
            assert!(w[1] >= w[0] - 0.003, "not monotone: {accs:?}");
        }
        // 8-bit close to base, 2-bit heavily degraded
        assert!(accs[6] > 0.74, "8-bit too low: {}", accs[6]);
        assert!(accs[0] < 0.55, "2-bit too high: {}", accs[0]);
    }

    #[test]
    fn qat8_init_beats_fp32_init() {
        let layers = mobilenet_v1();
        let mut fp32 = ProxyAccuracy::new(
            &layers,
            ProxyParams {
                init: InitModel::Fp32,
                epochs: 10,
                ..ProxyParams::default()
            },
        );
        let mut qat8 = ProxyAccuracy::new(
            &layers,
            ProxyParams {
                init: InitModel::Qat8,
                epochs: 5,
                ..ProxyParams::default()
            },
        );
        // paper Fig 3a: QAT-8 with e=5 beats FP32 with e=10
        for q in [3u8, 4, 5, 6] {
            let g = QuantConfig::uniform(28, q);
            assert!(
                qat8.accuracy(&g) > fp32.accuracy(&g),
                "q={q}"
            );
        }
    }

    #[test]
    fn more_epochs_help() {
        let layers = mobilenet_v1();
        let acc = |e: u32, q: u8| {
            ProxyAccuracy::new(
                &layers,
                ProxyParams {
                    epochs: e,
                    ..ProxyParams::default()
                },
            )
            .accuracy(&QuantConfig::uniform(28, q))
        };
        // paper Fig 3c: e=20 beats e=10 at the same bit-width
        assert!(acc(20, 4) > acc(10, 4));
        assert!(acc(10, 4) > acc(2, 4));
    }

    #[test]
    fn depthwise_and_edges_more_sensitive() {
        let layers = mobilenet_v1();
        let s = layer_sensitivities(&layers);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // layer 1 (dw1) more sensitive than layer 2 (pw1)
        assert!(s[1] > s[2]);
        // stem more sensitive than a mid pointwise
        assert!(s[0] > s[4]);
        // classifier elevated vs neighbor
        assert!(s[27] > s[26] * 0.5);
    }

    #[test]
    fn mixed_precision_beats_uniform_at_same_cost() {
        // spend bits where sensitivity is high: uniform 4 vs mixed with
        // 8-bit dw/stem layers and 3-bit fat pointwise layers
        let layers = mobilenet_v1();
        let mut p = proxy();
        let uniform = QuantConfig::uniform(28, 4);
        let mut mixed = QuantConfig::uniform(28, 4);
        let s = layer_sensitivities(&layers);
        let mut idx: Vec<usize> = (0..28).collect();
        idx.sort_by(|&a, &b| s[b].partial_cmp(&s[a]).unwrap());
        for &i in idx.iter().take(8) {
            mixed.layers[i] = (8, 8); // protect sensitive layers
        }
        for &i in idx.iter().rev().take(8) {
            mixed.layers[i] = (3, 3); // squeeze robust layers
        }
        assert!(p.accuracy(&mixed) > p.accuracy(&uniform));
    }

    #[test]
    fn calibration_recovers_scale() {
        let layers = mobilenet_v1();
        // generate "measurements" from a proxy with scale 3.0
        let mut truth = ProxyAccuracy::new(
            &layers,
            ProxyParams {
                scale: 3.0,
                ..ProxyParams::default()
            },
        );
        let meas: Vec<(QuantConfig, f64)> = (2..=8)
            .map(|q| {
                let g = QuantConfig::uniform(28, q);
                let a = truth.accuracy(&g);
                (g, a)
            })
            .collect();
        let mut fit = ProxyAccuracy::new(&layers, ProxyParams::default());
        let s = calibrate(&mut fit, &meas);
        assert!((s - 3.0).abs() < 0.15, "fitted scale {s}");
    }

    #[test]
    fn accuracy_bounded() {
        let mut p = proxy();
        for q in 2..=8 {
            let a = p.accuracy(&QuantConfig::uniform(28, q));
            assert!((0.01..=1.0).contains(&a));
        }
    }
}
