//! The execution engine: one scheduler for every fan-out in the system.
//!
//! The paper's method is a large pile of independent
//! `(layer, q_a, q_w) → mapper search` evaluations driven by NSGA-II
//! (§III-C). Before this subsystem, three ad-hoc mechanisms fought each
//! other for cores: `parallel_map`'s scoped threads, per-network layer
//! threads in `eval`, and `MapperConfig::shards` inside a single
//! workload. The engine replaces all three with one work-stealing pool
//! that owns the process-wide core budget:
//!
//! * [`pool`] — the executor: per-worker deques + a global injector,
//!   plain `std` primitives, nested fan-outs, caller participation.
//! * [`driver`] — the typed job layer: an `EvalJob` is one
//!   layer×quant-config mapper search through the shared
//!   [`MapperCache`](crate::mapper::cache::MapperCache); generations
//!   deduplicate jobs across genomes and a job splits into the mapper's
//!   deterministic shard subtasks *only when idle workers exist*.
//!   Results are keyed by job id and merged in index order, so every
//!   output is bit-identical to single-threaded execution regardless of
//!   worker count or steal order.
//! * [`checkpoint`] — generation-boundary snapshots of the NSGA-II
//!   search state plus the mapper cache (negative entries keep their
//!   draw-budget tags), so long searches survive interruption and
//!   resume to bit-identical final fronts.
//! * [`proto`] / [`remote`] — the multi-host seam: shard seeds are
//!   position-independent, so `qmap worker` processes execute the same
//!   `ShardSpec`s over length-prefixed, checksummed JSON frames and
//!   the driver merges through the same deterministic reduction.
//!   Worker loss, duplicate delivery, and reordering are absorbed
//!   without perturbing a single bit of the result (see [`Backend`]).

pub mod checkpoint;
pub mod driver;
pub mod pool;
pub mod proto;
pub mod remote;

pub use checkpoint::Checkpointer;
pub use pool::{Pool, ScopedTask};
pub use remote::WorkerOptions;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Where a generation's mapper jobs execute. The seam the ROADMAP's
/// distributed search plugs into: `Local` keeps everything on this
/// process's work-stealing pool; `Distributed` additionally fans
/// cache-miss jobs out to remote `qmap worker` processes, with the
/// local pool racing the same queue (and absorbing anything a lost
/// worker leaves behind). Results are bit-identical either way — see
/// [`remote::eval_jobs`].
#[derive(Debug, Clone)]
pub enum Backend {
    Local,
    Distributed {
        /// `host:port` of each `qmap worker --listen` process.
        workers: Vec<String>,
    },
}

/// The engine: a work-stealing [`Pool`] plus job-level accounting and
/// the execution [`Backend`]. Create one per process (or per
/// experiment) with the global core budget; every fan-out — NSGA-II
/// generations, bench harnesses, network characterizations — goes
/// through it.
pub struct Engine {
    pool: Pool,
    backend: Backend,
    jobs: AtomicU64,
    splits: AtomicU64,
    remote_jobs: AtomicU64,
    requeued_specs: AtomicU64,
    lost_workers: AtomicU64,
}

/// A point-in-time snapshot of the engine's counters.
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    /// Total concurrency budget (workers + the submitting thread).
    pub workers: usize,
    /// `EvalJob`s dispatched (one per unique layer×quant workload).
    pub jobs: u64,
    /// Jobs that split into shard subtasks because idle workers existed.
    pub splits: u64,
    /// Pool tasks executed (jobs + shard subtasks + helper drains).
    pub tasks: u64,
    /// Tasks taken from another worker's deque.
    pub steals: u64,
    /// Workers parked at the moment of the snapshot.
    pub idle_now: usize,
    /// Jobs whose batch completed on a remote worker.
    pub remote_jobs: u64,
    /// Shard specs a lost worker owed that were re-run locally.
    pub requeued_specs: u64,
    /// Remote workers that became unreachable or violated the protocol.
    pub lost_workers: u64,
}

impl Engine {
    /// An engine with a concurrency budget of `budget` threads
    /// (`0` = all available cores). `Engine::new(1)` executes
    /// everything inline — the serial baseline every parallel run is
    /// bit-identical to.
    pub fn new(budget: usize) -> Engine {
        Engine::with_backend(budget, Backend::Local)
    }

    /// An engine whose generations additionally fan out to remote
    /// `qmap worker` processes. The local pool still runs with the
    /// given budget — remote workers add capacity, they never replace
    /// the local one.
    pub fn distributed(budget: usize, workers: Vec<String>) -> Engine {
        if workers.is_empty() {
            return Engine::new(budget);
        }
        Engine::with_backend(budget, Backend::Distributed { workers })
    }

    pub fn with_backend(budget: usize, backend: Backend) -> Engine {
        Engine {
            pool: Pool::new(budget),
            backend,
            jobs: AtomicU64::new(0),
            splits: AtomicU64::new(0),
            remote_jobs: AtomicU64::new(0),
            requeued_specs: AtomicU64::new(0),
            lost_workers: AtomicU64::new(0),
        }
    }

    pub fn backend(&self) -> &Backend {
        &self.backend
    }

    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// The engine's concurrency budget.
    pub fn workers(&self) -> usize {
        self.pool.budget()
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            workers: self.pool.budget(),
            jobs: self.jobs.load(Ordering::Relaxed),
            splits: self.splits.load(Ordering::Relaxed),
            tasks: self.pool.tasks_executed(),
            steals: self.pool.steals(),
            idle_now: self.pool.idle_workers(),
            remote_jobs: self.remote_jobs.load(Ordering::Relaxed),
            requeued_specs: self.requeued_specs.load(Ordering::Relaxed),
            lost_workers: self.lost_workers.load(Ordering::Relaxed),
        }
    }

    pub(crate) fn note_jobs(&self, n: u64) {
        self.jobs.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn note_split(&self) {
        self.splits.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_remote_job(&self) {
        self.remote_jobs.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_requeued(&self, n: u64) {
        self.requeued_specs.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn note_lost_worker(&self) {
        self.lost_workers.fetch_add(1, Ordering::Relaxed);
    }

    /// Order-preserving parallel map over a slice: the engine's
    /// replacement for the retired `coordinator::parallel_map`. Results
    /// land in slots keyed by item index, so the output order (and every
    /// value in it) is independent of worker count and steal order.
    pub fn map<T, R>(&self, items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        {
            let f = &f;
            let slots = &slots;
            let mut tasks: Vec<ScopedTask> = Vec::with_capacity(n);
            for (i, item) in items.iter().enumerate() {
                tasks.push(Box::new(move || {
                    let r = f(item);
                    *slots[i].lock().unwrap() = Some(r);
                }));
            }
            self.pool.run_scoped(tasks);
        }
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("engine task completed"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_for_any_worker_count() {
        let xs: Vec<usize> = (0..100).collect();
        let expect: Vec<usize> = xs.iter().map(|x| x * 2).collect();
        for budget in [1usize, 2, 4, 8] {
            let engine = Engine::new(budget);
            assert_eq!(engine.map(&xs, |&x| x * 2), expect, "budget={budget}");
        }
    }

    #[test]
    fn map_handles_empty_input() {
        let engine = Engine::new(2);
        let out: Vec<u32> = engine.map(&[] as &[u32], |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn stats_count_tasks() {
        let engine = Engine::new(3);
        let xs: Vec<u64> = (0..50).collect();
        let _ = engine.map(&xs, |&x| x + 1);
        let st = engine.stats();
        assert_eq!(st.workers, 3);
        assert!(st.tasks >= 50, "tasks={}", st.tasks);
    }
}
