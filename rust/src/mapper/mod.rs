//! The mapper: searches the mapspace of one workload for the best
//! mapping under a quantization setting.
//!
//! Mirrors the paper's Timeloop configuration: "random search with
//! termination condition set to finding 2000 valid mappings per
//! workload", the best mapping selected by minimum EDP. A per-workload
//! result cache (the paper's §III-A caching mechanism) makes repeated
//! NSGA-II evaluations of similar genomes cheap.

pub mod cache;
pub mod gamma;
pub mod guide;
pub mod store;

pub use cache::WorkloadKey;

use crate::arch::Arch;
use crate::energy::{edp_lower_bound, estimate_into, BoundScratch, Estimate};
use crate::mapping::mapspace::MapSpace;
use crate::mapping::{LayerContext, LevelMapping, Mapping};
use crate::nest::{analyze_prefilled, NestAnalysis};
use crate::quant::LayerQuant;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{ConvLayer, Dim};

/// Mapper configuration.
#[derive(Debug, Clone, Copy)]
pub struct MapperConfig {
    /// Stop after this many *valid* mappings have been evaluated
    /// (paper: 2000).
    pub valid_target: u64,
    /// Hard cap on candidate draws (valid or not), to bound pathological
    /// workloads where validity is rare.
    pub max_draws: u64,
    /// RNG seed (combined with a workload hash for determinism).
    pub seed: u64,
    /// Parallel search shards for one workload (0 = one per available
    /// core). Targets and draw budgets split across shards; each shard
    /// derives its own seed from (seed, workload hash, shard index), so
    /// results are deterministic for a fixed (seed, shards) pair.
    pub shards: usize,
}

impl Default for MapperConfig {
    fn default() -> Self {
        MapperConfig {
            valid_target: 2000,
            max_draws: 400_000,
            seed: 0x51AB5EED,
            shards: 1,
        }
    }
}

/// Candidates drawn per block by the staged batch evaluator in
/// [`run_shard`]. Large enough to amortize the RNG/permutation setup
/// and keep the rejection cascade's branch behavior predictable, small
/// enough that a block of scratch mappings stays cache-resident.
const EVAL_BLOCK: usize = 64;

/// Reusable per-thread scratch for the allocation-free hot path: a block
/// of candidate `Mapping`s, the factorization slot buffer, the
/// cumulative tile-extent buffer, the tile-footprint slab shared between
/// the checker and the analyzer, and the nest/estimate output slots.
/// Build once per (thread, workload) and reuse across candidate draws —
/// the steady-state loop performs zero heap allocations per draw.
pub struct EvalContext {
    pub mapping: Mapping,
    pub fbuf: Vec<u64>,
    pub ext: Vec<[u64; 7]>,
    pub nest: NestAnalysis,
    pub est: Estimate,
    /// Batched-draw scratch: `EVAL_BLOCK` candidate mappings filled per
    /// block by [`run_shard`]'s draw stage.
    pub batch: Vec<Mapping>,
    /// Per-candidate verdict of the spatial pre-check stage.
    pub live: Vec<bool>,
    /// `num_levels * 3` tile-footprint slab: filled by
    /// [`LayerContext::check_tiles_into`], consumed by
    /// [`crate::nest::analyze_prefilled`].
    pub elems: Vec<u64>,
    /// Scratch for the admissible-bound stage
    /// ([`crate::energy::edp_lower_bound`]).
    pub bound: BoundScratch,
}

impl EvalContext {
    pub fn for_arch(arch: &Arch) -> Self {
        let space = MapSpace::of(arch);
        Self::with_dims(arch.levels.len(), space.slots())
    }

    pub fn with_dims(num_levels: usize, slots: usize) -> Self {
        EvalContext {
            mapping: Mapping::unit(num_levels),
            fbuf: vec![1; slots],
            ext: Vec::with_capacity(num_levels),
            nest: NestAnalysis::empty(),
            est: Estimate::empty(),
            batch: (0..EVAL_BLOCK).map(|_| Mapping::unit(num_levels)).collect(),
            live: vec![false; EVAL_BLOCK],
            elems: vec![0; num_levels * 3],
            bound: BoundScratch::new(),
        }
    }
}

/// Outcome of a mapper search on one workload.
#[derive(Debug, Clone, PartialEq)]
pub struct MapperResult {
    /// Best (minimum-EDP) estimate found; `None` if no valid mapping.
    pub best: Option<Estimate>,
    /// The mapping achieving `best`.
    pub best_mapping: Option<Mapping>,
    /// Number of valid mappings encountered.
    pub valid: u64,
    /// Number of candidates drawn.
    pub draws: u64,
}

/// One shard's slice of a search: its derived seed and its share of the
/// valid-mapping target and draw budget. The full decomposition of a
/// workload search is [`shard_plan`]; it is a pure function of the
/// `MapperConfig` and the workload, never of how the shards end up
/// being executed — which is what lets `engine::driver` run the same
/// shards on a work-stealing pool and still merge to bit-identical
/// results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub seed: u64,
    pub valid_target: u64,
    pub max_draws: u64,
}

impl ShardSpec {
    /// Wire form. Budgets and seeds are `u64`s that can exceed 2^53
    /// (e.g. `valid_target: u64::MAX` for draw-bounded searches), so
    /// every field travels as a hex string, never a JSON number.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::hex_u64(self.seed)),
            ("valid_target", Json::hex_u64(self.valid_target)),
            ("max_draws", Json::hex_u64(self.max_draws)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ShardSpec, String> {
        Ok(ShardSpec {
            seed: v.get("seed").as_hex_u64("spec seed")?,
            valid_target: v.get("valid_target").as_hex_u64("spec valid_target")?,
            max_draws: v.get("max_draws").as_hex_u64("spec max_draws")?,
        })
    }
}

/// Per-shard search outcome. Opaque outside the mapper: produced by
/// [`run_shard`], consumed (in shard-index order) by [`merge_shards`],
/// and shipped between hosts via [`ShardOutcome::to_json`] — the wire
/// form is bit-exact (every f64 travels as its IEEE-754 bits), so a
/// remotely executed shard merges identically to a local one.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardOutcome {
    /// (EDP, estimate, mapping) of the shard's winner.
    best: Option<(f64, Estimate, Mapping)>,
    valid: u64,
    draws: u64,
}

fn estimate_to_json(e: &Estimate) -> Json {
    Json::obj(vec![
        ("energy_pj", Json::hex_bits(e.energy_pj)),
        (
            "level_energy_pj",
            Json::Arr(e.level_energy_pj.iter().map(|&x| Json::hex_bits(x)).collect()),
        ),
        ("mac_energy_pj", Json::hex_bits(e.mac_energy_pj)),
        ("cycles", Json::hex_bits(e.cycles)),
        (
            "level_words",
            Json::Arr(e.level_words.iter().map(|&x| Json::hex_bits(x)).collect()),
        ),
        ("pes_used", Json::hex_u64(e.pes_used)),
    ])
}

fn hex_f64_arr(v: &Json, what: &str) -> Result<Vec<f64>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what}: not an array"))?
        .iter()
        .map(|x| x.as_f64_bits(what))
        .collect()
}

fn estimate_from_json(v: &Json) -> Result<Estimate, String> {
    Ok(Estimate {
        energy_pj: v.get("energy_pj").as_f64_bits("estimate energy_pj")?,
        level_energy_pj: hex_f64_arr(v.get("level_energy_pj"), "estimate level_energy_pj")?,
        mac_energy_pj: v.get("mac_energy_pj").as_f64_bits("estimate mac_energy_pj")?,
        cycles: v.get("cycles").as_f64_bits("estimate cycles")?,
        level_words: hex_f64_arr(v.get("level_words"), "estimate level_words")?,
        pes_used: v.get("pes_used").as_hex_u64("estimate pes_used")?,
    })
}

fn hex_u64_7(v: &Json, what: &str) -> Result<[u64; 7], String> {
    let arr = v.as_arr().ok_or_else(|| format!("{what}: not an array"))?;
    if arr.len() != 7 {
        return Err(format!("{what}: expected 7 entries, got {}", arr.len()));
    }
    let mut out = [0u64; 7];
    for (i, x) in arr.iter().enumerate() {
        out[i] = x.as_hex_u64(what)?;
    }
    Ok(out)
}

fn mapping_to_json(m: &Mapping) -> Json {
    let levels: Vec<Json> = m
        .levels
        .iter()
        .map(|lm| {
            Json::obj(vec![
                (
                    "temporal",
                    Json::Arr(lm.temporal.iter().map(|&x| Json::hex_u64(x)).collect()),
                ),
                (
                    "spatial",
                    Json::Arr(lm.spatial.iter().map(|&x| Json::hex_u64(x)).collect()),
                ),
                (
                    "perm",
                    Json::arr_usize(&lm.perm.iter().map(|d| d.index()).collect::<Vec<_>>()),
                ),
            ])
        })
        .collect();
    Json::obj(vec![("levels", Json::Arr(levels))])
}

fn mapping_from_json(v: &Json) -> Result<Mapping, String> {
    let mut levels = Vec::new();
    for lv in v.get("levels").as_arr().ok_or("mapping: missing levels")? {
        let perm_arr = lv.get("perm").as_arr().ok_or("mapping: missing perm")?;
        if perm_arr.len() != 7 {
            return Err(format!("mapping perm: expected 7 entries, got {}", perm_arr.len()));
        }
        let mut perm = [Dim::N; 7];
        for (i, x) in perm_arr.iter().enumerate() {
            let xf = x.as_f64().ok_or("mapping perm: not a number")?;
            // strict: a saturating cast would map -1 or 3.7 to a valid
            // index and silently corrupt the decoded mapping
            if !(xf.is_finite() && xf.fract() == 0.0 && (0.0..7.0).contains(&xf)) {
                return Err(format!("mapping perm: bad dim index {xf}"));
            }
            perm[i] = Dim::from_index(xf as usize);
        }
        levels.push(LevelMapping {
            temporal: hex_u64_7(lv.get("temporal"), "mapping temporal")?,
            spatial: hex_u64_7(lv.get("spatial"), "mapping spatial")?,
            perm,
        });
    }
    if levels.is_empty() {
        return Err("mapping: no levels".into());
    }
    Ok(Mapping { levels })
}

impl ShardOutcome {
    /// Bit-exact wire form: counters as hex `u64`s, the winning EDP,
    /// estimate, and mapping (if any) with every f64 as its raw bits.
    pub fn to_json(&self) -> Json {
        let best = match &self.best {
            None => Json::Null,
            Some((edp, est, m)) => Json::obj(vec![
                ("edp", Json::hex_bits(*edp)),
                ("est", estimate_to_json(est)),
                ("mapping", mapping_to_json(m)),
            ]),
        };
        Json::obj(vec![
            ("best", best),
            ("valid", Json::hex_u64(self.valid)),
            ("draws", Json::hex_u64(self.draws)),
        ])
    }

    /// Decode a wire-form outcome. Total: malformed input is an `Err`,
    /// never a panic — this is parsed from network bytes.
    pub fn from_json(v: &Json) -> Result<ShardOutcome, String> {
        let best = match v.get("best") {
            Json::Null => None,
            b => Some((
                b.get("edp").as_f64_bits("outcome edp")?,
                estimate_from_json(b.get("est"))?,
                mapping_from_json(b.get("mapping"))?,
            )),
        };
        Ok(ShardOutcome {
            best,
            valid: v.get("valid").as_hex_u64("outcome valid")?,
            draws: v.get("draws").as_hex_u64("outcome draws")?,
        })
    }

    /// Valid mappings this shard found (summary accessor for logs/tests).
    pub fn valid(&self) -> u64 {
        self.valid
    }

    /// Candidates this shard drew.
    pub fn draws(&self) -> u64 {
        self.draws
    }

    /// The shard winner's EDP, if any mapping was valid.
    pub fn best_edp(&self) -> Option<f64> {
        self.best.as_ref().map(|(edp, _, _)| *edp)
    }
}

/// The deterministic decomposition of one workload search into shards:
/// `effective_shards(cfg)` entries, each with a seed derived from
/// `(base_seed, shard index)` and an even split of the valid-mapping
/// target and draw budget (remainders to the lowest indices). One shard
/// reproduces the single-threaded candidate stream exactly.
///
/// Implemented as [`shard_plan_weighted`] under uniform weights, which
/// [`guide::apportion`] reduces to exactly the historical
/// `total / n + (i < total % n)` split — the plan (and therefore every
/// downstream result) is bit-identical to what it always was.
pub fn shard_plan(cfg: &MapperConfig, base_seed: u64) -> Vec<ShardSpec> {
    let n = effective_shards(cfg);
    shard_plan_weighted(cfg, base_seed, &vec![1u64; n])
}

/// [`shard_plan`] with per-shard budget weights: shard `i` receives a
/// share of the valid-mapping target and draw budget proportional to
/// `weights[i]`, rounded by largest remainder so both columns still sum
/// *exactly* to `cfg.valid_target` / `cfg.max_draws`. Seeds are
/// unchanged — weighting reapportions budgets, never the candidate
/// streams' identities. `weights.len()` must equal
/// [`effective_shards`]`(cfg)`; all-zero weights fall back to the
/// uniform split.
///
/// Note the determinism contract: result-bearing searches always use
/// the uniform [`shard_plan`] (guided budgeting would change which
/// candidates exist). This entry point exists for opt-in
/// experimentation and for the apportionment property tests.
pub fn shard_plan_weighted(cfg: &MapperConfig, base_seed: u64, weights: &[u64]) -> Vec<ShardSpec> {
    let n = effective_shards(cfg);
    assert_eq!(weights.len(), n, "one weight per effective shard");
    let targets = guide::apportion(cfg.valid_target, weights);
    let draws = guide::apportion(cfg.max_draws, weights);
    (0..n as u64)
        .map(|i| ShardSpec {
            seed: base_seed ^ i.wrapping_mul(0x9E3779B97F4A7C15),
            valid_target: targets[i as usize],
            max_draws: draws[i as usize],
        })
        .collect()
}

/// One shard of the random search, run as a staged batch evaluator:
///
/// 1. **Draw** a block of up to [`EVAL_BLOCK`] candidates back-to-back
///    (amortizing the RNG/permutation setup of `random_mapping_into`);
/// 2. **Spatial pre-check** the whole block with
///    [`LayerContext::check_spatial`] — pure integer tests that kill the
///    majority of draws without touching a tile footprint;
/// 3. **Full check + price** the survivors in draw order:
///    [`LayerContext::check_tiles_into`] fills the extents once and
///    records every kept tile footprint, which
///    [`crate::nest::analyze_prefilled`] + `estimate_into` then reuse —
///    no footprint is computed twice for a valid candidate.
///
/// Bit-identical to the one-at-a-time loop it replaced
/// (`tests/hotpath_equivalence.rs` asserts batched == scalar == naive):
/// candidates are consumed in draw order from the same shard-local RNG
/// stream, the cascade accepts iff `check` accepts, the pricing
/// arithmetic is unchanged, and candidates drawn past the
/// valid-target/draw-budget termination point are discarded along with
/// the RNG — never counted, never allowed to update the winner. Within
/// a shard the first strictly-lower EDP wins, so the result is
/// deterministic in the seed.
pub fn run_shard(space: &MapSpace, lctx: &LayerContext, spec: &ShardSpec) -> ShardOutcome {
    run_shard_observed(space, lctx, spec, &mut NoObserver)
}

/// The cascade stage an observer is being handed ([`StageObserver::timed`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// `random_mapping_into` over a block.
    Draw,
    /// `check_spatial` over a block, plus per-survivor `check_tiles_into`.
    Check,
    /// `edp_lower_bound` for an accepted candidate with a reigning
    /// winner (the admissible-bound pruning stage).
    Bound,
    /// `analyze_prefilled` + `estimate_into` for an accepted candidate.
    Price,
}

/// Per-stage observation hooks for [`run_shard`]'s staged cascade.
/// The hooks only *see* stage outcomes after the fact — they cannot
/// alter draws, checks, or pricing, so an observed shard is
/// bit-identical to an unobserved one by construction. All default
/// methods are no-ops: the plain [`run_shard`] monomorphizes over
/// [`NoObserver`] and compiles to the exact uninstrumented loop.
pub trait StageObserver {
    /// Run one cascade stage (optionally timing it — the default runs
    /// the stage untimed).
    #[inline(always)]
    fn timed<R>(&mut self, _stage: Stage, f: impl FnOnce() -> R) -> R {
        f()
    }
    #[inline(always)]
    fn spatial_reject(&mut self) {}
    #[inline(always)]
    fn tile_reject(&mut self) {}
    #[inline(always)]
    fn accept(&mut self) {}
    /// An accepted candidate whose EDP lower bound proved it cannot
    /// beat the reigning winner — counted toward `valid`, never priced.
    #[inline(always)]
    fn bound_prune(&mut self) {}
}

/// The no-op observer behind the plain [`run_shard`].
pub struct NoObserver;
impl StageObserver for NoObserver {}

/// Cascade stage counts for one shard: every draw lands in exactly one
/// of the three buckets, so `spatial_rejects + tile_rejects + valid`
/// equals the shard's draw count. Counting costs three predictable
/// integer increments per candidate and no timer reads — cheap enough
/// for the engine to leave on for every shard it executes.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ShardStats {
    /// Killed by the pure-integer spatial pre-check.
    pub spatial_rejects: u64,
    /// Survived the spatial stage, rejected by the tile/capacity check.
    pub tile_rejects: u64,
    /// Fully accepted (and counted toward the valid target).
    pub valid: u64,
    /// Subset of `valid` whose pricing was skipped because the
    /// admissible EDP lower bound already matched or exceeded the
    /// reigning winner. Sits *outside* the draw partition — a pruned
    /// candidate is still a valid one.
    pub bound_pruned: u64,
}

impl ShardStats {
    /// Total candidates observed (the partition property).
    pub fn draws(&self) -> u64 {
        self.spatial_rejects + self.tile_rejects + self.valid
    }

    pub fn merge(&mut self, other: &ShardStats) {
        self.spatial_rejects += other.spatial_rejects;
        self.tile_rejects += other.tile_rejects;
        self.valid += other.valid;
        self.bound_pruned += other.bound_pruned;
    }
}

impl StageObserver for ShardStats {
    #[inline(always)]
    fn spatial_reject(&mut self) {
        self.spatial_rejects += 1;
    }
    #[inline(always)]
    fn tile_reject(&mut self) {
        self.tile_rejects += 1;
    }
    #[inline(always)]
    fn accept(&mut self) {
        self.valid += 1;
    }
    #[inline(always)]
    fn bound_prune(&mut self) {
        self.bound_pruned += 1;
    }
}

/// [`ShardStats`] plus per-stage wall-clock — the bench-grade
/// instrumentation behind `perf_hotpath`'s stage-split rows (it
/// replaced the cumulative-prefix triple-run timing hack). Timer reads
/// happen per block for draw/spatial and per surviving candidate for
/// tile-check/pricing, so don't leave this variant on in the engine —
/// use [`run_shard_with_stats`] there.
#[derive(Debug, Default, Clone, Copy)]
pub struct ShardStageStats {
    pub stats: ShardStats,
    pub draw_ns: u64,
    pub check_ns: u64,
    pub bound_ns: u64,
    pub price_ns: u64,
}

impl ShardStageStats {
    /// Fraction of accepted candidates whose pricing the bound stage
    /// skipped (0 when nothing was accepted).
    pub fn bound_prune_rate(&self) -> f64 {
        if self.stats.valid == 0 {
            0.0
        } else {
            self.stats.bound_pruned as f64 / self.stats.valid as f64
        }
    }
}

impl StageObserver for ShardStageStats {
    #[inline(always)]
    fn timed<R>(&mut self, stage: Stage, f: impl FnOnce() -> R) -> R {
        let t0 = std::time::Instant::now();
        let r = f();
        let ns = t0.elapsed().as_nanos() as u64;
        match stage {
            Stage::Draw => self.draw_ns += ns,
            Stage::Check => self.check_ns += ns,
            Stage::Bound => self.bound_ns += ns,
            Stage::Price => self.price_ns += ns,
        }
        r
    }
    #[inline(always)]
    fn spatial_reject(&mut self) {
        self.stats.spatial_reject();
    }
    #[inline(always)]
    fn tile_reject(&mut self) {
        self.stats.tile_reject();
    }
    #[inline(always)]
    fn accept(&mut self) {
        self.stats.accept();
    }
    #[inline(always)]
    fn bound_prune(&mut self) {
        self.stats.bound_prune();
    }
}

/// [`run_shard`] with cascade stage counts on the side. The outcome is
/// bit-identical to [`run_shard`]'s — `ShardOutcome` itself is wire
/// format and must not grow fields, so the stats travel separately.
pub fn run_shard_with_stats(
    space: &MapSpace,
    lctx: &LayerContext,
    spec: &ShardSpec,
) -> (ShardOutcome, ShardStats) {
    let mut stats = ShardStats::default();
    let out = run_shard_observed(space, lctx, spec, &mut stats);
    (out, stats)
}

/// [`run_shard`] with stage counts *and* per-stage wall-clock.
pub fn run_shard_timed(
    space: &MapSpace,
    lctx: &LayerContext,
    spec: &ShardSpec,
) -> (ShardOutcome, ShardStageStats) {
    let mut stats = ShardStageStats::default();
    let out = run_shard_observed(space, lctx, spec, &mut stats);
    (out, stats)
}

fn run_shard_observed<O: StageObserver>(
    space: &MapSpace,
    lctx: &LayerContext,
    spec: &ShardSpec,
    o: &mut O,
) -> ShardOutcome {
    run_shard_cascade::<O, true>(space, lctx, spec, o)
}

/// [`run_shard`] with the admissible-bound stage compiled out — the
/// reference arm of the pruned==unpruned bit-identity oracle
/// (`tests/hotpath_equivalence.rs`, `benches/perf_hotpath.rs`). Not
/// used by the engine: pruning never changes the outcome, only the
/// work, so production always runs the pruned cascade.
pub fn run_shard_unpruned(space: &MapSpace, lctx: &LayerContext, spec: &ShardSpec) -> ShardOutcome {
    run_shard_cascade::<NoObserver, false>(space, lctx, spec, &mut NoObserver)
}

fn run_shard_cascade<O: StageObserver, const PRUNE: bool>(
    space: &MapSpace,
    lctx: &LayerContext,
    spec: &ShardSpec,
    o: &mut O,
) -> ShardOutcome {
    let (seed, valid_target, max_draws) = (spec.seed, spec.valid_target, spec.max_draws);
    let mut ctx = EvalContext::with_dims(lctx.num_levels, space.slots());
    let mut rng = Rng::new(seed);
    let mut best: Option<(f64, Estimate, Mapping)> = None;
    let mut valid = 0u64;
    let mut draws = 0u64;

    'blocks: while valid < valid_target && draws < max_draws {
        let block = (EVAL_BLOCK as u64).min(max_draws - draws) as usize;

        o.timed(Stage::Draw, || {
            for m in &mut ctx.batch[..block] {
                space.random_mapping_into(lctx, &mut rng, &mut ctx.fbuf, m);
            }
        });

        o.timed(Stage::Check, || {
            for i in 0..block {
                ctx.live[i] = lctx.check_spatial(&ctx.batch[i]).is_ok();
            }
        });

        for i in 0..block {
            draws += 1;
            if !ctx.live[i] {
                o.spatial_reject();
                continue;
            }
            let m = &ctx.batch[i];
            let tiles = o.timed(Stage::Check, || {
                lctx.check_tiles_into(m, &mut ctx.ext, &mut ctx.elems)
            });
            if tiles.is_err() {
                o.tile_reject();
                continue;
            }
            valid += 1;
            o.accept();
            // the admissible-bound stage: a candidate whose EDP lower
            // bound already meets or exceeds the reigning winner cannot
            // win the strict-< walk (bound <= exact ⇒ exact >= best ⇒
            // no update), so its full pricing is pure waste. A NaN
            // bound compares false and falls through to exact pricing —
            // never an incorrect prune. Only fires once a winner
            // exists and the workload's constants keep the bound
            // admissible (`bound_safe`).
            if PRUNE && lctx.bound_safe {
                if let Some((b, _, _)) = &best {
                    let bound = o.timed(Stage::Bound, || {
                        edp_lower_bound(lctx, m, &ctx.elems, &mut ctx.bound)
                    });
                    if bound >= *b {
                        o.bound_prune();
                        if valid >= valid_target {
                            break 'blocks;
                        }
                        continue;
                    }
                }
            }
            o.timed(Stage::Price, || {
                analyze_prefilled(lctx, m, &ctx.elems, &mut ctx.nest);
                estimate_into(lctx, &ctx.nest, &mut ctx.est);
            });
            let edp = ctx.est.edp();
            match &mut best {
                Some((b, be, bm)) => {
                    if edp < *b {
                        *b = edp;
                        be.copy_from(&ctx.est);
                        bm.copy_from(m);
                    }
                }
                None => best = Some((edp, ctx.est.clone(), m.clone())),
            }
            if valid >= valid_target {
                break 'blocks;
            }
        }
    }

    ShardOutcome { best, valid, draws }
}

/// Deterministic merge of shard outcomes: iterate in shard-index order,
/// keep the first strictly-minimum EDP (ties go to the lowest shard
/// index), and sum the counters. Order-independent of how the shards
/// were *executed*, so work-stealing (or remote) execution merges
/// identically to sequential execution.
///
/// Total on every input: an empty outcome set, or one where no shard
/// found a valid mapping, merges to the no-mapping result with summed
/// counters — no caller invariant required.
pub fn merge_shards(outcomes: Vec<ShardOutcome>) -> MapperResult {
    let mut valid = 0u64;
    let mut draws = 0u64;
    let mut best: Option<(f64, Estimate, Mapping)> = None;
    for r in outcomes {
        valid += r.valid;
        draws += r.draws;
        if let Some((edp, est, m)) = r.best {
            if best.as_ref().map_or(true, |(b, _, _)| edp < *b) {
                best = Some((edp, est, m));
            }
        }
    }
    match best {
        Some((_, est, m)) => MapperResult {
            best: Some(est),
            best_mapping: Some(m),
            valid,
            draws,
        },
        None => MapperResult {
            best: None,
            best_mapping: None,
            valid,
            draws,
        },
    }
}

/// Resolve the configured shard count (0 = auto) and cap it so no shard
/// is left without a share of the valid-mapping target *or* of the draw
/// budget: `shards > max_draws` used to hand some shards a zero-draw
/// budget (dead weight the merge then had to carry), so degenerate
/// configs now collapse to fewer shards instead. Always returns
/// `>= 1`, even for zero budgets.
pub fn effective_shards(cfg: &MapperConfig) -> usize {
    let s = if cfg.shards == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.shards
    };
    s.max(1)
        .min(cfg.valid_target.clamp(1, 1024) as usize)
        .min(cfg.max_draws.clamp(1, 1024) as usize)
}

/// Random-search the mapspace of `(layer, q)` on `arch`.
///
/// Bit-widths are canonicalized to their packing-equivalence class first
/// (see [`LayerQuant::canonical`]): the engine's capacity and energy
/// models depend on `q` only through the pack factor, so equivalent
/// settings must explore identical mapspaces (and share cache entries).
///
/// With `cfg.shards > 1` the valid-mapping target and draw budget split
/// across that many threads, each with a seed derived from
/// `(cfg.seed, workload, shard index)`, and the shard minima merge by
/// minimum EDP with ties resolved to the lowest shard index (within a
/// shard the strict `<` keeps the earliest winner) — deterministic for
/// a fixed (seed, shards) pair. `shards == 1` reproduces the
/// single-threaded candidate stream exactly.
pub fn search(arch: &Arch, layer: &ConvLayer, q: &LayerQuant, cfg: &MapperConfig) -> MapperResult {
    let q = &q.canonical(arch.word_bits, arch.bit_packing);
    let space = MapSpace::of(arch);
    let lctx = LayerContext::new(arch, layer, q);
    let specs = shard_plan(cfg, cfg.seed ^ workload_hash(layer, q));

    let outcomes: Vec<ShardOutcome> = if specs.len() <= 1 {
        specs.iter().map(|s| run_shard(&space, &lctx, s)).collect()
    } else {
        // standalone parallel path (scoped threads), bounded to the
        // machine: it used to spawn one thread per shard — up to 1024
        // on auto-sharded configs — so now at most
        // `available_parallelism` threads each walk a contiguous chunk
        // of the spec list in index order. Slots are keyed by shard
        // index, so the chunking cannot change the merge. Under the
        // engine the same specs run as work-stealing pool subtasks
        // instead — see `engine::driver::search_on_engine` — and merge
        // to the same result.
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(specs.len());
        let mut slots: Vec<Option<ShardOutcome>> = specs.iter().map(|_| None).collect();
        if threads <= 1 {
            for (spec, slot) in specs.iter().zip(slots.iter_mut()) {
                *slot = Some(run_shard(&space, &lctx, spec));
            }
        } else {
            let chunk = specs.len().div_ceil(threads);
            std::thread::scope(|sc| {
                for (spec_chunk, slot_chunk) in specs.chunks(chunk).zip(slots.chunks_mut(chunk)) {
                    let space = &space;
                    let lctx = &lctx;
                    sc.spawn(move || {
                        for (spec, slot) in spec_chunk.iter().zip(slot_chunk.iter_mut()) {
                            *slot = Some(run_shard(space, lctx, spec));
                        }
                    });
                }
            });
        }
        slots.into_iter().map(|r| r.expect("shard completed")).collect()
    };

    merge_shards(outcomes)
}

/// Stable 64-bit hash of a workload + quantization (cache key and seed
/// derivation). FNV-1a over the canonical fields, via the shared
/// `util::Fnv1a` (bit-identical to the previous inlined loop).
pub fn workload_hash(layer: &ConvLayer, q: &LayerQuant) -> u64 {
    let mut h = crate::util::Fnv1a::new();
    for &d in &layer.dims {
        h.write_u64(d);
    }
    h.write_u64(layer.stride.0);
    h.write_u64(layer.stride.1);
    h.write_u64(layer.kind as u64);
    h.write_u64(q.qa as u64);
    h.write_u64(q.qw as u64);
    h.write_u64(q.qo as u64);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{eyeriss, toy};
    use crate::workload::ConvLayer;

    #[test]
    fn finds_valid_mappings_on_toy() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let cfg = MapperConfig {
            valid_target: 200,
            max_draws: 100_000,
            seed: 1,
            shards: 1,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert!(r.valid >= 200);
        assert!(r.best.is_some());
        assert!(r.best.unwrap().edp() > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let cfg = MapperConfig {
            valid_target: 100,
            max_draws: 50_000,
            seed: 7,
            shards: 1,
        };
        let q = LayerQuant::uniform(4);
        let r1 = search(&a, &l, &q, &cfg);
        let r2 = search(&a, &l, &q, &cfg);
        assert_eq!(r1.best.map(|e| e.edp()), r2.best.map(|e| e.edp()));
        assert_eq!(r1.valid, r2.valid);
    }

    #[test]
    fn observed_shard_is_bit_identical_and_stats_partition_draws() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(4).canonical(a.word_bits, a.bit_packing);
        let space = MapSpace::of(&a);
        let lctx = LayerContext::new(&a, &l, &q);
        for spec in [
            ShardSpec { seed: 9, valid_target: 80, max_draws: 40_000 },
            // draw-bounded: the budget runs out mid-block
            ShardSpec { seed: 9, valid_target: u64::MAX, max_draws: 1000 },
            // degenerate: zero budget
            ShardSpec { seed: 9, valid_target: 10, max_draws: 0 },
        ] {
            let plain = run_shard(&space, &lctx, &spec);
            let (counted, stats) = run_shard_with_stats(&space, &lctx, &spec);
            let (timed, tstats) = run_shard_timed(&space, &lctx, &spec);
            // observation cannot move a single bit of the outcome
            assert_eq!(plain, counted, "{spec:?}");
            assert_eq!(plain, timed, "{spec:?}");
            // every draw lands in exactly one stage-outcome bucket
            assert_eq!(stats.draws(), plain.draws(), "{spec:?}");
            assert_eq!(stats.valid, plain.valid(), "{spec:?}");
            assert_eq!(tstats.stats, stats, "{spec:?}");
        }
    }

    #[test]
    fn sharded_search_is_deterministic() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(4);
        for shards in [2usize, 4] {
            let cfg = MapperConfig {
                valid_target: 120,
                max_draws: 60_000,
                seed: 7,
                shards,
            };
            let r1 = search(&a, &l, &q, &cfg);
            let r2 = search(&a, &l, &q, &cfg);
            assert_eq!(
                r1.best.as_ref().map(|e| e.edp().to_bits()),
                r2.best.as_ref().map(|e| e.edp().to_bits()),
                "shards={shards}"
            );
            assert_eq!(r1.valid, r2.valid);
            assert_eq!(r1.draws, r2.draws);
            assert!(r1.valid >= 120, "shards={shards} valid={}", r1.valid);
            assert_eq!(r1.best_mapping, r2.best_mapping);
        }
    }

    #[test]
    fn many_shards_use_bounded_threads_and_merge_identically() {
        // more shards than the machine has cores: the standalone
        // parallel path chunks them over bounded threads; the result
        // must equal a purely sequential run of the same shard plan
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(4).canonical(a.word_bits, a.bit_packing);
        let cfg = MapperConfig {
            valid_target: 96,
            max_draws: 96_000,
            seed: 9,
            shards: 96, // far above available_parallelism on any CI box
        };
        let got = search(&a, &l, &q, &cfg);
        let specs = shard_plan(&cfg, cfg.seed ^ workload_hash(&l, &q));
        assert_eq!(specs.len(), 96);
        let space = MapSpace::of(&a);
        let lctx = LayerContext::new(&a, &l, &q);
        let want = merge_shards(specs.iter().map(|s| run_shard(&space, &lctx, s)).collect());
        assert_eq!(got.valid, want.valid);
        assert_eq!(got.draws, want.draws);
        assert_eq!(
            got.best.as_ref().map(|e| e.edp().to_bits()),
            want.best.as_ref().map(|e| e.edp().to_bits())
        );
        assert_eq!(got.best_mapping, want.best_mapping);
    }

    #[test]
    fn sharded_targets_sum_to_config() {
        // draws split exactly: on a never-valid workload every shard
        // exhausts its share and the totals reassemble the budget
        let a = toy();
        let l = ConvLayer::conv("t", 97, 89, 1, 13, 1); // awkward primes
        let cfg = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 2_001, // deliberately not divisible by shards
            seed: 5,
            shards: 4,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert_eq!(r.draws, 2_001);
    }

    #[test]
    fn lower_bits_find_lower_edp_on_eyeriss() {
        // the synergy effect end-to-end through the mapper
        let a = eyeriss();
        let l = ConvLayer::dw("dw2", 32, 3, 112, 1);
        let cfg = MapperConfig {
            valid_target: 300,
            max_draws: 300_000,
            seed: 3,
            shards: 1,
        };
        let e16 = search(&a, &l, &LayerQuant::uniform(16), &cfg);
        let e4 = search(&a, &l, &LayerQuant::uniform(4), &cfg);
        let b16 = e16.best.expect("16b should map").edp();
        let b4 = e4.best.expect("4b should map").edp();
        assert!(b4 < b16, "edp4={b4} edp16={b16}");
    }

    #[test]
    fn hash_distinguishes_quant_and_shape() {
        let l1 = ConvLayer::conv("a", 4, 8, 3, 8, 1);
        let l2 = ConvLayer::conv("b", 8, 8, 3, 8, 1);
        let q8 = LayerQuant::uniform(8);
        let q4 = LayerQuant::uniform(4);
        assert_ne!(workload_hash(&l1, &q8), workload_hash(&l1, &q4));
        assert_ne!(workload_hash(&l1, &q8), workload_hash(&l2, &q8));
        // name does NOT affect the key: same shape+q hits the same cache
        let l1b = ConvLayer::conv("other_name", 4, 8, 3, 8, 1);
        assert_eq!(workload_hash(&l1, &q8), workload_hash(&l1b, &q8));
    }

    #[test]
    fn merge_shards_is_total_on_degenerate_inputs() {
        // empty outcome set: the no-mapping result, not a panic
        let r = merge_shards(Vec::new());
        assert!(r.best.is_none() && r.best_mapping.is_none());
        assert_eq!((r.valid, r.draws), (0, 0));
        // all-empty outcomes (no shard found a mapping): counters sum.
        // A zero-capacity weight scratchpad makes every mapping invalid,
        // so emptiness is guaranteed, not seed-dependent.
        let mut a = toy();
        a.levels[0].capacity = crate::arch::Capacity::PerTensor([0, 64, 64]);
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let space = MapSpace::of(&a);
        let q = LayerQuant::uniform(8);
        let lctx = LayerContext::new(&a, &l, &q);
        let outcomes: Vec<ShardOutcome> = (0..3)
            .map(|i| {
                run_shard(
                    &space,
                    &lctx,
                    &ShardSpec {
                        seed: i,
                        valid_target: u64::MAX,
                        max_draws: 10,
                    },
                )
            })
            .collect();
        assert!(outcomes.iter().all(|o| o.best_edp().is_none()));
        let r = merge_shards(outcomes);
        assert!(r.best.is_none());
        assert_eq!(r.draws, 30);
    }

    #[test]
    fn shard_plan_is_total_when_shards_exceed_budgets() {
        // more shards than draws: collapse instead of zero-budget shards
        let cfg = MapperConfig {
            valid_target: 1_000,
            max_draws: 3,
            seed: 1,
            shards: 16,
        };
        let specs = shard_plan(&cfg, 99);
        assert_eq!(specs.len(), 3);
        assert!(specs.iter().all(|s| s.max_draws >= 1));
        assert_eq!(specs.iter().map(|s| s.max_draws).sum::<u64>(), 3);
        // zero draw budget: one empty shard, still a valid plan
        let zero = MapperConfig {
            valid_target: 100,
            max_draws: 0,
            seed: 1,
            shards: 8,
        };
        let specs = shard_plan(&zero, 99);
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].max_draws, 0);
        // zero valid target likewise
        let novalid = MapperConfig {
            valid_target: 0,
            max_draws: 100,
            seed: 1,
            shards: 8,
        };
        assert_eq!(shard_plan(&novalid, 99).len(), 1);
        // and the full search on such configs terminates with no result
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let r = search(&a, &l, &LayerQuant::uniform(8), &zero);
        assert!(r.best.is_none());
        assert_eq!(r.draws, 0);
    }

    #[test]
    fn shard_plan_weighted_sums_exactly_and_keeps_seeds() {
        // random shard counts x budgets x weight profiles: both budget
        // columns reassemble the config exactly, and the seeds are the
        // uniform plan's seeds — weighting reapportions budgets, never
        // candidate-stream identities
        let mut rng = Rng::new(0x5EED_0A11);
        for _ in 0..200 {
            let shards = 1 + (rng.next_u64() % 12) as usize;
            let cfg = MapperConfig {
                valid_target: rng.next_u64() % 5_000,
                max_draws: 1 + rng.next_u64() % 1_000_000,
                seed: rng.next_u64(),
                shards,
            };
            let n = effective_shards(&cfg);
            let weights: Vec<u64> = (0..n).map(|_| rng.next_u64() % 100).collect();
            let plan = shard_plan_weighted(&cfg, cfg.seed, &weights);
            let uniform = shard_plan(&cfg, cfg.seed);
            assert_eq!(plan.len(), n);
            assert_eq!(
                plan.iter().map(|s| s.valid_target).sum::<u64>(),
                cfg.valid_target
            );
            assert_eq!(plan.iter().map(|s| s.max_draws).sum::<u64>(), cfg.max_draws);
            for (w, u) in plan.iter().zip(&uniform) {
                assert_eq!(w.seed, u.seed, "weighting must not touch seeds");
            }
            // uniform non-zero weights reproduce the legacy plan exactly
            assert_eq!(shard_plan_weighted(&cfg, cfg.seed, &vec![3u64; n]), uniform);
        }
    }

    #[test]
    fn shard_spec_json_roundtrips_extreme_budgets() {
        let spec = ShardSpec {
            seed: u64::MAX,
            valid_target: u64::MAX, // > 2^53: must not travel as an f64
            max_draws: (1u64 << 53) + 1,
        };
        let back = ShardSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        let reparsed =
            ShardSpec::from_json(&crate::util::json::parse(&spec.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(reparsed, spec);
        assert!(ShardSpec::from_json(&Json::Null).is_err());
    }

    #[test]
    fn shard_outcome_json_roundtrips_bit_exactly() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(4);
        let space = MapSpace::of(&a);
        let lctx = LayerContext::new(&a, &l, &q);
        let spec = ShardSpec {
            seed: 7,
            valid_target: 50,
            max_draws: 50_000,
        };
        let out = run_shard(&space, &lctx, &spec);
        assert!(out.best_edp().is_some());
        // through the value model AND through actual bytes
        let text = out.to_json().to_string();
        let back = ShardOutcome::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, out);
        assert_eq!(
            back.best_edp().unwrap().to_bits(),
            out.best_edp().unwrap().to_bits()
        );
        // a no-mapping outcome round-trips too
        let empty = run_shard(
            &space,
            &lctx,
            &ShardSpec {
                seed: 7,
                valid_target: u64::MAX,
                max_draws: 0,
            },
        );
        assert!(empty.best_edp().is_none());
        let back = ShardOutcome::from_json(&empty.to_json()).unwrap();
        assert_eq!(back, empty);
        // malformed wire data is an error, never a panic
        assert!(ShardOutcome::from_json(&Json::Num(3.0)).is_err());
        assert!(
            ShardOutcome::from_json(&Json::obj(vec![("best", Json::Num(1.0))])).is_err()
        );
        // perm indices must be exact in-range integers: a saturating
        // cast would turn -1 or 3.7 into a "valid" dim and corrupt the
        // mapping silently
        let mut doc = out.to_json();
        if let Json::Obj(top) = &mut doc {
            let best = top.get_mut("best").unwrap();
            if let Json::Obj(b) = best {
                let mapping = b.get_mut("mapping").unwrap();
                if let Json::Obj(mm) = mapping {
                    if let Some(Json::Arr(levels)) = mm.get_mut("levels") {
                        if let Json::Obj(l0) = &mut levels[0] {
                            l0.insert(
                                "perm".into(),
                                Json::arr_f64(&[-1.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
                            );
                        }
                    }
                }
            }
        }
        assert!(ShardOutcome::from_json(&doc).is_err(), "negative perm index accepted");
    }

    #[test]
    fn impossible_workload_returns_none() {
        // single PE spad of 16 words can't hold even one weight at 16b if
        // we also forbid DRAM-resident loops? Actually DRAM-heavy always
        // works; make a level-0 mandatory overflow by using a huge R so
        // that any unit tile... unit tiles always fit. So instead: check
        // that max_draws bounds the search on a workload with rare
        // validity rather than hanging.
        let a = toy();
        let l = ConvLayer::conv("t", 97, 89, 1, 13, 1); // awkward primes
        let cfg = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 2_000,
            seed: 5,
            shards: 1,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert_eq!(r.draws, 2_000);
    }
}
