//! Comparison baselines from the paper's evaluation (Fig. 6, Table II):
//!
//! * **Uniform** — every layer quantized to the same `(q, q)`; the
//!   "SoA solutions that do not explore the quantization of individual
//!   layers" (Ristretto/Eyeriss-style).
//! * **Naïve** — a hardware-*unaware* automated mixed-precision search:
//!   the same NSGA-II engine, but its hardware objective is the naïve
//!   model size in bits instead of accelerator EDP (PACT-style). Its
//!   winners are then *re-evaluated* on the real accelerator model,
//!   which is exactly how the paper exposes the weak size<->EDP
//!   correlation of Fig. 1.

use crate::accuracy::AccuracyModel;
use crate::arch::Arch;
use crate::engine::{driver, Engine};
use crate::eval::NetworkEval;
use crate::mapper::cache::MapperCache;
use crate::mapper::MapperConfig;
use crate::nsga::{self, NsgaConfig};
use crate::objective::{Axis, ObjectiveSpec, ObjectiveVec};
use crate::quant::QuantConfig;
use crate::workload::ConvLayer;

/// One evaluated configuration produced by a strategy.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub genome: QuantConfig,
    pub accuracy: f64,
    pub hw: NetworkEval,
    pub strategy: &'static str,
}

/// Fan a batch of genomes through the engine and pair each mappable one
/// with its accuracy (accuracy calls stay in genome order — the proxy is
/// pure, but order-stability keeps any future stateful model
/// deterministic too).
fn price_genomes(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    genomes: Vec<QuantConfig>,
    acc: &mut dyn AccuracyModel,
    cache: &MapperCache,
    cfg: &MapperConfig,
    strategy: &'static str,
) -> Vec<Candidate> {
    let evals = driver::evaluate_genomes(engine, arch, layers, &genomes, cache, cfg);
    genomes
        .into_iter()
        .zip(evals)
        .filter_map(|(genome, hw)| {
            let hw = hw?;
            Some(Candidate {
                accuracy: acc.accuracy(&genome),
                genome,
                hw,
                strategy,
            })
        })
        .collect()
}

/// Uniform-quantization sweep: evaluate `(q, q)` for q in 2..=8 (and the
/// 16-bit reference), fanned out on the engine.
pub fn uniform_sweep(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    acc: &mut dyn AccuracyModel,
    cache: &MapperCache,
    cfg: &MapperConfig,
    include_16bit: bool,
) -> Vec<Candidate> {
    let mut qs: Vec<u8> = (crate::quant::QMIN..=crate::quant::QMAX).collect();
    if include_16bit {
        qs.push(16);
    }
    let genomes: Vec<QuantConfig> = qs
        .iter()
        .map(|&q| QuantConfig::uniform(layers.len(), q))
        .collect();
    price_genomes(engine, arch, layers, genomes, acc, cache, cfg, "uniform")
}

/// Naïve hardware-unaware search: NSGA-II over `model_size,error`,
/// winners re-priced on the actual accelerator afterwards (on the
/// engine — the search loop itself touches no hardware model, which is
/// the point: its `model_size` axis is computed from the genome alone).
pub fn naive_search(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    acc: &mut dyn AccuracyModel,
    cache: &MapperCache,
    map_cfg: &MapperConfig,
    nsga_cfg: &NsgaConfig,
) -> Vec<Candidate> {
    let spec = ObjectiveSpec::new(&[Axis::ModelSize, Axis::Error])
        .expect("naive spec is valid");
    // the search loop is hardware-free, but the winners' re-pricing
    // below fans out through the engine: stamp its wire identity with
    // naive's own spec
    engine.set_objectives(spec);
    let front = nsga::run(
        layers.len(),
        nsga_cfg,
        |genomes| {
            genomes
                .iter()
                .map(|g| {
                    // both axes are genome-derivable, so the vector is
                    // built directly (still stamped with the spec) —
                    // no accelerator model in the loop, by design
                    let err = 1.0 - acc.accuracy(g);
                    let size = g.model_size_bits(layers) as f64;
                    ObjectiveVec::new(&spec, vec![size, err])
                })
                .collect()
        },
        |_, _| {},
    );
    let genomes: Vec<QuantConfig> = front.into_iter().map(|ind| ind.genome).collect();
    price_genomes(engine, arch, layers, genomes, acc, cache, map_cfg, "naive")
}

/// The proposed method over an arbitrary [`ObjectiveSpec`]: NSGA-II
/// with the hardware axes priced on the target accelerator through
/// `engine::driver` — deduplicated layer×quant jobs on the
/// work-stealing pool — and results bit-identical to a single-threaded
/// run for any worker count, pipeline depth, or fleet.
#[allow(clippy::too_many_arguments)]
pub fn search_with_objectives(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    acc: &mut dyn AccuracyModel,
    cache: &MapperCache,
    map_cfg: &MapperConfig,
    nsga_cfg: &NsgaConfig,
    objectives: &ObjectiveSpec,
    mut on_generation: impl FnMut(usize, &[nsga::Individual]),
) -> Vec<Candidate> {
    // the engine's wire identity must carry THIS search's spec —
    // installing it here means no caller can desync the two (a batch
    // stamped with a stale spec would quietly share worker-cache
    // identities across incomparable searches)
    engine.set_objectives(*objectives);
    let front = nsga::run(
        layers.len(),
        nsga_cfg,
        |genomes| {
            let evals = driver::evaluate_genomes(engine, arch, layers, genomes, cache, map_cfg);
            genomes
                .iter()
                .zip(&evals)
                .map(|(g, e)| objectives.evaluate(e.as_ref(), acc.accuracy(g)))
                .collect()
        },
        &mut on_generation,
    );
    let genomes: Vec<QuantConfig> = front.into_iter().map(|ind| ind.genome).collect();
    price_genomes(engine, arch, layers, genomes, acc, cache, map_cfg, "proposed")
}

/// The paper's default two-objective formulation (`edp,error`) —
/// [`search_with_objectives`] under [`ObjectiveSpec::default`].
pub fn proposed_search(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    acc: &mut dyn AccuracyModel,
    cache: &MapperCache,
    map_cfg: &MapperConfig,
    nsga_cfg: &NsgaConfig,
    on_generation: impl FnMut(usize, &[nsga::Individual]),
) -> Vec<Candidate> {
    search_with_objectives(
        engine,
        arch,
        layers,
        acc,
        cache,
        map_cfg,
        nsga_cfg,
        &ObjectiveSpec::default(),
        on_generation,
    )
}

/// The paper's full three-objective formulation: NSGA-II
/// "simultaneously minimizes the weight memory size (reflecting the
/// accelerator's memory subsystems), inference energy, and CNN error" —
/// the named spec `memory_energy,edp,error`. [`proposed_search`] is the
/// two-objective projection used for the accuracy-vs-EDP figures; this
/// variant also presses on the memory axis and is what Table II's
/// memory-energy columns report.
pub fn proposed_search3(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    acc: &mut dyn AccuracyModel,
    cache: &MapperCache,
    map_cfg: &MapperConfig,
    nsga_cfg: &NsgaConfig,
) -> Vec<Candidate> {
    let spec = ObjectiveSpec::new(&[Axis::MemoryEnergy, Axis::Edp, Axis::Error])
        .expect("three-objective spec is valid");
    search_with_objectives(
        engine, arch, layers, acc, cache, map_cfg, nsga_cfg, &spec, |_, _| {},
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::{ProxyAccuracy, ProxyParams};
    use crate::arch::presets::toy;
    use crate::workload::ConvLayer;

    fn net() -> Vec<ConvLayer> {
        vec![
            ConvLayer::conv("c1", 3, 8, 3, 8, 1),
            ConvLayer::dw("d1", 8, 3, 8, 1),
            ConvLayer::pw("p1", 8, 16, 8),
            ConvLayer::fc("fc", 16, 10),
        ]
    }

    fn map_cfg() -> MapperConfig {
        MapperConfig {
            valid_target: 40,
            max_draws: 40_000,
            seed: 11,
            shards: 1,
        }
    }

    #[test]
    fn uniform_sweep_monotone_energy() {
        let a = toy();
        let layers = net();
        let engine = Engine::new(2);
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
        let cache = MapperCache::new();
        let cands = uniform_sweep(&engine, &a, &layers, &mut acc, &cache, &map_cfg(), true);
        assert_eq!(cands.len(), 8); // q = 2..8 + 16
        // memory energy decreases from 16b to 2b
        let e16 = cands.last().unwrap().hw.memory_energy_pj;
        let e2 = cands[0].hw.memory_energy_pj;
        assert!(e2 < e16);
        // accuracy increases with bits
        assert!(cands[6].accuracy > cands[0].accuracy);
    }

    #[test]
    fn naive_search_produces_front() {
        let a = toy();
        let layers = net();
        let engine = Engine::new(2);
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
        let cache = MapperCache::new();
        let nsga_cfg = NsgaConfig {
            population: 8,
            offspring: 4,
            generations: 5,
            seed: 2,
            ..NsgaConfig::default()
        };
        let cands = naive_search(&engine, &a, &layers, &mut acc, &cache, &map_cfg(), &nsga_cfg);
        assert!(!cands.is_empty());
        for c in &cands {
            assert_eq!(c.strategy, "naive");
            assert!(c.hw.edp > 0.0);
        }
    }

    #[test]
    fn proposed_beats_uniform_hypervolume_ish() {
        // With the mapper in the loop the proposed front should contain a
        // point that matches 8-bit-uniform accuracy at lower EDP.
        let a = toy();
        let layers = net();
        let engine = Engine::new(4);
        let cache = MapperCache::new();
        let nsga_cfg = NsgaConfig {
            population: 12,
            offspring: 8,
            generations: 8,
            seed: 3,
            ..NsgaConfig::default()
        };
        let mut acc1 = ProxyAccuracy::new(&layers, ProxyParams::default());
        let uni = uniform_sweep(&engine, &a, &layers, &mut acc1, &cache, &map_cfg(), false);
        let mut acc2 = ProxyAccuracy::new(&layers, ProxyParams::default());
        let prop = proposed_search(
            &engine,
            &a,
            &layers,
            &mut acc2,
            &cache,
            &map_cfg(),
            &nsga_cfg,
            |_, _| {},
        );
        let u8c = uni.iter().find(|c| c.genome.layers[0].0 == 8).unwrap();
        let better = prop
            .iter()
            .any(|c| c.accuracy >= u8c.accuracy - 0.01 && c.hw.edp < u8c.hw.edp);
        assert!(better, "no proposed point dominates uniform-8");
    }
}
