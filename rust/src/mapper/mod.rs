//! The mapper: searches the mapspace of one workload for the best
//! mapping under a quantization setting.
//!
//! Mirrors the paper's Timeloop configuration: "random search with
//! termination condition set to finding 2000 valid mappings per
//! workload", the best mapping selected by minimum EDP. A per-workload
//! result cache (the paper's §III-A caching mechanism) makes repeated
//! NSGA-II evaluations of similar genomes cheap.

pub mod cache;
pub mod gamma;

use crate::arch::Arch;
use crate::energy::{estimate, Estimate};
use crate::mapping::mapspace::MapSpace;
use crate::mapping::{check, Mapping};
use crate::nest::analyze;
use crate::quant::LayerQuant;
use crate::util::rng::Rng;
use crate::workload::ConvLayer;

/// Mapper configuration.
#[derive(Debug, Clone, Copy)]
pub struct MapperConfig {
    /// Stop after this many *valid* mappings have been evaluated
    /// (paper: 2000).
    pub valid_target: u64,
    /// Hard cap on candidate draws (valid or not), to bound pathological
    /// workloads where validity is rare.
    pub max_draws: u64,
    /// RNG seed (combined with a workload hash for determinism).
    pub seed: u64,
}

impl Default for MapperConfig {
    fn default() -> Self {
        MapperConfig {
            valid_target: 2000,
            max_draws: 400_000,
            seed: 0x51AB5EED,
        }
    }
}

/// Outcome of a mapper search on one workload.
#[derive(Debug, Clone)]
pub struct MapperResult {
    /// Best (minimum-EDP) estimate found; `None` if no valid mapping.
    pub best: Option<Estimate>,
    /// The mapping achieving `best`.
    pub best_mapping: Option<Mapping>,
    /// Number of valid mappings encountered.
    pub valid: u64,
    /// Number of candidates drawn.
    pub draws: u64,
}

/// Random-search the mapspace of `(layer, q)` on `arch`.
///
/// Bit-widths are canonicalized to their packing-equivalence class first
/// (see [`LayerQuant::canonical`]): the engine's capacity and energy
/// models depend on `q` only through the pack factor, so equivalent
/// settings must explore identical mapspaces (and share cache entries).
pub fn search(arch: &Arch, layer: &ConvLayer, q: &LayerQuant, cfg: &MapperConfig) -> MapperResult {
    let q = &q.canonical(arch.word_bits, arch.bit_packing);
    let space = MapSpace::of(arch);
    let mut rng = Rng::new(cfg.seed ^ workload_hash(layer, q));
    let mut best: Option<(f64, Estimate, Mapping)> = None;
    let mut valid = 0u64;
    let mut draws = 0u64;

    while valid < cfg.valid_target && draws < cfg.max_draws {
        draws += 1;
        let m = space.random_mapping(layer, &mut rng);
        if check(arch, layer, q, &m).is_err() {
            continue;
        }
        valid += 1;
        let nest = analyze(arch, layer, &m);
        let est = estimate(arch, layer, q, &nest);
        let edp = est.edp();
        if best.as_ref().map_or(true, |(b, _, _)| edp < *b) {
            best = Some((edp, est, m));
        }
    }

    match best {
        Some((_, est, m)) => MapperResult {
            best: Some(est),
            best_mapping: Some(m),
            valid,
            draws,
        },
        None => MapperResult {
            best: None,
            best_mapping: None,
            valid,
            draws,
        },
    }
}

/// Stable 64-bit hash of a workload + quantization (cache key and seed
/// derivation). FNV-1a over the canonical fields.
pub fn workload_hash(layer: &ConvLayer, q: &LayerQuant) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut feed = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for &d in &layer.dims {
        feed(d);
    }
    feed(layer.stride.0);
    feed(layer.stride.1);
    feed(layer.kind as u64);
    feed(q.qa as u64);
    feed(q.qw as u64);
    feed(q.qo as u64);
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{eyeriss, toy};
    use crate::workload::ConvLayer;

    #[test]
    fn finds_valid_mappings_on_toy() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let cfg = MapperConfig {
            valid_target: 200,
            max_draws: 100_000,
            seed: 1,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert!(r.valid >= 200);
        assert!(r.best.is_some());
        assert!(r.best.unwrap().edp() > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let cfg = MapperConfig {
            valid_target: 100,
            max_draws: 50_000,
            seed: 7,
        };
        let q = LayerQuant::uniform(4);
        let r1 = search(&a, &l, &q, &cfg);
        let r2 = search(&a, &l, &q, &cfg);
        assert_eq!(r1.best.map(|e| e.edp()), r2.best.map(|e| e.edp()));
        assert_eq!(r1.valid, r2.valid);
    }

    #[test]
    fn lower_bits_find_lower_edp_on_eyeriss() {
        // the synergy effect end-to-end through the mapper
        let a = eyeriss();
        let l = ConvLayer::dw("dw2", 32, 3, 112, 1);
        let cfg = MapperConfig {
            valid_target: 300,
            max_draws: 300_000,
            seed: 3,
        };
        let e16 = search(&a, &l, &LayerQuant::uniform(16), &cfg);
        let e4 = search(&a, &l, &LayerQuant::uniform(4), &cfg);
        let b16 = e16.best.expect("16b should map").edp();
        let b4 = e4.best.expect("4b should map").edp();
        assert!(b4 < b16, "edp4={b4} edp16={b16}");
    }

    #[test]
    fn hash_distinguishes_quant_and_shape() {
        let l1 = ConvLayer::conv("a", 4, 8, 3, 8, 1);
        let l2 = ConvLayer::conv("b", 8, 8, 3, 8, 1);
        let q8 = LayerQuant::uniform(8);
        let q4 = LayerQuant::uniform(4);
        assert_ne!(workload_hash(&l1, &q8), workload_hash(&l1, &q4));
        assert_ne!(workload_hash(&l1, &q8), workload_hash(&l2, &q8));
        // name does NOT affect the key: same shape+q hits the same cache
        let l1b = ConvLayer::conv("other_name", 4, 8, 3, 8, 1);
        assert_eq!(workload_hash(&l1, &q8), workload_hash(&l1b, &q8));
    }

    #[test]
    fn impossible_workload_returns_none() {
        // single PE spad of 16 words can't hold even one weight at 16b if
        // we also forbid DRAM-resident loops? Actually DRAM-heavy always
        // works; make a level-0 mandatory overflow by using a huge R so
        // that any unit tile... unit tiles always fit. So instead: check
        // that max_draws bounds the search on a workload with rare
        // validity rather than hanging.
        let a = toy();
        let l = ConvLayer::conv("t", 97, 89, 1, 13, 1); // awkward primes
        let cfg = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 2_000,
            seed: 5,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert_eq!(r.draws, 2_000);
    }
}
