//! Generation-boundary checkpointing for long searches, persisted as
//! an **append-only journal** so checkpoint cost is O(new cache
//! entries) per generation instead of O(cache).
//!
//! ## Journal format (one JSON frame per line)
//!
//! ```text
//! {"journal":1,"ident":{...}}          header: format version + search identity
//! {"insert":{...cache entry...}}       one frame per mapper-cache insert
//! {"mark":{"generation":g,"rng":"...","population":[...]}}
//! ```
//!
//! * The **header** carries the [`SearchIdent`]; a journal written
//!   under one configuration refuses to resume under another.
//! * **insert** frames are exactly the `entries` objects of the old
//!   cache dump — positive summaries, or negative records with their
//!   draw-budget tags. `MapperCache` queues each live insert
//!   ([`MapperCache::drain_journal`]), and a generation's save appends
//!   only those.
//! * A **mark** frame is one generation boundary: completed-generation
//!   count, the breeding RNG's raw state, and the parent population
//!   (objectives as hex-encoded IEEE-754 bits, so `INFINITY` and every
//!   mantissa bit round-trip). Each save ends with a mark and an
//!   `fsync`, so a mark on disk is durable.
//!
//! **Replay** (load) applies insert frames in order and resumes from
//! the *last complete* mark. A torn final line — the crash-mid-append
//! case — is discarded; any complete insert frames past the last mark
//! are kept, which is sound because cache entries are pure data: extra
//! entries can only save re-searching, never change a bit of the
//! result. After a torn load the appender stays unarmed, so the next
//! save rewrites the file whole instead of welding new frames onto the
//! partial tail. A malformed line anywhere *before* the final one is
//! corruption and fails the load.
//!
//! **Compaction**: when the journal has accumulated far more insert
//! frames than the cache has entries (duplicate keys from re-searched
//! stale negatives, long resumed histories), the whole file is
//! rewritten — header, one insert per current entry, one mark — via
//! tmp + rename, and appending resumes. The rewrite is the same code
//! path as the initial save.
//!
//! Checkpoints from before the journal (the single-document v2
//! snapshot) still load; the first save then migrates the file to the
//! journal format.
//!
//! **Relation to the persistent cache store** (`mapper::store`,
//! `--cache-dir`): the journal stays the bit-identity source of truth
//! for resuming a *particular* search — RNG state, population, and
//! every insert in order. The store is a strictly-additive
//! read-through/write-behind tier shared *across* searches and
//! processes: losing it costs only warm-start time, and entries a
//! probe promotes from it are journaled exactly like fresh inserts,
//! so a resumed run never depends on the store being present.

use crate::arch::Arch;
use crate::mapper::cache::MapperCache;
use crate::mapper::guide::GuideState;
use crate::mapper::MapperConfig;
use crate::nsga::{Individual, NsgaConfig, SearchState};
use crate::objective::{ObjectiveSpec, ObjectiveVec};
use crate::obs::{self, metrics};
use crate::quant::QuantConfig;
use crate::util::json::{parse, Json};
use crate::util::rng::Rng;
use std::io::Write as _;
use std::sync::Mutex;
use std::time::Instant;

/// Journal format version (the `journal` field of the header frame).
const JOURNAL_VERSION: f64 = 1.0;

/// The pre-journal single-document snapshot version this module still
/// loads (see PR 3's note: bumped to 2.0 when `effective_shards`
/// changed the shard plan of degenerate configs).
const LEGACY_VERSION: f64 = 2.0;

/// Compaction slack: the journal is rewritten when the insert frames
/// appended since the last full write exceed `2 * cache.len() +
/// slack`. The default keeps compaction rare (duplicate keys are the
/// only way appends outpace entries); tests shrink it to force the
/// path.
const DEFAULT_COMPACT_SLACK: usize = 1024;

/// Identity of the search a checkpoint belongs to. A checkpoint written
/// under one configuration and resumed under another (different
/// accelerator, network size, mapper budgets/seed, or NSGA-II breeding
/// parameters, or a different *objective space*) would silently corrupt
/// the search — stale objectives mixed with fresh ones, incomparable
/// objective vectors, a diverged RNG stream — so `load` rejects any
/// mismatch instead. `generations` is deliberately absent: extending a
/// finished search with more generations is a legitimate resume.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchIdent {
    pub arch: String,
    pub num_layers: usize,
    /// Canonical [`ObjectiveSpec`] string (`edp,error`, ...). A
    /// checkpoint written under one objective space must never resume
    /// under another: dominance over mixed-spec vectors is garbage.
    /// Checkpoints from before the objective subsystem have no such
    /// field; they load as the historical two-objective default.
    pub objectives: String,
    pub mapper_seed: u64,
    pub valid_target: u64,
    pub max_draws: u64,
    pub shards: usize,
    pub population: usize,
    pub offspring: usize,
    pub nsga_seed: u64,
    pub p_mut_bits: u64,
    pub p_mut_acc_bits: u64,
}

impl SearchIdent {
    pub fn new(
        arch: &Arch,
        num_layers: usize,
        objectives: &ObjectiveSpec,
        map_cfg: &MapperConfig,
        nsga_cfg: &NsgaConfig,
    ) -> SearchIdent {
        SearchIdent {
            arch: arch.name.clone(),
            num_layers,
            objectives: objectives.canonical(),
            mapper_seed: map_cfg.seed,
            valid_target: map_cfg.valid_target,
            max_draws: map_cfg.max_draws,
            shards: map_cfg.shards,
            population: nsga_cfg.population,
            offspring: nsga_cfg.offspring,
            nsga_seed: nsga_cfg.seed,
            p_mut_bits: nsga_cfg.p_mut.to_bits(),
            p_mut_acc_bits: nsga_cfg.p_mut_acc.to_bits(),
        }
    }

    /// The checkpoint's objective spec, parsed back from its canonical
    /// string (total: a stored spec this build cannot parse is a clear
    /// error naming the axes, not garbage objectives).
    pub fn objective_spec(&self) -> Result<ObjectiveSpec, String> {
        ObjectiveSpec::parse(&self.objectives)
            .map_err(|e| format!("checkpoint objective spec: {e}"))
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::Str(self.arch.clone())),
            ("num_layers", Json::Num(self.num_layers as f64)),
            ("objectives", Json::Str(self.objectives.clone())),
            ("mapper_seed", Json::hex_u64(self.mapper_seed)),
            ("valid_target", Json::hex_u64(self.valid_target)),
            ("max_draws", Json::hex_u64(self.max_draws)),
            ("shards", Json::Num(self.shards as f64)),
            ("population", Json::Num(self.population as f64)),
            ("offspring", Json::Num(self.offspring as f64)),
            ("nsga_seed", Json::hex_u64(self.nsga_seed)),
            ("p_mut", Json::hex_u64(self.p_mut_bits)),
            ("p_mut_acc", Json::hex_u64(self.p_mut_acc_bits)),
        ])
    }

    fn from_json(v: &Json) -> Result<SearchIdent, String> {
        let hex = |key: &str| -> Result<u64, String> {
            v.get(key).as_hex_u64(&format!("checkpoint ident {key}"))
        };
        Ok(SearchIdent {
            arch: v
                .get("arch")
                .as_str()
                .ok_or("checkpoint ident: missing arch")?
                .to_string(),
            num_layers: v
                .get("num_layers")
                .as_f64()
                .ok_or("checkpoint ident: missing num_layers")? as usize,
            // checkpoints from before the objective subsystem (legacy
            // v2 snapshots and early journals) carry no spec: they were
            // all written by the hardcoded (EDP, error) pipeline, so
            // they migrate as the default spec
            objectives: v
                .get("objectives")
                .as_str()
                .unwrap_or(&ObjectiveSpec::default().canonical())
                .to_string(),
            mapper_seed: hex("mapper_seed")?,
            valid_target: hex("valid_target")?,
            max_draws: hex("max_draws")?,
            shards: v.get("shards").as_f64().ok_or("checkpoint ident: missing shards")? as usize,
            population: v
                .get("population")
                .as_f64()
                .ok_or("checkpoint ident: missing population")? as usize,
            offspring: v
                .get("offspring")
                .as_f64()
                .ok_or("checkpoint ident: missing offspring")? as usize,
            nsga_seed: hex("nsga_seed")?,
            p_mut_bits: hex("p_mut")?,
            p_mut_acc_bits: hex("p_mut_acc")?,
        })
    }

    fn check(&self, stored: &SearchIdent, path: &str) -> Result<(), String> {
        if stored.objectives != self.objectives {
            // name the one field a user is most likely to change on
            // purpose, with the exact fix
            return Err(format!(
                "{path}: checkpoint was written under objective spec \
                 '{}', this run uses '{}' — resuming would mix \
                 incomparable objective vectors. Re-run with \
                 --objectives {} to continue that search, or delete \
                 the checkpoint to start fresh under the new spec",
                stored.objectives, self.objectives, stored.objectives
            ));
        }
        if stored != self {
            return Err(format!(
                "{path}: checkpoint belongs to a different search configuration — \
                 saved {stored:?}, current {self:?}; resuming would corrupt the \
                 search (delete the file or restore the original flags)"
            ));
        }
        Ok(())
    }
}

/// The population's JSON form (shared by journal marks and the legacy
/// snapshot loader): genomes as byte arrays, objectives as hex bits.
fn population_to_json(pop: &[Individual]) -> Json {
    Json::Arr(
        pop.iter()
            .map(|ind| {
                Json::obj(vec![
                    (
                        "genome",
                        Json::Arr(
                            ind.genome
                                .encode()
                                .iter()
                                .map(|&b| Json::Num(b as f64))
                                .collect(),
                        ),
                    ),
                    ("last_qo", Json::Num(ind.genome.last_qo as f64)),
                    (
                        "objectives",
                        Json::Arr(ind.objectives.iter().map(|&x| Json::hex_bits(x)).collect()),
                    ),
                ])
            })
            .collect(),
    )
}

fn population_from_json(
    v: &Json,
    num_layers: usize,
    spec: &ObjectiveSpec,
) -> Result<Vec<Individual>, String> {
    let mut pop: Vec<Individual> = Vec::new();
    for ind in v.as_arr().ok_or("checkpoint: missing population")? {
        let bytes: Vec<u8> = ind
            .get("genome")
            .as_arr()
            .ok_or("checkpoint: bad genome")?
            .iter()
            .map(|g| {
                g.as_f64()
                    .map(|x| x as u8)
                    .ok_or_else(|| "checkpoint: bad gene".to_string())
            })
            .collect::<Result<_, _>>()?;
        let last_qo = ind.get("last_qo").as_f64().unwrap_or(8.0) as u8;
        let genome = QuantConfig::decode(&bytes, last_qo)?;
        if genome.len() != num_layers {
            return Err(format!(
                "checkpoint genome has {} layers, the network has {num_layers}",
                genome.len()
            ));
        }
        let mut objectives = Vec::new();
        for o in ind
            .get("objectives")
            .as_arr()
            .ok_or("checkpoint: bad objectives")?
        {
            objectives.push(o.as_f64_bits("objective")?);
        }
        if objectives.len() != spec.len() {
            return Err(format!(
                "checkpoint individual has {} objectives, the ident's spec \
                 '{spec}' has {} axes — corrupt or hand-edited checkpoint",
                objectives.len(),
                spec.len()
            ));
        }
        pop.push(Individual {
            genome,
            objectives: ObjectiveVec::rebound(spec, objectives),
        });
    }
    if pop.is_empty() {
        return Err("checkpoint: empty population".into());
    }
    Ok(pop)
}

/// Open append handle plus the compaction accounting.
struct Appender {
    file: std::fs::File,
    /// Insert frames written since the last full rewrite (replayed
    /// frames count too, on resume).
    appended: usize,
}

/// Saves/loads search checkpoints at a fixed path (journal format; see
/// the module docs). Numeric encoding is shared with the distributed
/// wire protocol (`engine::proto`): `Json::hex_u64` / `Json::hex_bits`
/// from `util::json`.
///
/// One `Checkpointer` journals one cache: the first [`Checkpointer::
/// save`] (or a successful journal [`Checkpointer::load`]) enables the
/// cache's insert queue, full-writes the file, and every later save
/// appends only the queued inserts plus a generation mark.
pub struct Checkpointer {
    path: String,
    writer: Mutex<Option<Appender>>,
    compact_slack: usize,
}

impl Checkpointer {
    pub fn new(path: impl Into<String>) -> Checkpointer {
        Checkpointer {
            path: path.into(),
            writer: Mutex::new(None),
            compact_slack: DEFAULT_COMPACT_SLACK,
        }
    }

    /// Lower the compaction trigger (tests force the rewrite path with
    /// slack 0).
    pub fn with_compact_slack(mut self, slack: usize) -> Checkpointer {
        self.compact_slack = slack;
        self
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    pub fn exists(&self) -> bool {
        std::path::Path::new(&self.path).exists()
    }

    /// Whether the appender is armed — the next save appends in place.
    /// Unarmed after construction, a legacy or torn load, or a failed
    /// append; all of those make the next save rewrite the file whole.
    /// Observability seam for the driver's checkpoint trace and the
    /// model-conformance suite's state projection.
    pub fn journal_armed(&self) -> bool {
        self.writer.lock().unwrap().is_some()
    }

    /// Insert frames appended since the last full rewrite (`None` when
    /// unarmed) — the left-hand side of the compaction trigger, exposed
    /// so tests and the conformance projection can observe exactly when
    /// a save compacted.
    pub fn journal_appended(&self) -> Option<usize> {
        self.writer.lock().unwrap().as_ref().map(|a| a.appended)
    }

    fn header_frame(ident: &SearchIdent) -> Json {
        Json::obj(vec![
            ("journal", Json::Num(JOURNAL_VERSION)),
            ("ident", ident.to_json()),
        ])
    }

    fn mark_frame(st: &SearchState, guide: &GuideState) -> Json {
        let mut fields = vec![
            ("generation", Json::Num(st.generation as f64)),
            ("rng", Json::hex_u64(st.rng.state())),
            ("population", population_to_json(&st.pop)),
        ];
        // written only when non-empty, so an unguided run's journal
        // stays byte-identical to the pre-guide format; the loader
        // treats a missing key as an empty guide
        if !guide.is_empty() {
            fields.push(("guide", guide.to_json()));
        }
        Json::obj(vec![("mark", Json::obj(fields))])
    }

    /// Full rewrite: header + one insert frame per current cache entry
    /// + one mark, atomically (tmp + rename), then reopen for appends.
    /// Both the first save of a run and every compaction land here.
    fn rewrite(
        &self,
        st: &SearchState,
        cache: &MapperCache,
        ident: &SearchIdent,
        guide: &GuideState,
    ) -> Result<Appender, String> {
        let tmp = format!("{}.tmp", self.path);
        let mut buf = String::new();
        buf.push_str(&Self::header_frame(ident).to_string());
        buf.push('\n');
        for e in cache.entries_json() {
            buf.push_str(&Json::obj(vec![("insert", e)]).to_string());
            buf.push('\n');
        }
        buf.push_str(&Self::mark_frame(st, guide).to_string());
        buf.push('\n');
        {
            let mut f = std::fs::File::create(&tmp).map_err(|e| format!("{tmp}: {e}"))?;
            f.write_all(buf.as_bytes()).map_err(|e| format!("{tmp}: {e}"))?;
            f.sync_data().map_err(|e| format!("{tmp}: {e}"))?;
        }
        std::fs::rename(&tmp, &self.path).map_err(|e| format!("{}: {e}", self.path))?;
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(&self.path)
            .map_err(|e| format!("{}: {e}", self.path))?;
        Ok(Appender { file, appended: 0 })
    }

    /// Checkpoint the search at a generation boundary.
    ///
    /// The first save of a process (or any save against a cache whose
    /// journal queue is not enabled) writes the whole file and arms
    /// the cache's insert queue; every subsequent save appends the
    /// queued inserts and one fsync'd mark — O(new entries), which is
    /// what makes per-generation checkpointing affordable at 10^6
    /// cache entries.
    pub fn save(
        &self,
        st: &SearchState,
        cache: &MapperCache,
        ident: &SearchIdent,
    ) -> Result<(), String> {
        self.save_with_guide(st, cache, ident, &GuideState::new())
    }

    /// [`Checkpointer::save`] carrying the engine's guide state (see
    /// [`crate::mapper::guide`]): the mark frame gains an optional
    /// `guide` key, written only when the state is non-empty, so the
    /// unguided journal bytes are unchanged. The guide is *not* part of
    /// [`SearchIdent`] — it is a placement-only hint, and a resume may
    /// legitimately carry more or less history than the saved run.
    pub fn save_with_guide(
        &self,
        st: &SearchState,
        cache: &MapperCache,
        ident: &SearchIdent,
        guide: &GuideState,
    ) -> Result<(), String> {
        let mut guard = self.writer.lock().unwrap();
        // append path: an armed writer and a journaling cache
        let mut appended: Option<Result<usize, String>> = None;
        if cache.journal_enabled() {
            if let Some(app) = guard.as_mut() {
                appended = Some((|| {
                    let pending = cache.drain_journal();
                    let n_pending = pending.len();
                    let mut buf = String::new();
                    for e in pending {
                        buf.push_str(&Json::obj(vec![("insert", e)]).to_string());
                        buf.push('\n');
                    }
                    buf.push_str(&Self::mark_frame(st, guide).to_string());
                    buf.push('\n');
                    let t_write = Instant::now();
                    app.file
                        .write_all(buf.as_bytes())
                        .map_err(|e| format!("{}: {e}", self.path))?;
                    let write_us = t_write.elapsed().as_secs_f64() * 1e6;
                    // the mark is the durability point: a resumed
                    // search restarts from the last mark on disk
                    let t_sync = Instant::now();
                    app.file.sync_data().map_err(|e| format!("{}: {e}", self.path))?;
                    let fsync_us = t_sync.elapsed().as_secs_f64() * 1e6;
                    app.appended += n_pending;
                    {
                        use std::sync::atomic::Ordering::Relaxed;
                        let c = metrics::counters();
                        c.ckpt_appends.fetch_add(1, Relaxed);
                        c.ckpt_append_entries.fetch_add(n_pending as u64, Relaxed);
                        c.ckpt_fsync_us.fetch_add(fsync_us as u64, Relaxed);
                    }
                    obs::event(
                        "ckpt_append",
                        vec![
                            ("entries", Json::Num(n_pending as f64)),
                            ("write_us", Json::Num(write_us)),
                            ("fsync_us", Json::Num(fsync_us)),
                        ],
                    );
                    Ok(app.appended)
                })());
            }
        }
        match appended {
            // a failed append may have left a partial frame at the
            // tail; disarm so the next save rewrites the file whole
            Some(Err(e)) => {
                *guard = None;
                Err(e)
            }
            Some(Ok(n)) => {
                if n > self.compact_slack + 2 * cache.len() {
                    match self.rewrite(st, cache, ident, guide) {
                        Ok(app) => {
                            metrics::counters()
                                .ckpt_compactions
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            obs::event(
                                "ckpt_compact",
                                vec![
                                    ("frames", Json::Num(n as f64)),
                                    ("entries", Json::Num(cache.len() as f64)),
                                ],
                            );
                            *guard = Some(app)
                        }
                        Err(e) => {
                            // the rename may already have happened: the
                            // old handle could point at an unlinked
                            // inode, where appends would "succeed"
                            // invisibly — disarm so the next save
                            // rewrites whole
                            *guard = None;
                            return Err(e);
                        }
                    }
                }
                Ok(())
            }
            // first save (or a non-journaling cache): arm the insert
            // queue — everything already in the cache is covered by
            // the full dump, everything after lands in the queue —
            // then write the whole file
            None => {
                cache.enable_journal();
                let _ = cache.drain_journal();
                *guard = Some(self.rewrite(st, cache, ident, guide)?);
                Ok(())
            }
        }
    }

    /// Restore a checkpoint: loads the cache entries into `cache` and
    /// returns the search state. Rejects version, search-identity, or
    /// genome-length mismatches with a clear error instead of resuming
    /// garbage; tolerates a torn final line (crash mid-append) by
    /// resuming from the last complete mark. On success the journal is
    /// reopened for appending, so later saves extend it in place.
    pub fn load(&self, ident: &SearchIdent, cache: &MapperCache) -> Result<SearchState, String> {
        self.load_with_guide(ident, cache).map(|(st, _)| st)
    }

    /// [`Checkpointer::load`] that also restores the guide state from
    /// the resumed mark (empty for journals written before the guide
    /// existed, for unguided runs, and for legacy snapshots — a missing
    /// key is an empty guide, never an error).
    pub fn load_with_guide(
        &self,
        ident: &SearchIdent,
        cache: &MapperCache,
    ) -> Result<(SearchState, GuideState), String> {
        let src =
            std::fs::read_to_string(&self.path).map_err(|e| format!("{}: {e}", self.path))?;
        // format sniff on the first line: journal header vs the legacy
        // single-document snapshot
        let first = src.lines().next().unwrap_or("");
        let head = parse(first);
        let is_journal = matches!(&head, Ok(h) if h.get("journal").as_f64().is_some());
        if !is_journal {
            let st = self.load_legacy(&src, ident, cache)?;
            // leave the writer unarmed: the first save migrates the
            // file to the journal format with a full rewrite
            return Ok((st, GuideState::new()));
        }
        let header = head.map_err(|e| format!("{}: {e}", self.path))?;
        if header.get("journal").as_f64() != Some(JOURNAL_VERSION) {
            return Err(format!(
                "{}: unsupported journal version (want {JOURNAL_VERSION})",
                self.path
            ));
        }
        let stored = SearchIdent::from_json(header.get("ident"))?;
        ident.check(&stored, &self.path)?;
        let lines: Vec<&str> = src.lines().collect();
        let mut latest: Option<Json> = None;
        let mut inserts = 0usize;
        let mut torn = false;
        for (i, line) in lines.iter().enumerate().skip(1) {
            let frame = match parse(line) {
                Ok(f) => f,
                Err(e) => {
                    if i + 1 == lines.len() {
                        // torn final line: the crash-mid-append case —
                        // everything before it is intact, stop here
                        torn = true;
                        break;
                    }
                    return Err(format!("{}: corrupt frame at line {}: {e}", self.path, i + 1));
                }
            };
            if !matches!(frame.get("insert"), Json::Null) {
                cache
                    .load_entry_json(frame.get("insert"))
                    .map_err(|e| format!("{}: insert frame at line {}: {e}", self.path, i + 1))?;
                inserts += 1;
            } else if !matches!(frame.get("mark"), Json::Null) {
                latest = Some(frame.get("mark").clone());
            } else {
                return Err(format!(
                    "{}: unknown frame at line {} (neither insert nor mark)",
                    self.path,
                    i + 1
                ));
            }
        }
        // a file that does not end in '\n' had its final append cut
        // short even if the last frame happens to parse — appending
        // after it would weld two frames into one line, so treat it as
        // torn (the frame itself is still safe to use: it was fully
        // written, only its terminator is missing)
        if !src.ends_with('\n') {
            torn = true;
        }
        let mark = latest.ok_or_else(|| {
            format!("{}: journal has no complete generation mark", self.path)
        })?;
        let generation = mark
            .get("generation")
            .as_f64()
            .ok_or("checkpoint: missing generation")? as usize;
        let rng = Rng::new(mark.get("rng").as_hex_u64("checkpoint rng")?);
        let spec = ident.objective_spec()?;
        let pop = population_from_json(mark.get("population"), ident.num_layers, &spec)?;
        let guide = match mark.get("guide") {
            Json::Null => GuideState::new(),
            g => GuideState::from_json(g).map_err(|e| format!("{}: {e}", self.path))?,
        };
        // arm the cache's insert queue; keep appending to the replayed
        // journal UNLESS the tail was torn — appending after partial
        // bytes would merge the torn line with the next frame into one
        // malformed middle-of-file line and make the journal
        // unloadable, so a torn journal leaves the writer unarmed and
        // the next save rewrites the file whole (tmp + rename)
        cache.enable_journal();
        let _ = cache.drain_journal();
        if torn {
            *self.writer.lock().unwrap() = None;
        } else {
            let file = std::fs::OpenOptions::new()
                .append(true)
                .open(&self.path)
                .map_err(|e| format!("{}: {e}", self.path))?;
            *self.writer.lock().unwrap() = Some(Appender {
                file,
                appended: inserts,
            });
        }
        Ok((
            SearchState {
                generation,
                pop,
                rng,
            },
            guide,
        ))
    }

    /// Load the pre-journal single-document snapshot format.
    fn load_legacy(
        &self,
        src: &str,
        ident: &SearchIdent,
        cache: &MapperCache,
    ) -> Result<SearchState, String> {
        let v = parse(src).map_err(|e| format!("{}: {e}", self.path))?;
        if v.get("version").as_f64() != Some(LEGACY_VERSION) {
            return Err(format!(
                "{}: unsupported checkpoint version (want the journal format or \
                 legacy {LEGACY_VERSION})",
                self.path
            ));
        }
        let stored = SearchIdent::from_json(v.get("ident"))?;
        ident.check(&stored, &self.path)?;
        let generation = v
            .get("generation")
            .as_f64()
            .ok_or("checkpoint: missing generation")? as usize;
        let rng = Rng::new(v.get("rng").as_hex_u64("checkpoint rng")?);
        let spec = ident.objective_spec()?;
        let pop = population_from_json(v.get("population"), ident.num_layers, &spec)?;
        cache
            .load_json(&v.get("cache").to_string())
            .map_err(|e| format!("checkpoint cache: {e}"))?;
        Ok(SearchState {
            generation,
            pop,
            rng,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;
    use crate::mapper::MapperConfig;
    use crate::quant::LayerQuant;
    use crate::workload::ConvLayer;

    fn tmp_path(tag: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!("qmap_ckpt_{tag}_{}.json", std::process::id()));
        p.to_string_lossy().into_owned()
    }

    fn ident() -> SearchIdent {
        SearchIdent::new(
            &toy(),
            4,
            &ObjectiveSpec::default(),
            &MapperConfig::default(),
            &NsgaConfig::default(),
        )
    }

    fn state_with_objectives(objs: Vec<Vec<f64>>) -> SearchState {
        SearchState {
            generation: 3,
            pop: objs
                .into_iter()
                .enumerate()
                .map(|(i, objectives)| Individual {
                    genome: QuantConfig::uniform(4, 2 + (i as u8 % 7)),
                    objectives: ObjectiveVec::raw(objectives),
                })
                .collect(),
            rng: Rng::new(0xFEED_F00D),
        }
    }

    #[test]
    fn state_roundtrips_bit_exactly_including_infinities() {
        let path = tmp_path("bits");
        let ckpt = Checkpointer::new(path.as_str());
        let mut st = state_with_objectives(vec![
            vec![1.5e-9, 0.25],
            vec![f64::INFINITY, 0.1],
            vec![3.141592653589793, 2.2250738585072014e-308],
        ]);
        // advance the RNG so a non-trivial state is saved
        for _ in 0..17 {
            st.rng.next_u64();
        }
        let cache = MapperCache::new();
        ckpt.save(&st, &cache, &ident()).unwrap();
        let cache2 = MapperCache::new();
        let back = ckpt.load(&ident(), &cache2).unwrap();
        assert_eq!(back.generation, st.generation);
        assert_eq!(back.rng.state(), st.rng.state());
        assert_eq!(back.pop.len(), st.pop.len());
        for (a, b) in st.pop.iter().zip(&back.pop) {
            assert_eq!(a.genome, b.genome);
            let ab: Vec<u64> = a.objectives.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u64> = b.objectives.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cache_rides_along_with_negative_entries() {
        // an unmappable workload becomes a negative entry; the
        // checkpoint must round-trip it with its draw-budget tag
        let path = tmp_path("negcache");
        let ckpt = Checkpointer::new(path.as_str());
        let mut a = toy();
        a.name = "toy-nospad".into();
        a.levels[0].capacity = crate::arch::Capacity::PerTensor([0, 64, 64]);
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let tiny = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 500,
            seed: 5,
            shards: 1,
        };
        let cache = MapperCache::new();
        assert!(cache.evaluate(&a, &l, &LayerQuant::uniform(8), &tiny).is_none());
        assert_eq!(cache.misses(), 1);

        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        let restored = MapperCache::new();
        ckpt.load(&ident(), &restored).unwrap();
        // negative hit without re-searching at the recorded budget
        assert!(restored
            .evaluate(&a, &l, &LayerQuant::uniform(8), &tiny)
            .is_none());
        assert_eq!(restored.misses(), 0, "negative entry lost its budget tag");
        assert_eq!(restored.hits(), 1);
        // a larger budget must still re-search instead of trusting it
        let bigger = MapperConfig {
            max_draws: 5_000,
            ..tiny
        };
        let _ = restored.evaluate(&a, &l, &LayerQuant::uniform(8), &bigger);
        assert_eq!(restored.misses(), 1, "bigger budget served from stale negative");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_mismatched_network() {
        let path = tmp_path("mismatch");
        let ckpt = Checkpointer::new(path.as_str());
        let cache = MapperCache::new();
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        // saved genomes have 4 layers; a 7-layer network must refuse
        let mut other = ident();
        other.num_layers = 7;
        assert!(ckpt.load(&other, &cache).is_err());
        // ... and so must any other drifted search parameter
        let mut other = ident();
        other.arch = "simba".into();
        assert!(ckpt.load(&other, &cache).is_err());
        let mut other = ident();
        other.mapper_seed ^= 1;
        assert!(ckpt.load(&other, &cache).is_err());
        let _ = std::fs::remove_file(&path);
    }

    /// Resuming under a different objective spec is a hard error that
    /// names both specs and the fix — never silent garbage.
    #[test]
    fn load_rejects_mismatched_objective_spec() {
        let path = tmp_path("objmismatch");
        let ckpt = Checkpointer::new(path.as_str());
        let cache = MapperCache::new();
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        let mut other = ident();
        other.objectives = "error,energy,weight_words".into();
        let err = ckpt.load(&other, &cache).unwrap_err();
        assert!(err.contains("edp,error"), "{err}");
        assert!(err.contains("error,energy,weight_words"), "{err}");
        assert!(err.contains("--objectives"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    /// A journal whose header predates the objective subsystem (no
    /// `objectives` field in the ident) loads as the historical
    /// two-objective default — and only as that.
    #[test]
    fn pre_objective_journal_migrates_to_the_default_spec() {
        let path = tmp_path("objlegacy");
        let ckpt = Checkpointer::new(path.as_str());
        let cache = MapperCache::new();
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        // strip the objectives field from the header line, simulating a
        // journal written before the field existed
        let text = std::fs::read_to_string(&path).unwrap();
        let stripped = text.replacen("\"objectives\":\"edp,error\",", "", 1);
        assert_ne!(text, stripped, "header must have carried the spec");
        std::fs::write(&path, stripped).unwrap();
        // default-spec ident: loads
        let back = Checkpointer::new(path.as_str())
            .load(&ident(), &MapperCache::new())
            .unwrap();
        assert_eq!(back.generation, 3);
        // three-objective ident: refused with the migration hint
        let mut other = ident();
        other.objectives = "error,energy,weight_words".into();
        let err = Checkpointer::new(path.as_str())
            .load(&other, &MapperCache::new())
            .unwrap_err();
        assert!(err.contains("edp,error"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    /// A three-objective search checkpoints and resumes with all three
    /// axes intact, bit for bit.
    #[test]
    fn three_objective_state_roundtrips() {
        let path = tmp_path("threeobj");
        let ckpt = Checkpointer::new(path.as_str());
        let spec = ObjectiveSpec::parse("error,energy,weight_words").unwrap();
        let mut id3 = ident();
        id3.objectives = spec.canonical();
        let st = state_with_objectives(vec![
            vec![0.25, 1.5e9, 40_000.0],
            vec![0.1, f64::INFINITY, f64::INFINITY],
        ]);
        ckpt.save(&st, &MapperCache::new(), &id3).unwrap();
        let back = ckpt.load(&id3, &MapperCache::new()).unwrap();
        assert_eq!(back.pop.len(), 2);
        for (a, b) in st.pop.iter().zip(&back.pop) {
            let ab: Vec<u64> = a.objectives.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u64> = b.objectives.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb);
            assert_eq!(b.objectives.spec_hash(), spec.hash());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_missing_or_corrupt_files() {
        let ckpt = Checkpointer::new(tmp_path("absent"));
        assert!(!ckpt.exists());
        assert!(ckpt.load(&ident(), &MapperCache::new()).is_err());

        let path = tmp_path("corrupt");
        std::fs::write(&path, "not json at all").unwrap();
        let ckpt = Checkpointer::new(path.as_str());
        assert!(ckpt.load(&ident(), &MapperCache::new()).is_err());
        let _ = std::fs::remove_file(&path);
    }

    /// The save-twice path: the second save must *append* — the first
    /// file's bytes stay a literal prefix — and carry only the entries
    /// inserted in between, plus the new mark.
    #[test]
    fn second_save_appends_only_the_new_entries() {
        let path = tmp_path("append");
        let ckpt = Checkpointer::new(path.as_str());
        let a = toy();
        let cfg = MapperConfig {
            valid_target: 20,
            max_draws: 20_000,
            seed: 5,
            shards: 1,
        };
        let cache = MapperCache::new();
        cache.evaluate(&a, &ConvLayer::fc("fc", 16, 10), &LayerQuant::uniform(8), &cfg);
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        let before = std::fs::read(&path).unwrap();
        // two fresh inserts between generation boundaries
        cache.evaluate(&a, &ConvLayer::fc("fc", 16, 12), &LayerQuant::uniform(8), &cfg);
        cache.evaluate(&a, &ConvLayer::fc("fc", 16, 14), &LayerQuant::uniform(8), &cfg);
        let mut st = state_with_objectives(vec![vec![3.0, 4.0]]);
        st.generation = 4;
        ckpt.save(&st, &cache, &ident()).unwrap();
        let after = std::fs::read(&path).unwrap();
        assert!(
            after.starts_with(&before),
            "a generation save must append, not rewrite"
        );
        let tail = String::from_utf8_lossy(&after[before.len()..]).into_owned();
        assert_eq!(
            tail.matches("{\"insert\":").count(),
            2,
            "exactly the two new entries ride the journal: {tail}"
        );
        // replay resumes from the latest mark with the full cache
        let restored = MapperCache::new();
        let back = ckpt.load(&ident(), &restored).unwrap();
        assert_eq!(back.generation, 4);
        assert_eq!(restored.len(), 3);
        let _ = std::fs::remove_file(&path);
    }

    /// A torn final line (crash mid-append) resumes from the last
    /// complete mark; complete insert frames past that mark are kept.
    #[test]
    fn torn_tail_resumes_from_the_last_complete_mark() {
        let path = tmp_path("torn");
        let ckpt = Checkpointer::new(path.as_str());
        let a = toy();
        let cfg = MapperConfig {
            valid_target: 20,
            max_draws: 20_000,
            seed: 5,
            shards: 1,
        };
        let cache = MapperCache::new();
        cache.evaluate(&a, &ConvLayer::fc("fc", 16, 10), &LayerQuant::uniform(8), &cfg);
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        cache.evaluate(&a, &ConvLayer::fc("fc", 16, 12), &LayerQuant::uniform(8), &cfg);
        let mut st = state_with_objectives(vec![vec![3.0, 4.0]]);
        st.generation = 4;
        ckpt.save(&st, &cache, &ident()).unwrap();
        // tear the file inside the final mark line
        let bytes = std::fs::read(&path).unwrap();
        let text = String::from_utf8(bytes).unwrap();
        let last_mark = text.rfind("{\"mark\":").expect("final mark frame");
        std::fs::write(&path, &text[..last_mark + 9]).unwrap();
        let restored = MapperCache::new();
        let resumed = Checkpointer::new(path.as_str());
        let back = resumed.load(&ident(), &restored).unwrap();
        assert_eq!(back.generation, 3, "must fall back to the last complete mark");
        // the complete insert frame past the surviving mark is kept
        assert_eq!(restored.len(), 2);
        // saving after a torn load must NOT append onto the partial
        // tail (that would weld two frames into one corrupt middle
        // line): the file is rewritten whole and loads again
        let mut st2 = state_with_objectives(vec![vec![5.0, 6.0]]);
        st2.generation = 5;
        resumed.save(&st2, &restored, &ident()).unwrap();
        let again = MapperCache::new();
        let back2 = Checkpointer::new(path.as_str()).load(&ident(), &again).unwrap();
        assert_eq!(back2.generation, 5);
        assert_eq!(again.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    /// A file whose final frame is complete but lost its trailing
    /// newline (crash between the frame bytes and the terminator) must
    /// load — and must NOT be appended to, or the next frame would
    /// weld onto the same line.
    #[test]
    fn missing_trailing_newline_is_treated_as_torn() {
        let path = tmp_path("noeol");
        let ckpt = Checkpointer::new(path.as_str());
        let cache = MapperCache::new();
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        std::fs::write(&path, text.trim_end_matches('\n')).unwrap();
        let resumed = Checkpointer::new(path.as_str());
        let back = resumed.load(&ident(), &MapperCache::new()).unwrap();
        assert_eq!(back.generation, 3, "the complete final mark still counts");
        // the next save must rewrite whole, leaving a loadable journal
        let mut st = state_with_objectives(vec![vec![3.0, 4.0]]);
        st.generation = 7;
        resumed.save(&st, &MapperCache::new(), &ident()).unwrap();
        let back2 = Checkpointer::new(path.as_str())
            .load(&ident(), &MapperCache::new())
            .unwrap();
        assert_eq!(back2.generation, 7);
        let _ = std::fs::remove_file(&path);
    }

    /// Corruption *before* the final line is an error, not a silent
    /// partial load.
    #[test]
    fn corrupt_middle_frame_is_rejected() {
        let path = tmp_path("midcorrupt");
        let ckpt = Checkpointer::new(path.as_str());
        let cache = MapperCache::new();
        let a = toy();
        let cfg = MapperConfig {
            valid_target: 20,
            max_draws: 20_000,
            seed: 5,
            shards: 1,
        };
        cache.evaluate(&a, &ConvLayer::fc("fc", 16, 10), &LayerQuant::uniform(8), &cfg);
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
        assert!(lines.len() >= 3, "header + insert + mark");
        lines[1] = "{\"insert\": garbage".into();
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let err = Checkpointer::new(path.as_str())
            .load(&ident(), &MapperCache::new())
            .unwrap_err();
        assert!(err.contains("corrupt frame"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    /// Forced compaction: with zero slack and duplicate-key churn the
    /// journal rewrites itself and stays loadable.
    #[test]
    fn compaction_bounds_the_journal_and_preserves_replay() {
        let path = tmp_path("compact");
        let ckpt = Checkpointer::new(path.as_str()).with_compact_slack(0);
        let a = toy();
        let cfg = MapperConfig {
            valid_target: 10,
            max_draws: 10_000,
            seed: 5,
            shards: 1,
        };
        let cache = MapperCache::new();
        let l = ConvLayer::fc("fc", 16, 10);
        let q = LayerQuant::uniform(8);
        let r = crate::mapper::search(&a, &l, &q, &cfg);
        cache.insert_search(&a, &l, &q, &cfg, &r);
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        // churn the same key: every insert queues a journal frame but
        // the cache stays at one entry, so appends outrun 2*len fast
        for gen in 0..6 {
            for _ in 0..4 {
                cache.insert_search(&a, &l, &q, &cfg, &r);
            }
            let mut st = state_with_objectives(vec![vec![1.0, 2.0]]);
            st.generation = 3 + gen;
            ckpt.save(&st, &cache, &ident()).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let inserts = text.matches("{\"insert\":").count();
        assert!(
            inserts <= 6,
            "compaction must bound duplicate insert frames, found {inserts}"
        );
        let restored = MapperCache::new();
        let back = Checkpointer::new(path.as_str()).load(&ident(), &restored).unwrap();
        assert_eq!(back.generation, 8);
        assert_eq!(restored.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    /// The crash window the explorer flagged: a torn tail *immediately
    /// after a compaction*. The compaction collapsed every older mark
    /// into one, so the torn append's fallback mark IS the compaction's
    /// — if the rewrite had dropped it, or the loader skipped it, the
    /// journal would be unresumable at exactly the moment it had the
    /// fewest marks. Resume must land on the compacted mark, keep the
    /// complete insert frames past it, and leave the appender unarmed.
    #[test]
    fn torn_tail_right_after_compaction_resumes_from_the_compacted_mark() {
        let path = tmp_path("torncompact");
        let ckpt = Checkpointer::new(path.as_str()).with_compact_slack(0);
        let a = toy();
        let cfg = MapperConfig {
            valid_target: 10,
            max_draws: 10_000,
            seed: 5,
            shards: 1,
        };
        let cache = MapperCache::new();
        let l = ConvLayer::fc("fc", 16, 10);
        let q = LayerQuant::uniform(8);
        let r = crate::mapper::search(&a, &l, &q, &cfg);
        cache.insert_search(&a, &l, &q, &cfg, &r);
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        assert!(ckpt.journal_armed());
        // churn the one key until the next save compacts: 3 queued
        // frames beat slack 0 + 2·1 entries
        for _ in 0..3 {
            cache.insert_search(&a, &l, &q, &cfg, &r);
        }
        let mut st = state_with_objectives(vec![vec![1.0, 2.0]]);
        st.generation = 4;
        ckpt.save(&st, &cache, &ident()).unwrap();
        assert_eq!(ckpt.journal_appended(), Some(0), "the gen-4 save must compact");
        // one more generation appends onto the freshly compacted file...
        cache.insert_search(&a, &l, &q, &cfg, &r);
        st.generation = 5;
        ckpt.save(&st, &cache, &ident()).unwrap();
        assert_eq!(ckpt.journal_appended(), Some(1));
        // ...and the process dies mid-append: gen 5's mark line is cut
        let text = std::fs::read_to_string(&path).unwrap();
        let last_mark = text.rfind("{\"mark\":").expect("final mark frame");
        std::fs::write(&path, &text[..last_mark + 9]).unwrap();
        // resume: the compaction's mark is the last complete one
        let restored = MapperCache::new();
        let resumed = Checkpointer::new(path.as_str());
        let back = resumed.load(&ident(), &restored).unwrap();
        assert_eq!(back.generation, 4, "must resume from the compacted mark");
        assert_eq!(restored.len(), 1, "complete frames past the mark are kept");
        assert!(
            !resumed.journal_armed(),
            "a torn resume must leave the appender unarmed"
        );
        assert_eq!(resumed.journal_appended(), None);
        // the next save heals the file whole, re-arms, and loads again
        st.generation = 5;
        resumed.save(&st, &restored, &ident()).unwrap();
        assert!(resumed.journal_armed());
        let back2 = Checkpointer::new(path.as_str())
            .load(&ident(), &MapperCache::new())
            .unwrap();
        assert_eq!(back2.generation, 5);
        let _ = std::fs::remove_file(&path);
    }

    /// The guide rides the mark frame: a non-empty state round-trips
    /// through `save_with_guide`/`load_with_guide`, while an empty
    /// guide leaves the journal byte-identical to the guideless format.
    #[test]
    fn guide_rides_the_mark_and_empty_guides_change_nothing() {
        let path = tmp_path("guide");
        let st = state_with_objectives(vec![vec![1.0, 2.0]]);
        let cache = MapperCache::new();
        // empty guide: byte-identical to the plain save
        Checkpointer::new(path.as_str())
            .save_with_guide(&st, &cache, &ident(), &GuideState::new())
            .unwrap();
        let plain = std::fs::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        Checkpointer::new(path.as_str()).save(&st, &cache, &ident()).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), plain, "empty guide must not change bytes");
        // non-empty guide: round-trips exactly
        let mut g = GuideState::new();
        g.note(0xAB, 10, 1_000);
        g.note(0xCD, 7, 70);
        Checkpointer::new(path.as_str())
            .save_with_guide(&st, &cache, &ident(), &g)
            .unwrap();
        let (back, gback) = Checkpointer::new(path.as_str())
            .load_with_guide(&ident(), &MapperCache::new())
            .unwrap();
        assert_eq!(back.generation, 3);
        assert_eq!(gback, g);
        // the plain loader still accepts the guided journal
        let st2 = Checkpointer::new(path.as_str())
            .load(&ident(), &MapperCache::new())
            .unwrap();
        assert_eq!(st2.generation, 3);
        let _ = std::fs::remove_file(&path);
    }

    /// A pre-journal (v2 single-document) checkpoint still loads, and
    /// the next save migrates the file to the journal format.
    #[test]
    fn legacy_snapshot_loads_and_migrates() {
        let path = tmp_path("legacy");
        let a = toy();
        let cfg = MapperConfig {
            valid_target: 20,
            max_draws: 20_000,
            seed: 5,
            shards: 1,
        };
        let cache = MapperCache::new();
        cache.evaluate(&a, &ConvLayer::fc("fc", 16, 10), &LayerQuant::uniform(8), &cfg);
        let st = state_with_objectives(vec![vec![1.0, f64::INFINITY]]);
        // the old format: one JSON document with a version field
        let doc = Json::obj(vec![
            ("version", Json::Num(LEGACY_VERSION)),
            ("ident", ident().to_json()),
            ("generation", Json::Num(st.generation as f64)),
            ("rng", Json::hex_u64(st.rng.state())),
            ("population", population_to_json(&st.pop)),
            ("cache", cache.to_json_value()),
        ]);
        std::fs::write(&path, doc.to_string()).unwrap();

        let ckpt = Checkpointer::new(path.as_str());
        let restored = MapperCache::new();
        let back = ckpt.load(&ident(), &restored).unwrap();
        assert_eq!(back.generation, st.generation);
        assert_eq!(back.rng.state(), st.rng.state());
        assert_eq!(restored.len(), 1);
        assert_eq!(
            back.pop[0].objectives[1].to_bits(),
            f64::INFINITY.to_bits()
        );
        // saving migrates to the journal format...
        ckpt.save(&back, &restored, &ident()).unwrap();
        let migrated = std::fs::read_to_string(&path).unwrap();
        assert!(migrated.starts_with("{\"ident\":") || migrated.starts_with("{\"journal\":"),
            "{migrated}");
        assert!(migrated.contains("\"journal\":"));
        // ...which loads again
        let again = MapperCache::new();
        let back2 = Checkpointer::new(path.as_str()).load(&ident(), &again).unwrap();
        assert_eq!(back2.generation, st.generation);
        assert_eq!(again.len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
