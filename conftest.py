"""Pytest root conftest: make the build-time Python package importable
when pytest runs from the repository root (`pytest python/tests/`)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
