//! The PJRT seam: a minimal executor trait plus a **deterministic stub
//! implementation**, so the `pjrt` feature compiles (and its tests run)
//! without the unvendorable `xla` bindings.
//!
//! The ROADMAP's runtime item was stuck on a hard dependency: the real
//! PJRT path needs the `xla_extension` C++ toolchain, which cannot ride
//! an offline build. This module inverts the dependency — `Runtime`
//! talks to a [`PjrtBackend`] trait whose contract is exactly the two
//! artifacts the AOT pipeline produces (a train step and an eval step
//! over flat host buffers), and ships a [`StubBackend`] that implements
//! the contract with pure, deterministic Rust math. A real
//! `xla`-backed implementation drops in behind the same trait (as a
//! path-dependency build of this file's sibling; see `Cargo.toml`'s
//! `[features]` notes) without touching any caller.
//!
//! ## Stub semantics
//!
//! The stub models QAT as quantized regression toward a fixed,
//! seed-derived target vector `t`:
//!
//! * `q(p, b)` fake-quantizes a parameter to a `b`-bit lattice
//!   (`step = 2^(1-b)`), with the genome's per-layer `qw` selecting the
//!   lattice for each contiguous parameter chunk;
//! * **loss** = `mean((q(p_i) - t_i)^2)` + an activation penalty
//!   `mean(4^(2 - qa_l)) * 1e-2` + a `0.01` floor (losses are positive);
//! * **train** applies one straight-through-estimator SGD step,
//!   `p_i -= lr * (2 (q(p_i) - t_i) + batch_noise_i)`, so loss falls
//!   geometrically toward a bit-width-dependent floor — more bits, a
//!   finer lattice, a lower floor, exactly the monotonicity the
//!   integration tests (and the proxy-accuracy calibration story)
//!   need;
//! * **eval** reports `correct = batch / (1 + loss)` — a smooth,
//!   deterministic stand-in for top-1 counts, bounded by the batch.
//!
//! Everything is a pure function of the inputs (the batch noise is
//! FNV-hashed from the batch bytes), so repeated executions are
//! bit-identical — the property every suite in this repo leans on.

/// One operand of an executable call, as flat host data (what PJRT
/// calls a host literal). The real backend copies these to device
/// buffers; the stub reads them in place.
pub enum Operand<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    Scalar(f32),
}

/// Which AOT artifact an HLO text file is. The real backend ignores
/// this (the HLO itself is the program); the stub keys its deterministic
/// math off it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `train_step.hlo.txt`: `(params, x, y, qa, qw, lr) -> new_params`.
    TrainStep,
    /// `eval_step.hlo.txt`: `(params, x, y, qa, qw) -> (correct, loss)`.
    EvalStep,
}

/// A loaded executable: one artifact, callable over flat buffers.
/// Outputs are flat `f32` buffers in artifact order (`train` returns
/// `[new_params]`, `eval` returns `[correct], [loss]`).
pub trait PjrtExecutable: Send + Sync {
    fn execute(&self, args: &[Operand<'_>]) -> Result<Vec<Vec<f32>>, String>;
}

/// A PJRT client: compiles artifact text into executables.
pub trait PjrtBackend: Send + Sync {
    fn platform_name(&self) -> String;
    fn compile_hlo(
        &self,
        hlo_text: &str,
        kind: ArtifactKind,
    ) -> Result<Box<dyn PjrtExecutable>, String>;
}

/// The backend `Runtime::load` uses: the deterministic stub. Swap the
/// body for a real `xla`-backed client when the bindings are available.
pub fn default_backend() -> Box<dyn PjrtBackend> {
    Box::new(StubBackend)
}

// -------------------------------------------------------------- stub

/// Deterministic pure-Rust stand-in for the CPU PJRT client.
pub struct StubBackend;

impl PjrtBackend for StubBackend {
    fn platform_name(&self) -> String {
        "stub-cpu".into()
    }

    fn compile_hlo(
        &self,
        hlo_text: &str,
        kind: ArtifactKind,
    ) -> Result<Box<dyn PjrtExecutable>, String> {
        if hlo_text.trim().is_empty() {
            return Err("stub backend: empty HLO artifact".into());
        }
        Ok(Box::new(StubExecutable { kind }))
    }
}

struct StubExecutable {
    kind: ArtifactKind,
}

/// SplitMix64 → uniform f32 in [-0.5, 0.5).
fn unit(seed: u64) -> f32 {
    let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 40) as f32 / (1u64 << 24) as f32 - 0.5
}

/// The fixed regression target for parameter `i` (seeded so it is a
/// property of the "model", not of any batch).
fn target(i: usize) -> f32 {
    0.8 * unit(0x7A26_E7A2 ^ i as u64)
}

/// Fake-quantize to a `bits`-bit lattice (straight-through lattice of
/// step `2^(1-bits)`); 16+ bits is treated as continuous.
fn quantize(p: f32, bits: f32) -> f32 {
    let b = bits.clamp(1.0, 16.0);
    if b >= 16.0 {
        return p;
    }
    let step = (1.0f32 - b).exp2();
    (p / step).round() * step
}

/// FNV-1a over the batch bytes: the seed of the per-batch gradient
/// noise (same batch, same noise — determinism end to end).
fn batch_hash(x: &[f32], y: &[i32]) -> u64 {
    let mut h = crate::util::Fnv1a::new();
    for &v in x {
        h.write(&v.to_le_bytes());
    }
    for &v in y {
        h.write(&v.to_le_bytes());
    }
    h.finish()
}

/// Per-parameter bit-width: `qw[l]` for the l-th contiguous chunk.
fn bits_for(i: usize, n_params: usize, qw: &[f32]) -> f32 {
    if qw.is_empty() {
        return 16.0;
    }
    let chunk = (n_params / qw.len()).max(1);
    qw[(i / chunk).min(qw.len() - 1)]
}

fn loss_of(params: &[f32], qa: &[f32], qw: &[f32]) -> f32 {
    let n = params.len().max(1);
    let mut sq = 0.0f32;
    for (i, &p) in params.iter().enumerate() {
        let d = quantize(p, bits_for(i, params.len(), qw)) - target(i);
        sq += d * d;
    }
    let act_pen: f32 = if qa.is_empty() {
        0.0
    } else {
        qa.iter().map(|&b| (2.0 - b.clamp(1.0, 16.0)).exp2().powi(2)).sum::<f32>()
            / qa.len() as f32
            * 1e-2
    };
    sq / n as f32 + act_pen + 0.01
}

impl PjrtExecutable for StubExecutable {
    fn execute(&self, args: &[Operand<'_>]) -> Result<Vec<Vec<f32>>, String> {
        let f32_arg = |i: usize| -> Result<&[f32], String> {
            match args.get(i) {
                Some(Operand::F32(v)) => Ok(*v),
                _ => Err(format!("stub executable: argument {i} must be f32 data")),
            }
        };
        let i32_arg = |i: usize| -> Result<&[i32], String> {
            match args.get(i) {
                Some(Operand::I32(v)) => Ok(*v),
                _ => Err(format!("stub executable: argument {i} must be i32 data")),
            }
        };
        match self.kind {
            ArtifactKind::TrainStep => {
                if args.len() != 6 {
                    return Err(format!("train step wants 6 operands, got {}", args.len()));
                }
                let (params, x, y) = (f32_arg(0)?, f32_arg(1)?, i32_arg(2)?);
                // qa is validated (arity/type) but only enters through
                // the eval-side activation penalty, as in the real
                // artifact (the train step's gradient is weight-side)
                let (_qa, qw) = (f32_arg(3)?, f32_arg(4)?);
                let lr = match &args[5] {
                    Operand::Scalar(v) => *v,
                    _ => return Err("train step: operand 5 must be the lr scalar".into()),
                };
                let noise_seed = batch_hash(x, y);
                let new_params: Vec<f32> = params
                    .iter()
                    .enumerate()
                    .map(|(i, &p)| {
                        let grad = 2.0
                            * (quantize(p, bits_for(i, params.len(), qw)) - target(i))
                            + 2e-3 * unit(noise_seed ^ i as u64);
                        p - lr * grad
                    })
                    .collect();
                Ok(vec![new_params])
            }
            ArtifactKind::EvalStep => {
                if args.len() != 5 {
                    return Err(format!("eval step wants 5 operands, got {}", args.len()));
                }
                let (params, _x, y) = (f32_arg(0)?, f32_arg(1)?, i32_arg(2)?);
                let (qa, qw) = (f32_arg(3)?, f32_arg(4)?);
                let loss = loss_of(params, qa, qw);
                let correct = y.len() as f32 / (1.0 + loss);
                Ok(vec![vec![correct], vec![loss]])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exe(kind: ArtifactKind) -> Box<dyn PjrtExecutable> {
        StubBackend.compile_hlo("// stub artifact", kind).unwrap()
    }

    #[test]
    fn empty_hlo_is_refused() {
        assert!(StubBackend.compile_hlo("  \n", ArtifactKind::TrainStep).is_err());
    }

    #[test]
    fn train_is_deterministic_and_reduces_loss() {
        let train = exe(ArtifactKind::TrainStep);
        let eval = exe(ArtifactKind::EvalStep);
        let mut params: Vec<f32> = (0..256).map(|i| 0.4 * unit(i as u64)).collect();
        let x = vec![0.5f32; 64];
        let y = vec![1i32, 2, 3, 4];
        let qa = vec![8.0f32; 4];
        let qw = vec![8.0f32; 4];
        let loss_at = |p: &[f32]| -> f32 {
            let out = eval
                .execute(&[
                    Operand::F32(p),
                    Operand::F32(&x),
                    Operand::I32(&y),
                    Operand::F32(&qa),
                    Operand::F32(&qw),
                ])
                .unwrap();
            out[1][0]
        };
        let l0 = loss_at(&params);
        for _ in 0..20 {
            let out = train
                .execute(&[
                    Operand::F32(&params),
                    Operand::F32(&x),
                    Operand::I32(&y),
                    Operand::F32(&qa),
                    Operand::F32(&qw),
                    Operand::Scalar(0.05),
                ])
                .unwrap();
            params = out.into_iter().next().unwrap();
        }
        let l1 = loss_at(&params);
        assert!(l1 < l0, "loss did not fall: {l0} -> {l1}");
        // identical inputs, identical outputs, bit for bit
        let a = train
            .execute(&[
                Operand::F32(&params),
                Operand::F32(&x),
                Operand::I32(&y),
                Operand::F32(&qa),
                Operand::F32(&qw),
                Operand::Scalar(0.05),
            ])
            .unwrap();
        let b = train
            .execute(&[
                Operand::F32(&params),
                Operand::F32(&x),
                Operand::I32(&y),
                Operand::F32(&qa),
                Operand::F32(&qw),
                Operand::Scalar(0.05),
            ])
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fewer_bits_floor_the_loss_higher() {
        // train to convergence at each width; the coarser lattice (and
        // activation penalty) must leave more residual loss
        let train = exe(ArtifactKind::TrainStep);
        let eval = exe(ArtifactKind::EvalStep);
        let x = vec![0.25f32; 32];
        let y = vec![0i32; 2];
        let loss_after = |bits: f32| -> f32 {
            let mut params: Vec<f32> = (0..128).map(|i| 0.4 * unit(i as u64)).collect();
            let q = vec![bits; 4];
            for _ in 0..60 {
                let out = train
                    .execute(&[
                        Operand::F32(&params),
                        Operand::F32(&x),
                        Operand::I32(&y),
                        Operand::F32(&q),
                        Operand::F32(&q),
                        Operand::Scalar(0.05),
                    ])
                    .unwrap();
                params = out.into_iter().next().unwrap();
            }
            eval.execute(&[
                Operand::F32(&params),
                Operand::F32(&x),
                Operand::I32(&y),
                Operand::F32(&q),
                Operand::F32(&q),
            ])
            .unwrap()[1][0]
        };
        assert!(loss_after(2.0) > loss_after(8.0));
    }
}
