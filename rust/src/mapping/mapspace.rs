//! Mapspace construction: random sampling and exhaustive enumeration of
//! candidate mappings.
//!
//! The mapspace for a (layer, architecture) pair is the cross product of
//! * ordered factorizations of each problem dim across the hierarchy
//!   slots (one temporal slot per level, one spatial slot per fanout
//!   level), and
//! * temporal loop permutations per level.
//!
//! Exhaustive enumeration (Table I) iterates factorizations x spatial
//! splits with the architecture's canonical dataflow permutation fixed,
//! mirroring how Timeloop's counts are reported per mapspace constraint
//! set; random sampling (the production mapper) additionally randomizes
//! permutations.

use super::constraints::MapConstraints;
use super::context::LayerContext;
use super::factorize::{
    count_ordered_factorizations, for_each_ordered_factorization, random_factorization_into,
    random_ordered_factorization,
};
use super::Mapping;
use crate::arch::Arch;
use crate::quant::LayerQuant;
use crate::util::rng::Rng;
use crate::workload::{ConvLayer, Dim, DIMS};

/// Hierarchy slots: temporal slots = one per level; spatial slots = the
/// subset of levels with fanout > 1 (per dim, a factorization entry).
#[derive(Debug, Clone)]
pub struct MapSpace {
    pub num_levels: usize,
    /// Levels with fanout > 1, in level order.
    pub spatial_levels: Vec<usize>,
}

impl MapSpace {
    pub fn of(arch: &Arch) -> Self {
        MapSpace {
            num_levels: arch.levels.len(),
            spatial_levels: arch
                .levels
                .iter()
                .enumerate()
                .filter(|(_, l)| l.fanout > 1)
                .map(|(i, _)| i)
                .collect(),
        }
    }

    /// Total slots a dim's size factorizes across.
    pub fn slots(&self) -> usize {
        self.num_levels + self.spatial_levels.len()
    }

    /// Upper bound on factorization-space size (ignoring permutations and
    /// validity): product over dims of ordered-factorization counts.
    pub fn factorization_space_size(&self, layer: &ConvLayer) -> f64 {
        DIMS.iter()
            .map(|&d| count_ordered_factorizations(layer.size(d), self.slots()) as f64)
            .product()
    }

    /// Draw a uniformly random (not necessarily valid) mapping.
    pub fn random_mapping(&self, layer: &ConvLayer, rng: &mut Rng) -> Mapping {
        let mut m = Mapping::unit(self.num_levels);
        for d in DIMS {
            let fs = random_ordered_factorization(layer.size(d), self.slots(), rng);
            // first `num_levels` entries -> temporal, rest -> spatial
            for lv in 0..self.num_levels {
                m.levels[lv].temporal[d.index()] = fs[lv];
            }
            for (si, &lv) in self.spatial_levels.iter().enumerate() {
                m.levels[lv].spatial[d.index()] = fs[self.num_levels + si];
            }
        }
        for lv in 0..self.num_levels {
            let mut perm = DIMS;
            rng.shuffle(&mut perm);
            m.levels[lv].perm = perm;
        }
        m
    }

    /// Allocation-free [`MapSpace::random_mapping`]: draw into a caller
    /// scratch `Mapping`, using the dim prime factorizations precomputed
    /// in `lctx` and a `slots()`-long factor buffer `fbuf`. Consumes the
    /// RNG stream identically to `random_mapping`, so for a fixed seed
    /// both paths sample the same candidates.
    pub fn random_mapping_into(
        &self,
        lctx: &LayerContext,
        rng: &mut Rng,
        fbuf: &mut [u64],
        m: &mut Mapping,
    ) {
        debug_assert_eq!(m.levels.len(), self.num_levels);
        debug_assert_eq!(fbuf.len(), self.slots());
        // Only the spatial arrays carry state between draws: every
        // temporal slot and every permutation is overwritten
        // unconditionally below, while spatial slots are written only at
        // the fanout levels. Resetting just `spatial` is therefore
        // equivalent to a full `reset_unit`, at a third of the stores.
        for lm in &mut m.levels {
            lm.spatial = [1; 7];
        }
        for d in DIMS {
            random_factorization_into(&lctx.dim_primes[d.index()], rng, fbuf);
            for lv in 0..self.num_levels {
                m.levels[lv].temporal[d.index()] = fbuf[lv];
            }
            for (si, &lv) in self.spatial_levels.iter().enumerate() {
                m.levels[lv].spatial[d.index()] = fbuf[self.num_levels + si];
            }
        }
        for lv in 0..self.num_levels {
            let mut perm = DIMS;
            rng.shuffle(&mut perm);
            m.levels[lv].perm = perm;
        }
    }

    /// Count (and optionally visit) every valid mapping in the reduced
    /// exhaustive space: all factorizations x spatial splits, canonical
    /// permutations. Intended for single layers (Table I); the visitor
    /// runs under a hard `limit` to bound runtime.
    ///
    /// This enumerates the architecture's *constrained* mapspace
    /// ([`MapConstraints::for_arch`]), matching how Timeloop counts are
    /// reported. Use [`MapSpace::enumerate_valid_with`] to supply a
    /// custom constraint set (or `MapConstraints::none` for the raw
    /// space).
    pub fn enumerate_valid(
        &self,
        arch: &Arch,
        layer: &ConvLayer,
        q: &LayerQuant,
        limit: u64,
        visit: impl FnMut(&Mapping),
    ) -> EnumStats {
        self.enumerate_valid_with(arch, layer, q, &MapConstraints::for_arch(arch), limit, visit)
    }

    /// [`MapSpace::enumerate_valid`] with an explicit constraint set.
    ///
    /// Internally builds a [`LayerContext`] so the per-candidate checks
    /// run on the precomputed table path (no per-candidate allocation).
    pub fn enumerate_valid_with(
        &self,
        arch: &Arch,
        layer: &ConvLayer,
        q: &LayerQuant,
        constraints: &MapConstraints,
        limit: u64,
        mut visit: impl FnMut(&Mapping),
    ) -> EnumStats {
        let slots = self.slots();
        let dims: Vec<Dim> = DIMS.to_vec();
        let mut factorizations: Vec<Vec<Vec<u64>>> = Vec::with_capacity(7);
        for &d in &dims {
            let mut fs = Vec::new();
            for_each_ordered_factorization(layer.size(d), slots, |f| {
                // constraint pre-filter: temporal slots must respect the
                // per-level dim whitelist; spatial slots must respect
                // the arch's spatial_dims (redundant with the checker
                // but prunes the recursion enormously)
                if !constraints.allows_factorization(self.num_levels, d, f) {
                    return;
                }
                for (si, &lv) in self.spatial_levels.iter().enumerate() {
                    if f[self.num_levels + si] > 1
                        && !arch.levels[lv].spatial_dims.contains(&d)
                    {
                        return;
                    }
                }
                fs.push(f.to_vec());
            });
            factorizations.push(fs);
        }

        let lctx = LayerContext::new(arch, layer, q);
        let mut stats = EnumStats::default();
        let mut m = Mapping::unit(self.num_levels);
        let mut ext: Vec<[u64; 7]> = Vec::with_capacity(self.num_levels);
        // canonical permutation per level: the arch's natural dataflow
        // order (keep DIMS order; the checker is permutation-insensitive,
        // permutations only affect access counts, not validity).
        self.rec_enumerate(&lctx, &factorizations, 0, &mut m, limit, &mut stats, &mut ext, &mut visit);
        stats
    }

    #[allow(clippy::too_many_arguments)]
    fn rec_enumerate(
        &self,
        lctx: &LayerContext,
        factorizations: &[Vec<Vec<u64>>],
        di: usize,
        m: &mut Mapping,
        limit: u64,
        stats: &mut EnumStats,
        ext: &mut Vec<[u64; 7]>,
        visit: &mut impl FnMut(&Mapping),
    ) {
        if stats.valid >= limit {
            stats.truncated = true;
            return;
        }
        if di == 7 {
            stats.examined += 1;
            if lctx.check(m, ext).is_ok() {
                stats.valid += 1;
                visit(m);
            }
            return;
        }
        let d = DIMS[di];
        for fs in &factorizations[di] {
            // place factors
            for lv in 0..self.num_levels {
                m.levels[lv].temporal[d.index()] = fs[lv];
            }
            for (si, &lv) in self.spatial_levels.iter().enumerate() {
                m.levels[lv].spatial[d.index()] = fs[self.num_levels + si];
            }
            // early prune 1: spatial product so far must not exceed fanout
            let mut prune = false;
            for &lv in &self.spatial_levels {
                if m.levels[lv].spatial_product() > lctx.fanout[lv] {
                    prune = true;
                    break;
                }
            }
            // early prune 2: tile footprints only grow as more dims are
            // placed, so a partial capacity overflow is final
            if !prune && !lctx.partial_capacity_ok(m, ext) {
                prune = true;
            }
            if !prune {
                self.rec_enumerate(lctx, factorizations, di + 1, m, limit, stats, ext, visit);
            }
            if stats.truncated {
                break;
            }
        }
        // reset dim to 1s
        for lv in 0..self.num_levels {
            m.levels[lv].temporal[d.index()] = 1;
        }
        for &lv in &self.spatial_levels {
            m.levels[lv].spatial[d.index()] = 1;
        }
    }
}

/// Outcome of an exhaustive enumeration.
#[derive(Debug, Default, Clone, Copy)]
pub struct EnumStats {
    pub examined: u64,
    pub valid: u64,
    pub truncated: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;
    use crate::mapping::check;
    use crate::quant::LayerQuant;
    use crate::workload::ConvLayer;

    #[test]
    fn random_mapping_products_match_dims() {
        let a = toy();
        let space = MapSpace::of(&a);
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 2);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let m = space.random_mapping(&l, &mut rng);
            let totals = m.total_extents();
            for d in DIMS {
                assert_eq!(totals[d.index()], l.size(d), "{d:?}");
            }
        }
    }

    #[test]
    fn some_random_mappings_are_valid() {
        let a = toy();
        let space = MapSpace::of(&a);
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let mut rng = Rng::new(2);
        let q = LayerQuant::uniform(8);
        let valid = (0..2000)
            .filter(|_| check(&a, &l, &q, &space.random_mapping(&l, &mut rng)).is_ok())
            .count();
        assert!(valid > 0, "no valid mappings sampled");
    }

    #[test]
    fn enumeration_counts_grow_with_lower_bitwidth() {
        // the Table I effect on the toy arch
        let a = toy();
        let space = MapSpace::of(&a);
        let l = ConvLayer::dw("dw", 8, 3, 8, 1);
        let n16 = space
            .enumerate_valid(&a, &l, &LayerQuant::uniform(16), u64::MAX, |_| {})
            .valid;
        let n8 = space
            .enumerate_valid(&a, &l, &LayerQuant::uniform(8), u64::MAX, |_| {})
            .valid;
        let n2 = space
            .enumerate_valid(&a, &l, &LayerQuant::uniform(2), u64::MAX, |_| {})
            .valid;
        assert!(n8 >= n16, "n8={n8} n16={n16}");
        assert!(n2 > n8, "n2={n2} n8={n8}");
        assert!(n16 > 0);
    }

    #[test]
    fn enumeration_respects_limit() {
        let a = toy();
        let space = MapSpace::of(&a);
        let l = ConvLayer::conv("t", 8, 16, 3, 16, 1);
        let st = space.enumerate_valid(&a, &l, &LayerQuant::uniform(4), 50, |_| {});
        assert!(st.truncated);
        assert_eq!(st.valid, 50);
    }

    #[test]
    fn visitor_sees_only_valid() {
        let a = toy();
        let space = MapSpace::of(&a);
        let l = ConvLayer::dw("dw", 8, 3, 8, 1);
        let q = LayerQuant::uniform(4);
        let mut n = 0;
        space.enumerate_valid(&a, &l, &q, u64::MAX, |m| {
            check(&a, &l, &q, m).unwrap();
            n += 1;
        });
        assert!(n > 0);
    }

    #[test]
    fn mapspace_slots() {
        let a = toy();
        let s = MapSpace::of(&a);
        assert_eq!(s.num_levels, 3);
        assert_eq!(s.spatial_levels, vec![1]);
        assert_eq!(s.slots(), 4);
    }

    #[test]
    fn random_mapping_into_matches_allocating_path() {
        // identical seed -> identical RNG stream -> identical candidates
        let a = toy();
        let space = MapSpace::of(&a);
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 2);
        let lctx = LayerContext::new(&a, &l, &LayerQuant::uniform(8));
        let mut r1 = Rng::new(41);
        let mut r2 = Rng::new(41);
        let mut m = Mapping::unit(space.num_levels);
        let mut fbuf = vec![1u64; space.slots()];
        for _ in 0..200 {
            let expect = space.random_mapping(&l, &mut r1);
            space.random_mapping_into(&lctx, &mut r2, &mut fbuf, &mut m);
            assert_eq!(m, expect);
        }
    }
}
