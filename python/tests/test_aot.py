"""AOT artifact tests: HLO text lowering and manifest integrity."""

import json

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_lowering_produces_hlo_text():
    hlos = aot.lower_all(batch=4)
    assert set(hlos) == {"train_step", "eval_step"}
    for name, text in hlos.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # parameters are declared in the entry computation
        assert "parameter(0)" in text, name


def test_manifest_consistent_with_model():
    m = aot.manifest(batch=4)
    assert m["num_layers"] == model.NUM_LAYERS
    assert m["param_size"] == model.PARAM_SIZE
    assert len(m["params"]) == len(model.PARAM_SPEC)
    # round-trips through json
    m2 = json.loads(json.dumps(m))
    assert m2 == m
    # offsets contiguous
    off = 0
    for p in m["params"]:
        assert p["offset"] == off
        off += int(np.prod(p["shape"]))
    assert off == m["param_size"]


def test_end_to_end_artifact_write(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--batch", "4"],
        capture_output=True,
        text=True,
        cwd=str(aot.__file__).rsplit("/compile/", 1)[0],
    )
    assert r.returncode == 0, r.stderr
    assert (out / "train_step.hlo.txt").exists()
    assert (out / "eval_step.hlo.txt").exists()
    meta = json.loads((out / "model_meta.json").read_text())
    raw = (out / "params_init.bin").read_bytes()
    assert len(raw) == meta["param_size"] * 4
    params = np.frombuffer(raw, dtype="<f4")
    assert np.isfinite(params).all()
    # init params loaded from disk match in-process init
    np.testing.assert_array_equal(params, np.asarray(model.init_params(0)))


def test_lowered_train_step_runs():
    """Compile the lowered train step and take one step (smoke)."""
    import jax

    batch = 4
    p = model.init_params(0)
    x = jnp.zeros((batch, model.IMG, model.IMG, model.IN_CH), jnp.float32)
    y = jnp.zeros((batch,), jnp.int32)
    q = jnp.full((model.NUM_LAYERS,), 8.0, jnp.float32)
    lowered = jax.jit(model.train_step).lower(p, x, y, q, q, jnp.float32(0.01))
    compiled = lowered.compile()
    new_p, loss = compiled(p, x, y, q, q, jnp.float32(0.01))
    assert new_p.shape == p.shape
    assert np.isfinite(float(loss))
