//! Persistent, cross-process mapper-cache store — the binary substrate
//! behind `qmap search --cache-dir` / `QMAP_CACHE_DIR`.
//!
//! The checkpoint journal (PR 4) already persists cache entries, but
//! replaying it is O(parse JSON journal) per process and the journal is
//! owned by one search. The store is the shared tier: an append-only
//! binary file any number of processes read and append concurrently, so
//! a cold process reaches a warm cache in O(read + index scan) and one
//! tenant's search warms every tenant's (ROADMAP's `qmap serve` vision).
//!
//! ## File format (all integers little-endian `u64`)
//!
//! ```text
//! header  : magic "QMAPSTR1" | identity | slots | fnv1a(prev 24 bytes)
//! record  : key | tag | payload[slots] | fnv1a(prev (2+slots)*8 bytes)
//! ```
//!
//! * **identity** pins what the records mean: for the search store it is
//!   [`search_identity`] (rendered arch + full mapper config), for the
//!   worker store the FNV of the driver's canonical arch text. Opening
//!   with a different identity is a *loud refusal* ([`StoreError`]),
//!   never a silent reuse — mixing identities would serve one config's
//!   results to another and break warm == cold bit-identity.
//! * **slots** is the fixed payload width of every record in this file.
//!   Payloads are raw `u64`s; `f64` fields travel as `to_bits()`, so a
//!   round trip is hex-exact and a warm start is bit-identical to cold.
//! * Every record carries its own FNV-1a checksum. The reader walks the
//!   file with per-record resynchronization: a record that fails its
//!   checksum is skipped and the walk slides forward one word at a time
//!   until checksums line up again — so torn tails (crash mid-append),
//!   bit flips, and truncation cost only the damaged records, never a
//!   panic and never the rest of the file.
//! * Appends go through one `O_APPEND` handle, one `write_all` per
//!   record — atomic on POSIX local filesystems, so concurrent
//!   processes interleave whole records and lose nothing. The file is
//!   never truncated or rewritten in place.
//!
//! The in-memory index (open addressing, built once at open) is
//! immutable afterwards: readers are lock-free, and entries appended by
//! *this* process are served by the in-memory `MapperCache` shards, so
//! the index only needs to see other processes' history — which a
//! reopen picks up.

use super::{effective_shards, MapperConfig, ShardOutcome};
use crate::arch::parser::render_arch;
use crate::arch::Arch;
use crate::energy::Estimate;
use crate::mapping::{LevelMapping, Mapping};
use crate::obs::metrics;
use crate::util::Fnv1a;
use crate::workload::Dim;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// File magic; the trailing `1` is the format version.
pub const MAGIC: [u8; 8] = *b"QMAPSTR1";
/// Header bytes: magic, identity, slots, checksum.
pub const HEADER_LEN: usize = 32;

/// Why a store could not be opened. Every variant renders an actionable
/// message — the CLI surfaces these as refusals, never silently starts
/// over a mismatched or unreadable file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    Io(String),
    NotAStore { path: String },
    IdentityMismatch { path: String, want: u64, found: u64 },
    SlotsMismatch { path: String, want: u64, found: u64 },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "cache store I/O error: {e}"),
            StoreError::NotAStore { path } => {
                write!(f, "{path}: not a qmap cache store (bad magic or corrupt header)")
            }
            StoreError::IdentityMismatch { path, want, found } => write!(
                f,
                "{path}: cache store identity {found:016x} does not match this \
                 run's identity {want:016x} (different arch or mapper config); \
                 refusing to reuse — point --cache-dir elsewhere or remove the file"
            ),
            StoreError::SlotsMismatch { path, want, found } => write!(
                f,
                "{path}: cache store record width {found} != expected {want}; \
                 refusing to reuse a mismatched layout"
            ),
        }
    }
}

/// One record slot-decoded from the file: `(tag, payload)`.
type Record<'a> = (u64, &'a [u64]);

/// Immutable open-addressing index over the records read at open.
/// Capacity is a power of two ≥ 2·n; linear probing; last record wins
/// (a re-append of a key supersedes earlier records, so upgrade paths —
/// e.g. a negative entry later found mappable — replay correctly).
struct IndexTable {
    mask: usize,
    /// Entry index + 1 per bucket; 0 = empty.
    buckets: Vec<u32>,
    keys: Vec<u64>,
    tags: Vec<u64>,
    /// Entry `i`'s payload at `i*slots..(i+1)*slots` — one slab, no
    /// per-record allocation.
    slab: Vec<u64>,
}

impl IndexTable {
    fn build(records: &[(u64, u64, Vec<u64>)], slots: usize) -> IndexTable {
        let cap = (records.len() * 2).next_power_of_two().max(16);
        let mut t = IndexTable {
            mask: cap - 1,
            buckets: vec![0u32; cap],
            keys: Vec::with_capacity(records.len()),
            tags: Vec::with_capacity(records.len()),
            slab: Vec::with_capacity(records.len() * slots),
        };
        for (key, tag, payload) in records {
            t.insert(*key, *tag, payload, slots);
        }
        t
    }

    fn insert(&mut self, key: u64, tag: u64, payload: &[u64], slots: usize) {
        let mut b = (key.wrapping_mul(0x9E3779B97F4A7C15) as usize) & self.mask;
        loop {
            match self.buckets[b] {
                0 => {
                    let i = self.keys.len();
                    self.keys.push(key);
                    self.tags.push(tag);
                    self.slab.extend_from_slice(payload);
                    self.buckets[b] = (i + 1) as u32;
                    return;
                }
                e => {
                    let i = (e - 1) as usize;
                    if self.keys[i] == key {
                        // last record wins: overwrite in place
                        self.tags[i] = tag;
                        self.slab[i * slots..(i + 1) * slots].copy_from_slice(payload);
                        return;
                    }
                }
            }
            b = (b + 1) & self.mask;
        }
    }

    fn lookup(&self, key: u64, slots: usize) -> Option<Record<'_>> {
        let mut b = (key.wrapping_mul(0x9E3779B97F4A7C15) as usize) & self.mask;
        loop {
            match self.buckets[b] {
                0 => return None,
                e => {
                    let i = (e - 1) as usize;
                    if self.keys[i] == key {
                        return Some((self.tags[i], &self.slab[i * slots..(i + 1) * slots]));
                    }
                }
            }
            b = (b + 1) & self.mask;
        }
    }
}

/// An open store: the immutable index over the file's history plus one
/// serialized appender. Cheap to share (`Arc`); reads never lock.
pub struct CacheStore {
    path: PathBuf,
    identity: u64,
    slots: usize,
    index: IndexTable,
    skipped: usize,
    open_us: u64,
    appender: Mutex<File>,
    appends: AtomicU64,
}

fn header_bytes(identity: u64, slots: u64) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(&MAGIC);
    h[8..16].copy_from_slice(&identity.to_le_bytes());
    h[16..24].copy_from_slice(&slots.to_le_bytes());
    let mut f = Fnv1a::new();
    f.write(&h[..24]);
    h[24..].copy_from_slice(&f.finish().to_le_bytes());
    h
}

fn read_u64(b: &[u8], i: usize) -> u64 {
    u64::from_le_bytes(b[i * 8..i * 8 + 8].try_into().unwrap())
}

impl CacheStore {
    /// Open (creating if missing) the store at `path` for the given
    /// identity and payload width. Validates the header and builds the
    /// index; damaged records are skipped (see module docs), a wrong
    /// identity or layout is a refusal, never a silent restart.
    pub fn open(path: &Path, identity: u64, slots: usize) -> Result<CacheStore, StoreError> {
        let t0 = std::time::Instant::now();
        Self::create_if_missing(path, identity, slots)?;
        let bytes = std::fs::read(path).map_err(|e| StoreError::Io(format!("{}: {e}", path.display())))?;
        let ps = path.display().to_string();
        if bytes.len() < HEADER_LEN || bytes[..8] != MAGIC {
            return Err(StoreError::NotAStore { path: ps });
        }
        let mut f = Fnv1a::new();
        f.write(&bytes[..24]);
        if f.finish() != read_u64(&bytes, 3) {
            return Err(StoreError::NotAStore { path: ps });
        }
        let found_id = read_u64(&bytes, 1);
        if found_id != identity {
            return Err(StoreError::IdentityMismatch { path: ps, want: identity, found: found_id });
        }
        let found_slots = read_u64(&bytes, 2);
        if found_slots != slots as u64 {
            return Err(StoreError::SlotsMismatch { path: ps, want: slots as u64, found: found_slots });
        }

        let stride = (3 + slots) * 8;
        let mut records: Vec<(u64, u64, Vec<u64>)> = Vec::new();
        let mut skipped = 0usize;
        let mut in_bad = false;
        let mut off = HEADER_LEN;
        // Per-record resync: slide one word forward through damaged
        // regions; an intact record anywhere past the damage is found
        // again (FNV collisions at a wrong offset are ~2^-64).
        while off + stride <= bytes.len() {
            let rec = &bytes[off..off + stride];
            let mut f = Fnv1a::new();
            f.write(&rec[..stride - 8]);
            if f.finish() == read_u64(rec, 2 + slots) {
                let key = read_u64(rec, 0);
                let tag = read_u64(rec, 1);
                let payload: Vec<u64> = (0..slots).map(|i| read_u64(rec, 2 + i)).collect();
                records.push((key, tag, payload));
                in_bad = false;
                off += stride;
            } else {
                if !in_bad {
                    skipped += 1;
                    in_bad = true;
                }
                off += 8;
            }
        }
        let index = IndexTable::build(&records, slots);

        let appender = OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(|e| StoreError::Io(format!("{}: {e}", path.display())))?;
        let open_us = t0.elapsed().as_micros() as u64;
        metrics::counters().store_open_us.fetch_add(open_us, Ordering::Relaxed);
        Ok(CacheStore {
            path: path.to_path_buf(),
            identity,
            slots,
            index,
            skipped,
            open_us,
            appender: Mutex::new(appender),
            appends: AtomicU64::new(0),
        })
    }

    /// Create the file with its header if it does not exist, atomically
    /// against concurrent creators: the header is written to a private
    /// temp file which is then `hard_link`ed into place — the link
    /// either publishes a complete header or fails because another
    /// process already did, so no reader ever sees a half-written one.
    fn create_if_missing(path: &Path, identity: u64, slots: usize) -> Result<(), StoreError> {
        if path.exists() {
            return Ok(());
        }
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let write = || -> std::io::Result<()> {
            let mut f = File::create(&tmp)?;
            f.write_all(&header_bytes(identity, slots as u64))?;
            f.sync_all()
        };
        write().map_err(|e| StoreError::Io(format!("{}: {e}", tmp.display())))?;
        match std::fs::hard_link(&tmp, path) {
            Ok(()) => {}
            // lost the race: another process published the header first
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {}
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                return Err(StoreError::Io(format!("{}: {e}", path.display())));
            }
        }
        let _ = std::fs::remove_file(&tmp);
        Ok(())
    }

    /// Look up a key in the history read at open. Lock-free. Returns
    /// `(tag, payload)`; decoding is the caller's (the store is a dumb
    /// word array — `mapper::cache` and the worker own their codecs).
    pub fn lookup(&self, key: u64) -> Option<Record<'_>> {
        self.index.lookup(key, self.slots)
    }

    /// Append one record: a single `O_APPEND` `write_all`, so records
    /// from concurrent processes interleave whole, never torn (short of
    /// a crash — which the reader's checksums absorb). Best-effort
    /// write-behind: an I/O error drops the record, never the search.
    pub fn append(&self, key: u64, tag: u64, payload: &[u64]) {
        debug_assert_eq!(payload.len(), self.slots);
        let mut buf = Vec::with_capacity((3 + self.slots) * 8);
        buf.extend_from_slice(&key.to_le_bytes());
        buf.extend_from_slice(&tag.to_le_bytes());
        for w in payload {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        let mut f = Fnv1a::new();
        f.write(&buf);
        buf.extend_from_slice(&f.finish().to_le_bytes());
        let mut file = self.appender.lock().unwrap();
        if file.write_all(&buf).is_ok() {
            self.appends.fetch_add(1, Ordering::Relaxed);
            metrics::counters().store_appends.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Entries visible in the index (the file's history at open).
    pub fn len(&self) -> usize {
        self.index.keys.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Damaged regions skipped while reading (0 on a healthy file).
    pub fn skipped(&self) -> usize {
        self.skipped
    }
    /// Wall-clock µs spent opening + indexing.
    pub fn open_us(&self) -> u64 {
        self.open_us
    }
    /// Records appended by this process since open.
    pub fn appends(&self) -> u64 {
        self.appends.load(Ordering::Relaxed)
    }
    pub fn path(&self) -> &Path {
        &self.path
    }
    pub fn identity(&self) -> u64 {
        self.identity
    }
    /// Every key in the index (tests/forensics).
    pub fn keys(&self) -> &[u64] {
        &self.index.keys
    }
}

/// Identity of a search-side store: rendered arch text plus the full
/// mapper config (seed, budgets, *effective* shard count). Cache values
/// are deterministic functions of exactly these, so pinning them is
/// what makes a warm start bit-identical to a cold one; objectives are
/// deliberately excluded — mapper results are objective-independent, so
/// 2- and 3-objective searches share a store.
pub fn search_identity(arch: &Arch, cfg: &MapperConfig) -> u64 {
    let mut h = Fnv1a::new();
    h.write(render_arch(arch).as_bytes());
    h.write_u64(cfg.seed);
    h.write_u64(cfg.valid_target);
    h.write_u64(cfg.max_draws);
    h.write_u64(effective_shards(cfg) as u64);
    h.finish()
}

/// Payload width of a search-store record (mirrors `CachedEval`).
pub const SEARCH_SLOTS: usize = 9;

/// Open (creating dir + file as needed) the search store for this
/// arch + mapper config under `dir`. Files are namespaced by identity
/// (`mapper_<identity>.qstore`), so one directory serves any number of
/// configs; the header check still refuses a tampered or foreign file.
pub fn open_search_store(
    dir: &str,
    arch: &Arch,
    cfg: &MapperConfig,
) -> Result<Arc<CacheStore>, StoreError> {
    let identity = search_identity(arch, cfg);
    std::fs::create_dir_all(dir).map_err(|e| StoreError::Io(format!("{dir}: {e}")))?;
    let path = Path::new(dir).join(format!("mapper_{identity:016x}.qstore"));
    Ok(Arc::new(CacheStore::open(&path, identity, SEARCH_SLOTS)?))
}

/// Payload width of a worker-store record for an arch with `levels`
/// memory levels: the fixed `ShardOutcome` scalars plus the
/// per-level estimate vectors and mapping rows.
pub fn outcome_slots(levels: usize) -> usize {
    // valid, draws, has_best, edp, energy_pj, mac_energy_pj, cycles,
    // pes_used + per level: level_energy, level_words, temporal[7],
    // spatial[7], perm[7]
    8 + levels * (2 + 21)
}

/// Encode a `ShardOutcome` into a fixed-width word payload, hex-exact
/// (`f64::to_bits`). `levels` must match the arch the outcome came
/// from; outcomes with no winner zero-fill the estimate/mapping region.
pub fn encode_outcome(out: &ShardOutcome, levels: usize) -> Vec<u64> {
    let mut w = Vec::with_capacity(outcome_slots(levels));
    w.push(out.valid);
    w.push(out.draws);
    match &out.best {
        None => {
            w.push(0);
            w.resize(outcome_slots(levels), 0);
        }
        Some((edp, est, m)) => {
            w.push(1);
            w.push(edp.to_bits());
            w.push(est.energy_pj.to_bits());
            w.push(est.mac_energy_pj.to_bits());
            w.push(est.cycles.to_bits());
            w.push(est.pes_used);
            for i in 0..levels {
                w.push(est.level_energy_pj.get(i).copied().unwrap_or(0.0).to_bits());
                w.push(est.level_words.get(i).copied().unwrap_or(0.0).to_bits());
                let lm = m.levels.get(i);
                for j in 0..7 {
                    w.push(lm.map_or(0, |l| l.temporal[j]));
                }
                for j in 0..7 {
                    w.push(lm.map_or(0, |l| l.spatial[j]));
                }
                for j in 0..7 {
                    w.push(lm.map_or(0, |l| l.perm[j].index() as u64));
                }
            }
        }
    }
    w
}

/// Decode a worker-store payload back into a `ShardOutcome`. Total:
/// anything malformed (wrong width, out-of-range perm index) is `None`
/// — the store then counts as a miss and the shard is re-searched.
pub fn decode_outcome(payload: &[u64], levels: usize) -> Option<ShardOutcome> {
    if payload.len() != outcome_slots(levels) {
        return None;
    }
    let valid = payload[0];
    let draws = payload[1];
    if payload[2] == 0 {
        return Some(ShardOutcome { best: None, valid, draws });
    }
    let edp = f64::from_bits(payload[3]);
    let mut est = Estimate {
        energy_pj: f64::from_bits(payload[4]),
        level_energy_pj: Vec::with_capacity(levels),
        mac_energy_pj: f64::from_bits(payload[5]),
        cycles: f64::from_bits(payload[6]),
        level_words: Vec::with_capacity(levels),
        pes_used: payload[7],
    };
    let mut mapping = Mapping { levels: Vec::with_capacity(levels) };
    let mut i = 8;
    for _ in 0..levels {
        est.level_energy_pj.push(f64::from_bits(payload[i]));
        est.level_words.push(f64::from_bits(payload[i + 1]));
        i += 2;
        let mut lm = LevelMapping {
            temporal: [0; 7],
            spatial: [0; 7],
            perm: [Dim::N; 7],
        };
        lm.temporal.copy_from_slice(&payload[i..i + 7]);
        lm.spatial.copy_from_slice(&payload[i + 7..i + 14]);
        for j in 0..7 {
            let d = payload[i + 14 + j];
            if d >= 7 {
                return None;
            }
            lm.perm[j] = Dim::from_index(d as usize);
        }
        i += 21;
        mapping.levels.push(lm);
    }
    Some(ShardOutcome { best: Some((edp, est, mapping)), valid, draws })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;
    use crate::mapping::mapspace::MapSpace;
    use crate::mapping::LayerContext;
    use crate::quant::LayerQuant;
    use crate::workload::ConvLayer;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("qmap_store_{}_{name}.qstore", std::process::id()))
    }

    #[test]
    fn roundtrip_and_reopen() {
        let p = tmp("roundtrip");
        let _ = std::fs::remove_file(&p);
        let s = CacheStore::open(&p, 0xABCD, 3).unwrap();
        assert_eq!(s.len(), 0);
        s.append(1, 1, &[10, 11, 12]);
        s.append(2, 0, &[20, 0, 0]);
        // in-process appends are not visible until reopen (by design:
        // the in-memory cache fronts them)
        assert!(s.lookup(1).is_none());
        assert_eq!(s.appends(), 2);
        drop(s);
        let s = CacheStore::open(&p, 0xABCD, 3).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.skipped(), 0);
        assert_eq!(s.lookup(1), Some((1, &[10u64, 11, 12][..])));
        assert_eq!(s.lookup(2), Some((0, &[20u64, 0, 0][..])));
        assert!(s.lookup(3).is_none());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn last_record_wins_on_reappend() {
        let p = tmp("dedup");
        let _ = std::fs::remove_file(&p);
        let s = CacheStore::open(&p, 7, 2).unwrap();
        s.append(42, 0, &[1, 1]);
        s.append(42, 1, &[2, 2]);
        drop(s);
        let s = CacheStore::open(&p, 7, 2).unwrap();
        assert_eq!(s.len(), 1, "re-appended key must dedup");
        assert_eq!(s.lookup(42), Some((1, &[2u64, 2][..])));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn identity_and_layout_mismatches_refuse() {
        let p = tmp("identity");
        let _ = std::fs::remove_file(&p);
        CacheStore::open(&p, 0x1111, 3).unwrap();
        let e = CacheStore::open(&p, 0x2222, 3).unwrap_err();
        assert!(matches!(e, StoreError::IdentityMismatch { want: 0x2222, found: 0x1111, .. }));
        assert!(e.to_string().contains("refusing"), "{e}");
        let e = CacheStore::open(&p, 0x1111, 4).unwrap_err();
        assert!(matches!(e, StoreError::SlotsMismatch { want: 4, found: 3, .. }));
        let _ = std::fs::remove_file(&p);
        // not a store at all
        std::fs::write(&p, b"definitely not a store file, but long enough to read").unwrap();
        let e = CacheStore::open(&p, 0x1111, 3).unwrap_err();
        assert!(matches!(e, StoreError::NotAStore { .. }));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn torn_tail_and_bit_flips_lose_only_damaged_records() {
        let p = tmp("torn");
        let _ = std::fs::remove_file(&p);
        let s = CacheStore::open(&p, 5, 2).unwrap();
        for k in 0..10u64 {
            s.append(k, 1, &[k * 10, k * 100]);
        }
        drop(s);
        let healthy = std::fs::read(&p).unwrap();
        let stride = (3 + 2) * 8;

        // torn tail: a partial final record is ignored, the rest kept
        std::fs::write(&p, &healthy[..healthy.len() - stride / 2]).unwrap();
        let s = CacheStore::open(&p, 5, 2).unwrap();
        assert_eq!(s.len(), 9);
        drop(s);

        // bit flip in the middle: only that record is lost, and the
        // resync walk recovers every record after it
        let mut flipped = healthy.clone();
        let mid = HEADER_LEN + 4 * stride + 9;
        flipped[mid] ^= 0x40;
        std::fs::write(&p, &flipped).unwrap();
        let s = CacheStore::open(&p, 5, 2).unwrap();
        assert_eq!(s.len(), 9);
        assert_eq!(s.skipped(), 1);
        assert!(s.lookup(4).is_none(), "damaged record must not be served");
        assert!(s.lookup(9).is_some(), "records after damage must survive");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn concurrent_creation_publishes_one_header() {
        let p = tmp("create_race");
        let _ = std::fs::remove_file(&p);
        let handles: Vec<_> = (0..4u64)
            .map(|i| {
                let p = p.clone();
                std::thread::spawn(move || {
                    let s = CacheStore::open(&p, 99, 1).unwrap();
                    s.append(i, 0, &[i]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = CacheStore::open(&p, 99, 1).unwrap();
        assert_eq!(s.len(), 4, "all four appends visible, zero skipped: {}", s.skipped());
        assert_eq!(s.skipped(), 0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn search_identity_pins_arch_and_config() {
        let a = toy();
        let cfg = MapperConfig { valid_target: 100, max_draws: 50_000, seed: 1, shards: 1 };
        let id = search_identity(&a, &cfg);
        assert_eq!(id, search_identity(&a, &cfg), "deterministic");
        assert_ne!(id, search_identity(&a, &MapperConfig { seed: 2, ..cfg }));
        assert_ne!(id, search_identity(&a, &MapperConfig { max_draws: 99, ..cfg }));
        assert_ne!(id, search_identity(&a, &MapperConfig { shards: 2, ..cfg }));
        let mut b = toy();
        b.levels[0].capacity = crate::arch::Capacity::PerTensor([0, 64, 64]);
        assert_ne!(id, search_identity(&b, &cfg));
    }

    #[test]
    fn outcome_codec_is_bit_exact() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(4).canonical(a.word_bits, a.bit_packing);
        let space = MapSpace::of(&a);
        let lctx = LayerContext::new(&a, &l, &q);
        let levels = a.levels.len();
        let spec = super::super::ShardSpec { seed: 7, valid_target: 50, max_draws: 50_000 };
        let out = super::super::run_shard(&space, &lctx, &spec);
        assert!(out.best_edp().is_some());
        let w = encode_outcome(&out, levels);
        assert_eq!(w.len(), outcome_slots(levels));
        let back = decode_outcome(&w, levels).unwrap();
        assert_eq!(back, out, "decode(encode(x)) must be bit-identical");
        // no-winner outcome
        let empty = super::super::run_shard(
            &space,
            &lctx,
            &super::super::ShardSpec { seed: 7, valid_target: u64::MAX, max_draws: 0 },
        );
        let w = encode_outcome(&empty, levels);
        assert_eq!(decode_outcome(&w, levels).unwrap(), empty);
        // malformed payloads decode to None, never panic
        assert!(decode_outcome(&w[..w.len() - 1], levels).is_none());
        let mut bad_perm = encode_outcome(&out, levels);
        bad_perm[8 + 2 + 14] = 7; // first level's first perm slot: out of range
        assert!(decode_outcome(&bad_perm, levels).is_none());
    }

    #[test]
    fn store_roundtrips_outcome_payloads() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(8).canonical(a.word_bits, a.bit_packing);
        let space = MapSpace::of(&a);
        let lctx = LayerContext::new(&a, &l, &q);
        let levels = a.levels.len();
        let spec = super::super::ShardSpec { seed: 3, valid_target: 30, max_draws: 30_000 };
        let out = super::super::run_shard(&space, &lctx, &spec);
        let p = tmp("outcome");
        let _ = std::fs::remove_file(&p);
        let s = CacheStore::open(&p, 1, outcome_slots(levels)).unwrap();
        s.append(0xFEED, 1, &encode_outcome(&out, levels));
        drop(s);
        let s = CacheStore::open(&p, 1, outcome_slots(levels)).unwrap();
        let (tag, payload) = s.lookup(0xFEED).unwrap();
        assert_eq!(tag, 1);
        assert_eq!(decode_outcome(payload, levels).unwrap(), out);
        let _ = std::fs::remove_file(&p);
    }
}
