//! Minimal JSON value model, parser, and writer.
//!
//! serde/serde_json are not available offline; the repo needs JSON for the
//! artifact manifest (written by `python/compile/aot.py`), the on-disk
//! mapper cache, and experiment result dumps. This implements the subset of
//! JSON we emit and consume (objects, arrays, strings, numbers, bools,
//! null; `\uXXXX` escapes accepted, surrogate pairs not needed for our
//! ASCII manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// A `u64` as a 16-digit hex string value. JSON numbers are f64, so
    /// integers above 2^53 (draw budgets, RNG states, hashes) and f64
    /// *bit patterns* both travel as hex strings; see [`Json::hex_bits`].
    pub fn hex_u64(x: u64) -> Json {
        Json::Str(format!("{x:016x}"))
    }

    /// An `f64` as the hex of its IEEE-754 bits — the only encoding that
    /// round-trips every value (infinities, subnormals, every last
    /// mantissa bit). Used by `engine::checkpoint` and the distributed
    /// wire protocol, where "close" is not "bit-identical".
    pub fn hex_bits(x: f64) -> Json {
        Self::hex_u64(x.to_bits())
    }

    /// Decode a [`Json::hex_u64`] value; `what` names the field in the
    /// error message.
    pub fn as_hex_u64(&self, what: &str) -> Result<u64, String> {
        let s = self.as_str().ok_or_else(|| format!("{what}: not a string"))?;
        u64::from_str_radix(s, 16).map_err(|_| format!("{what}: bad hex '{s}'"))
    }

    /// Decode a [`Json::hex_bits`] value back to the exact f64.
    pub fn as_f64_bits(&self, what: &str) -> Result<f64, String> {
        self.as_hex_u64(what).map(f64::from_bits)
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting the parser accepts. The parser is
/// recursive-descent, so unbounded nesting (`[[[[…`) from an untrusted
/// source — a network frame, a corrupt checkpoint — would overflow the
/// stack instead of returning an error. Our real documents nest < 10
/// deep.
pub const MAX_DEPTH: usize = 128;

/// Parse a JSON document. Returns an error message with byte offset on
/// malformed input; never panics, and refuses pathological nesting
/// (see [`MAX_DEPTH`]).
pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: src.as_bytes(),
        i: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        self.enter()?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap_or("");
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).ok_or("bad \\u codepoint")?);
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "utf8")?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "utf8")?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{txt}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,-3e2],"b":{"c":"hi\n","d":true,"e":null}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").get("c").as_str(), Some("hi\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn integers_write_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn hex_u64_roundtrips_extremes() {
        for x in [0u64, 1, u64::MAX, 0xDEAD_BEEF_CAFE_F00D] {
            let v = Json::hex_u64(x);
            assert_eq!(v.as_hex_u64("x").unwrap(), x);
            let v2 = parse(&v.to_string()).unwrap();
            assert_eq!(v2.as_hex_u64("x").unwrap(), x);
        }
        assert!(Json::Num(1.0).as_hex_u64("x").is_err());
        assert!(Json::Str("zz".into()).as_hex_u64("x").is_err());
    }

    #[test]
    fn hex_bits_roundtrips_every_f64_class() {
        for x in [
            0.0,
            -0.0,
            1.5e-308,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            std::f64::consts::PI,
        ] {
            let v = Json::hex_bits(x);
            assert_eq!(v.as_f64_bits("x").unwrap().to_bits(), x.to_bits());
        }
        // NaN round-trips by bit pattern even though NaN != NaN
        let v = Json::hex_bits(f64::NAN);
        assert_eq!(v.as_f64_bits("x").unwrap().to_bits(), f64::NAN.to_bits());
    }

    #[test]
    fn pathological_nesting_is_an_error_not_a_crash() {
        let deep = "[".repeat(MAX_DEPTH + 10) + &"]".repeat(MAX_DEPTH + 10);
        let err = parse(&deep).expect_err("deep nesting must be rejected");
        assert!(err.contains("nesting"), "{err}");
        // mixed object/array nesting hits the same guard
        let mixed = "{\"a\":".repeat(MAX_DEPTH) + "1" + &"}".repeat(MAX_DEPTH);
        assert!(parse(&mixed).is_err());
        // a document at a sane depth still parses
        let ok = "[".repeat(20) + &"]".repeat(20);
        assert!(parse(&ok).is_ok());
    }
}
