"""Fake-quantization primitives (L2 building blocks).

Per-tensor *asymmetric* quantization with dynamic (min/max observer)
range, matching the paper's training engine ("the quantization is based
on a per-tensor asymmetric approach"). Bit-widths are **traced values**
(f32 scalars), so one AOT-compiled executable serves every genome the
Rust search engine proposes — bit-widths arrive as runtime tensors, not
compile-time constants.

Gradients use the straight-through estimator (STE): the
quantize-dequantize round-trip is identity in the backward pass.
"""

import jax
import jax.numpy as jnp

# Values below this span are treated as constant tensors (no quantization
# noise can be represented anyway; avoids 0-division in scale).
_EPS = 1e-8


def qparams(t: jax.Array, bits: jax.Array):
    """Asymmetric per-tensor quantizer parameters (min, scale).

    ``bits`` is a traced f32 scalar; ``levels = 2^bits - 1``.
    Returns ``(tmin, scale)`` such that ``q = round((t - tmin)/scale)``
    lies in ``[0, levels]``.
    """
    levels = jnp.exp2(bits) - 1.0
    tmin = jnp.min(t)
    tmax = jnp.max(t)
    scale = jnp.maximum(tmax - tmin, _EPS) / levels
    return tmin, scale


def quant_dequant(t: jax.Array, bits: jax.Array) -> jax.Array:
    """Quantize-dequantize round trip (no STE; raw forward math)."""
    tmin, scale = qparams(t, bits)
    q = jnp.round((t - tmin) / scale)
    return q * scale + tmin


def fake_quant(t: jax.Array, bits: jax.Array) -> jax.Array:
    """Quantize-dequantize with straight-through gradient.

    Forward: ``quant_dequant(t, bits)``. Backward: identity w.r.t. ``t``
    (and no gradient into ``bits``).
    """
    dq = quant_dequant(t, jax.lax.stop_gradient(bits))
    return t + jax.lax.stop_gradient(dq - t)
