//! Experiment reporting: ASCII tables, CSV/JSON dumps, SVG figures, and
//! Pareto-front formatting shared by the benches that regenerate each
//! paper artifact.

pub mod svg;

use crate::baselines::Candidate;
use std::fmt::Write as _;

/// Render an ASCII table with a header row.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            let _ = write!(out, "+{}", "-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    for (i, h) in headers.iter().enumerate() {
        let _ = write!(out, "| {:<w$} ", h, w = widths[i]);
    }
    out.push_str("|\n");
    sep(&mut out);
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            let _ = write!(out, "| {:<w$} ", c, w = widths[i]);
        }
        out.push_str("|\n");
    }
    sep(&mut out);
    out
}

/// CSV rendering (comma-escaping via quoting).
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let esc = |s: &str| {
        if s.contains(',') || s.contains('"') || s.contains('\n') {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    };
    let mut out = String::new();
    out.push_str(
        &headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(","),
    );
    out.push('\n');
    for r in rows {
        out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

/// Format candidates as a Pareto table relative to a reference point
/// (the uniform-8-bit implementation, as in paper Fig. 6 / Table II).
pub fn pareto_table(cands: &[Candidate], ref_edp: f64, ref_mem: f64, ref_acc: f64) -> String {
    let mut rows: Vec<Vec<String>> = cands
        .iter()
        .map(|c| {
            vec![
                c.strategy.to_string(),
                format!("{:.4}", c.accuracy),
                format!("{:+.1}%", (c.accuracy - ref_acc) * 100.0),
                format!("{:.3}", c.hw.edp / ref_edp),
                format!(
                    "{:+.1}%",
                    (c.hw.memory_energy_pj / ref_mem - 1.0) * 100.0
                ),
            ]
        })
        .collect();
    rows.sort_by(|a, b| a[3].partial_cmp(&b[3]).unwrap_or(std::cmp::Ordering::Equal));
    table(
        &["strategy", "top-1", "Δacc", "EDP (rel u8)", "Δ mem-energy"],
        &rows,
    )
}

/// ASCII scatter of (x, y) points, log-x optional — a terminal stand-in
/// for the paper's figures.
pub fn ascii_scatter(
    points: &[(f64, f64, char)],
    width: usize,
    height: usize,
    x_label: &str,
    y_label: &str,
) -> String {
    if points.is_empty() {
        return "(no points)\n".into();
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y, _) in points {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 <= x0 {
        x1 = x0 + 1.0;
    }
    if y1 <= y0 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for &(x, y, c) in points {
        let xi = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
        let yi = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
        grid[height - 1 - yi][xi] = c;
    }
    let mut out = String::new();
    let _ = writeln!(out, "{y_label} ({y0:.3} .. {y1:.3})");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    let _ = writeln!(out, "+{}", "-".repeat(width));
    let _ = writeln!(out, " {x_label} ({x0:.3} .. {x1:.3})");
    out
}

/// Write an experiment artifact under `results/` (created on demand)
/// and return its path. Benches use this so every regenerated table and
/// figure leaves a CSV/JSON trace next to the printed output.
pub fn write_results(name: &str, content: &str) -> std::path::PathBuf {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(name);
    if let Err(e) = std::fs::write(&path, content) {
        eprintln!("warning: could not write {}: {e}", path.display());
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = table(
            &["a", "bbbb"],
            &[
                vec!["1".into(), "2".into()],
                vec!["333".into(), "4".into()],
            ],
        );
        assert!(t.contains("| a   | bbbb |"));
        assert!(t.contains("| 333 | 4    |"));
        // all lines same width
        let widths: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn csv_escaping() {
        let s = csv(&["x", "y"], &[vec!["a,b".into(), "c\"d".into()]]);
        assert_eq!(s, "x,y\n\"a,b\",\"c\"\"d\"\n");
    }

    #[test]
    fn scatter_renders_extremes() {
        let s = ascii_scatter(
            &[(0.0, 0.0, 'o'), (1.0, 1.0, '*')],
            20,
            5,
            "x",
            "y",
        );
        assert!(s.contains('o'));
        assert!(s.contains('*'));
        let first_grid_line = s.lines().nth(1).unwrap();
        assert!(first_grid_line.ends_with('*')); // top-right
    }

    #[test]
    fn scatter_empty() {
        assert_eq!(ascii_scatter(&[], 10, 5, "x", "y"), "(no points)\n");
    }
}
