//! Stateful property tests for the execution engine, in the style of
//! radupopescu/proptest-stateful's model-vs-SUT approach: generate a
//! random command sequence, apply it both to a *model* (single-threaded
//! `eval::evaluate_network` with its own cache) and to the *SUT* (the
//! work-stealing engine with a random worker count, random per-job
//! shard counts, and its own cache), and assert the two systems agree
//! bit-for-bit after every command — including across a mid-run
//! checkpoint save/restore of the NSGA-II search.

use qmap::accuracy::{ProxyAccuracy, ProxyParams};
use qmap::arch::presets::toy;
use qmap::engine::{driver, Checkpointer, Engine, SchedPolicy};
use qmap::eval::evaluate_network;
use qmap::mapper::cache::MapperCache;
use qmap::mapper::MapperConfig;
use qmap::nsga::NsgaConfig;
use qmap::objective::ObjectiveSpec;
use qmap::quant::{QuantConfig, QMAX, QMIN};
use qmap::util::prop::{check_shrink, Config};
use qmap::util::rng::Rng;
use qmap::workload::ConvLayer;

/// Objective spec for a generated script: `QMAP_OBJECTIVES` pins it
/// (the CI matrix rides a 3-objective cell); otherwise drawn from a
/// pool spanning 2-, 3-, and 4-axis spaces. The repo invariant —
/// checkpointed/parallel runs bit-identical to serial — must hold for
/// every spec, so the spec is part of the generated input.
fn pick_spec(r: &mut Rng) -> ObjectiveSpec {
    if let Some(pinned) = ObjectiveSpec::from_env().expect("QMAP_OBJECTIVES") {
        return pinned;
    }
    let pool = [
        "edp,error",
        "error,energy,weight_words",
        "memory_energy,edp,error",
        "error,energy,edp,model_size",
    ];
    ObjectiveSpec::parse(pool[r.below(pool.len() as u64) as usize]).expect("pool spec")
}

fn small_net() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("c1", 3, 8, 3, 16, 1),
        ConvLayer::dw("d1", 8, 3, 16, 1),
        ConvLayer::pw("p1", 8, 16, 16),
        ConvLayer::fc("fc", 16, 10),
    ]
}

/// Engine worker count: `QMAP_TEST_WORKERS` pins it (the CI matrix
/// runs {1, 2, 4}); otherwise it is drawn per script.
fn pick_workers(r: &mut Rng) -> usize {
    qmap::util::prop::env_test_workers().unwrap_or_else(|| r.range(1, 4))
}

/// Guided-scheduling flag: `QMAP_GUIDED` pins it (the CI matrix rides a
/// guided cell); otherwise drawn per script. When set, the engine's
/// validity-rate guide is pre-seeded with deterministic synthetic rates
/// before the first command, so the priority policy ranks by expected
/// draws from the start instead of only after the first fold. Guidance
/// is placement-only, so the flag must be invisible in every result.
fn pick_guided(r: &mut Rng) -> bool {
    match std::env::var("QMAP_GUIDED") {
        // the CI matrix exports an empty string on unpinned cells —
        // treat that as unset, not as "unguided"
        Ok(v) if !v.is_empty() => v == "1" || v.eq_ignore_ascii_case("true"),
        _ => r.below(2) == 1,
    }
}

fn random_genome(r: &mut Rng, n: usize) -> QuantConfig {
    let mut g = QuantConfig::uniform(n, 8);
    for l in g.layers.iter_mut() {
        l.0 = QMIN + r.below((QMAX - QMIN + 1) as u64) as u8;
        l.1 = QMIN + r.below((QMAX - QMIN + 1) as u64) as u8;
    }
    g
}

/// One command of the stateful test: a batch of genomes to evaluate.
#[derive(Debug, Clone)]
struct Cmd {
    genomes: Vec<QuantConfig>,
}

#[derive(Debug, Clone)]
struct Script {
    workers: usize,
    shards: usize,
    /// Job-injection order: FIFO, priority, or a random permutation —
    /// every one must be invisible in the results.
    policy: SchedPolicy,
    /// Pre-seed the validity-rate guide so scheduling is guided from
    /// the first command — also required to be invisible.
    guided: bool,
    commands: Vec<Cmd>,
}

fn random_policy(r: &mut Rng) -> SchedPolicy {
    match r.below(3) {
        0 => SchedPolicy::Fifo,
        1 => SchedPolicy::Priority,
        _ => SchedPolicy::Shuffled(r.next_u64()),
    }
}

fn random_script(r: &mut Rng) -> Script {
    let n = small_net().len();
    let commands = (0..r.range(2, 4))
        .map(|_| Cmd {
            genomes: (0..r.range(1, 3)).map(|_| random_genome(r, n)).collect(),
        })
        .collect();
    Script {
        workers: pick_workers(r),
        shards: r.range(1, 3),
        policy: random_policy(r),
        guided: pick_guided(r),
        commands,
    }
}

/// Shrink a failing script toward the smallest one that still fails:
/// drop trailing commands, thin each command's genome batch, walk the
/// worker / shard counts down toward the serial baseline, and soften
/// the scheduling policy to FIFO (a policy that can be removed without
/// fixing the failure was not the cause).
fn shrink_script(s: &Script) -> Vec<Script> {
    let mut out = Vec::new();
    if s.commands.len() > 1 {
        let mut t = s.clone();
        t.commands.pop();
        out.push(t);
    }
    for i in 0..s.commands.len() {
        if s.commands[i].genomes.len() > 1 {
            let mut t = s.clone();
            t.commands[i].genomes.pop();
            out.push(t);
        }
    }
    if s.workers > 1 {
        let mut t = s.clone();
        t.workers -= 1;
        out.push(t);
    }
    if s.shards > 1 {
        let mut t = s.clone();
        t.shards -= 1;
        out.push(t);
    }
    if s.policy != SchedPolicy::Fifo {
        let mut t = s.clone();
        t.policy = SchedPolicy::Fifo;
        out.push(t);
    }
    if s.guided {
        let mut t = s.clone();
        t.guided = false;
        out.push(t);
    }
    out
}

#[test]
fn engine_agrees_with_serial_model_under_random_job_mixes() {
    let arch = toy();
    let layers = small_net();
    check_shrink(&Config::from_env(0xE6E1, 10), random_script, shrink_script, |script| {
        let cfg = MapperConfig {
            valid_target: 24,
            max_draws: 24_000,
            seed: 13,
            shards: script.shards,
        };
        let engine = Engine::new(script.workers).with_sched_policy(script.policy);
        if script.guided {
            // deterministic synthetic rates; real workload hashes join
            // via the engine's own fold after the first command. The
            // guide may only reorder job placement, never results.
            for i in 0..4u64 {
                engine.guide_note(0x6A1D_E000 ^ i, 1 + i, 64 * (i + 1));
            }
        }
        let sut_cache = MapperCache::new();
        let model_cache = MapperCache::new();
        for (ci, cmd) in script.commands.iter().enumerate() {
            // SUT: deduplicated jobs on the work-stealing pool
            let got = driver::evaluate_genomes(
                &engine,
                &arch,
                &layers,
                &cmd.genomes,
                &sut_cache,
                &cfg,
            );
            // model: plain serial evaluation, genome by genome
            for (gi, g) in cmd.genomes.iter().enumerate() {
                let want = evaluate_network(&arch, &layers, g, &model_cache, &cfg);
                if got[gi] != want {
                    return Err(format!(
                        "command {ci}, genome {gi}: engine {:?} != serial {:?} \
                         (workers={}, shards={}, policy={:?}, guided={})",
                        got[gi], want, script.workers, script.shards, script.policy,
                        script.guided
                    ));
                }
            }
        }
        Ok(())
    });
}

fn ckpt_path(tag: u64) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("qmap_stateful_{tag}_{}.json", std::process::id()));
    p.to_string_lossy().into_owned()
}

/// Run the checkpointed search to `stop_after` generations (simulating
/// an interruption), then resume from the file with a *fresh* engine,
/// cache, and accuracy model, and compare the final front against an
/// uninterrupted run — bit-for-bit, for random worker counts and
/// interruption points.
#[test]
fn checkpoint_restore_mid_search_is_bit_identical() {
    let arch = toy();
    let layers = small_net();
    let map_cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 17,
        shards: 1,
    };
    let nsga_cfg = NsgaConfig {
        population: 8,
        offspring: 4,
        generations: 5,
        seed: 23,
        ..NsgaConfig::default()
    };

    let front_key = |cands: &[qmap::baselines::Candidate]| -> Vec<(Vec<u8>, u64)> {
        let mut k: Vec<(Vec<u8>, u64)> = cands
            .iter()
            .map(|c| (c.genome.encode(), c.hw.edp.to_bits()))
            .collect();
        k.sort();
        k
    };

    // the uninterrupted serial reference fronts, one per spec the
    // generator can draw (computed lazily, cached across cases and
    // shrink steps — the pool has at most four entries)
    let mut references: std::collections::HashMap<u64, Vec<(Vec<u8>, u64)>> =
        std::collections::HashMap::new();
    check_shrink(
        &Config::from_env(0xE6E2, 6),
        |r| (r.range(0, 4), pick_workers(r), r.next_u64(), pick_spec(r)),
        |&(stop_after, workers, tag, spec)| {
            // shrink toward the earliest interruption, the serial
            // engine, and the default objective space, keeping the
            // checkpoint-file tag stable
            let mut cands = Vec::new();
            if stop_after > 0 {
                cands.push((stop_after - 1, workers, tag, spec));
            }
            if workers > 1 {
                cands.push((stop_after, workers - 1, tag, spec));
            }
            if spec != ObjectiveSpec::default() {
                cands.push((stop_after, workers, tag, ObjectiveSpec::default()));
            }
            cands
        },
        |&(stop_after, workers, tag, spec)| {
            let reference = match references.get(&spec.hash()) {
                Some(r) => r.clone(),
                None => {
                    let engine = Engine::new(1);
                    let cache = MapperCache::new();
                    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
                    let path = ckpt_path(tag ^ 1);
                    let ckpt = Checkpointer::new(path.as_str());
                    let cands = driver::search_resumable(
                        &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg,
                        &spec, &ckpt, false,
                        |_, _| {},
                    )
                    .map_err(|e| format!("reference: {e}"))?;
                    let _ = std::fs::remove_file(&path);
                    let r = front_key(&cands);
                    references.insert(spec.hash(), r.clone());
                    r
                }
            };
            let path = ckpt_path(tag);
            let ckpt = Checkpointer::new(path.as_str());
            // phase 1: run, but stop after `stop_after` generations
            {
                let engine = Engine::new(workers);
                let cache = MapperCache::new();
                let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
                let truncated = NsgaConfig {
                    generations: stop_after,
                    ..nsga_cfg
                };
                driver::search_resumable(
                    &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &truncated, &spec,
                    &ckpt, false,
                    |_, _| {},
                )
                .map_err(|e| format!("phase 1: {e}"))?;
            }
            // phase 2: everything is dropped; resume from disk alone
            let resumed = {
                let engine = Engine::new(workers);
                let cache = MapperCache::new();
                let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
                driver::search_resumable(
                    &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec,
                    &ckpt, true,
                    |_, _| {},
                )
                .map_err(|e| format!("phase 2: {e}"))?
            };
            let _ = std::fs::remove_file(&path);
            let got = front_key(&resumed);
            if got != reference {
                return Err(format!(
                    "resumed front differs (stop_after={stop_after}, workers={workers}, \
                     spec={spec}):\ngot {got:?}\nwant {reference:?}"
                ));
            }
            Ok(())
        },
    );
}

/// A search checkpointed at every generation but never interrupted must
/// match the plain (non-checkpointed) `proposed_search` exactly — the
/// checkpoint machinery must be invisible to the result.
#[test]
fn checkpointing_does_not_perturb_the_search() {
    let arch = toy();
    let layers = small_net();
    let map_cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 29,
        shards: 1,
    };
    let nsga_cfg = NsgaConfig {
        population: 8,
        offspring: 4,
        generations: 3,
        seed: 31,
        ..NsgaConfig::default()
    };
    let engine = Engine::new(2);
    // the env-pinned spec when the matrix rides one, else the default
    let spec = ObjectiveSpec::from_env()
        .expect("QMAP_OBJECTIVES")
        .unwrap_or_default();

    let plain = {
        let cache = MapperCache::new();
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
        qmap::baselines::search_with_objectives(
            &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec, |_, _| {},
        )
    };
    let path = ckpt_path(0xC0);
    let ckpt = Checkpointer::new(path.as_str());
    let checkpointed = {
        let cache = MapperCache::new();
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
        driver::search_resumable(
            &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec, &ckpt,
            false,
            |_, _| {},
        )
        .expect("checkpointed search")
    };
    let _ = std::fs::remove_file(&path);

    assert_eq!(plain.len(), checkpointed.len());
    for (a, b) in plain.iter().zip(&checkpointed) {
        assert_eq!(a.genome, b.genome);
        assert_eq!(a.hw.edp.to_bits(), b.hw.edp.to_bits());
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    }
}
