//! Fig. 1 (a, b): correlation between naïve model size (total weight
//! bits) and (a) the packed weight-memory word count, (b) the EDP of one
//! inference on Eyeriss, over random mixed-precision MobileNetV1
//! configurations.
//!
//! Paper shape to reproduce: strong (but imperfect, bit-packing kinks)
//! size<->word correlation, *weak* size<->EDP correlation — the
//! motivation for hardware-aware quantization.
//!
//! Run: `cargo bench --bench fig1_correlation` (QMAP_PROFILE=full for
//! the paper's n=1000).

use qmap::coordinator::experiments::fig1_correlation;
use qmap::coordinator::RunConfig;
use qmap::report;
use std::time::Instant;

fn main() {
    let rc = RunConfig::from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2)
    });
    let n = match std::env::var("QMAP_PROFILE").as_deref() {
        Ok("fast") => 60,
        Ok("full") => 1000, // the paper's 1000 unique configurations
        _ => 250,
    };

    println!("=== Fig. 1: model size vs words / EDP ({n} random MobileNetV1 configs, Eyeriss) ===");
    let t0 = Instant::now();
    let r = fig1_correlation(n, &rc);
    let dt = t0.elapsed();

    // (a) size vs packed word count
    let pts_a: Vec<(f64, f64, char)> = r
        .points
        .iter()
        .map(|p| (p.model_size_bits as f64 / 1e6, p.weight_words as f64 / 1e6, '.'))
        .chain(std::iter::once((
            r.uniform8.model_size_bits as f64 / 1e6,
            r.uniform8.weight_words as f64 / 1e6,
            'U',
        )))
        .collect();
    println!("\n(a) Memory word count after bit-packing ('U' = uniform 8-bit):");
    print!(
        "{}",
        report::ascii_scatter(&pts_a, 72, 18, "model size [Mbit]", "weight words [M]")
    );

    // (b) size vs EDP
    let pts_b: Vec<(f64, f64, char)> = r
        .points
        .iter()
        .map(|p| (p.model_size_bits as f64 / 1e6, p.edp, '.'))
        .chain(std::iter::once((
            r.uniform8.model_size_bits as f64 / 1e6,
            r.uniform8.edp,
            'U',
        )))
        .collect();
    println!("\n(b) EDP on Eyeriss:");
    print!(
        "{}",
        report::ascii_scatter(&pts_b, 72, 18, "model size [Mbit]", "EDP [J*cycles]")
    );

    println!("\nPearson r (size vs packed words): {:+.4}", r.r_size_words);
    println!("Pearson r (size vs EDP):          {:+.4}", r.r_size_edp);
    println!(
        "paper shape: r(size,words) high but <1 (packing kinks); r(size,EDP) weak  ->  {}",
        if r.r_size_words > 0.85 && r.r_size_edp < r.r_size_words - 0.05 {
            "REPRODUCED"
        } else {
            "MISMATCH"
        }
    );

    let rows: Vec<Vec<String>> = r
        .points
        .iter()
        .map(|p| {
            vec![
                p.model_size_bits.to_string(),
                p.weight_words.to_string(),
                format!("{:.6e}", p.edp),
            ]
        })
        .collect();
    let path = report::write_results(
        "fig1_points.csv",
        &report::csv(&["model_size_bits", "weight_words", "edp"], &rows),
    );

    // SVG versions of both panels
    let mut pa = report::svg::Plot::new(
        "Fig 1(a): model size vs packed word count",
        "model size [Mbit]",
        "weight words [M]",
    );
    pa.scatter("random configs", &pts_a.iter().map(|&(x, y, _)| (x, y)).collect::<Vec<_>>());
    pa.scatter("uniform 8-bit", &[(r.uniform8.model_size_bits as f64 / 1e6, r.uniform8.weight_words as f64 / 1e6)]);
    report::write_results("fig1a.svg", &pa.render());
    let mut pb = report::svg::Plot::new(
        "Fig 1(b): model size vs EDP (Eyeriss)",
        "model size [Mbit]",
        "EDP [J*cycles]",
    );
    pb.scatter("random configs", &pts_b.iter().map(|&(x, y, _)| (x, y)).collect::<Vec<_>>());
    pb.scatter("uniform 8-bit", &[(r.uniform8.model_size_bits as f64 / 1e6, r.uniform8.edp)]);
    report::write_results("fig1b.svg", &pb.render());
    println!("[{dt:.2?}] wrote {} (+ fig1a.svg, fig1b.svg)", path.display());
}
