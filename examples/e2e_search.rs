//! End-to-end driver: all three layers composing on a real small
//! workload (DESIGN.md §6).
//!
//!   L1/L2 (build time): `make artifacts` lowered the JAX QAT
//!     MobileNetV1-0.25 (Pallas fake-quant matmul inside) to HLO text.
//!   Runtime: Rust loads the artifacts via PJRT — Python is NOT running.
//!   L3: (1) QAT-8 pre-training with a logged loss curve,
//!       (2) NSGA-II search with REAL QAT fine-tuning in the loop
//!           (accuracy) and the mapping engine (EDP on Eyeriss),
//!       (3) final Pareto front, recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_search`
//! Env: QMAP_PRETRAIN_STEPS (default 300), QMAP_GENS (default 6),
//!      QMAP_FINETUNE_STEPS (default 40).

use qmap::arch::presets;
use qmap::baselines::proposed_search;
use qmap::coordinator::RunConfig;
use qmap::data::SyntheticDataset;
use qmap::mapper::cache::MapperCache;
use qmap::report;
use qmap::runtime::qat::{QatAccuracy, QatBudget};
use qmap::runtime::{default_artifact_dir, Runtime};
use std::fmt::Write as _;
use std::time::Instant;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<(), String> {
    let t0 = Instant::now();
    println!("=== E2E: QAT-in-the-loop quantization + mapping search ===\n");

    // ---- load AOT artifacts (fails with a hint if `make artifacts` wasn't run)
    let rt = Runtime::load(default_artifact_dir())?;
    println!(
        "[runtime] PJRT platform: {}; model {} ({} layers, {} params, batch {})",
        rt.platform(),
        rt.meta.model,
        rt.meta.num_layers,
        rt.meta.param_size,
        rt.meta.batch
    );

    // ---- phase 1: QAT-8 pre-training (the paper's "QAT-8 initial model")
    let data = SyntheticDataset::new(0xDA7A);
    let steps = env_u64("QMAP_PRETRAIN_STEPS", 300);
    println!("\n[pretrain] QAT-8 for {steps} steps (loss curve below)");
    let mut curve: Vec<(u64, f32)> = Vec::new();
    let params = QatAccuracy::pretrain(&rt, &data, 8, steps, 0.05, |step, loss| {
        if step % 20 == 0 || step + 1 == steps {
            println!("  step {step:>5}  loss {loss:.4}");
        }
        curve.push((step, loss));
    })?;
    let first_avg: f32 =
        curve.iter().take(10).map(|&(_, l)| l).sum::<f32>() / 10.0_f32.min(curve.len() as f32);
    let last_avg: f32 = curve.iter().rev().take(10).map(|&(_, l)| l).sum::<f32>()
        / 10.0_f32.min(curve.len() as f32);
    println!("[pretrain] loss {first_avg:.4} -> {last_avg:.4} (must fall for the stack to be learning)");
    assert!(
        last_avg < first_avg,
        "loss did not decrease — training path broken"
    );

    // baseline accuracy of the QAT-8 checkpoint
    let mut qat = QatAccuracy::new(
        &rt,
        SyntheticDataset::new(0xDA7A),
        params,
        QatBudget {
            finetune_steps: env_u64("QMAP_FINETUNE_STEPS", 40),
            eval_batches: 6,
            lr: 0.02,
        },
    );
    let u8_acc = qat.evaluate(&qmap::quant::QuantConfig::uniform(rt.meta.num_layers, 8))?;
    println!("[pretrain] QAT-8 top-1 on held-out batches: {:.3}", u8_acc);

    // ---- phase 2: NSGA-II with real QAT in the loop, EDP on Eyeriss
    // The hardware side prices the *full-size* MobileNetV1 layer table —
    // the trained model is the width-scaled proxy (DESIGN.md §3).
    let arch = presets::eyeriss();
    let layers = qmap::workload::models::mobilenet_v1();
    assert_eq!(layers.len(), rt.meta.num_layers, "genome length mismatch");
    let cache = MapperCache::new();
    let mut rc = RunConfig::fast();
    rc.nsga.population = 16;
    rc.nsga.offspring = 8;
    rc.nsga.generations = env_u64("QMAP_GENS", 6) as usize;

    println!(
        "\n[search] NSGA-II: |P|={}, |Q|={}, {} generations, real QAT fine-tune per candidate",
        rc.nsga.population, rc.nsga.offspring, rc.nsga.generations
    );
    let t_search = Instant::now();
    let engine = qmap::engine::Engine::new(rc.threads);
    let front = proposed_search(
        &engine,
        &arch,
        &layers,
        &mut qat,
        &cache,
        &rc.mapper,
        &rc.nsga,
        |generation, pop| {
            // the default spec is (edp, error): look the axes up by
            // name instead of trusting positions
            let spec = qmap::objective::ObjectiveSpec::default();
            let i_err = spec.index_of(qmap::objective::Axis::Error).expect("error axis");
            let i_edp = spec.index_of(qmap::objective::Axis::Edp).expect("edp axis");
            let best_acc = pop
                .iter()
                .map(|i| 1.0 - i.objectives[i_err])
                .fold(f64::NEG_INFINITY, f64::max);
            let best_edp = pop
                .iter()
                .map(|i| i.objectives[i_edp])
                .fold(f64::INFINITY, f64::min);
            println!(
                "  gen {generation:>3}: best top-1 {best_acc:.3}, best EDP {best_edp:.3e} ({} mapper workloads cached)",
                cache.len()
            );
        },
    );
    println!("[search] done in {:.1?}", t_search.elapsed());

    // ---- phase 3: report the final front
    let reference = qmap::eval::evaluate_network(
        &arch,
        &layers,
        &qmap::quant::QuantConfig::uniform(layers.len(), 8),
        &cache,
        &rc.mapper,
    )
    .expect("uniform-8 maps");

    println!("\nfinal Pareto candidates (relative to uniform 8-bit):");
    print!(
        "{}",
        report::pareto_table(&front, reference.edp, reference.memory_energy_pj, u8_acc)
    );

    let best_saving = front
        .iter()
        .filter(|c| c.accuracy >= u8_acc - 0.005)
        .map(|c| 1.0 - c.hw.memory_energy_pj / reference.memory_energy_pj)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "best memory-energy saving at <=0.5% accuracy drop: {:.1}%",
        best_saving * 100.0
    );

    // persist the loss curve + front for EXPERIMENTS.md
    let mut csv = String::from("step,loss\n");
    for (s, l) in &curve {
        let _ = writeln!(csv, "{s},{l}");
    }
    report::write_results("e2e_loss_curve.csv", &csv);
    let rows: Vec<Vec<String>> = front
        .iter()
        .map(|c| {
            vec![
                format!("{:.4}", c.accuracy),
                format!("{:.4e}", c.hw.edp),
                format!("{:.4e}", c.hw.memory_energy_pj),
                c.genome
                    .layers
                    .iter()
                    .map(|&(a, w)| format!("{a}/{w}"))
                    .collect::<Vec<_>>()
                    .join(" "),
            ]
        })
        .collect();
    let path = report::write_results(
        "e2e_front.csv",
        &report::csv(&["top1", "edp", "mem_energy_pj", "genome"], &rows),
    );
    println!("\nwrote {}", path.display());
    println!("total {:.1?}; python was never on the request path.", t0.elapsed());
    Ok(())
}
