//! Precomputed per-workload evaluation context — the hot-path engine's
//! lookup tables.
//!
//! The mapper prices hundreds of thousands of candidate mappings per
//! `(arch, layer, quant)` workload, but everything the checker and the
//! nest analysis derive from the workload itself is invariant across
//! candidates: tensor-relevance of each problem dim, keeper chains,
//! per-level capacities in packed words, prime factorizations of the dim
//! sizes, per-level energy/bandwidth constants. [`LayerContext`]
//! precomputes all of it once so the per-candidate path
//! (`random_mapping_into` → [`LayerContext::check`] →
//! [`crate::nest::analyze_into`] → [`crate::energy::estimate_into`])
//! performs only table lookups and arithmetic — no heap allocation, no
//! re-derivation.
//!
//! The context path is bit-identical to the naive path
//! ([`crate::mapping::check`] / [`crate::nest::analyze`] /
//! [`crate::energy::estimate`]); `tests/hotpath_equivalence.rs` asserts
//! this property on random mappings.

use super::factorize::prime_factors;
use super::{Mapping, Violation};
use crate::arch::{Arch, Capacity};
use crate::quant::{pack_factor, LayerQuant};
use crate::util::ceil_div;
use crate::workload::{ConvLayer, Dim, Tensor, DIMS, TENSORS};

/// Immutable per-`(arch, layer, quant)` lookup tables for the mapper hot
/// path. Build once per workload with [`LayerContext::new`]; share
/// freely across search shards (`&LayerContext` is `Sync`).
#[derive(Debug, Clone)]
pub struct LayerContext {
    /// The workload (owned copy; `tile_elements` etc. run against it).
    pub layer: ConvLayer,
    /// Canonicalized quantization (packing-equivalence representative).
    pub q: LayerQuant,
    pub num_levels: usize,
    /// Prime factorization of each dim size, indexed by `Dim::index()`.
    pub dim_primes: Vec<Vec<(u64, u32)>>,
    /// Relevance bitmask per tensor: bit `d` set iff dim `d` is relevant
    /// to the tensor (replaces `ConvLayer::is_relevant` calls).
    pub relevant: [u8; 3],
    /// Keeper chain per tensor: levels storing the tensor, innermost
    /// first (DRAM always last).
    pub keepers: [Vec<usize>; 3],
    /// `keeps` flags per level (copy of `Level::keeps`).
    pub keeps: Vec<[bool; 3]>,
    /// Capacity model per level (DRAM entry is `Unbounded`).
    pub caps: Vec<Capacity>,
    /// SoA capacity tables: per-(level, tensor) word limits for
    /// `Capacity::PerTensor` levels, `u64::MAX` (never trips) elsewhere.
    /// Lets the capacity stage run without matching on the enum.
    pub cap_words: Vec<[u64; 3]>,
    /// Aggregate word limit for `Capacity::Shared` levels, `u64::MAX`
    /// elsewhere (the sum test then never fires).
    pub shared_cap: Vec<u64>,
    /// Spatial fanout per level.
    pub fanout: Vec<u64>,
    /// Allowed-spatial-dim bitmask per level.
    pub spatial_allowed: Vec<u8>,
    /// Multicast capability per level.
    pub multicast: Vec<bool>,
    /// Per-access energies per level `[W, I, O]`, pJ.
    pub access_energy: Vec<[f64; 3]>,
    /// `access_energy` flattened to one contiguous `num_levels * 3` slab
    /// (`lv * 3 + tensor`), for the energy accumulation loop.
    pub access_energy_flat: Vec<f64>,
    /// Bandwidth in words/cycle per level instance.
    pub bandwidth: Vec<f64>,
    /// Max parallel instances of each level (product of fanouts strictly
    /// above it, saturating).
    pub inst_cap: Vec<u64>,
    pub mac_energy_pj: f64,
    pub word_bits: u32,
    pub packing: bool,
    /// Elements per memory word per tensor (packing mode).
    pub pack_div: [u64; 3],
    /// Words per element per tensor (no-packing mode).
    pub unpack_mul: [u64; 3],
    /// The same two tables as `f64`, for the energy model.
    pub pack_div_f: [f64; 3],
    pub unpack_mul_f: [f64; 3],
    /// Full tensor footprints in elements.
    pub tensor_elems: [u64; 3],
    pub macs: u64,
    /// Whether [`crate::energy::edp_lower_bound`] is admissible for this
    /// workload: every access energy finite and non-negative, every
    /// bandwidth finite and positive, MAC energy finite and
    /// non-negative. The bound's monotonicity argument multiplies
    /// under-estimated traffic by these constants, which is only
    /// order-preserving when they are non-negative (and a NaN anywhere
    /// would poison every comparison). Exotic arch files that violate
    /// this simply run unpruned — never incorrectly pruned.
    pub bound_safe: bool,
}

impl LayerContext {
    /// Precompute the tables for one workload. `q` is canonicalized
    /// internally (see [`LayerQuant::canonical`]).
    pub fn new(arch: &Arch, layer: &ConvLayer, q: &LayerQuant) -> Self {
        let q = q.canonical(arch.word_bits, arch.bit_packing);
        let nl = arch.levels.len();

        let dim_primes: Vec<Vec<(u64, u32)>> =
            DIMS.iter().map(|&d| prime_factors(layer.size(d))).collect();

        let mut relevant = [0u8; 3];
        let mut keepers: [Vec<usize>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        let mut tensor_elems = [0u64; 3];
        let mut pack_div = [1u64; 3];
        let mut unpack_mul = [1u64; 3];
        for t in TENSORS {
            let ti = t.index();
            for d in DIMS {
                if layer.is_relevant(t, d) {
                    relevant[ti] |= 1 << d.index();
                }
            }
            keepers[ti] = (0..nl).filter(|&i| arch.levels[i].keeps_tensor(t)).collect();
            debug_assert!(!keepers[ti].is_empty());
            tensor_elems[ti] = layer.tensor_elements(t);
            pack_div[ti] = pack_factor(arch.word_bits, q.of(t));
            unpack_mul[ti] = ceil_div(q.of(t) as u64, arch.word_bits as u64);
        }

        let mut cap_words = Vec::with_capacity(nl);
        let mut shared_cap = Vec::with_capacity(nl);
        for l in &arch.levels {
            match &l.capacity {
                Capacity::Unbounded => {
                    cap_words.push([u64::MAX; 3]);
                    shared_cap.push(u64::MAX);
                }
                Capacity::Shared(a) => {
                    cap_words.push([u64::MAX; 3]);
                    shared_cap.push(*a);
                }
                Capacity::PerTensor(ws) => {
                    cap_words.push(*ws);
                    shared_cap.push(u64::MAX);
                }
            }
        }

        let mut spatial_allowed = Vec::with_capacity(nl);
        let mut inst_cap = Vec::with_capacity(nl);
        for lv in 0..nl {
            let mut mask = 0u8;
            for d in &arch.levels[lv].spatial_dims {
                mask |= 1 << d.index();
            }
            spatial_allowed.push(mask);
            let mut max_inst = 1u64;
            for l in arch.levels.iter().skip(lv + 1) {
                max_inst = max_inst.saturating_mul(l.fanout);
            }
            inst_cap.push(max_inst);
        }

        let bound_safe = arch
            .levels
            .iter()
            .all(|l| {
                l.access_energy_pj.iter().all(|&e| e.is_finite() && e >= 0.0)
                    && l.bandwidth_words.is_finite()
                    && l.bandwidth_words > 0.0
            })
            && arch.mac_energy_pj.is_finite()
            && arch.mac_energy_pj >= 0.0;

        LayerContext {
            layer: layer.clone(),
            q,
            num_levels: nl,
            dim_primes,
            relevant,
            keepers,
            keeps: arch.levels.iter().map(|l| l.keeps).collect(),
            caps: arch.levels.iter().map(|l| l.capacity.clone()).collect(),
            cap_words,
            shared_cap,
            fanout: arch.levels.iter().map(|l| l.fanout).collect(),
            spatial_allowed,
            multicast: arch.levels.iter().map(|l| l.multicast).collect(),
            access_energy_flat: arch
                .levels
                .iter()
                .flat_map(|l| l.access_energy_pj)
                .collect(),
            access_energy: arch.levels.iter().map(|l| l.access_energy_pj).collect(),
            bandwidth: arch.levels.iter().map(|l| l.bandwidth_words).collect(),
            inst_cap,
            mac_energy_pj: arch.mac_energy_pj,
            word_bits: arch.word_bits,
            packing: arch.bit_packing,
            pack_div_f: [pack_div[0] as f64, pack_div[1] as f64, pack_div[2] as f64],
            unpack_mul_f: [
                unpack_mul[0] as f64,
                unpack_mul[1] as f64,
                unpack_mul[2] as f64,
            ],
            pack_div,
            unpack_mul,
            tensor_elems,
            macs: layer.macs(),
            bound_safe,
        }
    }

    /// Table lookup replacing `ConvLayer::is_relevant`.
    #[inline]
    pub fn is_relevant(&self, t: Tensor, d: Dim) -> bool {
        self.relevant[t.index()] & (1 << d.index()) != 0
    }

    /// Words occupied by `elems` elements of tensor `t` (same result as
    /// `quant::packed_words` / `quant::unpacked_words`).
    #[inline]
    pub fn tile_words_from_elems(&self, t: Tensor, elems: u64) -> u64 {
        if self.packing {
            ceil_div(elems, self.pack_div[t.index()])
        } else {
            elems * self.unpack_mul[t.index()]
        }
    }

    /// Float word conversion used by the energy model (same result as
    /// `energy`'s internal `words`).
    #[inline]
    pub fn words_f(&self, t: Tensor, elems: f64) -> f64 {
        if self.packing {
            (elems / self.pack_div_f[t.index()]).ceil()
        } else {
            elems * self.unpack_mul_f[t.index()]
        }
    }

    /// Fill `ext` with the cumulative per-level tile extents of `m`
    /// (`ext[lv][d]` = product of temporal x spatial factors at levels
    /// `<= lv`). One O(levels x dims) pass replacing the naive path's
    /// per-(level, tensor) `Mapping::tile_extents` recomputation.
    pub fn fill_extents(&self, m: &Mapping, ext: &mut Vec<[u64; 7]>) {
        ext.clear();
        let mut cur = [1u64; 7];
        for lm in &m.levels {
            for d in 0..7 {
                cur[d] *= lm.temporal[d] * lm.spatial[d];
            }
            ext.push(cur);
        }
    }

    /// Tile footprint in elements of tensor `t` given cumulative extents
    /// at one level (clamped to the workload dims, as the naive path
    /// does during partial construction).
    #[inline]
    pub fn tile_elems_at(&self, t: Tensor, ext_lv: &[u64; 7]) -> u64 {
        let mut tile = *ext_lv;
        for d in 0..7 {
            tile[d] = tile[d].min(self.layer.dims[d]);
        }
        self.layer.tile_elements(t, &tile)
    }

    /// Table-driven validity check; same result (including the first
    /// violation reported) as [`crate::mapping::check`]. `ext` is a
    /// caller-provided scratch buffer (no allocation in steady state).
    pub fn check(&self, m: &Mapping, ext: &mut Vec<[u64; 7]>) -> Result<(), Violation> {
        assert_eq!(m.levels.len(), self.num_levels);
        self.fill_extents(m, ext);

        // (1) factor products
        let totals = &ext[self.num_levels - 1];
        for d in DIMS {
            if totals[d.index()] != self.layer.size(d) {
                return Err(Violation::FactorProduct(d));
            }
        }

        // (2) spatial constraints
        self.check_spatial(m)?;

        // (3) capacity with bit-packing; DRAM (last level) is unbounded
        for lv in 0..self.num_levels - 1 {
            let caps = &self.cap_words[lv];
            let mut shared_needed = 0u64;
            for t in TENSORS {
                let ti = t.index();
                if !self.keeps[lv][ti] {
                    continue;
                }
                let words = self.tile_words_from_elems(t, self.tile_elems_at(t, &ext[lv]));
                if words > caps[ti] {
                    return Err(Violation::CapacityExceeded {
                        level: lv,
                        tensor: t,
                        needed_words: words,
                        available_words: caps[ti],
                    });
                }
                shared_needed = shared_needed.saturating_add(words);
            }
            if shared_needed > self.shared_cap[lv] {
                return Err(Violation::SharedCapacityExceeded {
                    level: lv,
                    needed_words: shared_needed,
                    available_words: self.shared_cap[lv],
                });
            }
        }

        Ok(())
    }

    /// Stage one of the rejection cascade: the spatial constraints alone
    /// (fanout product, leaf-level, allowed-dim mask). Pure integer tests
    /// on the mapping — no extent fill, no division — so invalid spatial
    /// draws (the majority on fanout-constrained arches) die before any
    /// per-level footprint work.
    #[inline]
    pub fn check_spatial(&self, m: &Mapping) -> Result<(), Violation> {
        for (lv, lm) in m.levels.iter().enumerate() {
            let sp = lm.spatial_product();
            if self.fanout[lv] == 1 {
                if sp != 1 {
                    return Err(Violation::SpatialAtLeafLevel { level: lv });
                }
                continue;
            }
            if sp > self.fanout[lv] {
                return Err(Violation::FanoutExceeded { level: lv });
            }
            for d in DIMS {
                if lm.spatial[d.index()] > 1 && self.spatial_allowed[lv] & (1 << d.index()) == 0 {
                    return Err(Violation::SpatialDimNotAllowed { level: lv, dim: d });
                }
            }
        }
        Ok(())
    }

    /// Stage two of the rejection cascade: extent fill + factor products
    /// + capacity, for candidates that survived [`check_spatial`].
    /// Records the tile footprint in elements of every kept
    /// `(level, tensor)` pair below DRAM into `elems[lv * 3 + t]`
    /// (a `num_levels * 3` slab) — exactly the footprints
    /// [`crate::nest::analyze_prefilled`] needs, so a surviving
    /// candidate is priced without recomputing a single tile size.
    ///
    /// `check_spatial(m)` then `check_tiles_into(m, ..)` accepts iff
    /// [`LayerContext::check`] accepts; when a mapping violates both a
    /// factor-product and a spatial constraint the *reported* violation
    /// may differ (the cascade tests spatial first), which is why the
    /// batched mapper only consumes the verdict.
    ///
    /// [`check_spatial`]: LayerContext::check_spatial
    pub fn check_tiles_into(
        &self,
        m: &Mapping,
        ext: &mut Vec<[u64; 7]>,
        elems: &mut [u64],
    ) -> Result<(), Violation> {
        debug_assert_eq!(elems.len(), self.num_levels * 3);
        self.fill_extents(m, ext);

        let totals = &ext[self.num_levels - 1];
        for d in DIMS {
            if totals[d.index()] != self.layer.size(d) {
                return Err(Violation::FactorProduct(d));
            }
        }

        for lv in 0..self.num_levels - 1 {
            let caps = &self.cap_words[lv];
            let mut shared_needed = 0u64;
            for t in TENSORS {
                let ti = t.index();
                if !self.keeps[lv][ti] {
                    continue;
                }
                let el = self.tile_elems_at(t, &ext[lv]);
                elems[lv * 3 + ti] = el;
                let words = self.tile_words_from_elems(t, el);
                if words > caps[ti] {
                    return Err(Violation::CapacityExceeded {
                        level: lv,
                        tensor: t,
                        needed_words: words,
                        available_words: caps[ti],
                    });
                }
                shared_needed = shared_needed.saturating_add(words);
            }
            if shared_needed > self.shared_cap[lv] {
                return Err(Violation::SharedCapacityExceeded {
                    level: lv,
                    needed_words: shared_needed,
                    available_words: self.shared_cap[lv],
                });
            }
        }

        Ok(())
    }

    /// Monotone partial capacity check for enumeration pruning (ctx
    /// variant of the mapspace's pruner): with unplaced dims at extent 1,
    /// current footprints lower-bound the final ones.
    pub fn partial_capacity_ok(&self, m: &Mapping, ext: &mut Vec<[u64; 7]>) -> bool {
        self.fill_extents(m, ext);
        for lv in 0..self.num_levels - 1 {
            let mut shared = 0u64;
            for t in TENSORS {
                if !self.keeps[lv][t.index()] {
                    continue;
                }
                let words = self.tile_words_from_elems(t, self.tile_elems_at(t, &ext[lv]));
                match &self.caps[lv] {
                    Capacity::Unbounded => {}
                    Capacity::Shared(_) => shared += words,
                    Capacity::PerTensor(ws) => {
                        if words > ws[t.index()] {
                            return false;
                        }
                    }
                }
            }
            if let Capacity::Shared(avail) = self.caps[lv] {
                if shared > avail {
                    return false;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{eyeriss, simba, toy};
    use crate::mapping::mapspace::MapSpace;
    use crate::mapping::{check, Mapping};
    use crate::util::rng::Rng;

    #[test]
    fn relevance_mask_matches_layer() {
        for layer in [
            ConvLayer::conv("c", 16, 32, 3, 8, 1),
            ConvLayer::dw("d", 32, 3, 112, 1),
        ] {
            let ctx = LayerContext::new(&toy(), &layer, &LayerQuant::uniform(8));
            for t in TENSORS {
                for d in DIMS {
                    assert_eq!(ctx.is_relevant(t, d), layer.is_relevant(t, d), "{t:?} {d:?}");
                }
            }
        }
    }

    #[test]
    fn keeper_chains_match_arch() {
        for arch in [toy(), eyeriss(), simba()] {
            let l = ConvLayer::conv("c", 4, 8, 3, 8, 1);
            let ctx = LayerContext::new(&arch, &l, &LayerQuant::uniform(8));
            for t in TENSORS {
                let expect: Vec<usize> = (0..arch.levels.len())
                    .filter(|&i| arch.levels[i].keeps_tensor(t))
                    .collect();
                assert_eq!(ctx.keepers[t.index()], expect, "{} {t:?}", arch.name);
            }
        }
    }

    #[test]
    fn ctx_check_agrees_with_naive_check() {
        let mut rng = Rng::new(0xC0FFEE);
        let mut ext = Vec::new();
        for arch in [toy(), eyeriss(), simba()] {
            let space = MapSpace::of(&arch);
            for layer in [
                ConvLayer::conv("c", 4, 8, 3, 8, 1),
                ConvLayer::dw("d", 16, 3, 14, 1),
                ConvLayer::pw("p", 8, 16, 14),
            ] {
                for bits in [2u8, 4, 8, 16] {
                    let q = LayerQuant::uniform(bits).canonical(arch.word_bits, arch.bit_packing);
                    let ctx = LayerContext::new(&arch, &layer, &q);
                    for _ in 0..100 {
                        let m = space.random_mapping(&layer, &mut rng);
                        assert_eq!(
                            check(&arch, &layer, &q, &m),
                            ctx.check(&m, &mut ext),
                            "{} {} {}b",
                            arch.name,
                            layer.name,
                            bits
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn extents_match_mapping_tile_extents() {
        let a = toy();
        let l = ConvLayer::conv("c", 4, 8, 3, 8, 1);
        let ctx = LayerContext::new(&a, &l, &LayerQuant::uniform(8));
        let space = MapSpace::of(&a);
        let mut rng = Rng::new(7);
        let mut ext = Vec::new();
        for _ in 0..50 {
            let m = space.random_mapping(&l, &mut rng);
            ctx.fill_extents(&m, &mut ext);
            for lv in 0..a.levels.len() {
                assert_eq!(ext[lv], m.tile_extents(lv));
            }
        }
    }

    #[test]
    fn words_tables_match_quant_helpers() {
        use crate::quant::{packed_words, unpacked_words};
        let mut a = toy();
        for packing in [true, false] {
            a.bit_packing = packing;
            let l = ConvLayer::conv("c", 4, 8, 3, 8, 1);
            for bits in [2u8, 3, 5, 8, 16] {
                let q = LayerQuant::uniform(bits).canonical(a.word_bits, a.bit_packing);
                let ctx = LayerContext::new(&a, &l, &q);
                for t in TENSORS {
                    for elems in [0u64, 1, 7, 36, 1000] {
                        let expect = if packing {
                            packed_words(elems, a.word_bits, q.of(t))
                        } else {
                            unpacked_words(elems, a.word_bits, q.of(t))
                        };
                        assert_eq!(ctx.tile_words_from_elems(t, elems), expect);
                    }
                }
            }
        }
    }

    #[test]
    fn partial_capacity_agrees_on_unit_prefix() {
        // a unit mapping trivially fits everywhere
        let a = eyeriss();
        let l = ConvLayer::dw("d", 32, 3, 112, 1);
        let ctx = LayerContext::new(&a, &l, &LayerQuant::uniform(8));
        let m = Mapping::unit(a.levels.len());
        let mut ext = Vec::new();
        assert!(ctx.partial_capacity_ok(&m, &mut ext));
    }
}
