"""L2: quantization-aware MobileNetV1 (width 0.25, 32x32 input) in JAX.

This is the *trainable* twin of the full-size layer table in
``rust/src/workload/models.rs::scaled_mobilenet_v1`` — 28 quantizable
layers, aligned 1:1 so a bit-width genome indexes both consistently
(DESIGN.md §3). Pointwise convolutions and the classifier run through
the L1 Pallas kernel (``kernels.qmatmul``); stem and depthwise
convolutions use ``lax.conv_general_dilated`` with fake-quantized
operands (their MAC share is small).

Everything the Rust coordinator varies at runtime is a *tensor input*:

* ``params`` — one flat f32 vector (see ``PARAM_SPEC``),
* ``qa``/``qw`` — per-layer bit-width vectors (f32, length 28),
* ``lr`` — SGD learning rate scalar.

so a single AOT-compiled train/eval executable serves every genome.
"""

import os
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.qmatmul import qmatmul
from .kernels.ref import ref_qmatmul
from .quantize import fake_quant

# --- architecture table (must mirror rust scaled_mobilenet_v1) ----------

NUM_CLASSES = 10
IMG = 32
IN_CH = 3


def _w(ch: int) -> int:
    """Width multiplier 0.25 with floor 8 (same rule as the Rust table)."""
    return max(ch // 4, 8)


# (kind, cin, cout, stride); kind in {"conv", "dw", "pw", "fc"}
def arch_table() -> List[Tuple[str, int, int, int]]:
    layers: List[Tuple[str, int, int, int]] = [("conv", IN_CH, _w(32), 1)]
    blocks = [
        (32, 64, 1),
        (64, 128, 2),
        (128, 128, 1),
        (128, 256, 2),
        (256, 256, 1),
        (256, 512, 2),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 1024, 2),
        (1024, 1024, 1),
    ]
    for cin, cout, s in blocks:
        layers.append(("dw", _w(cin), _w(cin), s))
        layers.append(("pw", _w(cin), _w(cout), 1))
    layers.append(("fc", _w(1024), NUM_CLASSES, 1))
    return layers


ARCH = arch_table()
NUM_LAYERS = len(ARCH)  # 28; genome = 56 integers, as in the paper

# Use the Pallas kernel unless explicitly disabled (ablation/debugging).
USE_PALLAS = os.environ.get("QMAP_USE_PALLAS", "1") != "0"


def _mm(x, w, qa, qw):
    fn = qmatmul if USE_PALLAS else ref_qmatmul
    return fn(x, w, qa, qw)


# --- flat parameter vector ----------------------------------------------


def param_spec() -> List[Tuple[str, Tuple[int, ...], int]]:
    """[(name, shape, offset)] for the flat parameter vector."""
    spec = []
    off = 0

    def add(name, shape):
        nonlocal off
        spec.append((name, shape, off))
        off += int(jnp.prod(jnp.array(shape)))

    for i, (kind, cin, cout, _s) in enumerate(ARCH):
        if kind == "conv":
            add(f"l{i}.w", (3, 3, cin, cout))
        elif kind == "dw":
            # HWIO with feature_group_count=cin: I=1, O=cin
            add(f"l{i}.w", (3, 3, 1, cin))
        elif kind in ("pw", "fc"):
            add(f"l{i}.w", (cin, cout))
        add(f"l{i}.b", (cout,))
    return spec


PARAM_SPEC = param_spec()
PARAM_SIZE = PARAM_SPEC[-1][2] + int(
    jnp.prod(jnp.array(PARAM_SPEC[-1][1]))
)


def unflatten(params: jax.Array):
    """Flat vector -> dict of named tensors."""
    out = {}
    for name, shape, off in PARAM_SPEC:
        size = 1
        for s in shape:
            size *= s
        out[name] = params[off : off + size].reshape(shape)
    return out


def init_params(seed: int = 0) -> jax.Array:
    """He-init all weights into one flat vector (deterministic)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape, _off in PARAM_SPEC:
        key, sub = jax.random.split(key)
        if name.endswith(".b"):
            chunks.append(jnp.zeros(shape, jnp.float32).ravel())
        else:
            fan_in = 1
            for s in shape[:-1]:
                fan_in *= s
            std = (2.0 / fan_in) ** 0.5
            chunks.append(
                (jax.random.normal(sub, shape, jnp.float32) * std).ravel()
            )
    return jnp.concatenate(chunks)


# --- forward pass --------------------------------------------------------


def _dw_conv(h: jax.Array, w: jax.Array, stride: int) -> jax.Array:
    """3x3 depthwise conv as 9 shift-multiply-adds ('SAME' padding).

    Equivalent to ``conv_general_dilated(..., feature_group_count=C)``
    but ~20x faster on XLA CPU, whose grouped-conv path is a naive loop
    (§Perf: 144 ms -> 7 ms full forward). Also mirrors the VPU mapping
    the L1 Pallas dw kernel uses on TPU (DESIGN.md §Hardware-Adaptation).

    h: [B, H, W, C]; w: [3, 3, 1, C] (HWIO, groups=C).
    """
    b_, hh, ww_, c = h.shape
    ho = -(-hh // stride)
    wo = -(-ww_ // stride)
    ph = max((ho - 1) * stride + 3 - hh, 0)
    pw = max((wo - 1) * stride + 3 - ww_, 0)
    hp = jnp.pad(h, ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0)))
    acc = jnp.zeros((b_, ho, wo, c), jnp.float32)
    for r in range(3):
        for s in range(3):
            win = jax.lax.slice(
                hp,
                (0, r, s, 0),
                (b_, r + (ho - 1) * stride + 1, s + (wo - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            acc = acc + win * w[r, s, 0, :]
    return acc


def forward_dict(p, x: jax.Array, qa: jax.Array, qw: jax.Array):
    """Quantized forward pass over the *named-tensor* parameter dict.

    Differentiating w.r.t. the dict instead of the flat vector avoids 56
    pad-into-212906-floats ops in the backward pass (§Perf: the flat-
    param plumbing alone cost ~200 ms/step on one core; grads are
    re-flattened with a single concatenate in `train_step`).
    """
    h = x
    for i, (kind, cin, cout, stride) in enumerate(ARCH):
        w = fake_quant(p[f"l{i}.w"], qw[i])
        b = p[f"l{i}.b"]
        h = fake_quant(h, qa[i])  # layer-input activations (paper's q_a)
        if kind == "conv":
            h = jax.lax.conv_general_dilated(
                h, w, (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
        elif kind == "dw":
            h = _dw_conv(h, w, stride)
        elif kind == "pw":
            bsz, hh, ww_, _c = h.shape
            flat = h.reshape(bsz * hh * ww_, cin)
            # the Pallas hot-spot: fused fake-quant matmul. Activations
            # were already fake-quantized above; the kernel re-quantizes
            # (idempotent on already-quantized grids) and handles weights.
            flat = _mm(flat, p[f"l{i}.w"], qa[i], qw[i])
            h = flat.reshape(bsz, hh, ww_, cout)
        elif kind == "fc":
            h = jnp.mean(h, axis=(1, 2))  # global average pool
            h = _mm(h, p[f"l{i}.w"], qa[i], qw[i])
        if kind != "fc":
            h = jnp.clip(h + b, 0.0, 6.0)  # ReLU6, MobileNet's activation
        else:
            h = h + b
    return h


def forward(params: jax.Array, x: jax.Array, qa: jax.Array, qw: jax.Array):
    """Quantized forward pass from the flat parameter vector.

    params: [PARAM_SIZE] f32; x: [B, 32, 32, 3] f32 in [0,1];
    qa, qw: [NUM_LAYERS] f32 bit-widths. Returns logits [B, 10].
    """
    return forward_dict(unflatten(params), x, qa, qw)


def _loss_dict(p, x, y, qa, qw):
    logits = forward_dict(p, x, qa, qw)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def loss_fn(params, x, y, qa, qw):
    """Mean softmax cross-entropy; y: [B] int32 labels."""
    return _loss_dict(unflatten(params), x, y, qa, qw)


def train_step(params, x, y, qa, qw, lr):
    """One SGD step. Returns (new_params, loss).

    Gradients are taken w.r.t. the unflattened dict (cheap backward) and
    re-flattened with one concatenate — see `forward_dict`.
    """
    p = unflatten(params)
    loss, gdict = jax.value_and_grad(_loss_dict)(p, x, y, qa, qw)
    gflat = jnp.concatenate([gdict[name].ravel() for name, _, _ in PARAM_SPEC])
    return params - lr * gflat, loss


def eval_step(params, x, y, qa, qw):
    """Returns (correct_count f32, mean loss f32)."""
    logits = forward(params, x, qa, qw)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = jnp.sum((pred == y.astype(jnp.int32)).astype(jnp.float32))
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return correct, jnp.mean(nll)
