//! The execution engine: one scheduler for every fan-out in the system.
//!
//! The paper's method is a large pile of independent
//! `(layer, q_a, q_w) → mapper search` evaluations driven by NSGA-II
//! (§III-C). Before this subsystem, three ad-hoc mechanisms fought each
//! other for cores: `parallel_map`'s scoped threads, per-network layer
//! threads in `eval`, and `MapperConfig::shards` inside a single
//! workload. The engine replaces all three with one work-stealing pool
//! that owns the process-wide core budget:
//!
//! * [`pool`] — the executor: per-worker deques + a global injector,
//!   plain `std` primitives, nested fan-outs, caller participation.
//! * [`driver`] — the typed job layer: an `EvalJob` is one
//!   layer×quant-config mapper search through the shared
//!   [`MapperCache`](crate::mapper::cache::MapperCache); generations
//!   deduplicate jobs across genomes and a job splits into the mapper's
//!   deterministic shard subtasks *only when idle workers exist*.
//!   Results are keyed by job id and merged in index order, so every
//!   output is bit-identical to single-threaded execution regardless of
//!   worker count or steal order.
//! * [`checkpoint`] — generation-boundary snapshots of the NSGA-II
//!   search state plus the mapper cache (negative entries keep their
//!   draw-budget tags), so long searches survive interruption and
//!   resume to bit-identical final fronts.
//! * [`proto`] / [`remote`] — the multi-host seam: shard seeds are
//!   position-independent, so `qmap worker` processes execute the same
//!   `ShardSpec`s over length-prefixed, checksummed JSON frames and
//!   the driver merges through the same deterministic reduction.
//!   Worker loss, duplicate delivery, and reordering are absorbed
//!   without perturbing a single bit of the result (see [`Backend`]).
//!
//! Under all of it sits the optional persistent cache tier
//! ([`mapper::store`](crate::mapper::store), `--cache-dir`): the
//! driver's cache probes read through to an append-only cross-process
//! store and fresh results are appended behind, and `qmap worker`
//! persists shard outcomes the same way — so searches, workers, and
//! whole fleets warm-start across process lifetimes while every path
//! above stays bit-identical to a cold run.

pub mod checkpoint;
pub mod driver;
pub mod pool;
pub mod proto;
pub mod remote;

pub use checkpoint::Checkpointer;
pub use pool::{Pool, ScopedTask};
pub use remote::WorkerOptions;

use crate::mapper::guide::GuideState;
use crate::mapper::MapperConfig;
use crate::objective::ObjectiveSpec;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Where the addresses of remote `qmap worker` processes come from.
///
/// `Static` is a fixed fleet (the comma-separated `--workers` /
/// `QMAP_WORKERS` form). `File` is the elastic form (`--workers
/// @path`): a file of `host:port` lines that is **re-read at every
/// generation boundary** ([`remote::eval_jobs`] calls
/// [`WorkerSource::resolve`] once per generation), so a fleet can grow
/// or shrink mid-search without restarting the driver. Results are
/// bit-identical for any worker set, so membership churn is safe by
/// construction.
#[derive(Debug, Clone)]
pub enum WorkerSource {
    Static(Vec<String>),
    /// Path to a file of `host:port` entries (one per line; commas and
    /// blank lines tolerated, `#` comments skipped).
    File(String),
}

impl WorkerSource {
    /// Parse a `--workers` argument: `@path` selects the file form,
    /// anything else is a comma-separated static list.
    pub fn parse(s: &str) -> WorkerSource {
        let t = s.trim();
        match t.strip_prefix('@') {
            Some(path) => WorkerSource::File(path.trim().to_string()),
            None => WorkerSource::Static(
                t.split(',')
                    .map(str::trim)
                    .filter(|x| !x.is_empty())
                    .map(str::to_string)
                    .collect(),
            ),
        }
    }

    /// The current worker list. For the file form this re-reads the
    /// file; an unreadable file degrades to an empty list (local-only
    /// execution) with a warning, never an error — an elastic fleet
    /// shrinking to zero is a legitimate state.
    pub fn resolve(&self) -> Vec<String> {
        match self {
            WorkerSource::Static(ws) => ws.clone(),
            WorkerSource::File(path) => match std::fs::read_to_string(path) {
                // strip each line from '#' to end-of-line BEFORE
                // splitting on commas: '# hostA:1, hostB:2' retires
                // every host on the line, and 'hostA:1  # main rack'
                // keeps the host without swallowing the comment into
                // the address
                Ok(src) => src
                    .lines()
                    .map(|l| l.split('#').next().unwrap_or(""))
                    .flat_map(|l| l.split(','))
                    .map(str::trim)
                    .filter(|t| !t.is_empty())
                    .map(str::to_string)
                    .collect(),
                Err(e) => {
                    crate::obs::event_human(
                        crate::obs::Level::Status,
                        "workers_file_error",
                        vec![
                            ("path", crate::util::json::Json::Str(path.clone())),
                            ("detail", crate::util::json::Json::Str(e.to_string())),
                        ],
                        &format!(
                            "qmap: workers file {path}: {e} (running local-only this generation)"
                        ),
                    );
                    Vec::new()
                }
            },
        }
    }
}

/// Where a generation's mapper jobs execute. The seam the ROADMAP's
/// distributed search plugs into: `Local` keeps everything on this
/// process's work-stealing pool; `Distributed` additionally fans
/// cache-miss jobs out to remote `qmap worker` processes, with the
/// local pool racing the same queue (and absorbing anything a lost
/// worker leaves behind). Results are bit-identical either way — see
/// [`remote::eval_jobs`].
#[derive(Debug, Clone)]
pub enum Backend {
    Local,
    Distributed {
        /// The `qmap worker --listen` fleet, resolved to concrete
        /// `host:port` addresses at each generation boundary.
        workers: WorkerSource,
    },
}

/// Order in which a generation's [`driver::EvalJob`]s are injected
/// into the scheduler. Purely a placement decision: results are keyed
/// by job identity and merged deterministically, so every policy
/// produces bit-identical output — the property the stateful suites
/// pin across policy × pipeline-depth × worker-count permutations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// First-encounter order (the pre-priority behavior; kept as the
    /// bench baseline for the generation-tail comparison).
    Fifo,
    /// Descending effective draw budget (cache-probe-aware): workloads
    /// known to burn their whole budget (stale negative entries) run
    /// first, fresh misses next (largest layers first), cached jobs
    /// sink to the end. Longest-processing-time-first shrinks the
    /// generation tail that FIFO leaves. The default.
    Priority,
    /// Deterministic pseudo-random permutation of the job order (test
    /// harness: any permutation must merge bit-identically).
    Shuffled(u64),
}

/// Default window of outstanding batches per remote worker connection:
/// `QMAP_PIPELINE_DEPTH` (clamped to [1, 64]), else 4. Depth 1
/// reproduces the old one-in-flight-batch behavior.
fn default_pipeline_depth() -> usize {
    std::env::var("QMAP_PIPELINE_DEPTH")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|d| d.clamp(1, 64))
        .unwrap_or(4)
}

/// The engine: a work-stealing [`Pool`] plus job-level accounting and
/// the execution [`Backend`]. Create one per process (or per
/// experiment) with the global core budget; every fan-out — NSGA-II
/// generations, bench harnesses, network characterizations — goes
/// through it.
pub struct Engine {
    pool: Pool,
    backend: Backend,
    sched: SchedPolicy,
    pipeline: usize,
    /// The *active search's* objective space. The engine itself never
    /// computes objectives (jobs produce `NetworkEval`s); the spec
    /// rides here so the distributed layer can fold its identity hash
    /// into every batch — a mixed-version fleet disagreeing about the
    /// objective space fails loudly instead of mixing incomparable
    /// searches. Interior-mutable because the search entry points
    /// ([`crate::baselines::search_with_objectives`],
    /// [`driver::search_resumable`]) install their spec on whatever
    /// engine they were handed — the one value on the wire is always
    /// the one the running search uses, by construction.
    objectives: Mutex<ObjectiveSpec>,
    /// Validity-rate guidance folded from finished searches (see
    /// [`crate::mapper::guide`]). Placement-only by contract: the state
    /// ranks jobs in [`driver::order_jobs`] and rides checkpoints and
    /// batch frames, but never touches a result-bearing shard plan —
    /// fronts stay bit-identical to the unguided engine. Same
    /// interior-mutability story as `objectives`: searches fold into
    /// whatever engine they were handed.
    guide: Mutex<GuideState>,
    jobs: AtomicU64,
    splits: AtomicU64,
    remote_jobs: AtomicU64,
    requeued_specs: AtomicU64,
    lost_workers: AtomicU64,
    /// Last generation's scheduling tail, in microseconds (see
    /// [`EngineStats::last_tail_ms`]).
    tail_us: AtomicU64,
    /// Last distributed generation's effective pipeline window (see
    /// [`EngineStats::last_pipeline_depth`]).
    eff_pipeline: AtomicU64,
}

/// A point-in-time snapshot of the engine's counters.
///
/// Two kinds of field live here, with different reset semantics:
/// **cumulative** fields (`jobs`, `splits`, `tasks`, `steals`,
/// `remote_jobs`, `requeued_specs`, `lost_workers`) only ever grow over
/// the engine's lifetime, while **per-generation** fields
/// (`last_tail_ms`, `last_pipeline_depth`) describe the most recent
/// generation only and are zeroed in exactly one place —
/// [`Engine::begin_generation`], called at the top of every generation
/// evaluation ([`driver::evaluate_genomes`], [`remote::eval_jobs`]).
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    /// Total concurrency budget (workers + the submitting thread).
    pub workers: usize,
    /// `EvalJob`s dispatched (one per unique layer×quant workload).
    pub jobs: u64,
    /// Jobs that split into shard subtasks because idle workers existed.
    pub splits: u64,
    /// Pool tasks executed (jobs + shard subtasks + helper drains).
    pub tasks: u64,
    /// Tasks taken from another worker's deque.
    pub steals: u64,
    /// Workers parked at the moment of the snapshot.
    pub idle_now: usize,
    /// Jobs whose batch completed on a remote worker.
    pub remote_jobs: u64,
    /// Shard specs a lost worker owed that were re-run locally.
    pub requeued_specs: u64,
    /// Remote workers that became unreachable or violated the protocol.
    pub lost_workers: u64,
    /// The *effective* per-connection pipeline window the last
    /// distributed generation settled on: `remote::eval_jobs` measures
    /// per-connection batch RTT and serve time and clamps the
    /// configured [`Engine::pipeline_depth`] to
    /// `min(depth, ceil(rtt / serve) + 1)` — a window deep enough to
    /// hide the round-trip, no deeper (placement only; results are
    /// bit-identical at every depth). 0 until a distributed generation
    /// has run.
    pub last_pipeline_depth: usize,
    /// The last generation's scheduling tail: time between the job
    /// queue running dry (the last job being claimed, after which an
    /// out-of-work worker can only steal shards) and the last job
    /// finishing. The metric the priority scheduler exists to shrink;
    /// recorded by `driver::evaluate_genomes` on the local backend.
    pub last_tail_ms: f64,
}

impl Engine {
    /// An engine with a concurrency budget of `budget` threads
    /// (`0` = all available cores). `Engine::new(1)` executes
    /// everything inline — the serial baseline every parallel run is
    /// bit-identical to.
    pub fn new(budget: usize) -> Engine {
        Engine::with_backend(budget, Backend::Local)
    }

    /// An engine whose generations additionally fan out to remote
    /// `qmap worker` processes. The local pool still runs with the
    /// given budget — remote workers add capacity, they never replace
    /// the local one.
    pub fn distributed(budget: usize, workers: Vec<String>) -> Engine {
        if workers.is_empty() {
            return Engine::new(budget);
        }
        Engine::with_backend(
            budget,
            Backend::Distributed {
                workers: WorkerSource::Static(workers),
            },
        )
    }

    /// Like [`Engine::distributed`], but from a [`WorkerSource`]. An
    /// empty *static* list degrades to the local backend; a file
    /// source stays distributed even when the file is currently empty
    /// (an elastic fleet may grow later).
    pub fn distributed_source(budget: usize, source: WorkerSource) -> Engine {
        match source {
            WorkerSource::Static(ws) => Engine::distributed(budget, ws),
            src @ WorkerSource::File(_) => {
                Engine::with_backend(budget, Backend::Distributed { workers: src })
            }
        }
    }

    pub fn with_backend(budget: usize, backend: Backend) -> Engine {
        Engine {
            pool: Pool::new(budget),
            backend,
            sched: SchedPolicy::Priority,
            pipeline: default_pipeline_depth(),
            objectives: Mutex::new(ObjectiveSpec::default()),
            guide: Mutex::new(GuideState::new()),
            jobs: AtomicU64::new(0),
            splits: AtomicU64::new(0),
            remote_jobs: AtomicU64::new(0),
            requeued_specs: AtomicU64::new(0),
            lost_workers: AtomicU64::new(0),
            tail_us: AtomicU64::new(0),
            eff_pipeline: AtomicU64::new(0),
        }
    }

    /// Bind the run's objective space (default: the paper's
    /// `edp,error`). Purely identity: it changes what rides the batch
    /// headers and checkpoint idents, never what a mapper job computes.
    pub fn with_objectives(self, spec: ObjectiveSpec) -> Engine {
        self.set_objectives(spec);
        self
    }

    /// Install the active search's spec (what the search entry points
    /// call — an engine can serve searches under different specs over
    /// its lifetime, and the wire identity must always be the running
    /// one's).
    pub fn set_objectives(&self, spec: ObjectiveSpec) {
        *self.objectives.lock().unwrap() = spec;
    }

    /// The active search's objective spec (a copy; the spec is small
    /// and `Copy` by design).
    pub fn objectives(&self) -> ObjectiveSpec {
        *self.objectives.lock().unwrap()
    }

    /// Fold one finished search's outcome into the guide: the workload
    /// produced `valid` valid mappings over `drawn` draws. Saturating
    /// and commutative (see [`GuideState::note`]); bumps the
    /// `guide_updates` metrics counter.
    pub fn guide_note(&self, whash: u64, valid: u64, drawn: u64) {
        self.guide.lock().unwrap().note(whash, valid, drawn);
        crate::obs::metrics::counters().guide_updates.fetch_add(1, Ordering::Relaxed);
    }

    /// Estimated draws-to-target for a workload under `cfg` (see
    /// [`GuideState::expected_draws`]).
    pub fn guide_expected(&self, whash: u64, cfg: &MapperConfig) -> u64 {
        self.guide.lock().unwrap().expected_draws(whash, cfg)
    }

    /// The raw `(valid, drawn)` counts for a workload, if the guide has
    /// seen it — what `proto::batch` ships to workers as a rate hint.
    pub fn guide_rate(&self, whash: u64) -> Option<(u64, u64)> {
        self.guide.lock().unwrap().rate(whash)
    }

    /// A copy of the whole guide (what checkpoint saves persist).
    pub fn guide_snapshot(&self) -> GuideState {
        self.guide.lock().unwrap().clone()
    }

    /// Replace the guide wholesale (checkpoint resume installs the
    /// journaled state here before the first generation).
    pub fn set_guide(&self, g: GuideState) {
        *self.guide.lock().unwrap() = g;
    }

    pub fn guide_is_empty(&self) -> bool {
        self.guide.lock().unwrap().is_empty()
    }

    /// Override the job-injection order (results are bit-identical
    /// under every policy; see [`SchedPolicy`]).
    pub fn with_sched_policy(mut self, p: SchedPolicy) -> Engine {
        self.sched = p;
        self
    }

    /// Override the per-connection window of outstanding remote
    /// batches (>= 1; depth 1 = the old one-in-flight behavior).
    pub fn with_pipeline_depth(mut self, depth: usize) -> Engine {
        self.pipeline = depth.clamp(1, 64);
        self
    }

    pub fn sched_policy(&self) -> SchedPolicy {
        self.sched
    }

    pub fn pipeline_depth(&self) -> usize {
        self.pipeline
    }

    pub fn backend(&self) -> &Backend {
        &self.backend
    }

    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// The engine's concurrency budget.
    pub fn workers(&self) -> usize {
        self.pool.budget()
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            workers: self.pool.budget(),
            jobs: self.jobs.load(Ordering::Relaxed),
            splits: self.splits.load(Ordering::Relaxed),
            tasks: self.pool.tasks_executed(),
            steals: self.pool.steals(),
            idle_now: self.pool.idle_workers(),
            remote_jobs: self.remote_jobs.load(Ordering::Relaxed),
            requeued_specs: self.requeued_specs.load(Ordering::Relaxed),
            lost_workers: self.lost_workers.load(Ordering::Relaxed),
            last_tail_ms: self.tail_us.load(Ordering::Relaxed) as f64 / 1e3,
            last_pipeline_depth: self.eff_pipeline.load(Ordering::Relaxed) as usize,
        }
    }

    /// Record the effective pipeline window a distributed connection
    /// settled on (the deepest across the generation's connections
    /// wins — the stat answers "how much pipelining did we get").
    pub(crate) fn note_pipeline_depth(&self, depth: usize) {
        self.eff_pipeline.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Start a new generation's statistics window: the single place
    /// the per-generation [`EngineStats`] fields (`last_tail_ms`,
    /// `last_pipeline_depth`) are reset. Cumulative fields are never
    /// touched. Called at the top of every generation evaluation;
    /// calling it twice on the same boundary (driver, then the remote
    /// path it delegates to) is harmless — both run before any note.
    pub fn begin_generation(&self) {
        self.tail_us.store(0, Ordering::Relaxed);
        self.eff_pipeline.store(0, Ordering::Relaxed);
    }

    /// Record one generation's scheduling tail (seconds).
    pub(crate) fn note_tail(&self, secs: f64) {
        self.tail_us.store((secs.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub(crate) fn note_jobs(&self, n: u64) {
        self.jobs.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn note_split(&self) {
        self.splits.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_remote_job(&self) {
        self.remote_jobs.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_requeued(&self, n: u64) {
        self.requeued_specs.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn note_lost_worker(&self) {
        self.lost_workers.fetch_add(1, Ordering::Relaxed);
    }

    /// Order-preserving parallel map over a slice: the engine's
    /// replacement for the retired `coordinator::parallel_map`. Results
    /// land in slots keyed by item index, so the output order (and every
    /// value in it) is independent of worker count and steal order.
    pub fn map<T, R>(&self, items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        {
            let f = &f;
            let slots = &slots;
            let mut tasks: Vec<ScopedTask> = Vec::with_capacity(n);
            for (i, item) in items.iter().enumerate() {
                tasks.push(Box::new(move || {
                    let r = f(item);
                    *slots[i].lock().unwrap() = Some(r);
                }));
            }
            self.pool.run_scoped(tasks);
        }
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("engine task completed"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_for_any_worker_count() {
        let xs: Vec<usize> = (0..100).collect();
        let expect: Vec<usize> = xs.iter().map(|x| x * 2).collect();
        for budget in [1usize, 2, 4, 8] {
            let engine = Engine::new(budget);
            assert_eq!(engine.map(&xs, |&x| x * 2), expect, "budget={budget}");
        }
    }

    #[test]
    fn map_handles_empty_input() {
        let engine = Engine::new(2);
        let out: Vec<u32> = engine.map(&[] as &[u32], |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn stats_count_tasks() {
        let engine = Engine::new(3);
        let xs: Vec<u64> = (0..50).collect();
        let _ = engine.map(&xs, |&x| x + 1);
        let st = engine.stats();
        assert_eq!(st.workers, 3);
        assert!(st.tasks >= 50, "tasks={}", st.tasks);
    }

    #[test]
    fn worker_source_parses_static_and_file_forms() {
        match WorkerSource::parse("a:1, b:2 ,,c:3") {
            WorkerSource::Static(ws) => assert_eq!(ws, vec!["a:1", "b:2", "c:3"]),
            other => panic!("expected static source, got {other:?}"),
        }
        match WorkerSource::parse(" @/tmp/fleet.txt ") {
            WorkerSource::File(p) => assert_eq!(p, "/tmp/fleet.txt"),
            other => panic!("expected file source, got {other:?}"),
        }
        for empty in ["", " , "] {
            match WorkerSource::parse(empty) {
                WorkerSource::Static(ws) => assert!(ws.is_empty(), "{empty:?}"),
                other => panic!("expected empty static source, got {other:?}"),
            }
        }
    }

    #[test]
    fn worker_file_source_is_reread_on_every_resolve() {
        let mut path = std::env::temp_dir();
        path.push(format!("qmap_workers_{}.txt", std::process::id()));
        let path_str = path.to_string_lossy().into_owned();
        let src = WorkerSource::File(path_str.clone());
        // missing file: empty fleet, not an error
        let _ = std::fs::remove_file(&path);
        assert!(src.resolve().is_empty());
        // the fleet grows... (a commented-out line retires EVERY host
        // on it, including hosts after a comma; an inline comment does
        // not swallow the host before it)
        std::fs::write(
            &path,
            "hostA:7911  # main rack\n# hostX:1, hostY:2\nhostB:7911, hostC:7911\n",
        )
        .unwrap();
        assert_eq!(src.resolve(), vec!["hostA:7911", "hostB:7911", "hostC:7911"]);
        // ...and shrinks, between two resolves of the same source
        std::fs::write(&path, "hostB:7911\n").unwrap();
        assert_eq!(src.resolve(), vec!["hostB:7911"]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn file_backed_engine_stays_distributed_even_when_empty() {
        let engine = Engine::distributed_source(1, WorkerSource::File("/nonexistent".into()));
        assert!(matches!(engine.backend(), Backend::Distributed { .. }));
        // a static empty list still degrades to local
        let engine = Engine::distributed_source(1, WorkerSource::Static(Vec::new()));
        assert!(matches!(engine.backend(), Backend::Local));
    }

    #[test]
    fn begin_generation_resets_per_generation_stats_only() {
        let engine = Engine::new(2);
        let xs: Vec<u64> = (0..20).collect();
        let _ = engine.map(&xs, |&x| x);
        engine.note_jobs(5);
        engine.note_split();
        engine.note_tail(0.25);
        engine.note_pipeline_depth(3);
        let before = engine.stats();
        assert!(before.last_tail_ms > 0.0);
        assert_eq!(before.last_pipeline_depth, 3);
        engine.begin_generation();
        let after = engine.stats();
        // per-generation fields are zeroed...
        assert_eq!(after.last_tail_ms, 0.0);
        assert_eq!(after.last_pipeline_depth, 0);
        // ...cumulative fields survive the boundary
        assert_eq!(after.jobs, before.jobs);
        assert_eq!(after.splits, before.splits);
        assert_eq!(after.tasks, before.tasks);
        assert_eq!(after.steals, before.steals);
        assert_eq!(after.remote_jobs, before.remote_jobs);
        assert_eq!(after.requeued_specs, before.requeued_specs);
        assert_eq!(after.lost_workers, before.lost_workers);
    }

    #[test]
    fn pipeline_depth_reading_is_max_within_a_generation() {
        let engine = Engine::new(1);
        engine.begin_generation();
        engine.note_pipeline_depth(2);
        engine.note_pipeline_depth(5);
        engine.note_pipeline_depth(3);
        assert_eq!(engine.stats().last_pipeline_depth, 5);
        engine.begin_generation();
        assert_eq!(engine.stats().last_pipeline_depth, 0);
    }

    #[test]
    fn scheduling_knobs_are_builder_configurable() {
        let engine = Engine::new(1)
            .with_sched_policy(SchedPolicy::Fifo)
            .with_pipeline_depth(0); // clamped up to 1
        assert_eq!(engine.sched_policy(), SchedPolicy::Fifo);
        assert_eq!(engine.pipeline_depth(), 1);
        let engine = Engine::new(1).with_pipeline_depth(7);
        assert_eq!(engine.pipeline_depth(), 7);
        assert_eq!(engine.sched_policy(), SchedPolicy::Priority);
    }
}
