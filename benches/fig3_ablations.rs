//! Fig. 3 (a, b, c): NSGA-II ablations on MobileNetV1 / Eyeriss.
//!
//!   (a) initial model for QAT fine-tuning: FP32 (e=10) vs QAT-8 (e=5) —
//!       QAT-8 init reaches better accuracy at equal budget;
//!   (b) offspring size |Q| in {8, 16, 32} at a fixed evaluation budget —
//!       no significant difference between 8 and 32;
//!   (c) epochs e in {10, 20} (generations 28 vs 14) — higher e wins on
//!       the accuracy-EDP front despite fewer generations.
//!
//! Run: `cargo bench --bench fig3_ablations`.

use qmap::coordinator::experiments::{fig3a_init_model, fig3b_offspring, fig3c_epochs, Fig3Result};
use qmap::coordinator::RunConfig;
use qmap::report;
use std::time::Instant;

fn dominance_score(front_a: &[Vec<f64>], front_b: &[Vec<f64>]) -> f64 {
    // fraction of b's points weakly dominated by some point of a
    if front_b.is_empty() {
        return 0.0;
    }
    let dominated = front_b
        .iter()
        .filter(|q| {
            front_a
                .iter()
                .any(|p| p[0] <= q[0] && p[1] <= q[1] && (p[0] < q[0] || p[1] < q[1]))
        })
        .count();
    dominated as f64 / front_b.len() as f64
}

fn show(title: &str, r: &Fig3Result) {
    println!("\n--- {title} ---");
    let mut pts = Vec::new();
    let markers = ['A', 'B', 'C', 'D'];
    for (i, (label, front)) in r.arms.iter().enumerate() {
        let m = markers[i % markers.len()];
        println!(
            "  [{m}] {label}: {} front points, best top-1 {:.4}",
            front.len(),
            1.0 - front.iter().map(|p| p[1]).fold(f64::INFINITY, f64::min)
        );
        pts.extend(front.iter().map(|p| (p[0], 1.0 - p[1], m)));
    }
    print!("{}", report::ascii_scatter(&pts, 72, 18, "EDP", "top-1 accuracy"));
}

fn main() {
    let rc = RunConfig::from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2)
    });
    let t0 = Instant::now();

    println!("=== Fig. 3: NSGA-II ablations (MobileNetV1, Eyeriss) ===");

    let a = fig3a_init_model(&rc);
    show("(a) initial model: FP32/e=10 vs QAT-8/e=5", &a);
    let a_qat8_beats_fp32 = dominance_score(&a.arms[1].1, &a.arms[0].1);
    println!(
        "QAT-8 front dominates {:.0}% of FP32 front (paper: QAT-8 init better)",
        a_qat8_beats_fp32 * 100.0
    );

    let b = fig3b_offspring(&rc);
    show("(b) offspring size |Q| at fixed evaluation budget", &b);
    let d_8_32 = dominance_score(&b.arms[0].1, &b.arms[2].1);
    let d_32_8 = dominance_score(&b.arms[2].1, &b.arms[0].1);
    println!(
        "|Q|=8 vs |Q|=32 mutual dominance: {:.0}% / {:.0}% (paper: no significant difference)",
        d_8_32 * 100.0,
        d_32_8 * 100.0
    );

    let c = fig3c_epochs(&rc);
    show("(c) epochs e=10 (more gens) vs e=20 (fewer gens)", &c);
    let c_e20_beats_e10 = dominance_score(&c.arms[1].1, &c.arms[0].1);
    println!(
        "e=20 front dominates {:.0}% of e=10 front (paper: larger e preferred)",
        c_e20_beats_e10 * 100.0
    );

    let ok = a_qat8_beats_fp32 >= 0.3 && c_e20_beats_e10 >= 0.2 && (d_8_32 - d_32_8).abs() < 0.7;
    println!(
        "\npaper shape (a: QAT-8 init wins, b: |Q| indifferent, c: e=20 wins): {}",
        if ok { "REPRODUCED" } else { "MISMATCH" }
    );

    // persist all fronts
    let mut rows = Vec::new();
    for (panel, r) in [("a", &a), ("b", &b), ("c", &c)] {
        for (label, front) in &r.arms {
            for p in front {
                rows.push(vec![
                    panel.to_string(),
                    label.clone(),
                    format!("{:.6e}", p[0]),
                    format!("{:.6}", p[1]),
                ]);
            }
        }
    }
    let path = report::write_results(
        "fig3_fronts.csv",
        &report::csv(&["panel", "arm", "edp", "error"], &rows),
    );
    println!("[{:.2?}] wrote {}", t0.elapsed(), path.display());
}
