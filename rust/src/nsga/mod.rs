//! NSGA-II multi-objective genetic algorithm (Deb et al., 2002),
//! specialized for quantization genomes but generic over the evaluator.
//!
//! The paper's configuration (§III-C, §IV):
//! * genome: per-layer `(q_a, q_w)` integer tuples, 2..=8 bits;
//! * initial population: uniformly quantized configurations;
//! * uniform crossover: each integer from either parent with p=1/2;
//! * mutation: with `p_mutAcc` reset one random layer to 8/8; with
//!   `p_mut` replace one random integer with a random valid value;
//! * objectives: any k-axis [`crate::objective::ObjectiveSpec`] (the
//!   paper's default is CNN error and EDP, both minimized);
//! * selection: fast non-dominated sort + crowding distance.

use crate::objective::ObjectiveVec;
use crate::quant::{QuantConfig, QMAX, QMIN};
use crate::util::rng::Rng;

/// One evaluated individual.
#[derive(Debug, Clone)]
pub struct Individual {
    pub genome: QuantConfig,
    /// Objective values, all minimized, stamped with the
    /// [`ObjectiveSpec`](crate::objective::ObjectiveSpec) identity they
    /// were computed under. Every algorithm below is k-objective: the
    /// arity comes from the vectors, never from a hardcoded 2.
    pub objectives: ObjectiveVec,
}

/// NSGA-II hyper-parameters (paper defaults from §IV).
#[derive(Debug, Clone, Copy)]
pub struct NsgaConfig {
    /// Parent population size |P| (paper: 32).
    pub population: usize,
    /// Offspring per generation |Q| (paper: {8, 16, 32}).
    pub offspring: usize,
    /// Per-individual probability of the random-gene mutation (10%).
    pub p_mut: f64,
    /// Per-individual probability of the reset-layer-to-8/8 mutation (5%).
    pub p_mut_acc: f64,
    pub generations: usize,
    pub seed: u64,
}

impl Default for NsgaConfig {
    fn default() -> Self {
        NsgaConfig {
            population: 32,
            offspring: 16,
            p_mut: 0.10,
            p_mut_acc: 0.05,
            generations: 20,
            seed: 0xDEB2002,
        }
    }
}

/// `a` Pareto-dominates `b` (all objectives <=, at least one <).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort; returns fronts of indices (front 0 = Pareto).
pub fn non_dominated_sort(pop: &[Individual]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n];
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            if dominates(&pop[i].objectives, &pop[j].objectives) {
                dominated_by[i].push(j);
            } else if dominates(&pop[j].objectives, &pop[i].objectives) {
                dom_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Crowding distance within one front (NSGA-II diversity measure).
///
/// k-objective determinism: each axis's sort breaks ties by the **full
/// objective vector** (lexicographic), falling back to front order only
/// for exact duplicates. With a first-axis-only key, partially tied
/// points (equal energy, different error — routine in a k-D front of
/// quantized genomes) were ordered by front *position*, so the same
/// point's distance depended on where it sat in the input — the
/// selection-level cousin of the `pareto_front_of_points` tie bug. Now
/// the (vector → distance) map is a pure function of the objective
/// multiset; only indistinguishable exact duplicates still resolve by
/// position, which no caller can observe through their values.
pub fn crowding_distance(pop: &[Individual], front: &[usize]) -> Vec<f64> {
    let m = pop[front[0]].objectives.len();
    let n = front.len();
    let mut dist = vec![0.0f64; n];
    for k in 0..m {
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            let (pa, pb) = (&pop[front[a]].objectives, &pop[front[b]].objectives);
            match pa[k].partial_cmp(&pb[k]) {
                Some(std::cmp::Ordering::Equal) | None => {}
                Some(ord) => return ord,
            }
            for (x, y) in pa.iter().zip(pb.iter()) {
                match x.partial_cmp(y) {
                    Some(std::cmp::Ordering::Equal) | None => continue,
                    Some(ord) => return ord,
                }
            }
            std::cmp::Ordering::Equal
        });
        dist[order[0]] = f64::INFINITY;
        dist[order[n - 1]] = f64::INFINITY;
        let lo = pop[front[order[0]]].objectives[k];
        let hi = pop[front[order[n - 1]]].objectives[k];
        if hi <= lo {
            continue;
        }
        for w in 1..n - 1 {
            let prev = pop[front[order[w - 1]]].objectives[k];
            let next = pop[front[order[w + 1]]].objectives[k];
            dist[order[w]] += (next - prev) / (hi - lo);
        }
    }
    dist
}

/// Environmental selection (rank + crowding): keep the best `size`.
pub fn environmental_select(pop: Vec<Individual>, size: usize) -> Vec<Individual> {
    if pop.len() <= size {
        return pop;
    }
    let fronts = non_dominated_sort(&pop);
    let mut chosen: Vec<usize> = Vec::with_capacity(size);
    for front in &fronts {
        if chosen.len() + front.len() <= size {
            chosen.extend_from_slice(front);
        } else {
            let dist = crowding_distance(&pop, front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| {
                dist[b].partial_cmp(&dist[a]).unwrap_or(std::cmp::Ordering::Equal)
            });
            chosen.extend(order.iter().take(size - chosen.len()).map(|&w| front[w]));
            break;
        }
    }
    chosen.into_iter().map(|i| pop[i].clone()).collect()
}

/// Paper's uniform crossover: each gene from either parent, p=1/2.
pub fn uniform_crossover(a: &QuantConfig, b: &QuantConfig, rng: &mut Rng) -> QuantConfig {
    debug_assert_eq!(a.len(), b.len());
    let layers = a
        .layers
        .iter()
        .zip(&b.layers)
        .map(|(&(aa, aw), &(ba, bw))| {
            (
                if rng.chance(0.5) { aa } else { ba },
                if rng.chance(0.5) { aw } else { bw },
            )
        })
        .collect();
    QuantConfig {
        layers,
        last_qo: a.last_qo,
    }
}

/// Paper's mutations: `p_mut_acc` -> reset one random layer to 8/8;
/// `p_mut` -> replace one random gene with a random valid bit-width.
pub fn mutate(qc: &mut QuantConfig, p_mut: f64, p_mut_acc: f64, rng: &mut Rng) {
    if rng.chance(p_mut_acc) {
        let i = rng.below(qc.len() as u64) as usize;
        qc.layers[i] = (8, 8);
    }
    if rng.chance(p_mut) {
        let i = rng.below(qc.len() as u64) as usize;
        let q = QMIN + rng.below((QMAX - QMIN + 1) as u64) as u8;
        if rng.chance(0.5) {
            qc.layers[i].0 = q;
        } else {
            qc.layers[i].1 = q;
        }
    }
}

/// Everything a paused NSGA-II run needs to continue and still produce
/// a bit-identical final front: the number of completed generations,
/// the parent population, and the breeding RNG mid-stream.
/// `engine::checkpoint` persists it at generation boundaries.
#[derive(Debug, Clone)]
pub struct SearchState {
    /// Generations completed so far (0 = only the initial population).
    pub generation: usize,
    /// Parent population after the latest environmental selection.
    pub pop: Vec<Individual>,
    /// The breeding RNG (consumed only by crossover/mutation draws).
    pub rng: Rng,
}

/// Build and evaluate the initial population (the paper's uniformly
/// quantized configurations), run the first environmental selection,
/// and return the generation-0 state.
pub fn init_state<E>(num_layers: usize, cfg: &NsgaConfig, evaluate: &mut E) -> SearchState
where
    E: FnMut(&[QuantConfig]) -> Vec<ObjectiveVec>,
{
    let rng = Rng::new(cfg.seed);
    let genomes: Vec<QuantConfig> = (0..cfg.population)
        .map(|i| {
            let q = QMIN + (i as u8 % (QMAX - QMIN + 1));
            QuantConfig::uniform(num_layers, q)
        })
        .collect();
    let objs = evaluate(&genomes);
    assert_eq!(objs.len(), genomes.len(), "evaluator arity");
    let pop: Vec<Individual> = genomes
        .into_iter()
        .zip(objs)
        .map(|(genome, objectives)| Individual { genome, objectives })
        .collect();
    SearchState {
        generation: 0,
        pop: environmental_select(pop, cfg.population),
        rng,
    }
}

/// Advance the search by one generation: breed `cfg.offspring`
/// children, evaluate them, and select the next parent population.
pub fn step<E>(st: &mut SearchState, cfg: &NsgaConfig, evaluate: &mut E)
where
    E: FnMut(&[QuantConfig]) -> Vec<ObjectiveVec>,
{
    let mut offspring: Vec<QuantConfig> = Vec::with_capacity(cfg.offspring);
    for _ in 0..cfg.offspring {
        let pa = &st.pop[st.rng.below(st.pop.len() as u64) as usize].genome;
        let pb = &st.pop[st.rng.below(st.pop.len() as u64) as usize].genome;
        let mut child = uniform_crossover(pa, pb, &mut st.rng);
        mutate(&mut child, cfg.p_mut, cfg.p_mut_acc, &mut st.rng);
        offspring.push(child);
    }
    let objs = evaluate(&offspring);
    assert_eq!(objs.len(), offspring.len(), "evaluator arity");
    for (genome, objectives) in offspring.into_iter().zip(objs) {
        st.pop.push(Individual { genome, objectives });
    }
    let pop = std::mem::take(&mut st.pop);
    st.pop = environmental_select(pop, cfg.population);
    st.generation += 1;
}

/// The population's non-dominated front (the paper filters dominated
/// points from the final answer).
pub fn final_front(pop: &[Individual]) -> Vec<Individual> {
    let fronts = non_dominated_sort(pop);
    fronts[0].iter().map(|&i| pop[i].clone()).collect()
}

/// One NSGA-II run over a user-supplied evaluator.
///
/// `evaluate(genomes)` is called with the genomes needing objectives
/// (initial population, then each generation's offspring — parents carry
/// their values, matching the paper's note that |P| has minimal cost).
/// `on_generation(gen, population)` observes the parent population after
/// each environmental selection (Fig. 5 snapshots). Returns the final
/// non-dominated front.
///
/// Built on [`init_state`]/[`step`], so a checkpointed run through
/// `engine::driver::search_resumable` walks the identical RNG stream
/// and produces the identical front.
pub fn run<E, O>(
    num_layers: usize,
    cfg: &NsgaConfig,
    mut evaluate: E,
    mut on_generation: O,
) -> Vec<Individual>
where
    E: FnMut(&[QuantConfig]) -> Vec<ObjectiveVec>,
    O: FnMut(usize, &[Individual]),
{
    let mut st = init_state(num_layers, cfg, &mut evaluate);
    on_generation(0, &st.pop);
    while st.generation < cfg.generations {
        step(&mut st, cfg, &mut evaluate);
        on_generation(st.generation, &st.pop);
    }
    final_front(&st.pop)
}

/// Extract the Pareto front (objective vectors) from a set of points,
/// sorted **lexicographically across all axes** — not just the first.
/// Utility for reports/benches.
///
/// The full-vector sort matters: with a first-axis-only key, points
/// tying on axis 0 (equal energy, say) kept their *input* order, so two
/// pipelines producing the same front in different candidate orders
/// printed different files — latent nondeterminism the serial-vs-
/// distributed diffs would eventually trip over. The lexicographic key
/// is total over the non-NaN floats the front can contain (including
/// `INFINITY`), so the output order is a pure function of the set.
pub fn pareto_front_of_points(points: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let mut front: Vec<Vec<f64>> = Vec::new();
    for p in points {
        if points.iter().any(|q| dominates(q, p)) {
            continue;
        }
        if !front.contains(p) {
            front.push(p.clone());
        }
    }
    front.sort_by(|a, b| {
        for (x, y) in a.iter().zip(b) {
            match x.partial_cmp(y) {
                Some(std::cmp::Ordering::Equal) | None => continue,
                Some(ord) => return ord,
            }
        }
        a.len().cmp(&b.len())
    });
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(objs: &[f64]) -> Individual {
        Individual {
            genome: QuantConfig::uniform(2, 8),
            objectives: ObjectiveVec::raw(objs.to_vec()),
        }
    }

    #[test]
    fn dominance_relation() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0])); // incomparable
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal
    }

    #[test]
    fn sort_fronts() {
        let pop = vec![
            ind(&[1.0, 4.0]),
            ind(&[2.0, 3.0]),
            ind(&[3.0, 2.0]),
            ind(&[4.0, 4.0]), // dominated by (2,3) and (3,2)
            ind(&[5.0, 5.0]), // dominated by everything in front 0 and 1
        ];
        let fronts = non_dominated_sort(&pop);
        assert_eq!(fronts[0], vec![0, 1, 2]);
        assert_eq!(fronts[1], vec![3]);
        assert_eq!(fronts[2], vec![4]);
    }

    #[test]
    fn crowding_extremes_infinite() {
        let pop = vec![ind(&[1.0, 4.0]), ind(&[2.0, 3.0]), ind(&[3.0, 2.0])];
        let d = crowding_distance(&pop, &[0, 1, 2]);
        assert!(d[0].is_infinite());
        assert!(d[2].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
    }

    #[test]
    fn environmental_selection_prefers_front0_and_spread() {
        let pop = vec![
            ind(&[1.0, 5.0]),
            ind(&[2.0, 4.0]),
            ind(&[3.0, 3.0]),
            ind(&[4.0, 2.0]),
            ind(&[5.0, 1.0]),
            ind(&[6.0, 6.0]), // dominated
        ];
        let sel = environmental_select(pop, 4);
        assert_eq!(sel.len(), 4);
        assert!(sel.iter().all(|i| i.objectives.values() != [6.0, 6.0]));
        // extremes survive (infinite crowding)
        assert!(sel.iter().any(|i| i.objectives.values() == [1.0, 5.0]));
        assert!(sel.iter().any(|i| i.objectives.values() == [5.0, 1.0]));
    }

    #[test]
    fn selection_is_noop_when_small() {
        let pop = vec![ind(&[1.0, 1.0])];
        assert_eq!(environmental_select(pop, 4).len(), 1);
    }

    #[test]
    fn crossover_genes_come_from_parents() {
        let mut rng = Rng::new(3);
        let a = QuantConfig::uniform(10, 2);
        let b = QuantConfig::uniform(10, 8);
        for _ in 0..20 {
            let c = uniform_crossover(&a, &b, &mut rng);
            for (i, &(qa, qw)) in c.layers.iter().enumerate() {
                assert!(qa == 2 || qa == 8, "layer {i}");
                assert!(qw == 2 || qw == 8, "layer {i}");
            }
        }
    }

    #[test]
    fn mutation_keeps_genome_valid() {
        let mut rng = Rng::new(9);
        let mut qc = QuantConfig::uniform(28, 5);
        for _ in 0..500 {
            mutate(&mut qc, 0.5, 0.5, &mut rng);
            for &(qa, qw) in &qc.layers {
                assert!((QMIN..=QMAX).contains(&qa));
                assert!((QMIN..=QMAX).contains(&qw));
            }
        }
    }

    #[test]
    fn run_converges_on_synthetic_problem() {
        // objectives: f1 = total bits (minimize), f2 = "error" =
        // sum (8-q)^2 (minimize) -> a clean trade-off curve.
        let cfg = NsgaConfig {
            population: 16,
            offspring: 8,
            generations: 30,
            seed: 4,
            ..NsgaConfig::default()
        };
        let evaluate = |gs: &[QuantConfig]| {
            gs.iter()
                .map(|g| {
                    let bits: f64 = g.layers.iter().map(|&(a, w)| (a + w) as f64).sum();
                    let err: f64 = g
                        .layers
                        .iter()
                        .map(|&(a, w)| {
                            ((8 - a.min(8)) as f64).powi(2) + ((8 - w.min(8)) as f64).powi(2)
                        })
                        .sum();
                    ObjectiveVec::raw(vec![bits, err])
                })
                .collect()
        };
        let mut gens_seen = 0;
        let front = run(6, &cfg, evaluate, |_, _| gens_seen += 1);
        assert_eq!(gens_seen, cfg.generations + 1);
        assert!(!front.is_empty());
        // front must be mutually non-dominated
        for a in &front {
            for b in &front {
                assert!(
                    !dominates(&a.objectives, &b.objectives) || a.objectives == b.objectives
                );
            }
        }
        // and should reach near-extreme points on both objectives
        let min_bits = front
            .iter()
            .map(|i| i.objectives[0])
            .fold(f64::INFINITY, f64::min);
        let min_err = front
            .iter()
            .map(|i| i.objectives[1])
            .fold(f64::INFINITY, f64::min);
        assert!(min_bits <= 6.0 * 2.0 * 3.0, "min_bits={min_bits}");
        assert!(min_err <= 10.0, "min_err={min_err}");
    }

    #[test]
    fn pareto_front_util() {
        let pts = vec![
            vec![1.0, 4.0],
            vec![2.0, 3.0],
            vec![2.5, 3.5], // dominated by (2,3)
            vec![3.0, 1.0],
            vec![1.0, 4.0], // duplicate
        ];
        let f = pareto_front_of_points(&pts);
        assert_eq!(f, vec![vec![1.0, 4.0], vec![2.0, 3.0], vec![3.0, 1.0]]);
    }

    #[test]
    fn pareto_front_order_is_stable_under_first_axis_ties() {
        // three mutually non-dominated 3-axis points sharing the first
        // coordinate: the output order must be a pure function of the
        // set, regardless of the input permutation (the old first-axis
        // sort kept insertion order here)
        let a = vec![1.0, 5.0, 3.0];
        let b = vec![1.0, 4.0, 9.0];
        let c = vec![1.0, 3.0, 10.0];
        let want = vec![c.clone(), b.clone(), a.clone()];
        let perms: [[&Vec<f64>; 3]; 3] = [[&a, &b, &c], [&c, &a, &b], [&b, &c, &a]];
        for perm in perms {
            let pts: Vec<Vec<f64>> = perm.iter().map(|p| (*p).clone()).collect();
            assert_eq!(pareto_front_of_points(&pts), want, "input {pts:?}");
        }
    }
}
