//! Integer factorization utilities for tiling-factor enumeration.
//!
//! A mapping distributes each problem dimension `D` across `L` hierarchy
//! slots as an ordered factorization `D = f_1 * f_2 * ... * f_L`. The
//! mapspace enumerates (or samples) these ordered factorizations.

/// All divisors of `n`, ascending.
pub fn divisors(n: u64) -> Vec<u64> {
    assert!(n > 0);
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Prime factorization as (prime, exponent) pairs.
pub fn prime_factors(mut n: u64) -> Vec<(u64, u32)> {
    assert!(n > 0);
    let mut out = Vec::new();
    let mut p = 2;
    while p * p <= n {
        if n % p == 0 {
            let mut e = 0;
            while n % p == 0 {
                n /= p;
                e += 1;
            }
            out.push((p, e));
        }
        p += if p == 2 { 1 } else { 2 };
    }
    if n > 1 {
        out.push((n, 1));
    }
    out
}

/// Number of ordered factorizations of `n` into exactly `slots` factors
/// (factors of 1 allowed): product over primes of C(e + slots - 1, slots - 1).
pub fn count_ordered_factorizations(n: u64, slots: usize) -> u64 {
    if slots == 0 {
        return u64::from(n == 1);
    }
    prime_factors(n)
        .iter()
        .map(|&(_, e)| binomial(e as u64 + slots as u64 - 1, slots as u64 - 1))
        .product()
}

fn binomial(n: u64, k: u64) -> u64 {
    let k = k.min(n - k);
    let mut r: u128 = 1;
    for i in 0..k {
        r = r * (n - i) as u128 / (i + 1) as u128;
    }
    r as u64
}

/// Enumerate all ordered factorizations of `n` into exactly `slots`
/// factors, invoking `f` with each (factors of 1 allowed).
pub fn for_each_ordered_factorization(n: u64, slots: usize, mut f: impl FnMut(&[u64])) {
    let mut buf = vec![1u64; slots];
    rec(n, 0, slots, &mut buf, &mut f);

    fn rec(rem: u64, i: usize, slots: usize, buf: &mut [u64], f: &mut impl FnMut(&[u64])) {
        if i == slots - 1 {
            buf[i] = rem;
            f(buf);
            return;
        }
        for d in divisors(rem) {
            buf[i] = d;
            rec(rem / d, i + 1, slots, buf, f);
        }
    }
}

/// Sample one ordered factorization of `n` into `slots` factors uniformly
/// at random (per-prime stars-and-bars draw).
pub fn random_ordered_factorization(
    n: u64,
    slots: usize,
    rng: &mut crate::util::rng::Rng,
) -> Vec<u64> {
    let mut out = vec![1u64; slots.max(1)];
    if slots == 0 {
        return out;
    }
    random_factorization_into(&prime_factors(n), rng, &mut out);
    out
}

/// Allocation-free sampling core: distribute the given prime
/// factorization across `out.len()` slots, writing the factors in place.
/// Per prime factor instance, one uniform slot draw — NOT uniform over
/// compositions, but over *assignments*; Timeloop's random mapper does
/// per-factor uniform assignment too, which is what we mirror. The RNG
/// stream consumed is identical to [`random_ordered_factorization`]'s
/// (primes in ascending order, `e` draws per prime), so the in-place and
/// allocating paths sample bit-identical factorizations.
#[inline]
pub fn random_factorization_into(
    primes: &[(u64, u32)],
    rng: &mut crate::util::rng::Rng,
    out: &mut [u64],
) {
    debug_assert!(!out.is_empty());
    out.fill(1);
    // Size-1 dims (N on batch-1 nets, R/S on pointwise/FC layers) have
    // no primes to place: skip the scatter loop entirely. No RNG draw is
    // skipped — the allocating path draws nothing for them either.
    if primes.is_empty() {
        return;
    }
    let slots = out.len() as u64;
    for &(p, e) in primes {
        for _ in 0..e {
            let b = rng.below(slots) as usize;
            out[b] *= p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(7), vec![1, 7]);
        assert_eq!(divisors(112), vec![1, 2, 4, 7, 8, 14, 16, 28, 56, 112]);
    }

    #[test]
    fn prime_factors_basic() {
        assert_eq!(prime_factors(112), vec![(2, 4), (7, 1)]);
        assert_eq!(prime_factors(97), vec![(97, 1)]);
        assert_eq!(prime_factors(1), vec![]);
    }

    #[test]
    fn count_matches_enumeration() {
        for n in [1u64, 2, 12, 36, 112, 97] {
            for slots in 1..=4 {
                let mut cnt = 0u64;
                for_each_ordered_factorization(n, slots, |fs| {
                    assert_eq!(fs.iter().product::<u64>(), n);
                    cnt += 1;
                });
                assert_eq!(
                    cnt,
                    count_ordered_factorizations(n, slots),
                    "n={n} slots={slots}"
                );
            }
        }
    }

    #[test]
    fn count_known_values() {
        // 12 = 2^2*3 into 2 slots: C(3,1)*C(2,1) = 6: (1,12),(2,6),(3,4),(4,3),(6,2),(12,1)
        assert_eq!(count_ordered_factorizations(12, 2), 6);
        assert_eq!(count_ordered_factorizations(1, 3), 1);
        // 112 = 2^4 * 7 into 3 slots: C(6,2) * C(3,2) = 15 * 3 = 45
        assert_eq!(count_ordered_factorizations(112, 3), 45);
    }

    #[test]
    fn random_factorization_valid() {
        let mut r = Rng::new(5);
        for n in [112u64, 36, 97, 1] {
            for slots in 1..=4 {
                for _ in 0..50 {
                    let fs = random_ordered_factorization(n, slots, &mut r);
                    assert_eq!(fs.len(), slots.max(1));
                    assert_eq!(fs.iter().product::<u64>(), n);
                }
            }
        }
    }

    #[test]
    fn into_variant_matches_allocating_variant() {
        // same seed -> identical RNG consumption -> identical samples
        for n in [112u64, 36, 97, 1, 720] {
            for slots in 1..=4usize {
                let primes = prime_factors(n);
                let mut r1 = Rng::new(99);
                let mut r2 = Rng::new(99);
                let mut buf = vec![0u64; slots];
                for _ in 0..20 {
                    let a = random_ordered_factorization(n, slots, &mut r1);
                    random_factorization_into(&primes, &mut r2, &mut buf);
                    assert_eq!(a, buf, "n={n} slots={slots}");
                }
            }
        }
    }
}
