//! Equivalence and determinism properties of the allocation-free mapper
//! hot path.
//!
//! The refactored engine (`LayerContext` tables + `EvalContext` scratch
//! + `random_mapping_into`/`check`/`analyze_into`/`estimate_into`) must
//! be *bit-identical* to the naive path (`random_mapping`/`check`/
//! `analyze`/`estimate`) — same candidates, same verdicts, same floats.
//! The sharded search must be deterministic in (seed, shard-count), and
//! with one shard must reproduce the single-threaded reference loop
//! exactly.

use qmap::arch::presets::{eyeriss, simba, toy};
use qmap::arch::Arch;
use qmap::energy::{edp_lower_bound, estimate, estimate_into, BoundScratch, Estimate};
use qmap::mapper::{
    merge_shards, run_shard, run_shard_unpruned, search, shard_plan, workload_hash, EvalContext,
    MapperConfig, ShardSpec,
};
use qmap::mapping::mapspace::MapSpace;
use qmap::mapping::{check, LayerContext, Mapping};
use qmap::nest::{analyze, analyze_into, analyze_prefilled, NestAnalysis};
use qmap::quant::LayerQuant;
use qmap::util::rng::Rng;
use qmap::workload::ConvLayer;

fn layers_under_test() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("c1", 4, 8, 3, 8, 1),
        ConvLayer::conv("c2", 16, 32, 3, 14, 2),
        ConvLayer::dw("d1", 32, 3, 14, 1),
        ConvLayer::pw("p1", 16, 32, 14),
        ConvLayer::fc("f1", 64, 10),
    ]
}

#[test]
fn ctx_analysis_is_bit_identical_to_naive_path() {
    let mut total_checked = 0usize;
    for arch in [toy(), eyeriss(), simba()] {
        let space = MapSpace::of(&arch);
        let mut ectx = EvalContext::for_arch(&arch);
        for layer in layers_under_test() {
            for bits in [2u8, 4, 8] {
                let q = LayerQuant::uniform(bits).canonical(arch.word_bits, arch.bit_packing);
                let lctx = LayerContext::new(&arch, &layer, &q);
                let mut rng = Rng::new(0xB17 ^ bits as u64);
                for _ in 0..150 {
                    let m = space.random_mapping(&layer, &mut rng);
                    let naive = check(&arch, &layer, &q, &m);
                    let ctx = lctx.check(&m, &mut ectx.ext);
                    assert_eq!(naive, ctx, "{} {} {}b", arch.name, layer.name, bits);
                    if naive.is_err() {
                        continue;
                    }
                    total_checked += 1;

                    let nest_naive: NestAnalysis = analyze(&arch, &layer, &m);
                    analyze_into(&lctx, &m, &mut ectx.ext, &mut ectx.nest);
                    assert_eq!(nest_naive.macs, ectx.nest.macs);
                    assert_eq!(nest_naive.pes_used, ectx.nest.pes_used);
                    assert_eq!(
                        nest_naive.accesses, ectx.nest.accesses,
                        "{} {} {}b: access counts diverged",
                        arch.name, layer.name, bits
                    );

                    let est_naive: Estimate = estimate(&arch, &layer, &q, &nest_naive);
                    estimate_into(&lctx, &ectx.nest, &mut ectx.est);
                    assert_eq!(
                        est_naive, ectx.est,
                        "{} {} {}b: estimate diverged",
                        arch.name, layer.name, bits
                    );
                    assert_eq!(est_naive.edp().to_bits(), ectx.est.edp().to_bits());
                }
            }
        }
    }
    assert!(total_checked > 100, "too few valid samples: {total_checked}");
}

/// Replicates the pre-refactor single-threaded search loop with the
/// naive per-draw functions.
fn reference_search(
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    cfg: &MapperConfig,
) -> (Option<u64>, u64, u64) {
    let q = &q.canonical(arch.word_bits, arch.bit_packing);
    let space = MapSpace::of(arch);
    let mut rng = Rng::new(cfg.seed ^ workload_hash(layer, q));
    let mut best: Option<f64> = None;
    let mut valid = 0u64;
    let mut draws = 0u64;
    while valid < cfg.valid_target && draws < cfg.max_draws {
        draws += 1;
        let m = space.random_mapping(layer, &mut rng);
        if check(arch, layer, q, &m).is_err() {
            continue;
        }
        valid += 1;
        let nest = analyze(arch, layer, &m);
        let est = estimate(arch, layer, q, &nest);
        let edp = est.edp();
        if best.map_or(true, |b| edp < b) {
            best = Some(edp);
        }
    }
    (best.map(f64::to_bits), valid, draws)
}

#[test]
fn single_shard_search_matches_naive_reference() {
    for (arch, layer) in [
        (toy(), ConvLayer::conv("t", 4, 8, 3, 8, 1)),
        (eyeriss(), ConvLayer::dw("d", 32, 3, 14, 1)),
    ] {
        for bits in [4u8, 8] {
            let q = LayerQuant::uniform(bits);
            let cfg = MapperConfig {
                valid_target: 80,
                max_draws: 80_000,
                seed: 23,
                shards: 1,
            };
            let (ref_best, ref_valid, ref_draws) = reference_search(&arch, &layer, &q, &cfg);
            let r = search(&arch, &layer, &q, &cfg);
            assert_eq!(r.best.map(|e| e.edp().to_bits()), ref_best, "{} {bits}b", arch.name);
            assert_eq!(r.valid, ref_valid);
            assert_eq!(r.draws, ref_draws);
        }
    }
}

#[test]
fn sharded_search_is_deterministic_per_shard_count() {
    let arch = eyeriss();
    let layer = ConvLayer::pw("p", 16, 32, 14);
    let q = LayerQuant::uniform(4);
    for shards in [1usize, 2, 3, 8] {
        let cfg = MapperConfig {
            valid_target: 160,
            max_draws: 160_000,
            seed: 77,
            shards,
        };
        let r1 = search(&arch, &layer, &q, &cfg);
        let r2 = search(&arch, &layer, &q, &cfg);
        assert_eq!(
            r1.best.as_ref().map(|e| e.edp().to_bits()),
            r2.best.as_ref().map(|e| e.edp().to_bits()),
            "shards={shards}"
        );
        assert_eq!(r1.valid, r2.valid, "shards={shards}");
        assert_eq!(r1.draws, r2.draws, "shards={shards}");
        assert_eq!(r1.best_mapping, r2.best_mapping, "shards={shards}");
        assert!(r1.valid >= 160, "shards={shards}: valid={}", r1.valid);
    }
}

#[test]
fn sharded_best_is_a_valid_mapping_with_plausible_edp() {
    // the sharded winner must verify against the naive checker/pricer
    let arch = eyeriss();
    let layer = ConvLayer::dw("d", 32, 3, 14, 1);
    let q = LayerQuant::uniform(8);
    let cfg = MapperConfig {
        valid_target: 200,
        max_draws: 200_000,
        seed: 5,
        shards: 4,
    };
    let r = search(&arch, &layer, &q, &cfg);
    let est = r.best.expect("should map");
    let m = r.best_mapping.expect("mapping returned");
    let qc = q.canonical(arch.word_bits, arch.bit_packing);
    check(&arch, &layer, &qc, &m).expect("winner must be valid");
    let nest = analyze(&arch, &layer, &m);
    let naive = estimate(&arch, &layer, &qc, &nest);
    assert_eq!(naive.edp().to_bits(), est.edp().to_bits());
}

/// One-candidate-at-a-time replica of the pre-batching `run_shard` loop
/// (the allocation-free scalar pipeline: `random_mapping_into` +
/// `LayerContext::check` + `analyze_into` + `estimate_into`), with the
/// exact termination and first-winner semantics of the shard loop.
fn scalar_shard(
    space: &MapSpace,
    lctx: &LayerContext,
    spec: &ShardSpec,
) -> (Option<u64>, Option<Mapping>, u64, u64) {
    let mut ectx = EvalContext::with_dims(lctx.num_levels, space.slots());
    let mut rng = Rng::new(spec.seed);
    let mut best: Option<(f64, Mapping)> = None;
    let mut valid = 0u64;
    let mut draws = 0u64;
    while valid < spec.valid_target && draws < spec.max_draws {
        draws += 1;
        space.random_mapping_into(lctx, &mut rng, &mut ectx.fbuf, &mut ectx.mapping);
        if lctx.check(&ectx.mapping, &mut ectx.ext).is_err() {
            continue;
        }
        valid += 1;
        analyze_into(lctx, &ectx.mapping, &mut ectx.ext, &mut ectx.nest);
        estimate_into(lctx, &ectx.nest, &mut ectx.est);
        let edp = ectx.est.edp();
        if best.as_ref().map_or(true, |(b, _)| edp < *b) {
            best = Some((edp, ectx.mapping.clone()));
        }
    }
    let (b, m) = match best {
        Some((b, m)) => (Some(b.to_bits()), Some(m)),
        None => (None, None),
    };
    (b, m, valid, draws)
}

/// Naive (allocating, table-free) replica of the same shard loop.
fn naive_shard(
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    spec: &ShardSpec,
) -> (Option<u64>, Option<Mapping>, u64, u64) {
    let space = MapSpace::of(arch);
    let mut rng = Rng::new(spec.seed);
    let mut best: Option<(f64, Mapping)> = None;
    let mut valid = 0u64;
    let mut draws = 0u64;
    while valid < spec.valid_target && draws < spec.max_draws {
        draws += 1;
        let m = space.random_mapping(layer, &mut rng);
        if check(arch, layer, q, &m).is_err() {
            continue;
        }
        valid += 1;
        let edp = estimate(arch, layer, q, &analyze(arch, layer, &m)).edp();
        if best.as_ref().map_or(true, |(b, _)| edp < *b) {
            best = Some((edp, m));
        }
    }
    let (b, m) = match best {
        Some((b, m)) => (Some(b.to_bits()), Some(m)),
        None => (None, None),
    };
    (b, m, valid, draws)
}

#[test]
fn batched_shard_is_bit_identical_to_scalar_and_naive() {
    // the tentpole property: the staged batch evaluator must reproduce
    // the scalar pipeline AND the naive path candidate-for-candidate —
    // same winner (bits and mapping), same valid/draw counters — across
    // degenerate shapes (1x1, depthwise, stride 2, fc) and degenerate
    // budgets (zero draws, zero valid target, budgets that are not a
    // multiple of the batch size, targets that stop a block mid-way)
    for arch in [toy(), eyeriss()] {
        let space = MapSpace::of(&arch);
        for layer in layers_under_test() {
            let q = LayerQuant::uniform(4).canonical(arch.word_bits, arch.bit_packing);
            let lctx = LayerContext::new(&arch, &layer, &q);
            let specs = [
                ShardSpec { seed: 0xA1, valid_target: u64::MAX, max_draws: 0 },
                ShardSpec { seed: 0xA2, valid_target: 0, max_draws: 1_000 },
                ShardSpec { seed: 0xA3, valid_target: u64::MAX, max_draws: 64 },
                ShardSpec { seed: 0xA4, valid_target: u64::MAX, max_draws: 100 },
                ShardSpec { seed: 0xA5, valid_target: 7, max_draws: 20_000 },
                ShardSpec { seed: 0xA6, valid_target: 40, max_draws: 10_000 },
            ];
            for spec in specs {
                let got = merge_shards(vec![run_shard(&space, &lctx, &spec)]);
                let what = format!("{} {} spec={spec:?}", arch.name, layer.name);
                for (wb, wm, wv, wd) in [
                    scalar_shard(&space, &lctx, &spec),
                    naive_shard(&arch, &layer, &q, &spec),
                ] {
                    assert_eq!(got.best.as_ref().map(|e| e.edp().to_bits()), wb, "{what}");
                    assert_eq!(got.best_mapping, wm, "{what}");
                    assert_eq!(got.valid, wv, "{what}");
                    assert_eq!(got.draws, wd, "{what}");
                }
            }
        }
    }
}

#[test]
fn batched_shard_matches_scalar_replica_over_shard_plans() {
    // the same property through the deterministic shard decomposition:
    // every shard of a multi-shard plan, run batched, must equal its
    // scalar replica — so sharded searches cannot drift either
    let arch = eyeriss();
    let space = MapSpace::of(&arch);
    for layer in [ConvLayer::pw("p", 16, 32, 14), ConvLayer::dw("d", 32, 3, 14, 1)] {
        let q = LayerQuant::uniform(8).canonical(arch.word_bits, arch.bit_packing);
        let lctx = LayerContext::new(&arch, &layer, &q);
        for shards in [2usize, 3] {
            let cfg = MapperConfig {
                valid_target: 90,
                max_draws: 9_001, // not divisible by shards or blocks
                seed: 0x5EED,
                shards,
            };
            for spec in shard_plan(&cfg, cfg.seed ^ workload_hash(&layer, &q)) {
                let got = run_shard(&space, &lctx, &spec);
                let (wb, _, wv, wd) = scalar_shard(&space, &lctx, &spec);
                assert_eq!(got.best_edp().map(f64::to_bits), wb, "{spec:?}");
                assert_eq!(got.valid(), wv, "{spec:?}");
                assert_eq!(got.draws(), wd, "{spec:?}");
            }
        }
    }
}

#[test]
fn cascade_rejects_iff_full_check_rejects() {
    // the rejection cascade's verdict must agree with the monolithic
    // check on every candidate, and for accepted candidates the tile
    // footprints it records must price bit-identically to the
    // recomputing analyzer
    let mut accepted = 0usize;
    for arch in [toy(), eyeriss(), simba()] {
        let space = MapSpace::of(&arch);
        let mut ectx = EvalContext::for_arch(&arch);
        let mut nest2 = NestAnalysis::empty();
        for layer in layers_under_test() {
            let q = LayerQuant::uniform(4).canonical(arch.word_bits, arch.bit_packing);
            let lctx = LayerContext::new(&arch, &layer, &q);
            let mut rng = Rng::new(0xCA5CADE);
            for _ in 0..200 {
                let m = space.random_mapping(&layer, &mut rng);
                let full = lctx.check(&m, &mut ectx.ext).is_ok();
                let staged = lctx.check_spatial(&m).is_ok()
                    && lctx.check_tiles_into(&m, &mut ectx.ext, &mut ectx.elems).is_ok();
                assert_eq!(full, staged, "{} {}", arch.name, layer.name);
                if !staged {
                    continue;
                }
                accepted += 1;
                analyze_prefilled(&lctx, &m, &ectx.elems, &mut ectx.nest);
                analyze_into(&lctx, &m, &mut ectx.ext, &mut nest2);
                assert_eq!(ectx.nest.macs, nest2.macs);
                assert_eq!(ectx.nest.pes_used, nest2.pes_used);
                assert_eq!(
                    ectx.nest.accesses, nest2.accesses,
                    "{} {}: prefilled analysis diverged",
                    arch.name, layer.name
                );
            }
        }
    }
    assert!(accepted > 100, "too few accepted samples: {accepted}");
}

#[test]
fn edp_lower_bound_is_admissible_on_every_accepted_candidate() {
    // the pruning stage's soundness property: for every candidate that
    // survives the rejection cascade, the slab-derived lower bound must
    // never exceed the exact EDP — on all preset arches, layer shapes,
    // and bit-widths. A single violation here could make the pruned
    // cascade drop a true winner, so the comparison is plain `<=` on
    // the very floats the cascade compares.
    let mut accepted = 0usize;
    for arch in [toy(), eyeriss(), simba()] {
        let space = MapSpace::of(&arch);
        let mut ectx = EvalContext::for_arch(&arch);
        let mut scratch = BoundScratch::new();
        for layer in layers_under_test() {
            for bits in [2u8, 4, 8] {
                let q = LayerQuant::uniform(bits).canonical(arch.word_bits, arch.bit_packing);
                let lctx = LayerContext::new(&arch, &layer, &q);
                assert!(lctx.bound_safe, "{}: preset arch must be bound-safe", arch.name);
                let mut rng = Rng::new(0xB0D ^ bits as u64);
                for _ in 0..200 {
                    let m = space.random_mapping(&layer, &mut rng);
                    if lctx.check_spatial(&m).is_err()
                        || lctx.check_tiles_into(&m, &mut ectx.ext, &mut ectx.elems).is_err()
                    {
                        continue;
                    }
                    accepted += 1;
                    let bound = edp_lower_bound(&lctx, &m, &ectx.elems, &mut scratch);
                    analyze_prefilled(&lctx, &m, &ectx.elems, &mut ectx.nest);
                    estimate_into(&lctx, &ectx.nest, &mut ectx.est);
                    let exact = ectx.est.edp();
                    assert!(
                        bound <= exact,
                        "{} {} {}b: bound {bound} > exact {exact}",
                        arch.name,
                        layer.name,
                        bits
                    );
                    assert!(bound.is_finite() && bound >= 0.0, "{} {}", arch.name, layer.name);
                }
            }
        }
    }
    assert!(accepted > 300, "too few accepted samples: {accepted}");
}

#[test]
fn pruned_cascade_is_bit_identical_to_unpruned_over_shard_plans() {
    // the tentpole bit-identity oracle: the production (pruned) cascade,
    // the pruning-compiled-out reference cascade, and the scalar replica
    // must agree shard-for-shard — winner bits, winning mapping, valid
    // and draw counters — across multi-shard plans, and their merges
    // must agree too. Pruning may only change how much work pricing
    // does, never any observable result.
    for arch in [toy(), eyeriss(), simba()] {
        let space = MapSpace::of(&arch);
        for layer in [ConvLayer::conv("c", 16, 32, 3, 14, 2), ConvLayer::pw("p", 16, 32, 14)] {
            let q = LayerQuant::uniform(4).canonical(arch.word_bits, arch.bit_packing);
            let lctx = LayerContext::new(&arch, &layer, &q);
            for shards in [1usize, 3] {
                let cfg = MapperConfig {
                    valid_target: 60,
                    max_draws: 30_011, // not a multiple of shards or blocks
                    seed: 0xB0B,
                    shards,
                };
                let plan = shard_plan(&cfg, cfg.seed ^ workload_hash(&layer, &q));
                let pruned: Vec<_> = plan.iter().map(|s| run_shard(&space, &lctx, s)).collect();
                let unpruned: Vec<_> =
                    plan.iter().map(|s| run_shard_unpruned(&space, &lctx, s)).collect();
                for (spec, (p, u)) in plan.iter().zip(pruned.iter().zip(unpruned.iter())) {
                    let what = format!("{} {} {spec:?}", arch.name, layer.name);
                    assert_eq!(
                        p.best_edp().map(f64::to_bits),
                        u.best_edp().map(f64::to_bits),
                        "{what}"
                    );
                    assert_eq!(p.valid(), u.valid(), "{what}");
                    assert_eq!(p.draws(), u.draws(), "{what}");
                    let (sb, sm, sv, sd) = scalar_shard(&space, &lctx, spec);
                    assert_eq!(p.best_edp().map(f64::to_bits), sb, "{what}");
                    assert_eq!(p.valid(), sv, "{what}");
                    assert_eq!(p.draws(), sd, "{what}");
                    let _ = sm;
                }
                let mp = merge_shards(pruned);
                let mu = merge_shards(unpruned);
                assert_eq!(
                    mp.best.as_ref().map(|e| e.edp().to_bits()),
                    mu.best.as_ref().map(|e| e.edp().to_bits())
                );
                assert_eq!(mp.best_mapping, mu.best_mapping);
                assert_eq!(mp.valid, mu.valid);
                assert_eq!(mp.draws, mu.draws);
            }
        }
    }
}

#[test]
fn more_shards_never_reduce_total_valid_target_coverage() {
    // splitting the budget across shards must still reach the target on
    // an easy workload, whatever the shard count
    let arch = toy();
    let layer = ConvLayer::conv("t", 4, 8, 3, 8, 1);
    let q = LayerQuant::uniform(8);
    for shards in [1usize, 2, 5] {
        let cfg = MapperConfig {
            valid_target: 100,
            max_draws: 100_000,
            seed: 9,
            shards,
        };
        let r = search(&arch, &layer, &q, &cfg);
        assert!(r.valid >= 100, "shards={shards}: {}", r.valid);
        assert!(r.best.is_some());
    }
}
