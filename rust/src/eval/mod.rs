//! Network-level evaluation: characterize every layer of a quantized
//! CNN through the mapper (per-layer, as Timeloop does), then sum
//! energies/latencies — "the total energy is determined as a sum of the
//! energies required to compute every workload; the same is valid also
//! for total latency".

use crate::arch::Arch;
use crate::mapper::cache::{CachedEval, MapperCache};
use crate::mapper::MapperConfig;
use crate::quant::QuantConfig;
use crate::workload::ConvLayer;

/// Aggregated hardware metrics of one quantized network on one
/// accelerator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkEval {
    pub energy_pj: f64,
    pub memory_energy_pj: f64,
    pub mac_energy_pj: f64,
    pub cycles: f64,
    /// Sum of per-layer EDPs (paper's per-layer characterization).
    pub edp: f64,
    /// Coarse breakdown `[spads, buffers, dram]`, pJ.
    pub energy_breakdown_pj: [f64; 3],
    /// Weight-memory word count after packing (Fig. 1a metric).
    pub weight_words: u64,
    /// Naïve model size in bits (Fig. 1 x-axis).
    pub model_size_bits: u64,
}

/// Evaluate a full network configuration. Returns `None` if any layer
/// fails to map (no valid mapping found within the draw budget) — and
/// short-circuits on the first such layer, so a doomed genome does not
/// pay for characterizing its remaining layers.
pub fn evaluate_network(
    arch: &Arch,
    layers: &[ConvLayer],
    qc: &QuantConfig,
    cache: &MapperCache,
    cfg: &MapperConfig,
) -> Option<NetworkEval> {
    assert_eq!(layers.len(), qc.len(), "genome/layer-count mismatch");
    let mut per_layer: Vec<Option<CachedEval>> = Vec::with_capacity(layers.len());
    for (i, l) in layers.iter().enumerate() {
        match cache.evaluate(arch, l, &qc.layer(i), cfg) {
            Some(e) => per_layer.push(Some(e)),
            None => return None, // unmappable: the genome is dead already
        }
    }
    aggregate(arch, layers, qc, &per_layer)
}

// NOTE: the old `evaluate_network_parallel` (per-network scoped
// threads) is retired: parallel characterization now goes through
// `engine::driver::{evaluate_network, evaluate_genomes}`, which
// schedules one deduplicated job per layer×quant workload on the
// process-wide work-stealing pool and produces bit-identical results
// for any worker count.

/// Sum per-layer summaries into a [`NetworkEval`] (the paper's "total
/// energy is a sum over workloads"; same for latency). `None` if any
/// layer is missing. Shared by the serial path above and the engine
/// driver's per-genome assembly.
pub fn aggregate(
    arch: &Arch,
    layers: &[ConvLayer],
    qc: &QuantConfig,
    per_layer: &[Option<CachedEval>],
) -> Option<NetworkEval> {
    let mut out = NetworkEval {
        energy_pj: 0.0,
        memory_energy_pj: 0.0,
        mac_energy_pj: 0.0,
        cycles: 0.0,
        edp: 0.0,
        energy_breakdown_pj: [0.0; 3],
        weight_words: 0,
        model_size_bits: 0,
    };
    for r in per_layer {
        let r = (*r)?;
        out.energy_pj += r.energy_pj;
        out.memory_energy_pj += r.memory_energy_pj;
        out.mac_energy_pj += r.mac_energy_pj;
        out.cycles += r.cycles;
        out.edp += r.edp;
        for i in 0..3 {
            out.energy_breakdown_pj[i] += r.energy_breakdown_pj[i];
        }
    }
    out.weight_words = qc.weight_memory_words(layers, arch.word_bits);
    out.model_size_bits = qc.model_size_bits(layers);
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;
    use crate::workload::ConvLayer;

    fn small_net() -> Vec<ConvLayer> {
        vec![
            ConvLayer::conv("c1", 3, 8, 3, 16, 1),
            ConvLayer::dw("d1", 8, 3, 16, 1),
            ConvLayer::pw("p1", 8, 16, 16),
            ConvLayer::fc("fc", 16, 10),
        ]
    }

    fn cfg() -> MapperConfig {
        MapperConfig {
            valid_target: 60,
            max_draws: 60_000,
            seed: 2,
            shards: 1,
        }
    }

    #[test]
    fn totals_are_sums_of_layers() {
        let a = toy();
        let net = small_net();
        let qc = QuantConfig::uniform(net.len(), 8);
        let cache = MapperCache::new();
        let full = evaluate_network(&a, &net, &qc, &cache, &cfg()).unwrap();

        let mut e = 0.0;
        for (i, l) in net.iter().enumerate() {
            e += cache.evaluate(&a, l, &qc.layer(i), &cfg()).unwrap().energy_pj;
        }
        assert!((full.energy_pj - e).abs() < 1e-6);
        assert!(full.edp > 0.0);
        assert!(full.cycles > 0.0);
    }

    #[test]
    fn engine_matches_serial() {
        // the engine driver is the replacement for the retired
        // per-network thread fan-out; it must agree bit-for-bit
        let a = toy();
        let net = small_net();
        let qc = QuantConfig::uniform(net.len(), 4);
        let c1 = MapperCache::new();
        let c2 = MapperCache::new();
        let serial = evaluate_network(&a, &net, &qc, &c1, &cfg()).unwrap();
        let engine = crate::engine::Engine::new(4);
        let parallel =
            crate::engine::driver::evaluate_network(&engine, &a, &net, &qc, &c2, &cfg()).unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn quantization_reduces_network_energy() {
        let a = toy();
        let net = small_net();
        let cache = MapperCache::new();
        let e8 =
            evaluate_network(&a, &net, &QuantConfig::uniform(net.len(), 8), &cache, &cfg()).unwrap();
        let e2 =
            evaluate_network(&a, &net, &QuantConfig::uniform(net.len(), 2), &cache, &cfg()).unwrap();
        assert!(e2.memory_energy_pj < e8.memory_energy_pj);
        assert!(e2.weight_words < e8.weight_words);
    }

    #[test]
    fn unmappable_layer_short_circuits() {
        // zero-capacity weight spad: nothing maps
        let mut a = toy();
        a.name = "toy-nospad".into();
        a.levels[0].capacity = crate::arch::Capacity::PerTensor([0, 64, 64]);
        let net = small_net();
        let qc = QuantConfig::uniform(net.len(), 8);
        let cache = MapperCache::new();
        assert!(evaluate_network(&a, &net, &qc, &cache, &cfg()).is_none());
        // only the first layer was searched; the rest were never touched
        assert_eq!(cache.misses(), 1);
        // a repeat costs one negative-cache hit, zero new searches
        assert!(evaluate_network(&a, &net, &qc, &cache, &cfg()).is_none());
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn cache_shared_across_genomes() {
        let a = toy();
        let net = small_net();
        let cache = MapperCache::new();
        let mut qc1 = QuantConfig::uniform(net.len(), 8);
        let mut qc2 = QuantConfig::uniform(net.len(), 8);
        qc1.layers[0] = (4, 4);
        qc2.layers[0] = (4, 2); // only layer 0 differs between genomes
        evaluate_network(&a, &net, &qc1, &cache, &cfg()).unwrap();
        let misses_before = cache.misses();
        evaluate_network(&a, &net, &qc2, &cache, &cfg()).unwrap();
        // layer 1..3 are shared; layer0 differs (qw) and layer... note
        // qc2 layer0 qa/qw differ -> 1 new workload only
        assert_eq!(cache.misses(), misses_before + 1);
    }
}
