//! Stateful model-vs-SUT property tests for distributed shard
//! execution, in the style of proptest-stateful / polestar: generate a
//! random command sequence — submit a batch, drop the connection
//! mid-stream, deliver outcomes twice, reorder outcomes, kill a worker
//! and resume from the per-generation checkpoint — apply it to the
//! *SUT* (loopback `qmap` workers + the driver's ledger/scheduler) and
//! compare against the *model* (the plain single-threaded mapper /
//! search), asserting bit-identical results in every interleaving.
//!
//! The worker count is env-parameterized (`QMAP_TEST_WORKERS`, CI runs
//! {1, 2, 4}) and the property seeds honor `QMAP_PROP_SEED` /
//! `QMAP_PROP_CASES`, so a CI-reported failure replays exactly; on
//! failure the harness greedily shrinks the command sequence itself.

use qmap::accuracy::{ProxyAccuracy, ProxyParams};
use qmap::arch::parser::render_arch;
use qmap::arch::presets::toy;
use qmap::engine::remote::{spawn_local_worker, BatchLedger, RemoteClient};
use qmap::engine::{driver, Checkpointer, Engine, SchedPolicy, WorkerOptions};
use qmap::mapper::cache::MapperCache;
use qmap::mapper::{self, MapperConfig, MapperResult};
use qmap::mapping::mapspace::MapSpace;
use qmap::mapping::LayerContext;
use qmap::nsga::NsgaConfig;
use qmap::objective::ObjectiveSpec;
use qmap::quant::{LayerQuant, QuantConfig, QMAX, QMIN};
use qmap::util::prop::{check_shrink, Config};
use qmap::util::rng::Rng;
use qmap::workload::ConvLayer;
use std::time::Duration;

/// Objective spec for a generated case: `QMAP_OBJECTIVES` pins it (the
/// CI matrix rides a 3-objective cell); otherwise drawn per case —
/// serial/distributed/kill-and-resume bit-identity must hold for every
/// spec.
fn pick_spec(r: &mut Rng) -> ObjectiveSpec {
    if let Some(pinned) = ObjectiveSpec::from_env().expect("QMAP_OBJECTIVES") {
        return pinned;
    }
    let pool = [
        "edp,error",
        "error,energy,weight_words",
        "memory_energy,edp,error",
        "error,energy,edp,model_size",
    ];
    ObjectiveSpec::parse(pool[r.below(pool.len() as u64) as usize]).expect("pool spec")
}

fn small_net() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("c1", 3, 8, 3, 16, 1),
        ConvLayer::dw("d1", 8, 3, 16, 1),
        ConvLayer::pw("p1", 8, 16, 16),
        ConvLayer::fc("fc", 16, 10),
    ]
}

/// Loopback workers to stand up for the search-level tests
/// (`QMAP_TEST_WORKERS`, default 2 — the CI matrix runs {1, 2, 4}).
fn test_worker_count() -> usize {
    qmap::util::prop::env_test_workers().unwrap_or(2)
}

fn random_genome(r: &mut Rng, n: usize) -> QuantConfig {
    let mut g = QuantConfig::uniform(n, 8);
    for l in g.layers.iter_mut() {
        l.0 = QMIN + r.below((QMAX - QMIN + 1) as u64) as u8;
        l.1 = QMIN + r.below((QMAX - QMIN + 1) as u64) as u8;
    }
    g
}

// ------------------------------------------------- batch-level suite

/// Network fault injected into one command's worker.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Fault {
    None,
    /// Worker vanishes after this many outcome frames.
    DropAfter(usize),
    /// Every outcome frame arrives twice.
    DeliverTwice,
    /// Outcomes stream in reverse shard order.
    Reorder,
}

impl Fault {
    fn options(self) -> WorkerOptions {
        match self {
            Fault::None => WorkerOptions::default(),
            Fault::DropAfter(n) => WorkerOptions {
                drop_after: Some(n),
                ..WorkerOptions::default()
            },
            Fault::DeliverTwice => WorkerOptions {
                duplicate_outcomes: true,
                ..WorkerOptions::default()
            },
            Fault::Reorder => WorkerOptions {
                reverse_outcomes: true,
                ..WorkerOptions::default()
            },
        }
    }
}

/// One command: characterize `(layer, qa/qw)` through a worker with
/// the given fault.
#[derive(Debug, Clone)]
struct Cmd {
    layer: usize,
    qa: u8,
    qw: u8,
    fault: Fault,
}

#[derive(Debug, Clone)]
struct Script {
    shards: usize,
    commands: Vec<Cmd>,
}

fn random_script(r: &mut Rng) -> Script {
    let n = small_net().len();
    let commands = (0..r.range(2, 5))
        .map(|_| Cmd {
            layer: r.range(0, n - 1),
            qa: QMIN + r.below((QMAX - QMIN + 1) as u64) as u8,
            qw: QMIN + r.below((QMAX - QMIN + 1) as u64) as u8,
            fault: match r.below(4) {
                0 => Fault::None,
                1 => Fault::DropAfter(r.range(0, 3)),
                2 => Fault::DeliverTwice,
                _ => Fault::Reorder,
            },
        })
        .collect();
    Script {
        shards: r.range(1, 3),
        commands,
    }
}

/// Shrink toward the smallest still-failing script: fewer commands,
/// fewer shards, and faults softened to `None` (a fault that can be
/// removed without fixing the failure was not the cause).
fn shrink_script(s: &Script) -> Vec<Script> {
    let mut out = Vec::new();
    if s.commands.len() > 1 {
        let mut t = s.clone();
        t.commands.pop();
        out.push(t);
        let mut t = s.clone();
        t.commands.remove(0);
        out.push(t);
    }
    for i in 0..s.commands.len() {
        if s.commands[i].fault != Fault::None {
            let mut t = s.clone();
            t.commands[i].fault = Fault::None;
            out.push(t);
        }
    }
    if s.shards > 1 {
        let mut t = s.clone();
        t.shards -= 1;
        out.push(t);
    }
    out
}

fn run_script(script: &Script) -> Result<(), String> {
    let arch = toy();
    let layers = small_net();
    let rendered = render_arch(&arch);
    let cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 13,
        shards: script.shards,
    };
    for (ci, cmd) in script.commands.iter().enumerate() {
        let layer = &layers[cmd.layer];
        let q = LayerQuant {
            qa: cmd.qa,
            qw: cmd.qw,
            qo: 8,
        }
        .canonical(arch.word_bits, arch.bit_packing);

        // SUT: a fresh loopback worker with this command's fault, the
        // driver-side ledger, and local refill of anything undelivered
        let addr = spawn_local_worker(cmd.fault.options()).map_err(|e| format!("cmd {ci}: {e}"))?;
        let mut client = RemoteClient::connect(&addr, Duration::from_secs(20))
            .map_err(|e| format!("cmd {ci}: {e}"))?;
        let specs = mapper::shard_plan(&cfg, cfg.seed ^ mapper::workload_hash(layer, &q));
        let mut ledger = BatchLedger::new(specs);
        let net = client.run_batch(&rendered, layer, &q, &mut ledger);
        if net.is_err() && !matches!(cmd.fault, Fault::DropAfter(_)) {
            return Err(format!("cmd {ci}: unexpected transport failure: {net:?}"));
        }
        let space = MapSpace::of(&arch);
        let lctx = LayerContext::new(&arch, layer, &q);
        let got: MapperResult = ledger.finalize(|_, spec| mapper::run_shard(&space, &lctx, spec));

        // model: the plain serial mapper on the same workload
        let want = mapper::search(&arch, layer, &q, &cfg);
        let got_bits = got.best.as_ref().map(|e| e.edp().to_bits());
        let want_bits = want.best.as_ref().map(|e| e.edp().to_bits());
        if got_bits != want_bits
            || got.valid != want.valid
            || got.draws != want.draws
            || got.best_mapping != want.best_mapping
        {
            return Err(format!(
                "cmd {ci} ({cmd:?}): merged result diverged from the serial model\n  \
                 got  edp_bits={got_bits:?} valid={} draws={}\n  \
                 want edp_bits={want_bits:?} valid={} draws={}",
                got.valid, got.draws, want.valid, want.draws
            ));
        }
    }
    Ok(())
}

#[test]
fn faulty_distributed_batches_agree_with_the_serial_model() {
    check_shrink(
        &Config::from_env(0xD157, 8),
        random_script,
        shrink_script,
        |s| run_script(s),
    );
}

// -------------------------------------------- generation-level suite

#[test]
fn distributed_generation_is_bit_identical_even_with_flaky_workers() {
    let arch = toy();
    let layers = small_net();
    let cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 17,
        shards: 2,
    };
    let mut rng = Rng::new(0xBEEF);
    let genomes: Vec<QuantConfig> = (0..6)
        .map(|_| random_genome(&mut rng, layers.len()))
        .collect();
    let reference = {
        let engine = Engine::new(1);
        let cache = MapperCache::new();
        driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &cache, &cfg)
    };
    // a mixed fleet: healthy, vanishing, duplicating, reordering —
    // every fault class live in one generation
    let faults = [
        WorkerOptions::default(),
        WorkerOptions {
            drop_after: Some(1),
            ..WorkerOptions::default()
        },
        WorkerOptions {
            duplicate_outcomes: true,
            ..WorkerOptions::default()
        },
        WorkerOptions {
            reverse_outcomes: true,
            ..WorkerOptions::default()
        },
    ];
    let addrs: Vec<String> = (0..test_worker_count())
        .map(|i| spawn_local_worker(faults[i % faults.len()]).expect("loopback worker"))
        .collect();
    let engine = Engine::distributed(2, addrs);
    let cache = MapperCache::new();
    let got = driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &cache, &cfg);
    assert_eq!(reference.len(), got.len());
    for (gi, (a, b)) in reference.iter().zip(&got).enumerate() {
        match (a, b) {
            (Some(x), Some(y)) => {
                assert_eq!(x, y, "genome {gi}");
                assert_eq!(x.edp.to_bits(), y.edp.to_bits(), "genome {gi}");
            }
            (None, None) => {}
            _ => panic!("genome {gi}: mappability diverged ({a:?} vs {b:?})"),
        }
    }
}

/// Satellite property of the scheduling rework: *any* job-priority
/// permutation (FIFO, the cache-probe-aware priority order, or a
/// seeded shuffle) crossed with *any* pipeline depth — and a flaky
/// worker on top — must evaluate a generation bit-identically to the
/// single-threaded serial model. Runs in the CI stateful matrix, where
/// `QMAP_PIPELINE_DEPTH` also varies the engine-wide default.
#[test]
fn any_priority_permutation_and_pipeline_depth_is_bit_identical() {
    let arch = toy();
    let layers = small_net();
    let cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 37,
        shards: 2,
    };
    let mut rng = Rng::new(0xCAFE);
    let genomes: Vec<QuantConfig> = (0..5)
        .map(|_| random_genome(&mut rng, layers.len()))
        .collect();
    let reference = {
        let engine = Engine::new(1);
        let cache = MapperCache::new();
        driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &cache, &cfg)
    };

    #[derive(Debug, Clone)]
    struct Case {
        policy: SchedPolicy,
        depth: usize,
        drop_after: Option<usize>,
    }
    check_shrink(
        &Config::from_env(0xD159, 6),
        |r| Case {
            policy: match r.below(3) {
                0 => SchedPolicy::Fifo,
                1 => SchedPolicy::Priority,
                _ => SchedPolicy::Shuffled(r.next_u64()),
            },
            depth: r.range(1, 4),
            drop_after: if r.chance(0.5) {
                Some(r.range(0, 2))
            } else {
                None
            },
        },
        |c| {
            let mut cands = Vec::new();
            if c.depth > 1 {
                cands.push(Case {
                    depth: c.depth - 1,
                    ..c.clone()
                });
            }
            if c.policy != SchedPolicy::Fifo {
                cands.push(Case {
                    policy: SchedPolicy::Fifo,
                    ..c.clone()
                });
            }
            if c.drop_after.is_some() {
                cands.push(Case {
                    drop_after: None,
                    ..c.clone()
                });
            }
            cands
        },
        |c| {
            let opts = WorkerOptions {
                drop_after: c.drop_after,
                ..WorkerOptions::default()
            };
            let addrs: Vec<String> = (0..test_worker_count())
                .map(|_| spawn_local_worker(opts).map_err(|e| e.to_string()))
                .collect::<Result<_, _>>()?;
            let engine = Engine::distributed(2, addrs)
                .with_sched_policy(c.policy)
                .with_pipeline_depth(c.depth);
            let cache = MapperCache::new();
            let got = driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &cache, &cfg);
            // adaptive pipelining is placement-only: the effective
            // window may clamp below the configured depth, never above
            // it — and whatever it chose, the results above must not
            // move (the bit-identity check below)
            let st = engine.stats();
            if st.last_pipeline_depth > c.depth {
                return Err(format!(
                    "effective pipeline depth {} exceeds configured {} under {c:?}",
                    st.last_pipeline_depth, c.depth
                ));
            }
            if st.remote_jobs > 0 && st.last_pipeline_depth == 0 {
                return Err(format!(
                    "remote jobs completed but no effective depth was recorded under {c:?}"
                ));
            }
            for (gi, (a, b)) in reference.iter().zip(&got).enumerate() {
                match (a, b) {
                    (Some(x), Some(y)) if x == y && x.edp.to_bits() == y.edp.to_bits() => {}
                    (None, None) => {}
                    _ => {
                        return Err(format!(
                            "genome {gi} diverged under {c:?}: {a:?} vs {b:?}"
                        ))
                    }
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------ search-level suite

fn ckpt_path(tag: u64) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("qmap_dist_{tag}_{}.json", std::process::id()));
    p.to_string_lossy().into_owned()
}

fn front_key(cands: &[qmap::baselines::Candidate]) -> Vec<(Vec<u8>, u64)> {
    let mut k: Vec<(Vec<u8>, u64)> = cands
        .iter()
        .map(|c| (c.genome.encode(), c.hw.edp.to_bits()))
        .collect();
    k.sort();
    k
}

/// The acceptance property in-process: a distributed search's Pareto
/// front is bit-identical to the single-threaded serial run's.
#[test]
fn distributed_search_front_equals_the_serial_front() {
    let arch = toy();
    let layers = small_net();
    let map_cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 19,
        shards: 2,
    };
    let nsga_cfg = NsgaConfig {
        population: 8,
        offspring: 4,
        generations: 3,
        seed: 29,
        ..NsgaConfig::default()
    };
    // the env-pinned spec when the matrix rides one, else the default —
    // both engines carry it so the spec hash rides the batch identity
    let spec = ObjectiveSpec::from_env()
        .expect("QMAP_OBJECTIVES")
        .unwrap_or_default();
    let serial = {
        let engine = Engine::new(1).with_objectives(spec);
        let cache = MapperCache::new();
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
        qmap::baselines::search_with_objectives(
            &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec, |_, _| {},
        )
    };
    let addrs: Vec<String> = (0..test_worker_count())
        .map(|_| spawn_local_worker(WorkerOptions::default()).expect("loopback worker"))
        .collect();
    let distributed = {
        let engine = Engine::distributed(2, addrs).with_objectives(spec);
        let cache = MapperCache::new();
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
        qmap::baselines::search_with_objectives(
            &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec, |_, _| {},
        )
    };
    assert_eq!(front_key(&serial), front_key(&distributed));
    // accuracy objectives too, bit for bit
    assert_eq!(serial.len(), distributed.len());
    for (a, b) in serial.iter().zip(&distributed) {
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    }
}

/// Kill-and-resume: a distributed search over a *flaky* worker is
/// stopped after a random number of generations (simulating a driver
/// crash mid-search — the mid-generation work is lost, the
/// per-generation checkpoint is not), then resumed from the checkpoint
/// with a fresh engine, fresh workers, and fresh caches. The final
/// front must be bit-identical to an uninterrupted serial run, for
/// every interruption point, worker count, and fault mix.
#[test]
fn kill_and_resume_from_checkpoint_is_bit_identical() {
    let arch = toy();
    let layers = small_net();
    let map_cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 23,
        shards: 2,
    };
    let nsga_cfg = NsgaConfig {
        population: 8,
        offspring: 4,
        generations: 4,
        seed: 31,
        ..NsgaConfig::default()
    };
    // serial reference fronts, cached per spec across cases and
    // shrink steps (the generator pool has at most four entries)
    let mut references: std::collections::HashMap<u64, Vec<(Vec<u8>, u64)>> =
        std::collections::HashMap::new();
    check_shrink(
        &Config::from_env(0xD158, 4),
        |r| (r.range(0, 3), r.range(0, 2), r.next_u64(), pick_spec(r)),
        |&(stop_after, drop_after, tag, spec)| {
            let mut cands = Vec::new();
            if stop_after > 0 {
                cands.push((stop_after - 1, drop_after, tag, spec));
            }
            if drop_after > 0 {
                cands.push((stop_after, drop_after - 1, tag, spec));
            }
            if spec != ObjectiveSpec::default() {
                cands.push((stop_after, drop_after, tag, ObjectiveSpec::default()));
            }
            cands
        },
        |&(stop_after, drop_after, tag, spec)| {
            let reference = match references.get(&spec.hash()) {
                Some(r) => r.clone(),
                None => {
                    let engine = Engine::new(1);
                    let cache = MapperCache::new();
                    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
                    let path = ckpt_path(tag ^ 1);
                    let ckpt = Checkpointer::new(path.as_str());
                    let cands = driver::search_resumable(
                        &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg,
                        &spec, &ckpt, false,
                        |_, _| {},
                    )
                    .map_err(|e| format!("reference: {e}"))?;
                    let _ = std::fs::remove_file(&path);
                    let r = front_key(&cands);
                    references.insert(spec.hash(), r.clone());
                    r
                }
            };
            let path = ckpt_path(tag);
            let ckpt = Checkpointer::new(path.as_str());
            let flaky = WorkerOptions {
                drop_after: Some(drop_after),
                ..WorkerOptions::default()
            };
            // phase 1: distributed search over a worker that keeps
            // dying mid-stream, killed after `stop_after` generations
            {
                let addrs: Vec<String> = (0..test_worker_count())
                    .map(|_| spawn_local_worker(flaky).expect("loopback worker"))
                    .collect();
                let engine = Engine::distributed(2, addrs).with_objectives(spec);
                let cache = MapperCache::new();
                let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
                let truncated = NsgaConfig {
                    generations: stop_after,
                    ..nsga_cfg
                };
                driver::search_resumable(
                    &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &truncated, &spec,
                    &ckpt, false,
                    |_, _| {},
                )
                .map_err(|e| format!("phase 1: {e}"))?;
            }
            // phase 2: everything is gone but the checkpoint file;
            // resume on a fresh (still flaky) distributed engine
            let resumed = {
                let addrs: Vec<String> = (0..test_worker_count())
                    .map(|_| spawn_local_worker(flaky).expect("loopback worker"))
                    .collect();
                let engine = Engine::distributed(2, addrs).with_objectives(spec);
                let cache = MapperCache::new();
                let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
                driver::search_resumable(
                    &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec,
                    &ckpt, true,
                    |_, _| {},
                )
                .map_err(|e| format!("phase 2: {e}"))?
            };
            let _ = std::fs::remove_file(&path);
            let got = front_key(&resumed);
            if got != reference {
                return Err(format!(
                    "resumed distributed front differs \
                     (stop_after={stop_after}, drop_after={drop_after}, spec={spec}):\n  \
                     got {got:?}\n  want {reference:?}"
                ));
            }
            Ok(())
        },
    );
}

/// The acceptance criterion's negative half, end to end: a search
/// checkpointed under one objective spec refuses to resume under
/// another, naming both specs — never silently mixing fronts.
#[test]
fn resuming_under_a_different_objective_spec_is_a_hard_error() {
    let arch = toy();
    let layers = small_net();
    let map_cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 41,
        shards: 1,
    };
    let nsga_cfg = NsgaConfig {
        population: 6,
        offspring: 3,
        generations: 2,
        seed: 43,
        ..NsgaConfig::default()
    };
    let spec_a = ObjectiveSpec::parse("error,energy,weight_words").unwrap();
    let spec_b = ObjectiveSpec::parse("edp,error").unwrap();
    let path = ckpt_path(0xA11D);
    let ckpt = Checkpointer::new(path.as_str());
    {
        let engine = Engine::new(1).with_objectives(spec_a);
        let cache = MapperCache::new();
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
        driver::search_resumable(
            &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec_a, &ckpt,
            false,
            |_, _| {},
        )
        .expect("spec-A search");
    }
    let engine = Engine::new(1).with_objectives(spec_b);
    let cache = MapperCache::new();
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
    let err = driver::search_resumable(
        &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec_b, &ckpt, true,
        |_, _| {},
    )
    .expect_err("mismatched objective spec must refuse to resume");
    assert!(err.contains("error,energy,weight_words"), "{err}");
    assert!(err.contains("edp,error"), "{err}");
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------- flight-recorder suite

/// Flight-recorder dump files created since `before`, each parsed:
/// every line must be valid JSON (the dump is JSONL by contract) and
/// the first line the `flightrec_dump` header. Dumps from concurrent
/// tests ride along — callers filter by the addresses they own.
fn new_dumps(
    before: &[std::path::PathBuf],
) -> Vec<(std::path::PathBuf, Vec<qmap::util::json::Json>)> {
    qmap::obs::ring::recent_dumps()
        .into_iter()
        .filter(|p| !before.contains(p))
        .filter_map(|p| {
            // a concurrent test may have already deleted its dump
            let src = std::fs::read_to_string(&p).ok()?;
            let events: Vec<qmap::util::json::Json> = src
                .lines()
                .enumerate()
                .map(|(i, l)| {
                    qmap::util::json::parse(l)
                        .unwrap_or_else(|e| panic!("{}: dump line {}: {e}", p.display(), i + 1))
                })
                .collect();
            assert_eq!(
                events.first().and_then(|h| h.get("event").as_str()),
                Some("flightrec_dump"),
                "{}: dump must lead with the flightrec_dump header",
                p.display()
            );
            Some((p, events))
        })
        .collect()
}

/// Forensics: a worker lost mid-generation must leave a flight-recorder
/// dump on disk — valid JSONL carrying the `worker_lost` event and the
/// failing batch's `batch_sent` span for that address — while the
/// generation's results stay bit-identical to the serial model.
#[test]
fn lost_worker_leaves_a_forensic_dump_with_the_failing_batch() {
    let arch = toy();
    let layers = small_net();
    let cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 47,
        shards: 2,
    };
    let mut rng = Rng::new(0xF11E);
    let genomes: Vec<QuantConfig> = (0..4)
        .map(|_| random_genome(&mut rng, layers.len()))
        .collect();
    let reference = {
        let engine = Engine::new(1);
        let cache = MapperCache::new();
        driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &cache, &cfg)
    };
    let before = qmap::obs::ring::recent_dumps();
    let flaky = WorkerOptions {
        drop_after: Some(0),
        ..WorkerOptions::default()
    };
    let addrs: Vec<String> = (0..2)
        .map(|_| spawn_local_worker(flaky).expect("loopback worker"))
        .collect();
    let engine = Engine::distributed(2, addrs.clone());
    let cache = MapperCache::new();
    let got = driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &cache, &cfg);
    assert_eq!(reference, got, "a lost worker must never change results");
    assert!(
        engine.stats().lost_workers > 0,
        "the injected fault must actually fire"
    );

    let dumps = new_dumps(&before);
    assert!(
        !dumps.is_empty(),
        "a lost worker must dump the flight recorder"
    );
    let mine = |ev: &qmap::util::json::Json, kind: &str| {
        ev.get("event").as_str() == Some(kind)
            && ev
                .get("addr")
                .as_str()
                .map_or(false, |a| addrs.iter().any(|x| x.as_str() == a))
    };
    let ours = dumps.iter().any(|(_, events)| {
        events.iter().any(|e| mine(e, "worker_lost"))
            && events.iter().any(|e| mine(e, "batch_sent"))
    });
    assert!(
        ours,
        "some dump must contain this run's worker_lost event and the \
         failing batch's batch_sent span"
    );
}

/// Forensics: a server that completes the handshake and then streams
/// bytes that are not protocol frames must produce a `proto_error`
/// flight-recorder dump naming the offending address.
#[test]
fn protocol_garbage_leaves_a_proto_error_dump() {
    use std::io::Write as _;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr").to_string();
    let server = std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            // a valid hello so the handshake succeeds...
            let _ = qmap::engine::proto::write_msg(&mut s, &qmap::engine::proto::hello());
            // ...then raw garbage where a frame should be
            let _ = s.write_all(&[0xFF; 64]);
            let _ = s.flush();
            std::thread::sleep(Duration::from_millis(100));
        }
    });
    let before = qmap::obs::ring::recent_dumps();
    let mut client = RemoteClient::connect(&addr, Duration::from_secs(10)).expect("handshake");
    let got = client.recv_event();
    assert!(got.is_err(), "garbage must be rejected, got an event");
    let ours = new_dumps(&before).iter().any(|(_, events)| {
        events.iter().any(|e| {
            e.get("event").as_str() == Some("proto_error")
                && e.get("addr").as_str() == Some(addr.as_str())
        })
    });
    assert!(ours, "a proto_error dump naming {addr} must exist");
    let _ = server.join();
}

// ----------------------------------------------- model-grammar walks

/// Random walks over the window FSM's event *grammar*, driving the
/// model and the real [`PipelineWindow`] from the same event strings.
///
/// `tests/model_conformance.rs` exhausts this state machine up to its
/// documented small scope; this property extends coverage *past* the
/// exhaustive frontier (walks of up to 40 events over a wider job
/// pool) the way the rest of this suite samples: seeded by
/// `QMAP_PROP_SEED`, shrunk by event deletion, the failing input being
/// a list of grammar lines that pastes directly into a
/// `model_cex_window.script` replay.
#[test]
fn random_window_walks_conform_beyond_the_exhaustive_frontier() {
    use qmap::engine::remote::PipelineWindow;
    use qmap::model::window::{WindowEvent, WindowModel};
    use qmap::model::Fsm;

    let m = WindowModel {
        jobs: 4,
        shards: 2,
        depth: 3,
    };
    let cfg = Config::from_env(0xC0FFEE, 64);
    check_shrink(
        &cfg,
        |r| {
            // walk enabled events so deep schedules are reachable; the
            // trace is kept as grammar strings so a failure replays
            let mut s = m.initial();
            let mut lines: Vec<String> = Vec::new();
            for _ in 0..40 {
                let enabled = m.events(&s);
                if enabled.is_empty() {
                    break;
                }
                let e = enabled[r.below(enabled.len() as u64) as usize].clone();
                lines.push(m.show_event(&e));
                s = m.step(&s, &e);
            }
            lines
        },
        |lines| {
            // drop one event; disabled leftovers self-loop on both
            // sides, so every sublist is still a meaningful schedule
            (0..lines.len())
                .map(|i| {
                    let mut c = lines.clone();
                    c.remove(i);
                    c
                })
                .collect()
        },
        |lines| {
            let mut s = m.initial();
            let mut win = PipelineWindow::new(m.depth);
            let mut ids: Vec<Option<u64>> = vec![None; m.jobs];
            let mut next_id = 0u64;
            let mut lost = false;
            let mut swept = false;
            for (i, line) in lines.iter().enumerate() {
                let e = m
                    .parse_event(line)
                    .ok_or_else(|| format!("unparseable event '{line}'"))?;
                s = m.step(&s, &e);
                m.invariant(&s)
                    .map_err(|err| format!("step {i} ({line}): model invariant: {err}"))?;
                // mirror the pump's control flow on the real window
                let live = !lost && !swept;
                match &e {
                    WindowEvent::Send => {
                        if live && win.len() < m.depth {
                            if let Some(j) = ids.iter().position(|id| id.is_none()) {
                                next_id += 1;
                                win.on_sent(next_id, j);
                                ids[j] = Some(next_id);
                            }
                        }
                    }
                    WindowEvent::SendFail => {
                        if live && win.len() < m.depth {
                            if let Some(j) = ids.iter().position(|id| id.is_none()) {
                                win.on_send_failed(j);
                                ids[j] = Some(0);
                                lost = true;
                                win.on_loss();
                            }
                        }
                    }
                    WindowEvent::Outcome { job, .. } => {
                        if live && *job < ids.len() {
                            if let Some(id) = ids[*job] {
                                if let Some(wi) = win.on_outcome(id) {
                                    if wi != *job {
                                        return Err(format!(
                                            "step {i}: outcome for batch {id} routed to \
                                             job {wi}, not {job}"
                                        ));
                                    }
                                }
                            }
                        }
                    }
                    WindowEvent::Done { job } => {
                        if live && *job < ids.len() {
                            if let Some(id) = ids[*job] {
                                if let Some((wi, _, _)) = win.on_done(id) {
                                    if wi != *job {
                                        return Err(format!(
                                            "step {i}: done for batch {id} routed to \
                                             job {wi}, not {job}"
                                        ));
                                    }
                                }
                            }
                        }
                    }
                    WindowEvent::StaleOutcome { .. } | WindowEvent::StaleDone { .. } => {}
                    WindowEvent::Lose => {
                        if live {
                            lost = true;
                            win.on_loss();
                        }
                    }
                    WindowEvent::Sweep => {
                        if !swept && (lost || win.is_empty()) {
                            swept = true;
                        }
                    }
                }
                // retraction on the window-owned projections
                let firsts = win.tracked_first_outcomes();
                let got: Vec<(usize, bool)> = win
                    .inflight_entries()
                    .iter()
                    .map(|&(id, w)| (w, firsts.contains(&id)))
                    .collect();
                if got != s.inflight {
                    return Err(format!(
                        "step {i} ({line}): window {got:?} != model {:?}",
                        s.inflight
                    ));
                }
                let stamps = win.tracked_sends().len() + firsts.len();
                if stamps != s.timings {
                    return Err(format!(
                        "step {i} ({line}): {stamps} timing stamps live, \
                         the window accounts for {}",
                        s.timings
                    ));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------ worker restart, persisted cache

/// Spawn a real `qmap worker` OS process with `--cache-dir` and a
/// metrics endpoint, both on ephemeral ports, and parse the announced
/// addresses from its stderr. A drain thread keeps reading afterwards
/// so the worker never blocks on a full pipe.
fn spawn_worker_process(cache_dir: &std::path::Path) -> (std::process::Child, String, String) {
    use std::io::BufRead as _;
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_qmap"))
        .args(["worker", "--listen", "127.0.0.1:0", "--metrics", "127.0.0.1:0"])
        .arg("--cache-dir")
        .arg(cache_dir)
        .env_remove("QMAP_CACHE_DIR")
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn qmap worker");
    let mut reader = std::io::BufReader::new(child.stderr.take().expect("piped stderr"));
    let (mut listen, mut metrics) = (None, None);
    let mut line = String::new();
    while listen.is_none() || metrics.is_none() {
        line.clear();
        if reader.read_line(&mut line).expect("worker stderr") == 0 {
            panic!("worker exited before announcing its addresses");
        }
        if let Some(rest) = line.trim().strip_prefix("qmap worker metrics on http://") {
            metrics = Some(rest.trim_end_matches("/metrics").to_string());
        } else if let Some(rest) = line.trim().strip_prefix("qmap worker listening on ") {
            listen = Some(rest.split_whitespace().next().expect("addr").to_string());
        }
    }
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    (child, listen.expect("listen addr"), metrics.expect("metrics addr"))
}

/// One Prometheus counter from a worker's metrics endpoint.
fn scrape_counter(addr: &str, name: &str) -> u64 {
    use std::io::{Read as _, Write as _};
    let mut s = std::net::TcpStream::connect(addr).expect("connect metrics");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut body = String::new();
    s.read_to_string(&mut body).expect("read metrics");
    let row = format!("qmap_{name}_total ");
    body.lines()
        .find_map(|l| l.strip_prefix(&row))
        .unwrap_or_else(|| panic!("no {name} row in metrics:\n{body}"))
        .trim()
        .parse()
        .expect("counter value")
}

/// A worker killed and replaced by a fresh process on the same
/// `--cache-dir` serves bit-identical fronts from the persisted store:
/// run a distributed search, SIGKILL the worker, restart it cold on the
/// same directory, rerun — the fronts must match bit for bit and the
/// replacement's `store_hits` counter must prove the warm start came
/// from disk, not recomputation luck.
#[test]
fn worker_restart_with_persisted_cache_is_warm_and_bit_identical() {
    let arch = toy();
    let layers = small_net();
    let map_cfg = MapperConfig { valid_target: 24, max_draws: 24_000, seed: 37, shards: 2 };
    let nsga_cfg =
        NsgaConfig { population: 8, offspring: 4, generations: 2, seed: 41, ..NsgaConfig::default() };
    let spec = ObjectiveSpec::from_env().expect("QMAP_OBJECTIVES").unwrap_or_default();
    let mut store_dir = std::env::temp_dir();
    store_dir.push(format!("qmap_worker_restart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    std::fs::create_dir_all(&store_dir).unwrap();

    let run = |addr: String| {
        let engine = Engine::distributed(2, vec![addr]).with_objectives(spec);
        let cache = MapperCache::new();
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
        qmap::baselines::search_with_objectives(
            &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec, |_, _| {},
        )
    };

    let (mut w1, addr1, metrics1) = spawn_worker_process(&store_dir);
    let first = run(addr1);
    let appends = scrape_counter(&metrics1, "store_appends");
    assert!(appends > 0, "first worker persisted nothing");
    w1.kill().expect("kill worker");
    let _ = w1.wait();

    let (mut w2, addr2, metrics2) = spawn_worker_process(&store_dir);
    let second = run(addr2);
    let hits = scrape_counter(&metrics2, "store_hits");
    assert!(hits > 0, "restarted worker never hit the persisted store");
    w2.kill().expect("kill worker");
    let _ = w2.wait();

    assert_eq!(
        front_key(&first),
        front_key(&second),
        "store-served outcomes must be bit-identical to computed ones"
    );
    let _ = std::fs::remove_dir_all(&store_dir);
}
