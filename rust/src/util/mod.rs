//! Zero-dependency utilities: PRNG, JSON, stats, CLI parsing, and a mini
//! property-testing harness. These stand in for `rand`, `serde_json`,
//! `clap`, and `proptest`, none of which are available in the offline
//! build environment (see DESIGN.md §7).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Format a large count with thousands separators (report tables).
pub fn with_commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, c) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*c as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn commas() {
        assert_eq!(with_commas(0), "0");
        assert_eq!(with_commas(999), "999");
        assert_eq!(with_commas(1000), "1,000");
        assert_eq!(with_commas(1234567), "1,234,567");
    }
}
