//! Real QAT accuracy evaluation: the paper's training engine, executed
//! from Rust through the AOT artifacts.
//!
//! `QatAccuracy` implements [`crate::accuracy::AccuracyModel`] by
//! fine-tuning the pre-trained checkpoint for a step budget (the
//! "e epochs" analogue at our scale) with the genome's bit-widths, then
//! measuring top-1 on held-out synthetic batches. A per-genome
//! memoization cache avoids re-training duplicates within one search.

use super::Runtime;
use crate::accuracy::AccuracyModel;
use crate::data::SyntheticDataset;
use crate::quant::QuantConfig;
use rustc_hash::FxHashMap;

/// Budget knobs for in-the-loop QAT (scaled-down analogue of the paper's
/// e = 5/10/20 epochs).
#[derive(Debug, Clone, Copy)]
pub struct QatBudget {
    /// Fine-tuning steps per candidate.
    pub finetune_steps: u64,
    /// Held-out eval batches.
    pub eval_batches: u64,
    pub lr: f32,
}

impl Default for QatBudget {
    fn default() -> Self {
        QatBudget {
            finetune_steps: 60,
            eval_batches: 8,
            lr: 0.02,
        }
    }
}

/// Accuracy model backed by real QAT through PJRT.
pub struct QatAccuracy<'rt> {
    pub rt: &'rt Runtime,
    pub data: SyntheticDataset,
    /// Checkpoint to fine-tune from (e.g. the QAT-8 pre-trained params).
    pub base_params: Vec<f32>,
    pub budget: QatBudget,
    memo: FxHashMap<Vec<u8>, f64>,
    /// Batch counter offset separating train and eval streams.
    eval_stream: u64,
}

impl<'rt> QatAccuracy<'rt> {
    pub fn new(rt: &'rt Runtime, data: SyntheticDataset, base_params: Vec<f32>, budget: QatBudget) -> Self {
        QatAccuracy {
            rt,
            data,
            base_params,
            budget,
            memo: FxHashMap::default(),
            eval_stream: 1_000_000,
        }
    }

    fn genome_vectors(&self, qc: &QuantConfig) -> (Vec<f32>, Vec<f32>) {
        let qa: Vec<f32> = qc.layers.iter().map(|&(a, _)| a as f32).collect();
        let qw: Vec<f32> = qc.layers.iter().map(|&(_, w)| w as f32).collect();
        (qa, qw)
    }

    /// Fine-tune + evaluate one genome; returns top-1 accuracy.
    pub fn evaluate(&mut self, qc: &QuantConfig) -> Result<f64, String> {
        let key = qc.encode();
        if let Some(&hit) = self.memo.get(&key) {
            return Ok(hit);
        }
        let (qa, qw) = self.genome_vectors(qc);
        let b = self.rt.meta.batch;
        // device-resident fine-tune: params never round-trip to the host
        let mut sess = self.rt.train_session(&self.base_params)?;
        for step in 0..self.budget.finetune_steps {
            let batch = self.data.batch(b, step);
            sess.step(&batch.x, &batch.y, &qa, &qw, self.budget.lr)?;
        }
        let mut correct = 0.0f32;
        let mut total = 0usize;
        for i in 0..self.budget.eval_batches {
            let batch = self.data.batch(b, self.eval_stream + i);
            let (c, _loss) = sess.eval(&batch.x, &batch.y, &qa, &qw)?;
            correct += c;
            total += b;
        }
        let acc = correct as f64 / total as f64;
        self.memo.insert(key, acc);
        Ok(acc)
    }

    /// Pre-train the base checkpoint at a uniform bit-width (the QAT-8
    /// initial model of the paper). Returns the final training loss
    /// curve (for EXPERIMENTS.md / the E2E driver log).
    pub fn pretrain(
        rt: &Runtime,
        data: &SyntheticDataset,
        bits: u8,
        steps: u64,
        lr: f32,
        mut on_step: impl FnMut(u64, f32),
    ) -> Result<Vec<f32>, String> {
        let l = rt.meta.num_layers;
        let qa = vec![bits as f32; l];
        let qw = vec![bits as f32; l];
        let mut sess = rt.train_session(&rt.init_params)?;
        for step in 0..steps {
            let batch = data.batch(rt.meta.batch, step);
            sess.step(&batch.x, &batch.y, &qa, &qw, lr)?;
            // loss comes from an extra forward pass (the train artifact
            // returns only new_params; see runtime/mod.rs §Perf note)
            let (_, loss) = sess.eval(&batch.x, &batch.y, &qa, &qw)?;
            on_step(step, loss);
        }
        sess.params_to_host()
    }
}

impl AccuracyModel for QatAccuracy<'_> {
    fn accuracy(&mut self, qc: &QuantConfig) -> f64 {
        self.evaluate(qc).unwrap_or(0.0)
    }
    fn name(&self) -> &'static str {
        "qat"
    }
}
