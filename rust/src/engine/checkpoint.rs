//! Generation-boundary checkpointing for long searches.
//!
//! A checkpoint is one JSON document holding everything a search needs
//! to continue after an interruption and still produce a bit-identical
//! final front:
//!
//! * the NSGA-II [`SearchState`] — completed-generation count, the
//!   parent population (genomes plus objective vectors, the latter
//!   stored as hex-encoded IEEE-754 bits so `INFINITY` objectives of
//!   unmappable genomes and every last mantissa bit round-trip), and
//!   the breeding RNG's raw state;
//! * the full [`MapperCache`] dump (the ROADMAP's "batch cache
//!   persistence"): positive entries with their summaries, negative
//!   entries with their draw-budget tags, so a resumed search neither
//!   re-pays finished searches nor trusts failures recorded under a
//!   smaller budget.
//!
//! Writes go through a `.tmp` + rename, so an interruption mid-save
//! leaves the previous checkpoint intact.

use crate::arch::Arch;
use crate::mapper::cache::MapperCache;
use crate::mapper::MapperConfig;
use crate::nsga::{Individual, NsgaConfig, SearchState};
use crate::quant::QuantConfig;
use crate::util::json::{parse, Json};
use crate::util::rng::Rng;

/// Bumped to 2.0 with PR 3: `mapper::effective_shards` now also caps
/// the shard count by `max_draws`, so a degenerate config (more shards
/// than draws) produces a different `shard_plan` — and therefore
/// different cached results — than the same config under version 1.
/// Resuming a v1 checkpoint would silently mix the two plans; refusing
/// it keeps the resume-bit-identical guarantee honest.
const VERSION: f64 = 2.0;

/// Identity of the search a checkpoint belongs to. A checkpoint written
/// under one configuration and resumed under another (different
/// accelerator, network size, mapper budgets/seed, or NSGA-II breeding
/// parameters) would silently corrupt the search — stale objectives
/// mixed with fresh ones, a diverged RNG stream — so `load` rejects any
/// mismatch instead. `generations` is deliberately absent: extending a
/// finished search with more generations is a legitimate resume.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchIdent {
    pub arch: String,
    pub num_layers: usize,
    pub mapper_seed: u64,
    pub valid_target: u64,
    pub max_draws: u64,
    pub shards: usize,
    pub population: usize,
    pub offspring: usize,
    pub nsga_seed: u64,
    pub p_mut_bits: u64,
    pub p_mut_acc_bits: u64,
}

impl SearchIdent {
    pub fn new(
        arch: &Arch,
        num_layers: usize,
        map_cfg: &MapperConfig,
        nsga_cfg: &NsgaConfig,
    ) -> SearchIdent {
        SearchIdent {
            arch: arch.name.clone(),
            num_layers,
            mapper_seed: map_cfg.seed,
            valid_target: map_cfg.valid_target,
            max_draws: map_cfg.max_draws,
            shards: map_cfg.shards,
            population: nsga_cfg.population,
            offspring: nsga_cfg.offspring,
            nsga_seed: nsga_cfg.seed,
            p_mut_bits: nsga_cfg.p_mut.to_bits(),
            p_mut_acc_bits: nsga_cfg.p_mut_acc.to_bits(),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::Str(self.arch.clone())),
            ("num_layers", Json::Num(self.num_layers as f64)),
            ("mapper_seed", Json::hex_u64(self.mapper_seed)),
            ("valid_target", Json::hex_u64(self.valid_target)),
            ("max_draws", Json::hex_u64(self.max_draws)),
            ("shards", Json::Num(self.shards as f64)),
            ("population", Json::Num(self.population as f64)),
            ("offspring", Json::Num(self.offspring as f64)),
            ("nsga_seed", Json::hex_u64(self.nsga_seed)),
            ("p_mut", Json::hex_u64(self.p_mut_bits)),
            ("p_mut_acc", Json::hex_u64(self.p_mut_acc_bits)),
        ])
    }

    fn from_json(v: &Json) -> Result<SearchIdent, String> {
        let hex = |key: &str| -> Result<u64, String> {
            v.get(key).as_hex_u64(&format!("checkpoint ident {key}"))
        };
        Ok(SearchIdent {
            arch: v
                .get("arch")
                .as_str()
                .ok_or("checkpoint ident: missing arch")?
                .to_string(),
            num_layers: v
                .get("num_layers")
                .as_f64()
                .ok_or("checkpoint ident: missing num_layers")? as usize,
            mapper_seed: hex("mapper_seed")?,
            valid_target: hex("valid_target")?,
            max_draws: hex("max_draws")?,
            shards: v.get("shards").as_f64().ok_or("checkpoint ident: missing shards")? as usize,
            population: v
                .get("population")
                .as_f64()
                .ok_or("checkpoint ident: missing population")? as usize,
            offspring: v
                .get("offspring")
                .as_f64()
                .ok_or("checkpoint ident: missing offspring")? as usize,
            nsga_seed: hex("nsga_seed")?,
            p_mut_bits: hex("p_mut")?,
            p_mut_acc_bits: hex("p_mut_acc")?,
        })
    }
}

/// Saves/loads search checkpoints at a fixed path. Numeric encoding is
/// shared with the distributed wire protocol (`engine::proto`):
/// `Json::hex_u64` / `Json::hex_bits` from `util::json`.
pub struct Checkpointer {
    path: String,
}

impl Checkpointer {
    pub fn new(path: impl Into<String>) -> Checkpointer {
        Checkpointer { path: path.into() }
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    pub fn exists(&self) -> bool {
        std::path::Path::new(&self.path).exists()
    }

    /// Snapshot the search state and the mapper cache under the given
    /// search identity. Atomic at the filesystem level (temp file +
    /// rename).
    pub fn save(
        &self,
        st: &SearchState,
        cache: &MapperCache,
        ident: &SearchIdent,
    ) -> Result<(), String> {
        let pop: Vec<Json> = st
            .pop
            .iter()
            .map(|ind| {
                Json::obj(vec![
                    (
                        "genome",
                        Json::Arr(
                            ind.genome
                                .encode()
                                .iter()
                                .map(|&b| Json::Num(b as f64))
                                .collect(),
                        ),
                    ),
                    ("last_qo", Json::Num(ind.genome.last_qo as f64)),
                    (
                        "objectives",
                        Json::Arr(ind.objectives.iter().map(|&x| Json::hex_bits(x)).collect()),
                    ),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("version", Json::Num(VERSION)),
            ("ident", ident.to_json()),
            ("generation", Json::Num(st.generation as f64)),
            ("rng", Json::hex_u64(st.rng.state())),
            ("population", Json::Arr(pop)),
            ("cache", cache.to_json_value()),
        ]);
        let tmp = format!("{}.tmp", self.path);
        std::fs::write(&tmp, doc.to_string()).map_err(|e| format!("{tmp}: {e}"))?;
        std::fs::rename(&tmp, &self.path).map_err(|e| format!("{}: {e}", self.path))
    }

    /// Restore a checkpoint: loads the cache entries into `cache` and
    /// returns the search state. Rejects version, search-identity, or
    /// genome-length mismatches with a clear error instead of resuming
    /// garbage.
    pub fn load(&self, ident: &SearchIdent, cache: &MapperCache) -> Result<SearchState, String> {
        let num_layers = ident.num_layers;
        let src =
            std::fs::read_to_string(&self.path).map_err(|e| format!("{}: {e}", self.path))?;
        let v = parse(&src).map_err(|e| format!("{}: {e}", self.path))?;
        if v.get("version").as_f64() != Some(VERSION) {
            return Err(format!(
                "{}: unsupported checkpoint version (want {VERSION})",
                self.path
            ));
        }
        let stored = SearchIdent::from_json(v.get("ident"))?;
        if stored != *ident {
            return Err(format!(
                "{}: checkpoint belongs to a different search configuration — \
                 saved {stored:?}, current {ident:?}; resuming would corrupt the \
                 search (delete the file or restore the original flags)",
                self.path
            ));
        }
        let generation = v
            .get("generation")
            .as_f64()
            .ok_or("checkpoint: missing generation")? as usize;
        let rng = Rng::new(v.get("rng").as_hex_u64("checkpoint rng")?);
        let mut pop: Vec<Individual> = Vec::new();
        for ind in v
            .get("population")
            .as_arr()
            .ok_or("checkpoint: missing population")?
        {
            let bytes: Vec<u8> = ind
                .get("genome")
                .as_arr()
                .ok_or("checkpoint: bad genome")?
                .iter()
                .map(|g| {
                    g.as_f64()
                        .map(|x| x as u8)
                        .ok_or_else(|| "checkpoint: bad gene".to_string())
                })
                .collect::<Result<_, _>>()?;
            let last_qo = ind.get("last_qo").as_f64().unwrap_or(8.0) as u8;
            let genome = QuantConfig::decode(&bytes, last_qo)?;
            if genome.len() != num_layers {
                return Err(format!(
                    "checkpoint genome has {} layers, the network has {num_layers}",
                    genome.len()
                ));
            }
            let mut objectives = Vec::new();
            for o in ind
                .get("objectives")
                .as_arr()
                .ok_or("checkpoint: bad objectives")?
            {
                objectives.push(o.as_f64_bits("objective")?);
            }
            pop.push(Individual { genome, objectives });
        }
        if pop.is_empty() {
            return Err("checkpoint: empty population".into());
        }
        cache
            .load_json(&v.get("cache").to_string())
            .map_err(|e| format!("checkpoint cache: {e}"))?;
        Ok(SearchState {
            generation,
            pop,
            rng,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;
    use crate::mapper::MapperConfig;
    use crate::quant::LayerQuant;
    use crate::workload::ConvLayer;

    fn tmp_path(tag: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!("qmap_ckpt_{tag}_{}.json", std::process::id()));
        p.to_string_lossy().into_owned()
    }

    fn ident() -> SearchIdent {
        SearchIdent::new(&toy(), 4, &MapperConfig::default(), &NsgaConfig::default())
    }

    fn state_with_objectives(objs: Vec<Vec<f64>>) -> SearchState {
        SearchState {
            generation: 3,
            pop: objs
                .into_iter()
                .enumerate()
                .map(|(i, objectives)| Individual {
                    genome: QuantConfig::uniform(4, 2 + (i as u8 % 7)),
                    objectives,
                })
                .collect(),
            rng: Rng::new(0xFEED_F00D),
        }
    }

    #[test]
    fn state_roundtrips_bit_exactly_including_infinities() {
        let path = tmp_path("bits");
        let ckpt = Checkpointer::new(path.as_str());
        let mut st = state_with_objectives(vec![
            vec![1.5e-9, 0.25],
            vec![f64::INFINITY, 0.1],
            vec![3.141592653589793, 2.2250738585072014e-308],
        ]);
        // advance the RNG so a non-trivial state is saved
        for _ in 0..17 {
            st.rng.next_u64();
        }
        let cache = MapperCache::new();
        ckpt.save(&st, &cache, &ident()).unwrap();
        let cache2 = MapperCache::new();
        let back = ckpt.load(&ident(), &cache2).unwrap();
        assert_eq!(back.generation, st.generation);
        assert_eq!(back.rng.state(), st.rng.state());
        assert_eq!(back.pop.len(), st.pop.len());
        for (a, b) in st.pop.iter().zip(&back.pop) {
            assert_eq!(a.genome, b.genome);
            let ab: Vec<u64> = a.objectives.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u64> = b.objectives.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cache_rides_along_with_negative_entries() {
        // an unmappable workload becomes a negative entry; the
        // checkpoint must round-trip it with its draw-budget tag
        let path = tmp_path("negcache");
        let ckpt = Checkpointer::new(path.as_str());
        let mut a = toy();
        a.name = "toy-nospad".into();
        a.levels[0].capacity = crate::arch::Capacity::PerTensor([0, 64, 64]);
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let tiny = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 500,
            seed: 5,
            shards: 1,
        };
        let cache = MapperCache::new();
        assert!(cache.evaluate(&a, &l, &LayerQuant::uniform(8), &tiny).is_none());
        assert_eq!(cache.misses(), 1);

        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        let restored = MapperCache::new();
        ckpt.load(&ident(), &restored).unwrap();
        // negative hit without re-searching at the recorded budget
        assert!(restored
            .evaluate(&a, &l, &LayerQuant::uniform(8), &tiny)
            .is_none());
        assert_eq!(restored.misses(), 0, "negative entry lost its budget tag");
        assert_eq!(restored.hits(), 1);
        // a larger budget must still re-search instead of trusting it
        let bigger = MapperConfig {
            max_draws: 5_000,
            ..tiny
        };
        let _ = restored.evaluate(&a, &l, &LayerQuant::uniform(8), &bigger);
        assert_eq!(restored.misses(), 1, "bigger budget served from stale negative");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_mismatched_network() {
        let path = tmp_path("mismatch");
        let ckpt = Checkpointer::new(path.as_str());
        let cache = MapperCache::new();
        ckpt.save(&state_with_objectives(vec![vec![1.0, 2.0]]), &cache, &ident())
            .unwrap();
        // saved genomes have 4 layers; a 7-layer network must refuse
        let mut other = ident();
        other.num_layers = 7;
        assert!(ckpt.load(&other, &cache).is_err());
        // ... and so must any other drifted search parameter
        let mut other = ident();
        other.arch = "simba".into();
        assert!(ckpt.load(&other, &cache).is_err());
        let mut other = ident();
        other.mapper_seed ^= 1;
        assert!(ckpt.load(&other, &cache).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_missing_or_corrupt_files() {
        let ckpt = Checkpointer::new(tmp_path("absent"));
        assert!(!ckpt.exists());
        assert!(ckpt.load(&ident(), &MapperCache::new()).is_err());

        let path = tmp_path("corrupt");
        std::fs::write(&path, "not json at all").unwrap();
        let ckpt = Checkpointer::new(path.as_str());
        assert!(ckpt.load(&ident(), &MapperCache::new()).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
