//! Mini property-testing harness (proptest is unavailable offline),
//! in the spirit of proptest-stateful's model-vs-SUT loops.
//!
//! [`check_shrink`] draws random inputs, asserts the property, and on
//! failure performs a *real greedy shrink*: the failing input itself
//! is handed to a caller-supplied shrinker that proposes strictly
//! smaller variants, and the first variant that still fails becomes
//! the new failing input — repeated to a local minimum. (The previous
//! harness "shrank" by re-generating fresh candidates, which almost
//! never preserved the failure.)
//!
//! Every failure report names the seed and case, and every entry point
//! honors two environment overrides so a reported failure can be
//! replayed exactly:
//!
//! * `QMAP_PROP_SEED`  — root seed (decimal or `0x…` hex);
//! * `QMAP_PROP_CASES` — number of cases to run.
//!
//! A CI matrix sets `QMAP_PROP_SEED` to fan the stateful suites across
//! seeds without recompiling; a developer sets both to replay the
//! exact case a CI job reported.

use super::rng::Rng;

/// Cap on property re-evaluations spent shrinking one failure, so a
/// pathological shrinker cannot hang a test run.
const SHRINK_BUDGET: usize = 2_000;

/// Seed and case count for one property run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Config {
    pub seed: u64,
    pub cases: usize,
}

impl Config {
    pub fn new(seed: u64, cases: usize) -> Config {
        Config { seed, cases }
    }

    /// The given defaults, overridden by `QMAP_PROP_SEED` /
    /// `QMAP_PROP_CASES` when set (for replaying reported failures and
    /// for CI seed matrices). Unparseable values fall back to the
    /// defaults rather than silently running something unintended —
    /// with a note on stderr.
    pub fn from_env(default_seed: u64, default_cases: usize) -> Config {
        resolve(
            std::env::var("QMAP_PROP_SEED").ok(),
            std::env::var("QMAP_PROP_CASES").ok(),
            default_seed,
            default_cases,
        )
    }
}

/// Worker-count pin for the stateful suites: `QMAP_TEST_WORKERS` (the
/// CI matrix runs {1, 2, 4}); `None` when unset, unparseable, or zero
/// — callers fall back to their own default or a random draw. Lives
/// here beside the `QMAP_PROP_*` handling so every suite parses the
/// pinning convention identically.
pub fn env_test_workers() -> Option<usize> {
    std::env::var("QMAP_TEST_WORKERS")
        .ok()
        .and_then(|v| parse_u64(&v))
        .map(|w| w as usize)
        .filter(|&w| w >= 1)
}

fn parse_u64(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Pure core of [`Config::from_env`] (testable without touching the
/// process environment, which is racy under parallel tests).
fn resolve(
    seed_env: Option<String>,
    cases_env: Option<String>,
    default_seed: u64,
    default_cases: usize,
) -> Config {
    let seed = match &seed_env {
        None => default_seed,
        Some(s) => parse_u64(s).unwrap_or_else(|| {
            eprintln!("prop: ignoring unparseable QMAP_PROP_SEED='{s}'");
            default_seed
        }),
    };
    let cases = match &cases_env {
        None => default_cases,
        Some(s) => match parse_u64(s) {
            Some(n) => n as usize,
            None => {
                eprintln!("prop: ignoring unparseable QMAP_PROP_CASES='{s}'");
                default_cases
            }
        },
    };
    Config { seed, cases }
}

/// Run a property over randomly generated inputs, greedily shrinking
/// any failure to a local minimum before reporting it.
///
/// * `gen` maps an RNG to an input value.
/// * `shrink` proposes *smaller* variants of a failing input (return
///   an empty vec for unshrinkable inputs). It must make progress
///   toward a fixpoint — e.g. halve counts, drop elements — or the
///   shrink loop stops at [`SHRINK_BUDGET`] evaluations.
/// * `prop` returns `Err(msg)` to signal a violated property.
pub fn check_shrink<T: std::fmt::Debug>(
    cfg: &Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut shrink: impl FnMut(&T) -> Vec<T>,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut r = root.split(case as u64);
        let input = gen(&mut r);
        let msg = match prop(&input) {
            Ok(()) => continue,
            Err(m) => m,
        };
        // greedy descent: keep replacing the failing input with its
        // first still-failing shrink candidate
        let mut cur = input;
        let mut cur_msg = msg;
        let mut steps = 0usize;
        let mut budget = SHRINK_BUDGET;
        'descend: loop {
            for cand in shrink(&cur) {
                if budget == 0 {
                    break 'descend;
                }
                budget -= 1;
                if let Err(m) = prop(&cand) {
                    cur = cand;
                    cur_msg = m;
                    steps += 1;
                    continue 'descend;
                }
            }
            break; // no candidate still fails: local minimum
        }
        panic!(
            "property failed (seed={seed}, case={case}, shrunk {steps} step(s))\n  \
             minimal input: {cur:?}\n  error: {cur_msg}\n  \
             replay: QMAP_PROP_SEED={seed} QMAP_PROP_CASES={cases} cargo test <this test>",
            seed = cfg.seed,
            cases = case + 1,
        );
    }
}

/// Run a property over randomly generated inputs (no shrinking).
/// Honors the `QMAP_PROP_*` overrides; `seed`/`cases` are the
/// defaults. Kept for properties whose inputs have no useful smaller
/// form — prefer [`check_shrink`] elsewhere.
pub fn check<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check_shrink(&Config::from_env(seed, cases), gen, |_| Vec::new(), prop);
}

/// Like `check` but the property also receives an RNG (for randomized
/// assertions inside the property body). Honors the `QMAP_PROP_*`
/// overrides.
pub fn check_with_rng<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T, &mut Rng) -> Result<(), String>,
) {
    let cfg = Config::from_env(seed, cases);
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut r = root.split(case as u64);
        let input = gen(&mut r);
        let mut r2 = root.split(0x5EED ^ case as u64);
        if let Err(msg) = prop(&input, &mut r2) {
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {input:?}\n  \
                 error: {msg}\n  replay: QMAP_PROP_SEED={seed} QMAP_PROP_CASES={cases}",
                seed = cfg.seed,
                cases = case + 1,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn passing_property() {
        check(1, 200, |r| r.range(0, 100), |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_input() {
        check(2, 50, |r| r.range(0, 10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err(format!("{x} >= 5"))
            }
        });
    }

    #[test]
    fn greedy_shrink_reaches_the_minimal_failing_input() {
        // property: fails for every x >= 17; shrinker proposes x/2 and
        // x-1. Greedy descent from any failing draw must bottom out at
        // exactly 17 — the smallest input that still fails.
        let err = catch_unwind(AssertUnwindSafe(|| {
            check_shrink(
                &Config::new(3, 100),
                |r| r.range(0, 1000),
                |&x| {
                    let mut cands = Vec::new();
                    if x > 0 {
                        cands.push(x / 2);
                        cands.push(x - 1);
                    }
                    cands
                },
                |&x| {
                    if x < 17 {
                        Ok(())
                    } else {
                        Err(format!("{x} >= 17"))
                    }
                },
            );
        }))
        .expect_err("the property must fail");
        let msg = err
            .downcast_ref::<String>()
            .expect("panic payload is the formatted report");
        assert!(msg.contains("minimal input: 17"), "not shrunk to 17: {msg}");
        assert!(msg.contains("replay: QMAP_PROP_SEED="), "{msg}");
    }

    #[test]
    fn shrink_of_the_input_itself_not_a_regenerated_candidate() {
        // the shrinker sees exactly the failing value (a marker makes
        // any regenerated value detectable)
        let seen = std::cell::RefCell::new(Vec::new());
        let _ = catch_unwind(AssertUnwindSafe(|| {
            check_shrink(
                &Config::new(9, 5),
                |_| 1_000_000usize, // generator only produces this value
                |&x| {
                    seen.borrow_mut().push(x);
                    if x > 1 {
                        vec![x - 1]
                    } else {
                        Vec::new()
                    }
                },
                |&x| {
                    if x >= 999_998 {
                        Err("too big".into())
                    } else {
                        Ok(())
                    }
                },
            );
        }))
        .expect_err("must fail");
        let seen = seen.into_inner();
        // first shrink call sees the generated failing input verbatim,
        // later calls see its descendants
        assert_eq!(seen.first(), Some(&1_000_000));
        assert!(seen.windows(2).all(|w| w[1] == w[0] - 1), "{seen:?}");
    }

    #[test]
    fn shrink_budget_bounds_pathological_shrinkers() {
        // a shrinker that always reproduces the same failing value
        // must terminate via the budget, not loop forever
        let err = catch_unwind(AssertUnwindSafe(|| {
            check_shrink(
                &Config::new(4, 1),
                |_| 5usize,
                |&x| vec![x], // no progress, always still failing
                |_| Err("always".into()),
            );
        }))
        .expect_err("must fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("property failed"), "{msg}");
    }

    #[test]
    fn env_resolution_parses_decimal_and_hex() {
        let c = resolve(Some("123".into()), Some("7".into()), 1, 10);
        assert_eq!(c, Config::new(123, 7));
        let c = resolve(Some("0xE6E1".into()), None, 1, 10);
        assert_eq!(c, Config::new(0xE6E1, 10));
        // unparseable values fall back to the defaults
        let c = resolve(Some("banana".into()), Some("many".into()), 42, 3);
        assert_eq!(c, Config::new(42, 3));
        // absent: pure defaults
        assert_eq!(resolve(None, None, 8, 2), Config::new(8, 2));
    }
}
