//! The engine's typed job layer: the one way NSGA-II generations and
//! bench harnesses fan out mapper work.
//!
//! The unit of scheduling is an [`EvalJob`]: one layer×quant-config
//! mapper search, routed through the shared lock-striped
//! [`MapperCache`]. A generation's genomes are flattened into the set
//! of *unique* jobs (NSGA-II genomes share most of their layers, so
//! this deduplication is also what makes the cache effective), the set
//! runs on the work-stealing pool, and per-genome results are assembled
//! afterwards from the job table.
//!
//! Two invariants make every result bit-identical to single-threaded
//! execution (`Engine::new(1)`), regardless of worker count or steal
//! order:
//!
//! * results are keyed by job id (slot index), never by completion
//!   order, and genome assembly walks layers in index order;
//! * a job's shard decomposition is the mapper's deterministic
//!   [`shard_plan`](crate::mapper::shard_plan) — a pure function of the
//!   `MapperConfig` and workload. Idle workers only change *where* the
//!   shards execute, never what they compute, and
//!   [`merge_shards`](crate::mapper::merge_shards) reduces them in
//!   shard-index order.

use super::checkpoint::{Checkpointer, SearchIdent};
use super::{remote, Backend, Engine, SchedPolicy};
use crate::accuracy::AccuracyModel;
use crate::arch::Arch;
use crate::baselines::Candidate;
use crate::eval::{aggregate, NetworkEval};
use crate::mapper::cache::{CachedEval, MapperCache, WorkloadKey};
use crate::mapper::{self, MapperConfig};
use crate::mapping::mapspace::MapSpace;
use crate::mapping::LayerContext;
use crate::nsga::{self, Individual, NsgaConfig};
use crate::objective::{ObjectiveSpec, ObjectiveVec};
use crate::obs::{self, metrics};
use crate::quant::{LayerQuant, QuantConfig};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::ConvLayer;
use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// One schedulable unit: characterize `layer` under `quant` (canonical
/// form) on the current architecture. `layer_index` ties the job back
/// to the network tables; jobs with identical workload keys are
/// deduplicated before dispatch.
///
/// `key` is the workload's precomputed cache identity: the scheduler,
/// the cache probes, and the shard-seed derivation all reuse it, so a
/// job is hashed once when it is built, not once per consumer.
#[derive(Debug, Clone, Copy)]
pub struct EvalJob {
    pub layer_index: usize,
    pub quant: LayerQuant,
    pub key: WorkloadKey,
}

/// Run one workload search through the cache, executing cache misses on
/// the engine: the mapper's shard plan runs as stealable pool subtasks
/// when idle workers exist, inline otherwise — same shards, same merge,
/// same bits either way.
pub fn eval_layer(
    engine: &Engine,
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    cache: &MapperCache,
    cfg: &MapperConfig,
) -> Option<CachedEval> {
    eval_layer_hinted(engine, arch, layer, q, cache, cfg, false)
}

/// [`eval_layer`] with the generation-tail hint: `force_split` marks a
/// job running while the job queue is (nearly) dry, whose shards should
/// fan out even before any worker has parked. Placement only — the
/// shard plan and merge are identical either way.
fn eval_layer_hinted(
    engine: &Engine,
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    cache: &MapperCache,
    cfg: &MapperConfig,
    force_split: bool,
) -> Option<CachedEval> {
    let q = q.canonical(arch.word_bits, arch.bit_packing);
    let wk = WorkloadKey::of(arch, layer, &q);
    eval_layer_keyed(engine, arch, layer, &q, wk, cache, cfg, force_split)
}

/// The keyed core of [`eval_layer`]: `q` must be canonical and `wk` its
/// [`WorkloadKey`]. Probe, search-on-miss, and insert all reuse the
/// precomputed key — the workload is never re-hashed.
///
/// When the cache has a persistent backing store attached
/// (`--cache-dir`), `probe_key` consults it on an in-memory miss and
/// `insert_search_key` writes the fresh result behind — so a cold
/// process warm-starts here without any change to this flow. The
/// store is strictly additive: a hit serves the same bits a
/// re-search would produce, and the checkpoint journal (not the
/// store) remains the bit-identity source of truth for resume.
#[allow(clippy::too_many_arguments)]
fn eval_layer_keyed(
    engine: &Engine,
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    wk: WorkloadKey,
    cache: &MapperCache,
    cfg: &MapperConfig,
    force_split: bool,
) -> Option<CachedEval> {
    if let Some(res) = cache.probe_key(wk, cfg) {
        return res;
    }
    let r = search_on_engine_keyed(engine, arch, layer, q, wk.whash, cfg, force_split);
    cache.insert_search_key(wk, cfg, &r)
}

/// The engine-side twin of [`mapper::search`]: identical decomposition
/// ([`mapper::shard_plan`]) and identical reduction
/// ([`mapper::merge_shards`]), but the shards execute as pool subtasks
/// *only when idle workers exist* — otherwise the owning worker runs
/// them sequentially. Both paths are bit-identical to each other and to
/// `mapper::search` for the same `MapperConfig`.
pub fn search_on_engine(
    engine: &Engine,
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    cfg: &MapperConfig,
) -> mapper::MapperResult {
    search_on_engine_hinted(engine, arch, layer, q, cfg, false)
}

/// The split decision: shards fan out when idle workers exist (the
/// steady-state heuristic), or when `force_split` says the generation
/// is in its tail — fewer unfinished jobs than workers, so the largest
/// still-running jobs should hand their shards to the workers that are
/// about to go idle rather than keep them serial.
fn search_on_engine_hinted(
    engine: &Engine,
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    cfg: &MapperConfig,
    force_split: bool,
) -> mapper::MapperResult {
    let q = q.canonical(arch.word_bits, arch.bit_packing);
    let whash = mapper::workload_hash(layer, &q);
    search_on_engine_keyed(engine, arch, layer, &q, whash, cfg, force_split)
}

/// The keyed core of [`search_on_engine`]: `q` must be canonical and
/// `whash` its workload hash (the shard-seed basis), so callers holding
/// a [`WorkloadKey`] skip the re-canonicalization and re-hash.
fn search_on_engine_keyed(
    engine: &Engine,
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    whash: u64,
    cfg: &MapperConfig,
    force_split: bool,
) -> mapper::MapperResult {
    let space = MapSpace::of(arch);
    let lctx = LayerContext::new(arch, layer, q);
    let specs = mapper::shard_plan(cfg, cfg.seed ^ whash);
    let split = specs.len() > 1
        && (engine.pool().idle_workers() > 0 || (force_split && engine.workers() > 1));
    // the cascade stage counts ride on the side of each outcome —
    // `ShardOutcome` is wire format and stays untouched, and the
    // counters can't feed back into the search (see `obs`)
    let run = |s: &mapper::ShardSpec| {
        let (outcome, stats) = mapper::run_shard_with_stats(&space, &lctx, s);
        note_shard(&layer.name, whash, &stats);
        outcome
    };
    let outcomes = if split {
        engine.note_split();
        engine.map(&specs, run)
    } else {
        specs.iter().map(run).collect()
    };
    let result = mapper::merge_shards(outcomes);
    // fold the search's validity rate into the guide (local twin of the
    // fold in `remote::eval_jobs`; the two paths are disjoint per job,
    // so no outcome is counted twice). Commutative saturating sums —
    // schedule order cannot change the folded state.
    engine.guide_note(whash, result.valid, result.draws);
    result
}

/// Fold one finished shard's cascade stage counts into the process
/// counters and the event stream. Pure observation, after the fact: the
/// outcome the caller merges is already computed and untouched.
pub(crate) fn note_shard(layer: &str, whash: u64, stats: &mapper::ShardStats) {
    use std::sync::atomic::Ordering::Relaxed;
    let c = metrics::counters();
    c.shards.fetch_add(1, Relaxed);
    c.shard_draws.fetch_add(stats.draws(), Relaxed);
    c.shard_spatial_rejects.fetch_add(stats.spatial_rejects, Relaxed);
    c.shard_tile_rejects.fetch_add(stats.tile_rejects, Relaxed);
    c.shard_valid.fetch_add(stats.valid, Relaxed);
    c.bound_pruned.fetch_add(stats.bound_pruned, Relaxed);
    obs::event(
        "shard",
        vec![
            ("layer", Json::Str(layer.to_string())),
            ("whash", Json::hex_u64(whash)),
            ("draws", Json::Num(stats.draws() as f64)),
            ("valid", Json::Num(stats.valid as f64)),
            ("spatial_rejects", Json::Num(stats.spatial_rejects as f64)),
            ("tile_rejects", Json::Num(stats.tile_rejects as f64)),
            ("bound_pruned", Json::Num(stats.bound_pruned as f64)),
        ],
    );
}

/// Inject a generation's jobs in scheduler order (see [`SchedPolicy`]).
///
/// `Priority` sorts by descending *effective draw budget* — the
/// cache-probe-aware cost estimate from
/// [`MapperCache::effective_draws`]: stale negatives (guaranteed to
/// burn the whole budget) first, fresh misses next, cached jobs (cost
/// 0) last. Within a cost class the guide's estimated
/// draws-to-target ([`Engine::guide_expected`]) ranks the historically
/// hardest workloads first — longest-job-first placement that shrinks
/// the generation tail; a cold guide estimates every job at the full
/// draw budget, so the ranking degrades to larger layers (more MACs
/// per draw) ahead. Ties break on first-encounter order, so the order
/// is deterministic. Pure placement: every policy produces
/// bit-identical results.
pub(crate) fn order_jobs(
    engine: &Engine,
    layers: &[ConvLayer],
    jobs: &[EvalJob],
    cache: &MapperCache,
    cfg: &MapperConfig,
) -> Vec<EvalJob> {
    let mut idx: Vec<usize> = (0..jobs.len()).collect();
    match engine.sched_policy() {
        SchedPolicy::Fifo => {}
        SchedPolicy::Priority => {
            let guided = !engine.guide_is_empty();
            let key: Vec<(u64, u64, u64)> = jobs
                .iter()
                .map(|j| {
                    let layer = &layers[j.layer_index];
                    (
                        cache.effective_draws_key(j.key, cfg),
                        engine.guide_expected(j.key.whash, cfg),
                        layer.macs(),
                    )
                })
                .collect();
            idx.sort_by(|&a, &b| key[b].cmp(&key[a]).then(a.cmp(&b)));
            if guided {
                // did guidance actually move anything? Rank the same
                // keys without the guide element (no second cache
                // probe) and compare — one counter bump per reordered
                // generation.
                let mut base: Vec<usize> = (0..jobs.len()).collect();
                base.sort_by(|&a, &b| {
                    (key[b].0, key[b].2).cmp(&(key[a].0, key[a].2)).then(a.cmp(&b))
                });
                if base != idx {
                    metrics::counters().guided_reorderings.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        SchedPolicy::Shuffled(seed) => {
            let mut r = Rng::new(seed ^ jobs.len() as u64);
            r.shuffle(&mut idx);
        }
    }
    idx.into_iter().map(|i| jobs[i]).collect()
}

/// Evaluate a population of genomes on the engine: deduplicate the
/// layer×quant workloads across all genomes into unique [`EvalJob`]s,
/// run them on the pool, then assemble each genome's [`NetworkEval`]
/// from the job table (`None` if any of its layers is unmappable).
///
/// Replaces both `coordinator::parallel_map` over
/// `eval::evaluate_network` and the retired `evaluate_network_parallel`
/// as the fan-out path, with one scheduler and no duplicated searches
/// within a generation.
pub fn evaluate_genomes(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    genomes: &[QuantConfig],
    cache: &MapperCache,
    cfg: &MapperConfig,
) -> Vec<Option<NetworkEval>> {
    if genomes.is_empty() {
        return Vec::new();
    }
    // the single place per-generation stats reset (EngineStats reset
    // contract); the deltas below feed the gen_eval trace event
    engine.begin_generation();
    let counters = metrics::counters();
    let (hits0, misses0) = (cache.hits(), cache.misses());
    let stats0 = engine.stats();
    // One WorkloadKey per (genome, layer), computed up front: the
    // alive-check, the dedup map, the scheduler, the cache probes, and
    // the final assembly all reuse these handles, so a generation's
    // scheduling pass hashes each workload once, not three-plus times.
    let keys: Vec<Vec<WorkloadKey>> = genomes
        .iter()
        .map(|qc| {
            assert_eq!(qc.len(), layers.len(), "genome/layer-count mismatch");
            (0..layers.len())
                .map(|i| WorkloadKey::of(arch, &layers[i], &qc.layer(i)))
                .collect()
        })
        .collect();
    // A genome with a negative-cached layer is already dead: don't
    // schedule its workloads (a live genome sharing one still will).
    // This restores the serial evaluator's short-circuit economics for
    // repeat offenders; the assembly below still evaluates any
    // uncached layers of a dead genome serially up to the dead layer,
    // exactly as the serial path would.
    let alive: Vec<bool> = keys
        .iter()
        .map(|ks| {
            ks.iter().all(|&wk| {
                let probe = cache.probe_key(wk, cfg);
                match &probe {
                    Some(Some(_)) => &counters.cache_probe_hits,
                    Some(None) => &counters.cache_probe_negative,
                    None => &counters.cache_probe_misses,
                }
                .fetch_add(1, Ordering::Relaxed);
                probe != Some(None)
            })
        })
        .collect();
    // unique jobs across the live population, in first-encounter order;
    // `refs` counts how many (genome, layer) pairs each unique job
    // serves — the dedup leverage the job trace events report
    let mut index: FxHashMap<WorkloadKey, usize> = FxHashMap::default();
    let mut jobs: Vec<EvalJob> = Vec::new();
    let mut refs: Vec<u64> = Vec::new();
    for (gi, qc) in genomes.iter().enumerate() {
        if !alive[gi] {
            continue;
        }
        for i in 0..layers.len() {
            let wk = keys[gi][i];
            match index.get(&wk) {
                Some(&j) => refs[j] += 1,
                None => {
                    index.insert(wk, jobs.len());
                    jobs.push(EvalJob {
                        layer_index: i,
                        quant: qc.layer(i).canonical(arch.word_bits, arch.bit_packing),
                        key: wk,
                    });
                    refs.push(1);
                }
            }
        }
    }
    let pairs: u64 = refs.iter().sum();
    engine.note_jobs(jobs.len() as u64);
    counters.jobs.fetch_add(jobs.len() as u64, Ordering::Relaxed);
    match engine.backend() {
        // local: the unique jobs fan out over the work-stealing pool in
        // scheduler order (priority by default — largest effective draw
        // budgets first, cached jobs last), with the tail instrumented:
        // once fewer unfinished jobs remain than workers, each job runs
        // with the force-split hint so its shards feed the workers the
        // dry queue is about to idle.
        Backend::Local => {
            let ordered = order_jobs(engine, layers, &jobs, cache, cfg);
            let remaining = AtomicUsize::new(ordered.len());
            let t0 = Instant::now();
            let spans: Vec<(f64, f64)> = engine.map(&ordered, |job| {
                let claimed = t0.elapsed().as_secs_f64();
                let tail_mode = remaining.load(Ordering::Relaxed) <= engine.workers();
                let _ = eval_layer_keyed(
                    engine,
                    arch,
                    &layers[job.layer_index],
                    &job.quant,
                    job.key,
                    cache,
                    cfg,
                    tail_mode,
                );
                remaining.fetch_sub(1, Ordering::Relaxed);
                let done = t0.elapsed().as_secs_f64();
                obs::event(
                    "job",
                    vec![
                        ("layer", Json::Str(layers[job.layer_index].name.clone())),
                        ("whash", Json::hex_u64(job.key.whash)),
                        ("refs", Json::Num(refs[index[&job.key]] as f64)),
                        ("us", Json::Num((done - claimed) * 1e6)),
                    ],
                );
                (claimed, done)
            });
            // generation tail = last finish minus last claim: once the
            // final job has been claimed the queue is dry, and whatever
            // runs past that point is the tail the scheduler tries to
            // shrink (exposed as EngineStats::last_tail_ms)
            let last_claim = spans.iter().map(|s| s.0).fold(0.0f64, f64::max);
            let last_finish = spans.iter().map(|s| s.1).fold(0.0f64, f64::max);
            engine.note_tail(last_finish - last_claim);
        }
        // distributed: remote workers and the local pool race the same
        // job queue; every job lands in the cache either way, with the
        // same bits (remote::eval_jobs merges the same shard plan)
        Backend::Distributed { workers } => {
            let addrs = workers.resolve();
            remote::eval_jobs(engine, arch, layers, &jobs, cache, cfg, &addrs);
        }
    }
    // one generation-summary event: cache/steal/split deltas over the
    // job phase (assembly below probes warm entries only and would
    // drown the signal, so it is excluded on purpose)
    let stats1 = engine.stats();
    let (d_steals, d_splits) = (stats1.steals - stats0.steals, stats1.splits - stats0.splits);
    counters.steals.fetch_add(d_steals, Ordering::Relaxed);
    counters.splits.fetch_add(d_splits, Ordering::Relaxed);
    obs::event(
        "gen_eval",
        vec![
            ("pairs", Json::Num(pairs as f64)),
            ("unique_jobs", Json::Num(jobs.len() as f64)),
            ("cache_hits", Json::Num((cache.hits() - hits0) as f64)),
            ("cache_misses", Json::Num((cache.misses() - misses0) as f64)),
            ("steals", Json::Num(d_steals as f64)),
            ("splits", Json::Num(d_splits as f64)),
            ("tail_ms", Json::Num(stats1.last_tail_ms)),
        ],
    );
    // assemble per genome through the cache (every probe is a hit: the
    // job phase above inserted a positive or negative entry for each
    // unique workload), walking layers in index order and
    // short-circuiting dead genomes exactly like the serial evaluator
    genomes
        .iter()
        .zip(&keys)
        .map(|(qc, ks)| {
            let mut per: Vec<Option<CachedEval>> = Vec::with_capacity(layers.len());
            for (i, l) in layers.iter().enumerate() {
                match cache.evaluate_key(ks[i], arch, l, &qc.layer(i), cfg) {
                    Some(e) => per.push(Some(e)),
                    None => return None, // unmappable layer: genome is dead
                }
            }
            aggregate(arch, layers, qc, &per)
        })
        .collect()
}

/// Engine-scheduled single-network characterization (the one-genome
/// case of [`evaluate_genomes`]). Against a fresh cache it does not
/// short-circuit on the first unmappable layer the way the serial
/// [`eval::evaluate_network`](crate::eval::evaluate_network) does —
/// the unique jobs run concurrently — but once the failure is
/// negative-cached, later calls skip the genome's workloads entirely,
/// and the returned value is identical either way.
pub fn evaluate_network(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    qc: &QuantConfig,
    cache: &MapperCache,
    cfg: &MapperConfig,
) -> Option<NetworkEval> {
    evaluate_genomes(engine, arch, layers, std::slice::from_ref(qc), cache, cfg)
        .pop()
        .expect("one genome in, one result out")
}

/// The paper's hardware-aware NSGA-II search over an arbitrary
/// [`ObjectiveSpec`] (default: EDP on the target accelerator, CNN
/// error), scheduled on the engine and checkpointed to `ckpt` at every
/// generation boundary — population, breeding-RNG state, and the
/// mapper cache (negative entries keep their draw-budget tags). With
/// `resume` and an existing checkpoint file, the search continues
/// where it stopped and produces a final front bit-identical to an
/// uninterrupted run; the spec is part of the checkpoint identity, so
/// resuming under a *different* spec is a hard error, never silent
/// garbage.
#[allow(clippy::too_many_arguments)]
pub fn search_resumable(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    acc: &mut dyn AccuracyModel,
    cache: &MapperCache,
    map_cfg: &MapperConfig,
    nsga_cfg: &NsgaConfig,
    objectives: &ObjectiveSpec,
    ckpt: &Checkpointer,
    resume: bool,
    mut on_generation: impl FnMut(usize, &[Individual]),
) -> Result<Vec<Candidate>, String> {
    // the engine's wire identity always carries the running search's
    // spec (see baselines::search_with_objectives for why)
    engine.set_objectives(*objectives);
    let mut evaluate = |genomes: &[QuantConfig]| -> Vec<ObjectiveVec> {
        let evals = evaluate_genomes(engine, arch, layers, genomes, cache, map_cfg);
        genomes
            .iter()
            .zip(&evals)
            .map(|(g, e)| objectives.evaluate(e.as_ref(), acc.accuracy(g)))
            .collect()
    };

    let ident = SearchIdent::new(arch, layers.len(), objectives, map_cfg, nsga_cfg);
    let mut st = if resume && ckpt.exists() {
        // the guide resumes with the search: the journaled validity
        // rates land on the engine before the first generation, so a
        // resumed driver schedules from the same history an
        // uninterrupted one would have (placement only — the fronts
        // are bit-identical either way)
        let (st, guide) = ckpt.load_with_guide(&ident, cache)?;
        engine.set_guide(guide);
        st
    } else {
        let st = nsga::init_state(layers.len(), nsga_cfg, &mut evaluate);
        on_generation(0, &st.pop);
        ckpt.save_with_guide(&st, cache, &ident, &engine.guide_snapshot())?;
        st
    };
    while st.generation < nsga_cfg.generations {
        nsga::step(&mut st, nsga_cfg, &mut evaluate);
        on_generation(st.generation, &st.pop);
        ckpt.save_with_guide(&st, cache, &ident, &engine.guide_snapshot())?;
        // one trace line per durable generation: whether the journal
        // appender survived the save (unarmed means the next save
        // rewrites whole — a torn resume or a failed append upstream)
        obs::event(
            "gen_checkpointed",
            vec![
                ("generation", Json::Num(st.generation as f64)),
                ("journal_armed", Json::Bool(ckpt.journal_armed())),
                (
                    "journal_appended",
                    Json::Num(ckpt.journal_appended().unwrap_or(0) as f64),
                ),
            ],
        );
    }

    let front = nsga::final_front(&st.pop);
    Ok(front
        .into_iter()
        .filter_map(|ind| {
            let hw = evaluate_network(engine, arch, layers, &ind.genome, cache, map_cfg)?;
            Some(Candidate {
                accuracy: acc.accuracy(&ind.genome),
                genome: ind.genome,
                hw,
                strategy: "proposed",
            })
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;
    use crate::eval;

    fn net() -> Vec<ConvLayer> {
        vec![
            ConvLayer::conv("c1", 3, 8, 3, 16, 1),
            ConvLayer::dw("d1", 8, 3, 16, 1),
            ConvLayer::pw("p1", 8, 16, 16),
            ConvLayer::fc("fc", 16, 10),
        ]
    }

    fn cfg(shards: usize) -> MapperConfig {
        MapperConfig {
            valid_target: 40,
            max_draws: 40_000,
            seed: 2,
            shards,
        }
    }

    #[test]
    fn engine_network_eval_is_bit_identical_to_serial() {
        let a = toy();
        let layers = net();
        for shards in [1usize, 3] {
            let c = cfg(shards);
            let qc = QuantConfig::uniform(layers.len(), 4);
            let serial_cache = MapperCache::new();
            let serial = eval::evaluate_network(&a, &layers, &qc, &serial_cache, &c).unwrap();
            for workers in [1usize, 2, 4, 8] {
                let engine = Engine::new(workers);
                let cache = MapperCache::new();
                let got = evaluate_network(&engine, &a, &layers, &qc, &cache, &c).unwrap();
                assert_eq!(serial, got, "workers={workers} shards={shards}");
                assert_eq!(serial.edp.to_bits(), got.edp.to_bits());
            }
        }
    }

    #[test]
    fn generation_dedup_searches_each_workload_once() {
        let a = toy();
        let layers = net();
        let c = cfg(1);
        let engine = Engine::new(4);
        let cache = MapperCache::new();
        // two genomes differing only in layer 0 → distinct workloads =
        // (4 + 1) minus pack-class overlaps; every job searched once
        let g1 = QuantConfig::uniform(layers.len(), 8);
        let mut g2 = QuantConfig::uniform(layers.len(), 8);
        g2.layers[0] = (4, 4);
        let genomes = vec![g1, g2];
        let evals = evaluate_genomes(&engine, &a, &layers, &genomes, &cache, &c);
        assert_eq!(evals.len(), 2);
        assert!(evals[0].is_some() && evals[1].is_some());
        // every unique workload was searched exactly once
        assert_eq!(cache.misses() as usize, cache.len());
        let misses_before = cache.misses();
        // re-evaluating the same genomes costs zero new searches
        let again = evaluate_genomes(&engine, &a, &layers, &genomes, &cache, &c);
        assert_eq!(evals, again);
        assert_eq!(cache.misses(), misses_before);
    }

    #[test]
    fn unmappable_layer_yields_none_like_serial() {
        let mut a = toy();
        a.name = "toy-nospad".into();
        a.levels[0].capacity = crate::arch::Capacity::PerTensor([0, 64, 64]);
        let layers = net();
        let c = cfg(1);
        let qc = QuantConfig::uniform(layers.len(), 8);
        let serial_cache = MapperCache::new();
        assert!(eval::evaluate_network(&a, &layers, &qc, &serial_cache, &c).is_none());
        let engine = Engine::new(3);
        let cache = MapperCache::new();
        assert!(evaluate_network(&engine, &a, &layers, &qc, &cache, &c).is_none());
    }

    #[test]
    fn distributed_backend_is_bit_identical_to_local() {
        let a = toy();
        let layers = net();
        let c = cfg(2); // sharded jobs: remote batches carry >1 spec
        let qc = QuantConfig::uniform(layers.len(), 4);
        let serial = {
            let engine = Engine::new(1);
            let cache = MapperCache::new();
            evaluate_network(&engine, &a, &layers, &qc, &cache, &c).unwrap()
        };
        let addr = remote::spawn_local_worker(crate::engine::WorkerOptions::default())
            .expect("loopback worker");
        for budget in [1usize, 3] {
            let engine = Engine::distributed(budget, vec![addr.clone()]);
            let cache = MapperCache::new();
            let got = evaluate_network(&engine, &a, &layers, &qc, &cache, &c).unwrap();
            assert_eq!(serial, got, "budget={budget}");
            assert_eq!(serial.edp.to_bits(), got.edp.to_bits());
        }
        // an empty worker list silently degrades to the local backend
        let engine = Engine::distributed(2, Vec::new());
        assert!(matches!(engine.backend(), Backend::Local));
    }

    #[test]
    fn population_results_independent_of_worker_count() {
        let a = toy();
        let layers = net();
        let c = cfg(2); // sharded jobs: exercises the split path too
        let mut rng = crate::util::rng::Rng::new(77);
        let genomes: Vec<QuantConfig> = (0..6)
            .map(|_| {
                let mut g = QuantConfig::uniform(layers.len(), 8);
                for l in g.layers.iter_mut() {
                    l.0 = 2 + rng.below(7) as u8;
                    l.1 = 2 + rng.below(7) as u8;
                }
                g
            })
            .collect();
        let reference: Vec<Option<NetworkEval>> = {
            let engine = Engine::new(1);
            let cache = MapperCache::new();
            evaluate_genomes(&engine, &a, &layers, &genomes, &cache, &c)
        };
        for workers in [2usize, 4, 8] {
            let engine = Engine::new(workers);
            let cache = MapperCache::new();
            let got = evaluate_genomes(&engine, &a, &layers, &genomes, &cache, &c);
            assert_eq!(reference, got, "workers={workers}");
        }
    }

    #[test]
    fn sched_policy_never_changes_results() {
        let a = toy();
        let layers = net();
        let c = cfg(2);
        let mut rng = crate::util::rng::Rng::new(41);
        let genomes: Vec<QuantConfig> = (0..5)
            .map(|_| {
                let mut g = QuantConfig::uniform(layers.len(), 8);
                for l in g.layers.iter_mut() {
                    l.0 = 2 + rng.below(7) as u8;
                    l.1 = 2 + rng.below(7) as u8;
                }
                g
            })
            .collect();
        let reference = {
            let engine = Engine::new(1).with_sched_policy(SchedPolicy::Fifo);
            let cache = MapperCache::new();
            evaluate_genomes(&engine, &a, &layers, &genomes, &cache, &c)
        };
        for policy in [
            SchedPolicy::Fifo,
            SchedPolicy::Priority,
            SchedPolicy::Shuffled(7),
            SchedPolicy::Shuffled(0xDEAD_BEEF),
        ] {
            let engine = Engine::new(3).with_sched_policy(policy);
            let cache = MapperCache::new();
            let got = evaluate_genomes(&engine, &a, &layers, &genomes, &cache, &c);
            assert_eq!(reference, got, "policy={policy:?}");
            // a second generation over a warm cache: priority now sinks
            // the cached jobs; the results still cannot move
            let again = evaluate_genomes(&engine, &a, &layers, &genomes, &cache, &c);
            assert_eq!(reference, again, "warm policy={policy:?}");
        }
    }

    #[test]
    fn priority_order_sinks_cached_jobs_and_is_deterministic() {
        let a = toy();
        let layers = net();
        let c = cfg(1);
        let engine = Engine::new(1); // default policy: Priority
        let cache = MapperCache::new();
        let quants: Vec<LayerQuant> = (0..layers.len())
            .map(|i| {
                LayerQuant::uniform(if i % 2 == 0 { 4 } else { 8 })
                    .canonical(a.word_bits, a.bit_packing)
            })
            .collect();
        let jobs: Vec<EvalJob> = quants
            .iter()
            .enumerate()
            .map(|(i, &quant)| EvalJob {
                layer_index: i,
                quant,
                key: WorkloadKey::of(&a, &layers[i], &quant),
            })
            .collect();
        // cold cache: every job costs max_draws; ties resolve by MACs
        // (descending), then first-encounter order — deterministic
        let cold1 = order_jobs(&engine, &layers, &jobs, &cache, &c);
        let cold2 = order_jobs(&engine, &layers, &jobs, &cache, &c);
        let key = |v: &[EvalJob]| v.iter().map(|j| j.layer_index).collect::<Vec<_>>();
        assert_eq!(key(&cold1), key(&cold2));
        let macs: Vec<u64> = cold1.iter().map(|j| layers[j.layer_index].macs()).collect();
        let sorted = {
            let mut m = macs.clone();
            m.sort_unstable_by(|x, y| y.cmp(x));
            m
        };
        assert_eq!(macs, sorted, "cold priority order must be MACs-descending");
        // warm one workload: it must sink to the end of the order
        let warm_idx = cold1[0].layer_index;
        cache.evaluate(&a, &layers[warm_idx], &cold1[0].quant, &c);
        let warm = order_jobs(&engine, &layers, &jobs, &cache, &c);
        assert_eq!(
            warm.last().unwrap().layer_index,
            warm_idx,
            "cached job must sink to the tail of the schedule"
        );
    }
}
