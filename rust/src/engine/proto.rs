//! Wire protocol for distributed shard execution.
//!
//! One frame = one JSON document, delimited and integrity-checked:
//!
//! ```text
//! +------+----------+-----------+-----------------+
//! | QMAP | len: u32 | fnv: u64  | payload (JSON)  |
//! | 4 B  | BE       | BE        | len bytes       |
//! +------+----------+-----------+-----------------+
//! ```
//!
//! Design constraints, in order:
//!
//! * **Total decoding.** Frames arrive from the network; every
//!   malformed input — truncation, a flipped bit, a hostile length
//!   prefix — must produce an `Err`, never a panic and never an
//!   attempt to allocate the attacker's choice of buffer. The length
//!   is validated against [`MAX_FRAME`] *before* any allocation, and
//!   the FNV-1a checksum over the payload catches corruption that the
//!   JSON grammar would happily accept.
//! * **Bit-exactness.** Every f64 in a message travels as its IEEE-754
//!   bit pattern and every u64 as hex (the same convention as
//!   `engine::checkpoint`, via the shared `util::json` helpers), so a
//!   `ShardOutcome` computed on another host merges into a Pareto
//!   front bit-identical to local execution.
//! * **Statelessness.** A `batch` message carries everything a worker
//!   needs — the rendered architecture spec, the workload, the
//!   canonical quantization, and the shard specs — so any batch can be
//!   re-sent to any worker (or re-run locally) at any time. Fault
//!   tolerance upstream is just re-execution.
//!
//! Messages (the `type` field):
//!
//! * `hello`  — version handshake, sent by the worker on connect.
//! * `batch`  — driver → worker: execute these [`ShardSpec`]s.
//! * `outcome`— worker → driver: one shard's [`ShardOutcome`], keyed
//!   by `(id, shard)`; may arrive duplicated or out of order.
//! * `done`   — worker → driver: batch `id` fully streamed.
//! * `error`  — worker → driver: the batch could not be executed.

use crate::mapper::{ShardOutcome, ShardSpec};
use crate::quant::LayerQuant;
use crate::util::json::{parse, Json};
use crate::workload::{ConvLayer, LayerKind};
use std::io::{Read, Write};

/// Protocol version; bumped on any incompatible message change.
/// Checked on both sides: the driver refuses a worker whose `hello`
/// advertises a different version, and the worker refuses a `batch`
/// whose `v` field mismatches (drivers never send `hello`, so the
/// batch itself carries the driver's version).
pub const VERSION: u64 = 1;

/// Frame magic: catches a peer that is not speaking this protocol at
/// all (or a stream that lost sync) on the first four bytes.
pub const MAGIC: [u8; 4] = *b"QMAP";

/// Hard cap on a frame payload. A `batch` for the largest real
/// workload is a few kilobytes; 16 MiB leaves three orders of margin
/// while keeping a hostile length prefix from turning into a
/// multi-gigabyte allocation.
pub const MAX_FRAME: usize = 16 << 20;

const HEADER_LEN: usize = 4 + 4 + 8;

/// FNV-1a over a byte slice — the frame checksum (the shared
/// `util::Fnv1a` implementation). Not cryptographic; it exists to turn
/// line noise and truncation into clean errors, not to authenticate
/// peers.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    crate::util::fnv1a(bytes)
}

/// Encode one payload as a complete frame (header + payload bytes).
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(&fnv1a(payload).to_be_bytes());
    out.extend_from_slice(payload);
    out
}

/// Write one frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), String> {
    if payload.len() > MAX_FRAME {
        return Err(format!(
            "refusing to send a {} byte frame (max {MAX_FRAME})",
            payload.len()
        ));
    }
    w.write_all(&encode_frame(payload)).map_err(|e| format!("send: {e}"))?;
    w.flush().map_err(|e| format!("send: {e}"))
}

/// Read one frame's payload. Total: truncated input, wrong magic, a
/// length prefix beyond [`MAX_FRAME`], or a checksum mismatch all
/// return `Err` — the length is validated before the payload buffer is
/// allocated, so a hostile prefix cannot force an OOM.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, String> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).map_err(|e| format!("frame header: {e}"))?;
    if header[..4] != MAGIC {
        return Err("frame: bad magic (peer is not speaking the qmap protocol)".into());
    }
    let len = u32::from_be_bytes([header[4], header[5], header[6], header[7]]) as usize;
    if len > MAX_FRAME {
        return Err(format!("frame: length {len} exceeds the {MAX_FRAME} byte cap"));
    }
    let want = u64::from_be_bytes([
        header[8], header[9], header[10], header[11], header[12], header[13], header[14],
        header[15],
    ]);
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| format!("frame payload: {e}"))?;
    let got = fnv1a(&payload);
    if got != want {
        return Err(format!("frame: checksum mismatch (want {want:016x}, got {got:016x})"));
    }
    Ok(payload)
}

/// Write one message (a JSON value) as a frame.
pub fn write_msg(w: &mut impl Write, msg: &Json) -> Result<(), String> {
    write_frame(w, msg.to_string().as_bytes())
}

/// Read one message. Malformed UTF-8 or JSON (including pathological
/// nesting — see `util::json::MAX_DEPTH`) is an `Err`.
pub fn read_msg(r: &mut impl Read) -> Result<Json, String> {
    let payload = read_frame(r)?;
    let text = std::str::from_utf8(&payload).map_err(|_| "frame: payload is not UTF-8")?;
    parse(text).map_err(|e| format!("frame json: {e}"))
}

/// The `type` field of a message, or an error naming what was there.
pub fn msg_type(msg: &Json) -> Result<&str, String> {
    msg.get("type")
        .as_str()
        .ok_or_else(|| format!("message has no type: {}", msg.to_string()))
}

// ---------------------------------------------------------- messages

/// The worker's greeting.
pub fn hello() -> Json {
    Json::obj(vec![
        ("type", Json::Str("hello".into())),
        ("version", Json::hex_u64(VERSION)),
    ])
}

/// Workload wire form. The name rides along for log readability only —
/// `mapper::workload_hash` ignores it, so it cannot affect results.
pub fn layer_to_json(l: &ConvLayer) -> Json {
    Json::obj(vec![
        ("name", Json::Str(l.name.clone())),
        (
            "kind",
            Json::Str(
                match l.kind {
                    LayerKind::Standard => "standard",
                    LayerKind::Depthwise => "depthwise",
                }
                .into(),
            ),
        ),
        ("dims", Json::Arr(l.dims.iter().map(|&d| Json::hex_u64(d)).collect())),
        ("stride", Json::Arr(vec![Json::hex_u64(l.stride.0), Json::hex_u64(l.stride.1)])),
    ])
}

/// Decode and *validate* a workload: zero dims or strides are rejected
/// here (`ConvLayer::new` asserts on them, and a worker must never
/// panic on network input).
pub fn layer_from_json(v: &Json) -> Result<ConvLayer, String> {
    let kind = match v.get("kind").as_str() {
        Some("standard") => LayerKind::Standard,
        Some("depthwise") => LayerKind::Depthwise,
        other => return Err(format!("layer kind: bad value {other:?}")),
    };
    let dims_arr = v.get("dims").as_arr().ok_or("layer dims: not an array")?;
    if dims_arr.len() != 7 {
        return Err(format!("layer dims: expected 7 entries, got {}", dims_arr.len()));
    }
    let mut dims = [0u64; 7];
    for (i, d) in dims_arr.iter().enumerate() {
        dims[i] = d.as_hex_u64("layer dim")?;
        if dims[i] == 0 {
            return Err("layer dims: zero-sized dimension".into());
        }
    }
    let stride_arr = v.get("stride").as_arr().ok_or("layer stride: not an array")?;
    if stride_arr.len() != 2 {
        return Err("layer stride: expected 2 entries".into());
    }
    let stride = (
        stride_arr[0].as_hex_u64("layer stride")?,
        stride_arr[1].as_hex_u64("layer stride")?,
    );
    if stride.0 == 0 || stride.1 == 0 {
        return Err("layer stride: zero stride".into());
    }
    if kind == LayerKind::Depthwise && dims[2] != 1 {
        return Err("layer dims: depthwise layers must have C = 1".into());
    }
    Ok(ConvLayer {
        name: v.get("name").as_str().unwrap_or("remote").to_string(),
        kind,
        dims,
        stride,
    })
}

pub fn quant_to_json(q: &LayerQuant) -> Json {
    Json::obj(vec![
        ("qa", Json::Num(q.qa as f64)),
        ("qw", Json::Num(q.qw as f64)),
        ("qo", Json::Num(q.qo as f64)),
    ])
}

pub fn quant_from_json(v: &Json) -> Result<LayerQuant, String> {
    let field = |key: &str| -> Result<u8, String> {
        let x = v.get(key).as_f64().ok_or_else(|| format!("quant {key}: missing"))?;
        if !(x.is_finite() && (0.0..=255.0).contains(&x) && x.fract() == 0.0) {
            return Err(format!("quant {key}: bad value {x}"));
        }
        Ok(x as u8)
    };
    let q = LayerQuant {
        qa: field("qa")?,
        qw: field("qw")?,
        qo: field("qo")?,
    };
    if q.qa == 0 || q.qw == 0 || q.qo == 0 {
        return Err("quant: zero bit-width".into());
    }
    Ok(q)
}

/// Driver → worker: execute `specs` for one workload. The architecture
/// travels as its rendered text spec — `arch::parser`'s round-trip is
/// exact (asserted by `spec_roundtrip`), so the worker rebuilds the
/// identical numerics. `search` identifies the driver's search (a hash
/// of the arch spec, mapper budgets, and objective-spec identity) and
/// scopes the worker's local shard-outcome cache; it never affects
/// what is computed, only what may be *reused*, and reuse is sound
/// because a shard outcome is a pure function of
/// `(arch, layer, quant, spec)`. Workers predating the field treat its
/// absence as search 0.
///
/// `objectives` is the driver's canonical objective-spec string
/// (`engine::Engine::objectives`). Workers never compute objectives,
/// but they *validate* the field: a worker that cannot parse the spec
/// (an axis this build does not know) answers with an `error` frame
/// naming the axis instead of participating in a search whose
/// objective space it does not share — the loud-failure seam for
/// mixed-version fleets. Workers predating the field ignore it, which
/// is sound for exactly the axes that existed then.
///
/// `guide` is the driver's accumulated `(valid, drawn)` counts for
/// this workload (see `mapper::guide`) — a purely observational hint
/// for the worker's own metrics/logs, written only when the driver has
/// history. Additive and optional: workers predating the field ignore
/// it (`decode_batch` reads fields by name), and a worker never lets
/// it near the shard execution path — outcomes are a pure function of
/// `(arch, layer, quant, spec)` with or without it.
#[allow(clippy::too_many_arguments)]
pub fn batch(
    id: u64,
    search: u64,
    objectives: &str,
    arch_spec: &str,
    layer: &ConvLayer,
    q: &LayerQuant,
    specs: &[ShardSpec],
    guide: Option<(u64, u64)>,
) -> Json {
    let mut fields = vec![
        ("type", Json::Str("batch".into())),
        ("v", Json::hex_u64(VERSION)),
        ("id", Json::hex_u64(id)),
        ("search", Json::hex_u64(search)),
        ("objectives", Json::Str(objectives.to_string())),
        ("arch", Json::Str(arch_spec.to_string())),
        ("layer", layer_to_json(layer)),
        ("quant", quant_to_json(q)),
        ("specs", Json::Arr(specs.iter().map(|s| s.to_json()).collect())),
    ];
    if let Some((valid, drawn)) = guide {
        fields.push((
            "guide",
            Json::obj(vec![
                ("valid", Json::hex_u64(valid)),
                ("drawn", Json::hex_u64(drawn)),
            ]),
        ));
    }
    Json::obj(fields)
}

/// Worker → driver: one shard's outcome.
pub fn outcome(id: u64, shard: usize, out: &ShardOutcome) -> Json {
    Json::obj(vec![
        ("type", Json::Str("outcome".into())),
        ("id", Json::hex_u64(id)),
        ("shard", Json::Num(shard as f64)),
        ("outcome", out.to_json()),
    ])
}

/// Worker → driver: batch `id` is complete.
pub fn done(id: u64) -> Json {
    Json::obj(vec![("type", Json::Str("done".into())), ("id", Json::hex_u64(id))])
}

/// Worker → driver: the batch failed (reason for the driver's logs;
/// the driver re-runs the specs locally either way).
pub fn error(msg: &str) -> Json {
    Json::obj(vec![
        ("type", Json::Str("error".into())),
        ("msg", Json::Str(msg.to_string())),
    ])
}

/// One event of a worker's interleaved reply stream (see
/// `RemoteClient::recv_event` in `engine::remote`).
#[derive(Debug)]
pub enum WorkerEvent {
    /// One shard's outcome for batch `id`; may arrive duplicated or
    /// out of order.
    Outcome {
        id: u64,
        shard: usize,
        outcome: ShardOutcome,
    },
    /// Batch `id` fully streamed.
    Done { id: u64 },
}

/// Decode one worker→driver reply frame into a [`WorkerEvent`]. Total:
/// `error` frames, unknown types, and malformed fields are `Err` —
/// the caller condemns the connection. `peer` names the worker in
/// error strings. Lives next to the wire format so the driver's pump
/// and the model-conformance suites consume one decoder.
pub fn decode_event(m: &Json, peer: &str) -> Result<WorkerEvent, String> {
    match msg_type(m)? {
        "outcome" => {
            let id = m.get("id").as_hex_u64("outcome id")?;
            // strict index decode: a saturating `as usize` on a
            // negative/fractional value would silently land in
            // the wrong ledger slot — reject instead
            let sf = m.get("shard").as_f64().ok_or("outcome: missing shard")?;
            if !(sf.is_finite() && sf.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&sf)) {
                return Err(format!("worker {peer}: bad shard index {sf}"));
            }
            let outcome = ShardOutcome::from_json(m.get("outcome"))?;
            Ok(WorkerEvent::Outcome {
                id,
                shard: sf as usize,
                outcome,
            })
        }
        "done" => Ok(WorkerEvent::Done {
            id: m.get("id").as_hex_u64("done id")?,
        }),
        "error" => Err(format!(
            "worker {peer}: {}",
            m.get("msg").as_str().unwrap_or("unspecified error")
        )),
        other => Err(format!("worker {peer}: unexpected '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;
    use crate::arch::parser::{parse_arch, render_arch};

    #[test]
    fn decode_event_is_total_and_names_the_peer() {
        let ev = decode_event(&done(7), "w1").expect("done decodes");
        assert!(matches!(ev, WorkerEvent::Done { id: 7 }));
        let e = decode_event(&error("boom"), "w1").unwrap_err();
        assert!(e.contains("worker w1") && e.contains("boom"), "{e}");
        let e = decode_event(&hello(), "w2").unwrap_err();
        assert!(e.contains("unexpected"), "{e}");
        // fractional, negative, and non-finite shard indices must be
        // rejected before any slot arithmetic
        for bad in [0.5, -1.0, f64::NAN, 1e18] {
            let m = Json::obj(vec![
                ("type", Json::Str("outcome".into())),
                ("id", Json::hex_u64(1)),
                ("shard", Json::Num(bad)),
                ("outcome", Json::Null),
            ]);
            let e = decode_event(&m, "w3").unwrap_err();
            assert!(e.contains("bad shard index"), "{e}");
        }
    }

    #[test]
    fn frame_roundtrip() {
        let payload = br#"{"type":"hello"}"#;
        let framed = encode_frame(payload);
        let mut cur = std::io::Cursor::new(framed);
        assert_eq!(read_frame(&mut cur).unwrap(), payload.to_vec());
    }

    #[test]
    fn truncated_frames_error() {
        let framed = encode_frame(b"0123456789");
        for cut in 0..framed.len() {
            let mut cur = std::io::Cursor::new(framed[..cut].to_vec());
            assert!(read_frame(&mut cur).is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn any_flipped_bit_is_detected() {
        let framed = encode_frame(br#"{"type":"done","id":"00"}"#);
        for byte in 0..framed.len() {
            for bit in 0..8 {
                let mut bad = framed.clone();
                bad[byte] ^= 1 << bit;
                let mut cur = std::io::Cursor::new(bad);
                // a flip in the length prefix may ask for more bytes
                // than exist (Err), a shorter prefix fails the
                // checksum over the shorter slice, a payload/checksum
                // flip fails the comparison, a magic flip fails the
                // magic check — every single-bit flip must error.
                assert!(
                    read_frame(&mut cur).is_err(),
                    "flip byte {byte} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn hostile_length_prefix_is_rejected_before_allocation() {
        let mut framed = encode_frame(b"tiny");
        // rewrite the length to 4 GiB - 1; the reader must reject it
        // from the header alone instead of allocating
        framed[4..8].copy_from_slice(&u32::MAX.to_be_bytes());
        let mut cur = std::io::Cursor::new(framed);
        let err = read_frame(&mut cur).unwrap_err();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn oversize_payload_refused_on_send() {
        let big = vec![b'x'; MAX_FRAME + 1];
        let mut sink = Vec::new();
        assert!(write_frame(&mut sink, &big).is_err());
        assert!(sink.is_empty());
    }

    #[test]
    fn layer_and_quant_wire_roundtrip() {
        for l in [
            ConvLayer::conv("c1", 3, 8, 3, 16, 2),
            ConvLayer::dw("d1", 8, 3, 16, 1),
            ConvLayer::fc("fc", 16, 10),
        ] {
            let back = layer_from_json(&layer_to_json(&l)).unwrap();
            assert_eq!(back, l);
        }
        let q = LayerQuant { qa: 4, qw: 6, qo: 8 };
        assert_eq!(quant_from_json(&quant_to_json(&q)).unwrap(), q);
    }

    #[test]
    fn hostile_layer_and_quant_are_rejected_not_panicked() {
        // zero dim (ConvLayer::new would assert)
        let mut bad = layer_to_json(&ConvLayer::fc("fc", 16, 10));
        if let Json::Obj(m) = &mut bad {
            m.insert(
                "dims".into(),
                Json::Arr((0..7).map(|_| Json::hex_u64(0)).collect()),
            );
        }
        assert!(layer_from_json(&bad).is_err());
        assert!(layer_from_json(&Json::Null).is_err());
        assert!(quant_from_json(&Json::Null).is_err());
        let nan_q = Json::obj(vec![
            ("qa", Json::Num(f64::NAN)),
            ("qw", Json::Num(8.0)),
            ("qo", Json::Num(8.0)),
        ]);
        assert!(quant_from_json(&nan_q).is_err());
    }

    #[test]
    fn batch_message_roundtrips_through_bytes() {
        let arch = toy();
        let l = ConvLayer::conv("c1", 3, 8, 3, 16, 1);
        let q = LayerQuant::uniform(4);
        let specs = crate::mapper::shard_plan(
            &crate::mapper::MapperConfig {
                valid_target: 10,
                max_draws: 1000,
                seed: 3,
                shards: 3,
            },
            42,
        );
        let msg =
            batch(7, 0xFEED_5EED, "edp,error", &render_arch(&arch), &l, &q, &specs, Some((3, 77)));
        // no guide → no field on the wire (old workers see old bytes)
        let bare = batch(7, 0, "edp,error", &render_arch(&arch), &l, &q, &specs, None);
        assert!(matches!(bare.get("guide"), Json::Null));
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        let back = read_msg(&mut cur).unwrap();
        assert_eq!(msg_type(&back).unwrap(), "batch");
        assert_eq!(back.get("id").as_hex_u64("id").unwrap(), 7);
        assert_eq!(back.get("search").as_hex_u64("search").unwrap(), 0xFEED_5EED);
        assert_eq!(back.get("objectives").as_str().unwrap(), "edp,error");
        let g = back.get("guide");
        assert_eq!(g.get("valid").as_hex_u64("valid").unwrap(), 3);
        assert_eq!(g.get("drawn").as_hex_u64("drawn").unwrap(), 77);
        let arch_back = parse_arch(back.get("arch").as_str().unwrap()).unwrap();
        assert_eq!(arch_back, arch);
        assert_eq!(layer_from_json(back.get("layer")).unwrap(), l);
        let specs_back: Vec<_> = back
            .get("specs")
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| ShardSpec::from_json(s).unwrap())
            .collect();
        assert_eq!(specs_back, specs);
    }
}
