//! Workload-result cache (the paper's §III-A caching mechanism).
//!
//! "Once a layer workload has been evaluated, the results are stored in
//! a cache. Subsequently, the cached results can be read and reused when
//! trying to find the best plan for the same workload." NSGA-II genomes
//! share most of their layers, so hit rates are high after the first
//! generation.
//!
//! The cache is keyed by `workload_hash(layer, quant)` (shape + strides
//! + kind + bit-widths) and the architecture name, is thread-safe, and
//! can persist to a JSON file across runs. Two hot-path properties:
//!
//! * **Lock striping** — entries spread over [`NUM_SHARDS`] independent
//!   `RwLock`ed maps selected by the high bits of the key, so
//!   population-parallel NSGA-II evaluations no longer serialize behind
//!   a single lock.
//! * **Negative caching** — unmappable workloads are stored as `None`,
//!   so every later genome touching one costs a lookup instead of
//!   re-paying the full `max_draws` search. The JSON dump records them
//!   with a `mappable: false` marker.

use super::store::{self, CacheStore};
use super::{search, workload_hash, MapperConfig, MapperResult};
use crate::arch::Arch;
use crate::obs::metrics;
use crate::quant::LayerQuant;
use crate::util::json::{parse, Json};
use crate::workload::ConvLayer;
use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

/// Lock stripes; a power of two so the top key bits index directly.
pub const NUM_SHARDS: usize = 16;
const SHARD_SHIFT: u32 = 64 - 4; // log2(NUM_SHARDS) top bits

/// Precomputed cache identity of one `(arch, layer, quant)` workload.
///
/// `probe`, `effective_draws`, `evaluate`, and `insert_search` each used
/// to re-canonicalize `q` and re-run the FNV hash from scratch, so one
/// scheduling pass over a generation hashed every job three-plus times.
/// Compute this handle once per job with [`WorkloadKey::of`] and pass it
/// through the `*_key` methods instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorkloadKey {
    /// `workload_hash(layer, canonical q)` — also the mapper's
    /// shard-seed basis (`cfg.seed ^ whash`).
    pub whash: u64,
    /// The cache map key: `whash` continued with the arch name, xored
    /// with the packing mode.
    key: u64,
}

impl WorkloadKey {
    /// Compute the key for one workload. `q` is canonicalized to its
    /// packing-equivalence representative internally — the same
    /// canonicalization `mapper::search` and the cache itself apply, so
    /// equivalent settings share one entry.
    pub fn of(arch: &Arch, layer: &ConvLayer, q: &LayerQuant) -> Self {
        let q = q.canonical(arch.word_bits, arch.bit_packing);
        let whash = workload_hash(layer, &q);
        // continue the workload hash's FNV stream with the arch name
        // (bit-identical to the previous inlined loop)
        let mut h = crate::util::Fnv1a::with_state(whash);
        h.write(arch.name.as_bytes());
        let key = h.finish() ^ ((arch.bit_packing as u64) << 7);
        WorkloadKey { whash, key }
    }
}

/// The cached summary of one workload evaluation (everything the search
/// engine needs; the winning mapping itself is not persisted).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CachedEval {
    pub energy_pj: f64,
    pub memory_energy_pj: f64,
    pub cycles: f64,
    pub edp: f64,
    pub valid_mappings: u64,
    /// Per-level memory energy is folded to the three coarse components
    /// reported in Fig. 4: innermost (spads/regs), middle (GLB/PE bufs),
    /// DRAM.
    pub energy_breakdown_pj: [f64; 3],
    pub mac_energy_pj: f64,
}

/// One cache slot: either a mapped workload's summary, or a negative
/// record of a failed search tagged with the draw budget that failed.
/// A later probe with a *larger* `max_draws` re-runs the search instead
/// of trusting a smaller budget's failure; probes at or below the
/// recorded budget are served as (negative) hits.
#[derive(Debug, Clone, Copy, PartialEq)]
enum CacheEntry {
    Mapped(CachedEval),
    Unmappable { max_draws: u64 },
}

/// Thread-safe, lock-striped mapper cache with negative caching (see
/// [`CacheEntry`]).
pub struct MapperCache {
    shards: Vec<RwLock<FxHashMap<u64, CacheEntry>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// When set (by `engine::checkpoint`'s journal), every
    /// `insert_search` also queues its entry JSON in `pending` so the
    /// next checkpoint appends exactly the new entries — O(new) instead
    /// of the old O(cache) full-dump rewrite. Off by default: callers
    /// that never checkpoint pay nothing but one relaxed load.
    journal: AtomicBool,
    pending: Mutex<Vec<Json>>,
    /// Optional persistent tier (see [`crate::mapper::store`]): probes
    /// that miss in memory consult it before declaring a true miss
    /// (read-through, with promotion into the shard maps), and every
    /// live insert is appended (write-behind). Strictly additive: with
    /// an identity-matched store attached, a warm run is bit-identical
    /// to a cold one.
    backing: OnceLock<Arc<CacheStore>>,
}

impl Default for MapperCache {
    fn default() -> Self {
        Self::new()
    }
}

impl MapperCache {
    pub fn new() -> Self {
        MapperCache {
            shards: (0..NUM_SHARDS)
                .map(|_| RwLock::new(FxHashMap::default()))
                .collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            journal: AtomicBool::new(false),
            pending: Mutex::new(Vec::new()),
            backing: OnceLock::new(),
        }
    }

    /// Attach a persistent store as the read-through/write-behind tier.
    /// At most one per cache; later calls are ignored. The caller is
    /// responsible for identity discipline — open the store through
    /// [`store::open_search_store`] so a mismatched arch or mapper
    /// config is refused instead of silently served.
    pub fn set_backing(&self, store: Arc<CacheStore>) {
        let _ = self.backing.set(store);
    }

    /// The attached persistent store, if any.
    pub fn backing(&self) -> Option<&Arc<CacheStore>> {
        self.backing.get()
    }

    #[inline]
    fn shard(&self, key: u64) -> &RwLock<FxHashMap<u64, CacheEntry>> {
        &self.shards[(key >> SHARD_SHIFT) as usize]
    }

    /// Evaluate a workload through the cache, running the mapper on miss.
    /// Returns `None` for unmappable workloads — a result that is itself
    /// cached (tagged with the failing draw budget), so repeated probes
    /// cost one lookup; a probe with a larger `max_draws` than any
    /// recorded failure re-runs the search.
    pub fn evaluate(
        &self,
        arch: &Arch,
        layer: &ConvLayer,
        q: &LayerQuant,
        cfg: &MapperConfig,
    ) -> Option<CachedEval> {
        self.evaluate_key(WorkloadKey::of(arch, layer, q), arch, layer, q, cfg)
    }

    /// [`MapperCache::evaluate`] with a precomputed [`WorkloadKey`]
    /// (`arch`/`layer`/`q` are still needed to run the mapper on a
    /// miss, but are never re-hashed).
    pub fn evaluate_key(
        &self,
        wk: WorkloadKey,
        arch: &Arch,
        layer: &ConvLayer,
        q: &LayerQuant,
        cfg: &MapperConfig,
    ) -> Option<CachedEval> {
        if let Some(hit) = self.probe_key(wk, cfg) {
            return hit;
        }
        let r = search(arch, layer, q, cfg);
        self.insert_search_key(wk, cfg, &r)
    }

    /// The lookup half of [`MapperCache::evaluate`]: `Some(Some(e))` is
    /// a positive hit, `Some(None)` a negative hit that is valid for
    /// `cfg.max_draws`, and `None` a miss — the caller must run the
    /// search (however it likes; the engine runs it on the work-stealing
    /// pool) and record it with [`MapperCache::insert_search`].
    pub fn probe(
        &self,
        arch: &Arch,
        layer: &ConvLayer,
        q: &LayerQuant,
        cfg: &MapperConfig,
    ) -> Option<Option<CachedEval>> {
        self.probe_key(WorkloadKey::of(arch, layer, q), cfg)
    }

    /// [`MapperCache::probe`] with a precomputed [`WorkloadKey`].
    pub fn probe_key(&self, wk: WorkloadKey, cfg: &MapperConfig) -> Option<Option<CachedEval>> {
        let key = wk.key;
        if let Some(hit) = self.shard(key).read().unwrap().get(&key) {
            match hit {
                CacheEntry::Mapped(e) => {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Some(Some(*e));
                }
                CacheEntry::Unmappable { max_draws } if *max_draws >= cfg.max_draws => {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Some(None);
                }
                // stale negative from a smaller budget: report a miss so
                // the caller pays the search again with the bigger budget
                CacheEntry::Unmappable { .. } => {}
            }
        }
        self.probe_backing(key, cfg)
    }

    /// The read-through tier of [`MapperCache::probe_key`]: consult the
    /// persistent store (when attached) after an in-memory miss. A
    /// decisive store answer is promoted into the in-memory shard (and
    /// the journal queue, so checkpoints stay self-contained) and
    /// counted as a hit. Promotion inserts directly — never through
    /// `insert_search_key` — so a store-served entry is not appended
    /// back to the store it came from.
    fn probe_backing(&self, key: u64, cfg: &MapperConfig) -> Option<Option<CachedEval>> {
        let store = self.backing.get()?;
        let m = metrics::counters();
        let decoded = store.lookup(key).and_then(|(tag, p)| Self::entry_from_record(tag, p));
        let Some(entry) = decoded else {
            m.store_misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        let out = match entry {
            CacheEntry::Mapped(e) => Some(e),
            CacheEntry::Unmappable { max_draws } => {
                if max_draws < cfg.max_draws {
                    // stale negative: not decisive at this budget
                    m.store_misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
                None
            }
        };
        self.shard(key).write().unwrap().insert(key, entry);
        if self.journal.load(Ordering::Relaxed) {
            self.pending.lock().unwrap().push(Self::entry_json(key, &entry));
        }
        self.hits.fetch_add(1, Ordering::Relaxed);
        m.store_hits.fetch_add(1, Ordering::Relaxed);
        Some(out)
    }

    /// Scheduling cost estimate for a workload under `cfg` — the
    /// "effective draw budget" the engine's priority scheduler sorts
    /// by. Cache hits (positive, or negative at a sufficient budget)
    /// cost 0 and sink to the end of a generation's schedule; fresh
    /// misses may burn up to `max_draws`; a *stale* negative (recorded
    /// under a smaller budget) is known to burn its whole budget
    /// without terminating early, so it ranks above a fresh miss.
    /// Unlike [`MapperCache::probe`] this never touches the hit/miss
    /// counters — it is a scheduling peek, not a lookup.
    pub fn effective_draws(
        &self,
        arch: &Arch,
        layer: &ConvLayer,
        q: &LayerQuant,
        cfg: &MapperConfig,
    ) -> u64 {
        self.effective_draws_key(WorkloadKey::of(arch, layer, q), cfg)
    }

    /// [`MapperCache::effective_draws`] with a precomputed
    /// [`WorkloadKey`] — what the engine's priority scheduler calls, so
    /// a generation's scheduling pass hashes each job once.
    pub fn effective_draws_key(&self, wk: WorkloadKey, cfg: &MapperConfig) -> u64 {
        let key = wk.key;
        match self.shard(key).read().unwrap().get(&key) {
            Some(CacheEntry::Mapped(_)) => 0,
            Some(CacheEntry::Unmappable { max_draws }) => {
                if *max_draws >= cfg.max_draws {
                    0
                } else {
                    cfg.max_draws.saturating_add(*max_draws)
                }
            }
            None => cfg.max_draws,
        }
    }

    /// The record half of [`MapperCache::evaluate`]: fold a finished
    /// mapper search into a cache entry (counting the miss), and return
    /// the summary served to the caller. Failed searches are stored as
    /// negative entries tagged with the draw budget that failed.
    pub fn insert_search(
        &self,
        arch: &Arch,
        layer: &ConvLayer,
        q: &LayerQuant,
        cfg: &MapperConfig,
        r: &MapperResult,
    ) -> Option<CachedEval> {
        self.insert_search_key(WorkloadKey::of(arch, layer, q), cfg, r)
    }

    /// [`MapperCache::insert_search`] with a precomputed [`WorkloadKey`].
    pub fn insert_search_key(
        &self,
        wk: WorkloadKey,
        cfg: &MapperConfig,
        r: &MapperResult,
    ) -> Option<CachedEval> {
        let key = wk.key;
        self.misses.fetch_add(1, Ordering::Relaxed);
        let (entry, out) = match &r.best {
            Some(est) => {
                let nl = est.level_energy_pj.len();
                let mut breakdown = [0.0f64; 3];
                for (i, &e) in est.level_energy_pj.iter().enumerate() {
                    let slot = if i == nl - 1 {
                        2 // DRAM
                    } else if i == 0 {
                        0 // innermost spads/regs
                    } else {
                        1 // middle buffers
                    };
                    breakdown[slot] += e;
                }
                let cached = CachedEval {
                    energy_pj: est.energy_pj,
                    memory_energy_pj: est.memory_energy_pj(),
                    cycles: est.cycles,
                    edp: est.edp(),
                    valid_mappings: r.valid,
                    energy_breakdown_pj: breakdown,
                    mac_energy_pj: est.mac_energy_pj,
                };
                (CacheEntry::Mapped(cached), Some(cached))
            }
            None => (
                CacheEntry::Unmappable {
                    max_draws: cfg.max_draws,
                },
                None,
            ),
        };
        self.shard(key).write().unwrap().insert(key, entry);
        if self.journal.load(Ordering::Relaxed) {
            self.pending.lock().unwrap().push(Self::entry_json(key, &entry));
        }
        if let Some(store) = self.backing.get() {
            let (tag, payload) = Self::entry_record(&entry);
            store.append(key, tag, &payload);
        }
        out
    }

    /// Store-record form of one entry (see [`crate::mapper::store`] for
    /// the container format): tag 1 = mapped, tag 0 = negative; every
    /// f64 travels as its IEEE-754 bits, so the round trip is hex-exact.
    fn entry_record(v: &CacheEntry) -> (u64, [u64; store::SEARCH_SLOTS]) {
        match v {
            CacheEntry::Mapped(e) => (
                1,
                [
                    e.energy_pj.to_bits(),
                    e.memory_energy_pj.to_bits(),
                    e.cycles.to_bits(),
                    e.edp.to_bits(),
                    e.valid_mappings,
                    e.energy_breakdown_pj[0].to_bits(),
                    e.energy_breakdown_pj[1].to_bits(),
                    e.energy_breakdown_pj[2].to_bits(),
                    e.mac_energy_pj.to_bits(),
                ],
            ),
            CacheEntry::Unmappable { max_draws } => (0, [*max_draws, 0, 0, 0, 0, 0, 0, 0, 0]),
        }
    }

    /// Decode a store record. Total: an unknown tag or wrong payload
    /// width is `None` (treated as a store miss), never a panic.
    fn entry_from_record(tag: u64, p: &[u64]) -> Option<CacheEntry> {
        if p.len() != store::SEARCH_SLOTS {
            return None;
        }
        Some(match tag {
            1 => CacheEntry::Mapped(CachedEval {
                energy_pj: f64::from_bits(p[0]),
                memory_energy_pj: f64::from_bits(p[1]),
                cycles: f64::from_bits(p[2]),
                edp: f64::from_bits(p[3]),
                valid_mappings: p[4],
                energy_breakdown_pj: [
                    f64::from_bits(p[5]),
                    f64::from_bits(p[6]),
                    f64::from_bits(p[7]),
                ],
                mac_energy_pj: f64::from_bits(p[8]),
            }),
            0 => CacheEntry::Unmappable { max_draws: p[0] },
            _ => return None,
        })
    }

    /// Start queueing every future `insert_search` for the checkpoint
    /// journal (see [`MapperCache::drain_journal`]). Idempotent.
    /// Entries arriving via `load_json`/`load_entry_json` are *not*
    /// queued — they were read from a journal or dump in the first
    /// place.
    pub fn enable_journal(&self) {
        self.journal.store(true, Ordering::SeqCst);
    }

    pub fn journal_enabled(&self) -> bool {
        self.journal.load(Ordering::SeqCst)
    }

    /// Take the entries inserted since the last drain (their JSON
    /// object form, same schema as `to_json`'s `entries`). Empty
    /// unless [`MapperCache::enable_journal`] was called.
    pub fn drain_journal(&self) -> Vec<Json> {
        std::mem::take(&mut *self.pending.lock().unwrap())
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialize to JSON (for cross-run persistence). Unmappable
    /// workloads persist as `{key, mappable: false, max_draws}` entries.
    pub fn to_json(&self) -> String {
        self.to_json_value().to_string()
    }

    /// One entry's JSON object form — shared by the full dump
    /// (`to_json`), the journal pending queue, and the checkpoint
    /// journal's full-rewrite frames.
    fn entry_json(k: u64, v: &CacheEntry) -> Json {
        match v {
            CacheEntry::Mapped(v) => Json::obj(vec![
                ("key", Json::Str(format!("{k:016x}"))),
                ("mappable", Json::Bool(true)),
                ("energy_pj", Json::Num(v.energy_pj)),
                ("memory_energy_pj", Json::Num(v.memory_energy_pj)),
                ("cycles", Json::Num(v.cycles)),
                ("edp", Json::Num(v.edp)),
                ("valid_mappings", Json::Num(v.valid_mappings as f64)),
                ("breakdown", Json::arr_f64(&v.energy_breakdown_pj)),
                ("mac_energy_pj", Json::Num(v.mac_energy_pj)),
            ]),
            CacheEntry::Unmappable { max_draws } => Json::obj(vec![
                ("key", Json::Str(format!("{k:016x}"))),
                ("mappable", Json::Bool(false)),
                ("max_draws", Json::Num(*max_draws as f64)),
            ]),
        }
    }

    /// Every entry as its JSON object form, in shard order. The
    /// checkpoint journal writes these one frame per line.
    pub fn entries_json(&self) -> Vec<Json> {
        let mut entries = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let map = shard.read().unwrap();
            for (k, v) in map.iter() {
                entries.push(Self::entry_json(*k, v));
            }
        }
        entries
    }

    /// The dump as a [`Json`] value — lets `engine::checkpoint` embed
    /// the cache in a larger document without a serialize/parse round
    /// trip.
    pub fn to_json_value(&self) -> Json {
        Json::obj(vec![("entries", Json::Arr(self.entries_json()))])
    }

    /// Parse and insert one entry object (one element of a dump's
    /// `entries`, or one journal `insert` frame). Total on malformed
    /// input. Entries from before negative caching (no `mappable`
    /// field) load as mappable; negative entries without a `max_draws`
    /// field load with budget 0, i.e. any future probe re-searches.
    pub fn load_entry_json(&self, e: &Json) -> Result<(), String> {
        let key = u64::from_str_radix(e.get("key").as_str().ok_or("key")?, 16)
            .map_err(|_| "bad key")?;
        if matches!(e.get("mappable"), Json::Bool(false)) {
            let max_draws = e.get("max_draws").as_f64().unwrap_or(0.0) as u64;
            self.shard(key)
                .write()
                .unwrap()
                .insert(key, CacheEntry::Unmappable { max_draws });
            return Ok(());
        }
        let bd = e.get("breakdown").as_arr().ok_or("breakdown")?;
        if bd.len() != 3 {
            return Err("breakdown len".into());
        }
        self.shard(key).write().unwrap().insert(
            key,
            CacheEntry::Mapped(CachedEval {
                energy_pj: e.get("energy_pj").as_f64().ok_or("energy")?,
                memory_energy_pj: e.get("memory_energy_pj").as_f64().ok_or("mem")?,
                cycles: e.get("cycles").as_f64().ok_or("cycles")?,
                edp: e.get("edp").as_f64().ok_or("edp")?,
                valid_mappings: e.get("valid_mappings").as_f64().ok_or("valid")? as u64,
                energy_breakdown_pj: [
                    bd[0].as_f64().ok_or("bd0")?,
                    bd[1].as_f64().ok_or("bd1")?,
                    bd[2].as_f64().ok_or("bd2")?,
                ],
                mac_energy_pj: e.get("mac_energy_pj").as_f64().ok_or("mac")?,
            }),
        );
        Ok(())
    }

    /// Load entries from a JSON dump produced by `to_json`.
    pub fn load_json(&self, src: &str) -> Result<usize, String> {
        let v = parse(src)?;
        let entries = v.get("entries").as_arr().ok_or("missing entries")?;
        let mut n = 0;
        for e in entries {
            self.load_entry_json(e)?;
            n += 1;
        }
        Ok(n)
    }

    /// Persist to a file (best-effort convenience).
    pub fn save_file(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Load from a file if it exists; returns entries loaded. A missing
    /// file is a silent cold start, but an unreadable or corrupt one is
    /// surfaced as a Status-level `cache_load_failed` event — operators
    /// must be able to tell "cold start" from "cache file rejected".
    pub fn load_file(&self, path: &str) -> usize {
        let fail = |err: &str| {
            crate::obs::event_human(
                crate::obs::Level::Status,
                "cache_load_failed",
                vec![
                    ("path", Json::Str(path.into())),
                    ("error", Json::Str(err.into())),
                ],
                &format!("qmap: cache file {path} rejected ({err}); starting cold"),
            );
            0
        };
        match std::fs::read_to_string(path) {
            Ok(src) => match self.load_json(&src) {
                Ok(n) => n,
                Err(e) => fail(&e),
            },
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => 0,
            Err(e) => fail(&e.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;

    fn cfg() -> MapperConfig {
        MapperConfig {
            valid_target: 100,
            max_draws: 50_000,
            seed: 1,
            shards: 1,
        }
    }

    #[test]
    fn second_lookup_hits() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(8);
        let r1 = cache.evaluate(&a, &l, &q, &cfg()).unwrap();
        assert_eq!(cache.misses(), 1);
        let r2 = cache.evaluate(&a, &l, &q, &cfg()).unwrap();
        assert_eq!(cache.hits(), 1);
        assert_eq!(r1, r2);
    }

    #[test]
    fn different_quant_misses() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        cache.evaluate(&a, &l, &LayerQuant::uniform(8), &cfg()).unwrap();
        cache.evaluate(&a, &l, &LayerQuant::uniform(4), &cfg()).unwrap();
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.len(), 2);
    }

    /// A toy variant whose weight scratchpad holds zero words: every
    /// mapping violates capacity, so no workload can ever map.
    fn unmappable_arch() -> crate::arch::Arch {
        let mut a = toy();
        a.name = "toy-nospad".into();
        a.levels[0].capacity = crate::arch::Capacity::PerTensor([0, 64, 64]);
        a
    }

    #[test]
    fn unmappable_workload_is_negative_cached() {
        let cache = MapperCache::new();
        let a = unmappable_arch();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let tiny = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 500,
            seed: 5,
            shards: 1,
        };
        assert!(cache.evaluate(&a, &l, &LayerQuant::uniform(8), &tiny).is_none());
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
        // the second probe must NOT re-run the search
        assert!(cache.evaluate(&a, &l, &LayerQuant::uniform(8), &tiny).is_none());
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn larger_budget_retries_a_negative_entry() {
        // a failure recorded under a small draw budget must not poison
        // later probes that are willing to search harder
        let cache = MapperCache::new();
        let a = toy();
        // rare-but-possible validity: awkward primes on the toy arch
        let l = ConvLayer::conv("t", 97, 89, 1, 13, 1);
        let q = LayerQuant::uniform(8);
        let starved = MapperConfig {
            valid_target: 1,
            max_draws: 1, // one draw: essentially guaranteed to fail
            seed: 5,
            shards: 1,
        };
        assert!(cache.evaluate(&a, &l, &q, &starved).is_none());
        assert_eq!(cache.misses(), 1);
        // same budget: served from the negative entry
        assert!(cache.evaluate(&a, &l, &q, &starved).is_none());
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        // bigger budget: the cache re-searches instead of trusting the
        // starved failure (and this workload is findable with draws)
        let generous = MapperConfig {
            valid_target: 1,
            max_draws: 200_000,
            seed: 5,
            shards: 1,
        };
        let r = cache.evaluate(&a, &l, &q, &generous);
        assert_eq!(cache.misses(), 2, "negative entry must not be trusted");
        if let Some(e) = r {
            // once found, the mapped entry replaces the negative one
            assert!(e.edp > 0.0);
            assert!(cache.evaluate(&a, &l, &q, &starved).is_some());
        }
    }

    #[test]
    fn json_roundtrip() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(8);
        let r1 = cache.evaluate(&a, &l, &q, &cfg()).unwrap();

        let dump = cache.to_json();
        let cache2 = MapperCache::new();
        assert_eq!(cache2.load_json(&dump).unwrap(), 1);
        // the restored entry is served as a hit
        let r2 = cache2.evaluate(&a, &l, &q, &cfg()).unwrap();
        assert_eq!(cache2.hits(), 1);
        assert_eq!(r1, r2);
    }

    #[test]
    fn json_roundtrip_preserves_negative_entries() {
        let cache = MapperCache::new();
        let a = unmappable_arch();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let tiny = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 500,
            seed: 5,
            shards: 1,
        };
        assert!(cache.evaluate(&a, &l, &LayerQuant::uniform(8), &tiny).is_none());

        let dump = cache.to_json();
        assert!(dump.contains("\"mappable\":false"), "{dump}");
        let cache2 = MapperCache::new();
        assert_eq!(cache2.load_json(&dump).unwrap(), 1);
        assert!(cache2.evaluate(&a, &l, &LayerQuant::uniform(8), &tiny).is_none());
        assert_eq!(cache2.hits(), 1);
        assert_eq!(cache2.misses(), 0);
    }

    #[test]
    fn legacy_json_without_marker_loads() {
        // dumps from before negative caching carry no `mappable` field
        let legacy = "{\"entries\": [{\"key\": \"00000000000000aa\", \
            \"energy_pj\": 1.5, \"memory_energy_pj\": 1.0, \"cycles\": 2.0, \
            \"edp\": 3.0, \"valid_mappings\": 4, \"breakdown\": [0.5, 0.25, 0.25], \
            \"mac_energy_pj\": 0.5}]}";
        let cache = MapperCache::new();
        assert_eq!(cache.load_json(legacy).unwrap(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn breakdown_sums_to_memory_energy() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let r = cache
            .evaluate(&a, &l, &LayerQuant::uniform(8), &cfg())
            .unwrap();
        let s: f64 = r.energy_breakdown_pj.iter().sum();
        assert!((s - r.memory_energy_pj).abs() < 1e-6);
    }

    #[test]
    fn corrupt_json_rejected() {
        let cache = MapperCache::new();
        assert!(cache.load_json("{\"entries\": [{\"key\": \"zz\"}]}").is_err());
        assert!(cache.load_json("not json").is_err());
    }

    #[test]
    fn effective_draws_ranks_misses_and_stale_negatives() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(8);
        let c = cfg();
        // unknown workload: a fresh miss costs the full budget
        assert_eq!(cache.effective_draws(&a, &l, &q, &c), c.max_draws);
        assert_eq!(cache.hits() + cache.misses(), 0, "a peek must not count");
        // mapped workload: cost 0 (sinks to the end of the schedule)
        cache.evaluate(&a, &l, &q, &c).unwrap();
        assert_eq!(cache.effective_draws(&a, &l, &q, &c), 0);
        // negative entry at a small budget: free at that budget,
        // ranked above a fresh miss at a larger one
        let ua = unmappable_arch();
        let starved = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 500,
            seed: 5,
            shards: 1,
        };
        assert!(cache.evaluate(&ua, &l, &q, &starved).is_none());
        assert_eq!(cache.effective_draws(&ua, &l, &q, &starved), 0);
        let bigger = MapperConfig {
            max_draws: 5_000,
            ..starved
        };
        let hard = cache.effective_draws(&ua, &l, &q, &bigger);
        assert!(hard > bigger.max_draws, "stale negative must outrank a fresh miss");
    }

    #[test]
    fn journal_queue_captures_only_live_inserts() {
        let cache = MapperCache::new();
        let a = toy();
        let c = cfg();
        // before enabling: inserts are not queued
        cache.evaluate(&a, &ConvLayer::conv("t", 4, 8, 3, 8, 1), &LayerQuant::uniform(8), &c);
        assert!(cache.drain_journal().is_empty());
        cache.enable_journal();
        assert!(cache.journal_enabled());
        // a live search lands in the queue once
        cache.evaluate(&a, &ConvLayer::conv("t", 4, 16, 3, 8, 1), &LayerQuant::uniform(8), &c);
        let q1 = cache.drain_journal();
        assert_eq!(q1.len(), 1);
        assert!(matches!(q1[0].get("mappable"), Json::Bool(true)));
        // draining empties the queue; a cache hit queues nothing
        cache.evaluate(&a, &ConvLayer::conv("t", 4, 16, 3, 8, 1), &LayerQuant::uniform(8), &c);
        assert!(cache.drain_journal().is_empty());
        // replayed entries (load path) are not re-queued
        let dump = cache.to_json();
        let other = MapperCache::new();
        other.enable_journal();
        other.load_json(&dump).unwrap();
        assert!(other.drain_journal().is_empty());
        // and a queued entry round-trips through load_entry_json
        cache.evaluate(&a, &ConvLayer::conv("t", 4, 32, 3, 8, 1), &LayerQuant::uniform(8), &c);
        let q2 = cache.drain_journal();
        assert_eq!(q2.len(), 1);
        let fresh = MapperCache::new();
        fresh.load_entry_json(&q2[0]).unwrap();
        assert_eq!(fresh.len(), 1);
    }

    #[test]
    fn workload_key_paths_match_recomputing_paths() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(5); // non-canonical representative
        let c = cfg();
        let wk = WorkloadKey::of(&a, &l, &q);
        // the key canonicalizes internally: equivalent settings agree
        assert_eq!(wk, WorkloadKey::of(&a, &l, &q.canonical(a.word_bits, a.bit_packing)));
        // key-based and recomputing paths see the same cache state
        assert_eq!(cache.effective_draws_key(wk, &c), cache.effective_draws(&a, &l, &q, &c));
        assert!(cache.probe_key(wk, &c).is_none());
        let r = cache.evaluate_key(wk, &a, &l, &q, &c).unwrap();
        assert_eq!(cache.probe(&a, &l, &q, &c), Some(Some(r)));
        assert_eq!(cache.probe_key(wk, &c), Some(Some(r)));
        assert_eq!(cache.effective_draws_key(wk, &c), 0);
        assert_eq!(cache.misses(), 1);
    }

    fn tmp_store_dir(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("qmap_cache_{}_{name}", std::process::id()))
    }

    #[test]
    fn backing_store_promotes_and_appends_bit_identically() {
        let dir = tmp_store_dir("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        let a = toy();
        let c = cfg();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(8);

        // cold run with a store attached: the live insert is appended
        let cache = MapperCache::new();
        cache.set_backing(crate::mapper::store::open_search_store(dirs, &a, &c).unwrap());
        let r1 = cache.evaluate(&a, &l, &q, &c).unwrap();
        assert_eq!(cache.backing().unwrap().appends(), 1);
        assert_eq!(cache.misses(), 1);

        // a fresh "process" (fresh cache, reopened store) is served the
        // bit-identical entry without re-searching
        let cache2 = MapperCache::new();
        cache2.set_backing(crate::mapper::store::open_search_store(dirs, &a, &c).unwrap());
        assert_eq!(cache2.backing().unwrap().len(), 1);
        let hit = cache2.probe(&a, &l, &q, &c).expect("store must serve the probe");
        assert_eq!(hit, Some(r1), "warm entry must be hex-exact");
        assert_eq!((cache2.hits(), cache2.misses()), (1, 0));
        // promoted into memory, and promotion did not re-append
        assert_eq!(cache2.len(), 1);
        assert_eq!(cache2.probe(&a, &l, &q, &c), Some(Some(r1)));
        assert_eq!(cache2.backing().unwrap().appends(), 0);
        // an unknown workload is still a miss
        assert!(cache2.probe(&a, &ConvLayer::conv("t", 4, 16, 3, 8, 1), &q, &c).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn backing_store_negative_entries_respect_budgets() {
        let dir = tmp_store_dir("negative");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        let a = unmappable_arch();
        let tiny = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 500,
            seed: 5,
            shards: 1,
        };
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(8);
        let cache = MapperCache::new();
        cache.set_backing(crate::mapper::store::open_search_store(dirs, &a, &tiny).unwrap());
        assert!(cache.evaluate(&a, &l, &q, &tiny).is_none());
        assert_eq!(cache.backing().unwrap().appends(), 1);

        let cache2 = MapperCache::new();
        cache2.set_backing(crate::mapper::store::open_search_store(dirs, &a, &tiny).unwrap());
        // at the recorded budget the stored negative is decisive
        assert_eq!(cache2.probe(&a, &l, &q, &tiny), Some(None));
        assert_eq!(cache2.hits(), 1);
        // at a larger budget it is stale: a true miss, re-search required
        let bigger = MapperConfig { max_draws: 5_000, ..tiny };
        let cache3 = MapperCache::new();
        cache3.set_backing(crate::mapper::store::open_search_store(dirs, &a, &tiny).unwrap());
        assert!(cache3.probe(&a, &l, &q, &bigger).is_none());
        assert_eq!(cache3.hits(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_captures_store_promotions() {
        // checkpoints must stay self-contained: an entry served from
        // the store lands in the journal queue exactly like a live
        // insert would
        let dir = tmp_store_dir("journal");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        let a = toy();
        let c = cfg();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(8);
        let cold = MapperCache::new();
        cold.set_backing(crate::mapper::store::open_search_store(dirs, &a, &c).unwrap());
        cold.evaluate(&a, &l, &q, &c).unwrap();

        let warm = MapperCache::new();
        warm.enable_journal();
        warm.set_backing(crate::mapper::store::open_search_store(dirs, &a, &c).unwrap());
        warm.probe(&a, &l, &q, &c).expect("warm probe");
        let queued = warm.drain_journal();
        assert_eq!(queued.len(), 1);
        let replay = MapperCache::new();
        replay.load_entry_json(&queued[0]).unwrap();
        assert_eq!(replay.probe(&a, &l, &q, &c), warm.probe(&a, &l, &q, &c));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn striping_spreads_entries_without_losing_any() {
        let cache = MapperCache::new();
        let a = toy();
        // several distinct workloads land in (usually) several stripes
        for k in [4u64, 8, 16, 32] {
            for q in [2u8, 4, 8] {
                let l = ConvLayer::conv("t", 4, k, 3, 8, 1);
                cache.evaluate(&a, &l, &LayerQuant::uniform(q), &cfg());
            }
        }
        assert_eq!(cache.len(), 12);
        assert_eq!(cache.misses(), 12);
    }
}
