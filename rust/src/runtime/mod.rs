//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path. Python never runs at request time — the
//! artifacts in `artifacts/` are produced once by `make artifacts`
//! (`python/compile/aot.py`) and this module is the only consumer.
//!
//! Execution goes through the [`backend::PjrtBackend`] trait. The
//! build ships the deterministic [`backend::StubBackend`] (pure Rust,
//! no `xla` bindings), so `--features pjrt` compiles and its tests run
//! offline; a real PJRT client implements the same trait when the
//! `xla_extension` toolchain is available. HLO *text* remains the
//! interchange format because jax>=0.5 serialized protos use 64-bit
//! instruction ids that the vendored XLA rejects (see
//! /opt/xla-example/README.md).

pub mod backend;
pub mod qat;

use crate::util::json::{parse, Json};
use backend::{ArtifactKind, Operand, PjrtBackend, PjrtExecutable};
use std::path::{Path, PathBuf};

/// Parsed `model_meta.json` manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub model: String,
    pub num_layers: usize,
    pub param_size: usize,
    pub batch: usize,
    pub img: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub use_pallas: bool,
}

impl ModelMeta {
    pub fn from_json(src: &str) -> Result<Self, String> {
        let v = parse(src).map_err(|e| format!("model_meta.json: {e}"))?;
        let need = |k: &str| -> Result<usize, String> {
            v.get(k)
                .as_usize()
                .ok_or_else(|| format!("manifest missing '{k}'"))
        };
        Ok(ModelMeta {
            model: v
                .get("model")
                .as_str()
                .ok_or("manifest missing 'model'")?
                .to_string(),
            num_layers: need("num_layers")?,
            param_size: need("param_size")?,
            batch: need("batch")?,
            img: need("img")?,
            in_ch: need("in_ch")?,
            num_classes: need("num_classes")?,
            use_pallas: matches!(v.get("use_pallas"), Json::Bool(true)),
        })
    }
}

/// A compiled artifact bundle: PJRT backend + train/eval executables +
/// initial parameters.
pub struct Runtime {
    backend: Box<dyn PjrtBackend>,
    train: Box<dyn PjrtExecutable>,
    eval: Box<dyn PjrtExecutable>,
    pub meta: ModelMeta,
    pub init_params: Vec<f32>,
}

impl Runtime {
    /// Load `model_meta.json`, `{train,eval}_step.hlo.txt` and
    /// `params_init.bin` from an artifact directory, on the default
    /// backend ([`backend::default_backend`]).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        Self::load_with(backend::default_backend(), dir)
    }

    /// [`Runtime::load`] on an explicit backend (tests, or a real PJRT
    /// client built against the `xla` bindings).
    pub fn load_with(backend: Box<dyn PjrtBackend>, dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref();
        let meta_src = std::fs::read_to_string(dir.join("model_meta.json")).map_err(|e| {
            format!(
                "reading {}/model_meta.json (run `make artifacts`): {e}",
                dir.display()
            )
        })?;
        let meta = ModelMeta::from_json(&meta_src)?;

        let compile = |name: &str, kind: ArtifactKind| -> Result<Box<dyn PjrtExecutable>, String> {
            let path = dir.join(name);
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("reading {}: {e}", path.display()))?;
            backend
                .compile_hlo(&text, kind)
                .map_err(|e| format!("compiling {}: {e}", path.display()))
        };
        let train = compile("train_step.hlo.txt", ArtifactKind::TrainStep)?;
        let eval = compile("eval_step.hlo.txt", ArtifactKind::EvalStep)?;

        let raw = std::fs::read(dir.join("params_init.bin"))
            .map_err(|e| format!("reading params_init.bin: {e}"))?;
        if raw.len() != meta.param_size * 4 {
            return Err(format!(
                "params_init.bin: expected {} bytes, got {}",
                meta.param_size * 4,
                raw.len()
            ));
        }
        let init_params: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        Ok(Runtime {
            backend,
            train,
            eval,
            meta,
            init_params,
        })
    }

    pub fn platform(&self) -> String {
        self.backend.platform_name()
    }

    /// One SGD step. `params` is updated in place; returns the
    /// post-step loss on the same batch (an extra forward pass — the
    /// train artifact returns only `new_params`, see aot.py).
    pub fn train_step(
        &self,
        params: &mut Vec<f32>,
        x: &[f32],
        y: &[i32],
        qa: &[f32],
        qw: &[f32],
        lr: f32,
    ) -> Result<f32, String> {
        self.check_shapes(params, x, y, qa, qw)?;
        let mut sess = self.train_session(params)?;
        sess.step(x, y, qa, qw, lr)?;
        let (_, loss) = sess.eval(x, y, qa, qw)?;
        *params = sess.params_to_host()?;
        Ok(loss)
    }

    /// Start a training session from a host checkpoint. (With a real
    /// device backend the session is where parameters stay
    /// device-resident between steps; the trait keeps that invisible
    /// to callers.)
    pub fn train_session(&self, params: &[f32]) -> Result<TrainSession<'_>, String> {
        if params.len() != self.meta.param_size {
            return Err(format!(
                "params: expected {} values, got {}",
                self.meta.param_size,
                params.len()
            ));
        }
        Ok(TrainSession {
            rt: self,
            params: params.to_vec(),
        })
    }

    /// Evaluate one batch. Returns (correct_count, mean_loss).
    pub fn eval_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        qa: &[f32],
        qw: &[f32],
    ) -> Result<(f32, f32), String> {
        self.check_shapes(params, x, y, qa, qw)?;
        let outs = self.eval.execute(&[
            Operand::F32(params),
            Operand::F32(x),
            Operand::I32(y),
            Operand::F32(qa),
            Operand::F32(qw),
        ])?;
        Self::unpack_eval(&outs)
    }

    fn unpack_eval(outs: &[Vec<f32>]) -> Result<(f32, f32), String> {
        // the eval artifact returns a (correct, loss) pair
        if outs.len() != 2 || outs[0].is_empty() || outs[1].is_empty() {
            return Err(format!(
                "eval_step: expected (correct, loss) outputs, got {} buffers",
                outs.len()
            ));
        }
        Ok((outs[0][0], outs[1][0]))
    }

    fn check_shapes(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        qa: &[f32],
        qw: &[f32],
    ) -> Result<(), String> {
        let m = &self.meta;
        if params.len() != m.param_size {
            return Err(format!(
                "params: expected {} values, got {}",
                m.param_size,
                params.len()
            ));
        }
        let want_x = m.batch * m.img * m.img * m.in_ch;
        if x.len() != want_x {
            return Err(format!("x: expected {} values, got {}", want_x, x.len()));
        }
        if y.len() != m.batch {
            return Err(format!("y: expected {} labels, got {}", m.batch, y.len()));
        }
        if qa.len() != m.num_layers || qw.len() != m.num_layers {
            return Err(format!(
                "qa/qw: expected {} entries, got {}/{}",
                m.num_layers,
                qa.len(),
                qw.len()
            ));
        }
        Ok(())
    }
}

/// A training loop over the session's parameter state. Each
/// [`TrainSession::step`] feeds the previous step's `new_params` output
/// straight back into the next dispatch; only batches (and the scalar
/// loss) cross the caller boundary.
pub struct TrainSession<'rt> {
    rt: &'rt Runtime,
    params: Vec<f32>,
}

impl TrainSession<'_> {
    /// One SGD step. The updated parameters replace the session's
    /// state. (The train artifact intentionally has no loss output —
    /// use [`TrainSession::eval`] to sample a loss curve.)
    pub fn step(
        &mut self,
        x: &[f32],
        y: &[i32],
        qa: &[f32],
        qw: &[f32],
        lr: f32,
    ) -> Result<(), String> {
        self.rt.check_shapes(&self.params, x, y, qa, qw)?;
        let outs = self.rt.train.execute(&[
            Operand::F32(&self.params),
            Operand::F32(x),
            Operand::I32(y),
            Operand::F32(qa),
            Operand::F32(qw),
            Operand::Scalar(lr),
        ])?;
        let new_params = outs
            .into_iter()
            .next()
            .ok_or("train_step: expected 1 output (new_params)")?;
        if new_params.len() != self.params.len() {
            return Err(format!(
                "train_step: new_params has {} values, expected {}",
                new_params.len(),
                self.params.len()
            ));
        }
        self.params = new_params;
        Ok(())
    }

    /// Drain any in-flight work (a no-op for host-side backends; kept
    /// so device-resident implementations have their sync point).
    pub fn sync(&mut self) -> Result<(), String> {
        Ok(())
    }

    /// Evaluate a batch against the session's current parameters.
    pub fn eval(&mut self, x: &[f32], y: &[i32], qa: &[f32], qw: &[f32]) -> Result<(f32, f32), String> {
        self.rt.eval_step(&self.params, x, y, qa, qw)
    }

    /// Copy the current parameters back to the caller.
    pub fn params_to_host(&mut self) -> Result<Vec<f32>, String> {
        Ok(self.params.clone())
    }
}

/// Locate the repo's artifact directory: `$QMAP_ARTIFACTS` or
/// `artifacts/` relative to the workspace root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("QMAP_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Write a minimal, self-consistent artifact bundle for the stub
/// backend: the manifest, two stub HLO files, and a deterministic
/// `params_init.bin`. Lets `runtime_integration` (and CI) exercise the
/// whole runtime stack without `make artifacts`' Python toolchain; the
/// real artifacts, when present, take precedence.
pub fn write_stub_artifacts(dir: impl AsRef<Path>) -> Result<(), String> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let meta = r#"{"model":"stub_mobilenet_v1","num_layers":28,"param_size":1792,"batch":8,"img":32,"in_ch":3,"num_classes":10,"use_pallas":false}"#;
    let write = |name: &str, bytes: &[u8]| -> Result<(), String> {
        let p = dir.join(name);
        std::fs::write(&p, bytes).map_err(|e| format!("{}: {e}", p.display()))
    };
    write("model_meta.json", meta.as_bytes())?;
    let hlo = "// stub HLO artifact: executed by runtime::backend::StubBackend\n";
    write("train_step.hlo.txt", hlo.as_bytes())?;
    write("eval_step.hlo.txt", hlo.as_bytes())?;
    // deterministic initial params in [-0.4, 0.4] (same SplitMix64 the
    // stub's target uses a different seed of)
    let mut params = Vec::with_capacity(1792 * 4);
    for i in 0..1792u64 {
        let mut z = (i ^ 0x1217_A9A5).wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        let v = ((z >> 40) as f32 / (1u64 << 24) as f32 - 0.5) * 0.8;
        params.extend_from_slice(&v.to_le_bytes());
    }
    write("params_init.bin", &params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let src = r#"{"model":"scaled_mobilenet_v1","num_layers":28,
            "param_size":100,"batch":32,"img":32,"in_ch":3,
            "num_classes":10,"use_pallas":true}"#;
        let m = ModelMeta::from_json(src).unwrap();
        assert_eq!(m.num_layers, 28);
        assert_eq!(m.batch, 32);
        assert!(m.use_pallas);
    }

    #[test]
    fn manifest_missing_field_errors() {
        assert!(ModelMeta::from_json("{}").is_err());
        assert!(ModelMeta::from_json("not json").is_err());
    }

    #[test]
    fn load_missing_dir_fails_with_hint() {
        match Runtime::load("/nonexistent/path") {
            Ok(_) => panic!("expected load failure"),
            Err(err) => assert!(err.contains("make artifacts")),
        }
    }

    #[test]
    fn stub_artifacts_roundtrip_through_load() {
        let mut dir = std::env::temp_dir();
        dir.push(format!("qmap_stub_art_{}", std::process::id()));
        write_stub_artifacts(&dir).unwrap();
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.meta.num_layers, 28);
        assert_eq!(rt.init_params.len(), rt.meta.param_size);
        assert_eq!(rt.platform(), "stub-cpu");
        let _ = std::fs::remove_dir_all(&dir);
    }

    // Full runtime execution tests live in
    // rust/tests/runtime_integration.rs (they generate stub artifacts
    // when `make artifacts` has not run).
}
