"""Pure-jnp oracle for the Pallas fake-quant matmul kernel.

This is the CORE correctness reference: ``python/tests/test_kernel.py``
sweeps shapes and bit-widths (hypothesis) asserting the Pallas kernel
matches this implementation to float tolerance.
"""

import jax
import jax.numpy as jnp

from ..quantize import quant_dequant


def ref_qdwconv(
    x: jax.Array, w: jax.Array, qa_bits: jax.Array, qw_bits: jax.Array, stride: int = 1
) -> jax.Array:
    """Reference fake-quant depthwise conv ('SAME' padding).

    x: [B, H, W, C]; w: [R, S, C]; quantized per-tensor asymmetric.
    """
    xq = quant_dequant(x, qa_bits)
    wq = quant_dequant(w, qw_bits)
    c = x.shape[-1]
    return jax.lax.conv_general_dilated(
        xq,
        wq[:, :, None, :],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def ref_qmatmul(
    x: jax.Array, w: jax.Array, qa_bits: jax.Array, qw_bits: jax.Array
) -> jax.Array:
    """Reference: ``fq(x) @ fq(w)`` with per-tensor asymmetric fake quant.

    x: [M, K] activations, quantized to ``qa_bits``.
    w: [K, N] weights, quantized to ``qw_bits``.
    Accumulation in f32.
    """
    xq = quant_dequant(x, qa_bits)
    wq = quant_dequant(w, qw_bits)
    return jnp.matmul(xq, wq, preferred_element_type=jnp.float32)
