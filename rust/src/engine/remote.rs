//! Distributed shard execution over the engine seam.
//!
//! The ROADMAP's multi-host search, built on the invariants PR 2 left
//! in place: a [`ShardSpec`] is position-independent (its seed is
//! derived from the config and workload, never from where it runs) and
//! [`merge_shards`](mapper::merge_shards) reduces in shard-index
//! order. So a remote worker can execute the same specs a local pool
//! worker would, ship the [`ShardOutcome`]s back over
//! [`proto`](super::proto) frames, and the driver merges a Pareto
//! front bit-identical to single-host serial execution — for any
//! worker set, disconnect order, or duplicate delivery.
//!
//! Roles:
//!
//! * [`serve`] — the worker side (`qmap worker --listen ADDR`): accept
//!   connections, execute `batch` messages against a locally rebuilt
//!   `MapSpace`/`LayerContext`, stream `outcome`s back. Stateless
//!   across batches; safe to kill at any time.
//! * [`BatchLedger`] — the driver-side collection point for one
//!   batch's outcomes: keyed by shard index, idempotent under
//!   duplicate delivery, indifferent to arrival order, and able to say
//!   exactly which shards a lost worker still owed.
//! * [`eval_jobs`] — the distributed scheduler behind
//!   `engine::driver::evaluate_genomes`: remote connections and the
//!   local pool race a single claim counter over the generation's
//!   cache-miss jobs (priority-ordered, largest effective draw budget
//!   first), each connection pipelining a window of batches
//!   (`Engine::pipeline_depth`) so workers never stall a round-trip
//!   between batches; a lost worker's unacknowledged specs are
//!   re-injected into the local pool. Shards are idempotent, so fault
//!   tolerance is re-execution — nothing else. Workers additionally
//!   keep a per-search shard-outcome cache, so re-sent specs cost a
//!   lookup instead of a search.
//!
//! Fault injection for the stateful test suite lives in
//! [`WorkerOptions`]: a worker can be told to drop the connection
//! mid-stream, deliver every outcome twice, or stream outcomes in
//! reverse order. The driver must produce bit-identical results under
//! all of them — that is the property `tests/distributed_stateful.rs`
//! pins.

use super::driver::EvalJob;
use super::proto;
use super::Engine;
use crate::arch::parser::{parse_arch, render_arch};
use crate::arch::Arch;
use crate::mapper::cache::{MapperCache, WorkloadKey};
use crate::mapper::{self, MapperConfig, MapperResult, ShardOutcome, ShardSpec};
use crate::mapping::mapspace::MapSpace;
use crate::mapping::LayerContext;
use crate::obs::{self, metrics, ring};
use crate::quant::LayerQuant;
use crate::util::json::Json;
use crate::workload::ConvLayer;
use rustc_hash::FxHashMap;
use std::collections::VecDeque;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Worker behavior knobs. The defaults are a well-behaved worker; the
/// fault options let the stateful tests stand up adversarial workers
/// on a loopback socket and assert that the driver's results do not
/// change by a single bit.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerOptions {
    /// Close the connection (without `done`) after this many `outcome`
    /// frames have been sent across the connection's lifetime —
    /// simulates a worker lost mid-stream.
    pub drop_after: Option<usize>,
    /// Send every `outcome` frame twice — simulates duplicate
    /// delivery. The driver's ledger must treat outcomes as idempotent.
    pub duplicate_outcomes: bool,
    /// Stream a batch's outcomes in reverse shard order — simulates
    /// reordering. The driver must merge by shard index, not arrival.
    pub reverse_outcomes: bool,
    /// Skip the per-search shard-outcome cache: every spec re-runs the
    /// mapper. For measurement (the bench's pipelining rows must not
    /// be contaminated by cache hits — the cache is process-global, so
    /// a second in-process worker would otherwise inherit the first
    /// run's outcomes) and for memory-constrained deployments.
    pub disable_outcome_cache: bool,
    /// Cooperative shutdown switch (SIGTERM / stdin-close handling in
    /// `qmap worker`): once set, [`serve`] stops accepting new
    /// connections, every connection finishes its in-flight batch
    /// (outcomes and `done` fully flushed) and closes instead of
    /// reading another, and `serve` returns once nothing is executing.
    /// `&'static` keeps the options `Copy`; the CLI leaks one flag per
    /// process, tests leak one per case.
    pub shutdown: Option<&'static AtomicBool>,
}

/// Driver-side network timeout (connect + per-read). Workers stream
/// each outcome as soon as its shard finishes, so this bounds one
/// shard's compute (not a whole batch); still, leave headroom for a
/// full-profile single-shard search on a slow machine, and override
/// with `QMAP_WORKER_TIMEOUT_MS` when a deployment knows better. On
/// expiry the worker is treated as lost and its specs re-run locally.
pub fn worker_timeout() -> Duration {
    let ms = std::env::var("QMAP_WORKER_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(30_000);
    Duration::from_millis(ms.max(1))
}

// ------------------------------------------------------------ worker

/// Serve batches forever on `listener`, one thread per connection.
/// Every connection failure is contained, and so are transient
/// `accept` errors (ECONNABORTED from a driver that reset before the
/// accept, EMFILE under fd pressure) — a fleet worker documented as
/// "kill/restart freely" must not die because one peer misbehaved.
/// Only a long unbroken run of accept failures (listener genuinely
/// dead) ends the loop.
pub fn serve(listener: TcpListener, opts: WorkerOptions) {
    let mut consecutive_failures = 0u32;
    // batches currently executing across all connections — the set a
    // graceful shutdown waits for
    let executing = Arc::new(AtomicUsize::new(0));
    if opts.shutdown.is_some() {
        // poll the flag between accepts (std has no accept timeout)
        if let Err(e) = listener.set_nonblocking(true) {
            eprintln!("qmap worker: set_nonblocking: {e} (shutdown flag will not be polled)");
        }
    }
    loop {
        if let Some(flag) = opts.shutdown {
            if flag.load(Ordering::SeqCst) {
                // stop accepting; let in-flight batches stream out. A
                // batch already sitting in a connection's socket buffer
                // may not have marked itself executing yet, so require
                // two quiet readings a grace period apart before
                // declaring the worker drained.
                loop {
                    while executing.load(Ordering::SeqCst) > 0 {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    std::thread::sleep(Duration::from_millis(150));
                    if executing.load(Ordering::SeqCst) == 0 {
                        return;
                    }
                }
            }
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                consecutive_failures = 0;
                // the listener may be non-blocking (shutdown polling);
                // the per-connection socket must not be
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let executing = Arc::clone(&executing);
                let spawned = std::thread::Builder::new()
                    .name("qmap-worker-conn".into())
                    .spawn(move || {
                        if let Err(e) = serve_conn(stream, opts, &executing) {
                            eprintln!("qmap worker: connection {peer}: {e}");
                        }
                    });
                if let Err(e) = spawned {
                    eprintln!("qmap worker: spawn for {peer}: {e}");
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => {
                consecutive_failures += 1;
                eprintln!("qmap worker: accept: {e} ({consecutive_failures} in a row)");
                if consecutive_failures >= 128 {
                    eprintln!("qmap worker: listener looks dead, giving up");
                    return;
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Bind an OS-chosen loopback port and serve on a background thread;
/// returns the `host:port` to hand to a driver. Used by the stateful
/// tests, the CI smoke, and the bench's distributed row.
pub fn spawn_local_worker(opts: WorkerOptions) -> Result<String, String> {
    let listener =
        TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bind 127.0.0.1:0: {e}"))?;
    let addr = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?.to_string();
    std::thread::Builder::new()
        .name("qmap-worker".into())
        .spawn(move || serve(listener, opts))
        .map_err(|e| format!("spawn worker thread: {e}"))?;
    Ok(addr)
}

/// How long a worker connection may sit idle (no incoming batch)
/// before the worker drops it. Drivers connect per generation and
/// never idle this long; what this bounds is the *half-open* case — a
/// driver host that lost power or a silently dropped flow would
/// otherwise pin one connection thread and one fd in `read_exact`
/// forever, and a long-lived fleet worker would leak its way to
/// EMFILE.
const CONN_IDLE_TIMEOUT: Duration = Duration::from_secs(600);

/// One worker connection: hello, then execute batches until the peer
/// hangs up. A malformed batch gets an `error` reply, a panic inside
/// the mapper is caught and reported the same way — network input must
/// never take the worker down. When the shutdown flag is raised, the
/// in-flight batch still streams to completion, then the connection
/// closes instead of reading the next message.
fn serve_conn(
    stream: TcpStream,
    opts: WorkerOptions,
    executing: &AtomicUsize,
) -> Result<(), String> {
    stream.set_nodelay(true).ok();
    // an expired idle timeout surfaces as a read_msg error below, and
    // the connection closes cleanly (the driver reconnects per
    // generation anyway); the write timeout bounds streaming outcomes
    // to a driver that stopped reading
    stream.set_read_timeout(Some(CONN_IDLE_TIMEOUT)).ok();
    stream.set_write_timeout(Some(CONN_IDLE_TIMEOUT)).ok();
    let mut reader =
        BufReader::new(stream.try_clone().map_err(|e| format!("clone stream: {e}"))?);
    let mut writer = BufWriter::new(stream);
    proto::write_msg(&mut writer, &proto::hello())?;
    let mut sent_outcomes = 0usize;
    loop {
        let msg = match proto::read_msg(&mut reader) {
            Ok(m) => m,
            // peer closed or sent garbage; either way this connection
            // is over (the driver re-runs anything unacknowledged)
            Err(_) => return Ok(()),
        };
        let ty = match proto::msg_type(&msg) {
            Ok(t) => t.to_string(),
            Err(e) => {
                proto::write_msg(&mut writer, &proto::error(&e))?;
                continue;
            }
        };
        match ty.as_str() {
            "batch" => {
                executing.fetch_add(1, Ordering::SeqCst);
                let end = catch_unwind(AssertUnwindSafe(|| {
                    handle_batch(&msg, &mut writer, opts, &mut sent_outcomes)
                }));
                executing.fetch_sub(1, Ordering::SeqCst);
                match end {
                    Ok(Ok(BatchEnd::Done)) => {}
                    Ok(Ok(BatchEnd::Drop)) => return Ok(()), // injected fault
                    Ok(Err(e)) => return Err(e), // transport gone: close
                    Err(_) => {
                        proto::write_msg(
                            &mut writer,
                            &proto::error("worker panicked executing the batch"),
                        )?;
                    }
                }
                if let Some(flag) = opts.shutdown {
                    if flag.load(Ordering::SeqCst) {
                        // in-flight batch flushed above; bow out (the
                        // driver re-runs anything it had not yet sent)
                        return Ok(());
                    }
                }
            }
            "hello" => {}
            other => {
                proto::write_msg(
                    &mut writer,
                    &proto::error(&format!("unexpected message type '{other}'")),
                )?;
            }
        }
    }
}

/// How a batch ended on the worker side.
enum BatchEnd {
    /// Streamed to completion (or answered with an `error` reply).
    Done,
    /// The injected drop fault fired: the caller closes the connection.
    Drop,
}

// ------------------------------------------------------ worker cache

/// How many distinct searches the worker keeps shard outcomes for
/// (least-recently-*active* eviction: every `put` refreshes its
/// search's position, so a long-lived search streaming batches is
/// never evicted by newer one-shot searches), and how many outcomes
/// one search may hold before its map is reset. Both bounds exist
/// purely to cap memory on a long-lived fleet worker serving many
/// drivers.
const WORKER_CACHE_SEARCHES: usize = 4;
const WORKER_CACHE_ENTRIES: usize = 1 << 16;

/// The worker-side shard-outcome cache: one map per search identity
/// (the `search` field of `batch` messages), keyed by the full shard
/// identity hash. Sound because [`mapper::run_shard`] is a pure
/// function of `(arch, layer, quant, spec)` — a cached outcome is
/// byte-for-byte what a fresh run would produce — so repeated specs
/// across batches and generations (driver restarts without their cache
/// file, several drivers sharing a fleet, re-sent batches after a lost
/// connection) hit locally instead of re-searching. Shared by every
/// connection of the process.
struct WorkerCache {
    searches: Mutex<(VecDeque<u64>, FxHashMap<u64, FxHashMap<u64, ShardOutcome>>)>,
}

impl WorkerCache {
    fn get(&self, search: u64, key: u64) -> Option<ShardOutcome> {
        let g = self.searches.lock().unwrap();
        g.1.get(&search).and_then(|m| m.get(&key)).cloned()
    }

    fn put(&self, search: u64, key: u64, out: &ShardOutcome) {
        let mut g = self.searches.lock().unwrap();
        let (order, maps) = &mut *g;
        if maps.contains_key(&search) {
            // re-registration: refresh recency instead of pushing a
            // duplicate `order` entry — a duplicate would both leak
            // the queue and let this search's own earlier entry evict
            // another search's map on overflow
            if let Some(pos) = order.iter().position(|&s| s == search) {
                order.remove(pos);
            }
            order.push_back(search);
        } else {
            order.push_back(search);
            while order.len() > WORKER_CACHE_SEARCHES {
                if let Some(old) = order.pop_front() {
                    maps.remove(&old);
                }
            }
            maps.insert(search, FxHashMap::default());
        }
        let m = maps.get_mut(&search).expect("inserted above");
        if m.len() >= WORKER_CACHE_ENTRIES {
            m.clear();
        }
        m.insert(key, out.clone());
    }
}

fn worker_cache() -> &'static WorkerCache {
    static CACHE: OnceLock<WorkerCache> = OnceLock::new();
    CACHE.get_or_init(|| WorkerCache {
        searches: Mutex::new((VecDeque::new(), FxHashMap::default())),
    })
}

/// The full identity of one shard's work: the arch source text (the
/// canonical `render_arch` form the driver sends), the workload hash,
/// and every `ShardSpec` field. Everything `run_shard`'s result
/// depends on is folded in, so equal keys imply bit-identical
/// outcomes.
fn shard_cache_key(arch_src: &str, layer: &ConvLayer, q: &LayerQuant, spec: &ShardSpec) -> u64 {
    let mut h = crate::util::Fnv1a::new();
    h.write(arch_src.as_bytes());
    h.write_u64(mapper::workload_hash(layer, q));
    h.write_u64(spec.seed);
    h.write_u64(spec.valid_target);
    h.write_u64(spec.max_draws);
    h.finish()
}

// ----------------------------------------- persistent worker store

/// Directory for the worker's persistent outcome store (`qmap worker
/// --cache-dir DIR` / `QMAP_CACHE_DIR`). Set once at startup; unset =
/// in-memory caching only.
static WORKER_STORE_DIR: OnceLock<String> = OnceLock::new();

/// Point the worker at a persistent outcome-store directory. Outcomes
/// are persisted in the same binary format as the search-side store
/// (`mapper::store`), one file per arch, so worker restarts and whole
/// fleets warm-start instead of re-searching. Call before [`serve`];
/// later calls are ignored.
pub fn set_worker_store_dir(dir: String) {
    let _ = WORKER_STORE_DIR.set(dir);
}

/// Lazily opened per-arch stores, keyed by FNV of the canonical arch
/// text the driver sends (which pins the record layout too — payload
/// width is a function of the arch's level count). A failed open is
/// remembered as `None` so a bad path is reported once, not per batch:
/// the worker proceeds cold — the store is a cache tier, never a
/// correctness dependency, so unlike the search side an unusable file
/// must not kill a fleet worker.
fn worker_store(arch_src: &str, levels: usize) -> Option<Arc<mapper::store::CacheStore>> {
    let dir = WORKER_STORE_DIR.get()?;
    static STORES: OnceLock<Mutex<FxHashMap<u64, Option<Arc<mapper::store::CacheStore>>>>> =
        OnceLock::new();
    let stores = STORES.get_or_init(|| Mutex::new(FxHashMap::default()));
    let identity = crate::util::fnv1a(arch_src.as_bytes());
    let mut g = stores.lock().unwrap();
    g.entry(identity)
        .or_insert_with(|| {
            let open = || -> Result<Arc<mapper::store::CacheStore>, mapper::store::StoreError> {
                std::fs::create_dir_all(dir)
                    .map_err(|e| mapper::store::StoreError::Io(format!("{dir}: {e}")))?;
                let path =
                    std::path::Path::new(dir).join(format!("worker_{identity:016x}.qstore"));
                Ok(Arc::new(mapper::store::CacheStore::open(
                    &path,
                    identity,
                    mapper::store::outcome_slots(levels),
                )?))
            };
            match open() {
                Ok(s) => {
                    obs::event_human(
                        obs::Level::Status,
                        "worker_store_open",
                        vec![
                            ("path", Json::Str(s.path().display().to_string())),
                            ("entries", Json::Num(s.len() as f64)),
                            ("open_us", Json::Num(s.open_us() as f64)),
                        ],
                        &format!(
                            "qmap worker: outcome store {} ({} entries, opened in {} us)",
                            s.path().display(),
                            s.len(),
                            s.open_us()
                        ),
                    );
                    Some(s)
                }
                Err(e) => {
                    obs::event_human(
                        obs::Level::Status,
                        "worker_store_failed",
                        vec![("error", Json::Str(e.to_string()))],
                        &format!("qmap worker: outcome store disabled: {e}"),
                    );
                    None
                }
            }
        })
        .clone()
}

/// One decoded `batch` message: everything needed to run it.
struct BatchWork {
    id: u64,
    /// Search identity scoping the worker-side outcome cache (0 for
    /// drivers predating the field).
    search: u64,
    arch_src: String,
    arch: Arch,
    layer: ConvLayer,
    quant: LayerQuant,
    specs: Vec<ShardSpec>,
}

/// Decode a `batch` message into everything needed to run it. Total:
/// hostile input is an `Err` (which becomes an `error` reply), never a
/// panic.
fn decode_batch(msg: &Json) -> Result<BatchWork, String> {
    let v = msg.get("v").as_hex_u64("batch version")?;
    if v != proto::VERSION {
        return Err(format!(
            "batch speaks protocol version {v}, this worker speaks {}",
            proto::VERSION
        ));
    }
    let id = msg.get("id").as_hex_u64("batch id")?;
    let search = msg.get("search").as_hex_u64("batch search").unwrap_or(0);
    // objective-space validation: the worker never computes objectives,
    // but a spec this build cannot even parse means the fleet is mixed
    // across incompatible versions — refuse loudly (the driver logs the
    // error and re-runs locally) rather than serve a search whose
    // objective space this worker does not share. Absent/empty field =
    // a driver predating the objective subsystem; its axes are the
    // default pair every build knows.
    if let Some(objectives) = msg.get("objectives").as_str() {
        if !objectives.is_empty() {
            crate::objective::ObjectiveSpec::parse(objectives)
                .map_err(|e| format!("batch objectives: {e} (mixed-version fleet?)"))?;
        }
    }
    let arch_src = msg.get("arch").as_str().ok_or("batch: missing arch")?;
    let arch = parse_arch(arch_src).map_err(|e| format!("batch arch: {e}"))?;
    let layer = proto::layer_from_json(msg.get("layer"))?;
    let q = proto::quant_from_json(msg.get("quant"))?;
    // the driver sends canonical quants; canonicalizing again is
    // idempotent and protects against non-canonical peers
    let quant = q.canonical(arch.word_bits, arch.bit_packing);
    let mut specs = Vec::new();
    for s in msg.get("specs").as_arr().ok_or("batch: missing specs")? {
        specs.push(ShardSpec::from_json(s)?);
    }
    // the driver's optional validity-rate hint (see `mapper::guide`):
    // observational only — validate, count, and keep it away from the
    // execution path (outcomes stay a pure function of the specs).
    // Absent field = a driver predating the guide; nothing to count.
    let g = msg.get("guide");
    if !matches!(g, Json::Null) {
        let _ = g.get("valid").as_hex_u64("batch guide valid")?;
        let _ = g.get("drawn").as_hex_u64("batch guide drawn")?;
        metrics::counters().guide_updates.fetch_add(1, Ordering::Relaxed);
    }
    Ok(BatchWork {
        id,
        search,
        arch_src: arch_src.to_string(),
        arch,
        layer,
        quant,
        specs,
    })
}

/// Run one batch, streaming each [`ShardOutcome`] **as soon as its
/// shard finishes** — the worker-side twin of the mapper hot path,
/// bit-identical because `run_shard` is a pure function of
/// `(arch, layer, quant, spec)`. Incremental streaming matters twice:
/// the driver's per-read timeout only has to cover one shard's
/// compute, and a worker that dies mid-batch has already shipped its
/// finished shards, so only the genuinely lost ones re-run locally.
fn handle_batch(
    msg: &Json,
    writer: &mut BufWriter<TcpStream>,
    opts: WorkerOptions,
    sent: &mut usize,
) -> Result<BatchEnd, String> {
    let work = match decode_batch(msg) {
        Ok(d) => d,
        Err(e) => {
            proto::write_msg(writer, &proto::error(&e))?;
            return Ok(BatchEnd::Done);
        }
    };
    let BatchWork {
        id,
        search,
        arch_src,
        arch,
        layer,
        quant: q,
        specs,
    } = work;
    let space = MapSpace::of(&arch);
    let lctx = LayerContext::new(&arch, &layer, &q);
    let cache = worker_cache();
    let whash = mapper::workload_hash(&layer, &q);
    let run_fresh = |spec: &ShardSpec| -> ShardOutcome {
        let (out, stats) = mapper::run_shard_with_stats(&space, &lctx, spec);
        super::driver::note_shard(&layer.name, whash, &stats);
        out
    };
    // the per-search outcome cache: a spec this worker has already run
    // for the same search (an earlier batch, an earlier generation, a
    // re-send after a lost connection) is served without re-searching —
    // the cached outcome is bit-identical to a fresh run by purity.
    // Behind it sits the optional persistent store: a spec any earlier
    // *process* ran is decoded from disk instead of re-searched, and
    // fresh outcomes are appended for the next process. The in-memory
    // cache is keyed per search; the store key (`shard_cache_key`)
    // folds the full shard identity, so it is shared across searches.
    let levels = arch.levels.len();
    let pstore =
        if opts.disable_outcome_cache { None } else { worker_store(&arch_src, levels) };
    let run_cached = |spec: &ShardSpec| -> ShardOutcome {
        if opts.disable_outcome_cache {
            return run_fresh(spec);
        }
        let key = shard_cache_key(&arch_src, &layer, &q, spec);
        if let Some(hit) = cache.get(search, key) {
            metrics::counters().worker_cache_hits.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        if let Some(s) = &pstore {
            let stored = s
                .lookup(key)
                .and_then(|(_, payload)| mapper::store::decode_outcome(payload, levels));
            if let Some(out) = stored {
                metrics::counters().store_hits.fetch_add(1, Ordering::Relaxed);
                cache.put(search, key, &out);
                return out;
            }
            metrics::counters().store_misses.fetch_add(1, Ordering::Relaxed);
        }
        let out = run_fresh(spec);
        if let Some(s) = &pstore {
            s.append(key, 1, &mapper::store::encode_outcome(&out, levels));
        }
        cache.put(search, key, &out);
        out
    };
    // returns Ok(false) when the injected drop fault says to vanish
    let send = |writer: &mut BufWriter<TcpStream>,
                sent: &mut usize,
                i: usize,
                out: &ShardOutcome|
     -> Result<bool, String> {
        if let Some(n) = opts.drop_after {
            if *sent >= n {
                return Ok(false);
            }
        }
        proto::write_msg(writer, &proto::outcome(id, i, out))?;
        *sent += 1;
        if opts.duplicate_outcomes {
            proto::write_msg(writer, &proto::outcome(id, i, out))?;
        }
        Ok(true)
    };
    if opts.reverse_outcomes {
        // fault-injection path only: compute everything, then stream
        // in reverse shard order to exercise the driver's reordering
        let outs: Vec<ShardOutcome> = specs.iter().map(&run_cached).collect();
        for i in (0..outs.len()).rev() {
            if !send(writer, sent, i, &outs[i])? {
                return Ok(BatchEnd::Drop);
            }
        }
    } else {
        for (i, spec) in specs.iter().enumerate() {
            let out = run_cached(spec);
            if !send(writer, sent, i, &out)? {
                return Ok(BatchEnd::Drop);
            }
        }
    }
    proto::write_msg(writer, &proto::done(id))?;
    metrics::counters().batches_served.fetch_add(1, Ordering::Relaxed);
    Ok(BatchEnd::Done)
}

// ------------------------------------------------------------ ledger

/// Driver-side outcome collection for one batch. Slots are keyed by
/// shard index, so delivery order is irrelevant; duplicates are
/// ignored (shards are deterministic, so a duplicate carries the same
/// bits); and [`BatchLedger::missing`] names exactly the specs a lost
/// worker still owed — the re-injection set.
#[derive(Debug, Clone)]
pub struct BatchLedger {
    specs: Vec<ShardSpec>,
    slots: Vec<Option<ShardOutcome>>,
}

impl BatchLedger {
    pub fn new(specs: Vec<ShardSpec>) -> BatchLedger {
        let n = specs.len();
        BatchLedger {
            specs,
            slots: (0..n).map(|_| None).collect(),
        }
    }

    pub fn specs(&self) -> &[ShardSpec] {
        &self.specs
    }

    /// Record one shard's outcome. Returns `Ok(true)` if it filled the
    /// slot, `Ok(false)` for an ignored duplicate, and `Err` for a
    /// shard index outside the batch (a protocol violation — the
    /// caller should stop trusting the peer).
    pub fn deliver(&mut self, shard: usize, out: ShardOutcome) -> Result<bool, String> {
        match self.slots.get_mut(shard) {
            None => Err(format!(
                "shard index {shard} out of range ({} shards in the batch)",
                self.specs.len()
            )),
            Some(slot) => {
                if slot.is_none() {
                    *slot = Some(out);
                    Ok(true)
                } else {
                    Ok(false)
                }
            }
        }
    }

    /// Shard indices not yet delivered.
    pub fn missing(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn is_complete(&self) -> bool {
        self.slots.iter().all(|s| s.is_some())
    }

    /// Merge to the final [`MapperResult`], running `fill` for any
    /// shard no worker delivered. Because the merge walks slots in
    /// shard-index order, the result is independent of which host
    /// computed which shard, in what order, or how many times.
    pub fn finalize(
        mut self,
        mut fill: impl FnMut(usize, &ShardSpec) -> ShardOutcome,
    ) -> MapperResult {
        for i in 0..self.slots.len() {
            if self.slots[i].is_none() {
                self.slots[i] = Some(fill(i, &self.specs[i]));
            }
        }
        mapper::merge_shards(
            self.slots
                .into_iter()
                .map(|s| s.expect("all slots filled above"))
                .collect(),
        )
    }
}

// ------------------------------------------------------------ client

/// One driver→worker connection.
pub struct RemoteClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
    addr: String,
}

impl RemoteClient {
    /// Connect and complete the hello exchange within `timeout` (which
    /// also becomes the per-read and per-write timeout for batches —
    /// the write timeout keeps a deep pipeline from blocking forever
    /// against a worker that stopped draining its socket).
    pub fn connect(addr: &str, timeout: Duration) -> Result<RemoteClient, String> {
        let sockaddr = addr
            .to_socket_addrs()
            .map_err(|e| format!("{addr}: {e}"))?
            .next()
            .ok_or_else(|| format!("{addr}: no address"))?;
        let stream =
            TcpStream::connect_timeout(&sockaddr, timeout).map_err(|e| format!("{addr}: {e}"))?;
        stream
            .set_read_timeout(Some(timeout))
            .map_err(|e| format!("{addr}: {e}"))?;
        stream
            .set_write_timeout(Some(timeout))
            .map_err(|e| format!("{addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        let reader =
            BufReader::new(stream.try_clone().map_err(|e| format!("{addr}: {e}"))?);
        let writer = BufWriter::new(stream);
        let mut client = RemoteClient {
            reader,
            writer,
            next_id: 1,
            addr: addr.to_string(),
        };
        let m = proto::read_msg(&mut client.reader)?;
        if proto::msg_type(&m)? != "hello" {
            return Err(format!("{addr}: expected hello, got {}", m.to_string()));
        }
        let version = m.get("version").as_hex_u64("hello version")?;
        if version != proto::VERSION {
            return Err(format!(
                "{addr}: protocol version {version} (this driver speaks {})",
                proto::VERSION
            ));
        }
        Ok(client)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Ship one batch without waiting for anything back; returns the
    /// batch id. The building block of the pipelined scheduler: up to
    /// [`Engine::pipeline_depth`](super::Engine::pipeline_depth)
    /// batches ride the connection concurrently, each identified by
    /// its id in the interleaved outcome stream.
    #[allow(clippy::too_many_arguments)]
    pub fn send_batch(
        &mut self,
        arch_spec: &str,
        search: u64,
        objectives: &str,
        layer: &ConvLayer,
        q: &LayerQuant,
        specs: &[ShardSpec],
        guide: Option<(u64, u64)>,
    ) -> Result<u64, String> {
        let id = self.next_id;
        self.next_id += 1;
        proto::write_msg(
            &mut self.writer,
            &proto::batch(id, search, objectives, arch_spec, layer, q, specs, guide),
        )?;
        Ok(id)
    }

    /// The next `outcome`/`done` event on the connection. `error`
    /// frames, protocol violations, and transport failures are `Err` —
    /// the connection is then unusable and the caller re-runs whatever
    /// its ledgers still miss. Every failure is recorded as a
    /// `proto_error` event and triggers a flight-recorder dump, so the
    /// frames leading up to a hostile or corrupted stream are on disk
    /// before the caller falls back.
    pub fn recv_event(&mut self) -> Result<WorkerEvent, String> {
        match self.recv_event_inner() {
            Ok(ev) => Ok(ev),
            Err(e) => {
                metrics::counters().proto_errors.fetch_add(1, Ordering::Relaxed);
                obs::event(
                    "proto_error",
                    vec![
                        ("addr", Json::Str(self.addr.clone())),
                        ("detail", Json::Str(e.clone())),
                    ],
                );
                let _ = ring::dump("proto_error");
                Err(e)
            }
        }
    }

    fn recv_event_inner(&mut self) -> Result<WorkerEvent, String> {
        let m = proto::read_msg(&mut self.reader)?;
        proto::decode_event(&m, &self.addr)
    }

    /// Execute one batch remotely, delivering outcomes into `ledger`
    /// as they stream in (the depth-1 special case of the pipeline;
    /// kept for the batch-level tests and simple callers). On `Err`
    /// the connection is unusable but the ledger keeps everything
    /// already delivered — the caller re-runs only
    /// [`BatchLedger::missing`].
    pub fn run_batch(
        &mut self,
        arch_spec: &str,
        layer: &ConvLayer,
        q: &LayerQuant,
        ledger: &mut BatchLedger,
    ) -> Result<(), String> {
        let specs: Vec<ShardSpec> = ledger.specs().to_vec();
        let id = self.send_batch(arch_spec, 0, "", layer, q, &specs, None)?;
        loop {
            match self.recv_event()? {
                WorkerEvent::Outcome {
                    id: oid,
                    shard,
                    outcome,
                } => {
                    if oid != id {
                        continue; // stale frame from an earlier batch
                    }
                    ledger.deliver(shard, outcome)?;
                }
                WorkerEvent::Done { id: did } => {
                    if did == id {
                        return Ok(());
                    }
                }
            }
        }
    }
}

// the event type and its total decoder live with the wire format in
// `proto` (the model-conformance seam: one decoder, every consumer);
// re-exported here because the driver side is where callers meet it
pub use super::proto::WorkerEvent;

// ------------------------------------------------------------ window

/// One connection's pipelined-batch window: which batches are in
/// flight, plus the adaptive-depth timing bookkeeping (send and
/// first-outcome stamps, rtt/serve EWMAs at α = 1/2).
///
/// Extracted from the pump closure so the bookkeeping has an explicit
/// lifecycle: [`PipelineWindow::on_loss`] drains the timing stamps
/// **together with** the window, so stamps for batches whose `done`
/// never arrives (worker lost mid-flight) structurally cannot outlive
/// their batches and accumulate — the leak is impossible even for a
/// window that outlives a connection. The explicit structure is also
/// what the model-conformance suite (`model::window`,
/// `tests/model_conformance.rs`) drives through every small-scope
/// interleaving, projecting [`PipelineWindow::inflight_entries`] /
/// [`PipelineWindow::tracked_sends`] /
/// [`PipelineWindow::tracked_first_outcomes`] back onto the model.
///
/// Placement only: results are bit-identical at every depth and under
/// every timing, because outcomes land in slot-keyed
/// [`BatchLedger`]s.
#[derive(Debug, Clone)]
pub struct PipelineWindow {
    /// Configured depth ceiling (≥ 1).
    depth: usize,
    /// `(batch id, work index)` per in-flight batch, send order.
    inflight: Vec<(u64, usize)>,
    /// Send stamp per in-flight batch that was actually written.
    sent_at: Vec<(u64, std::time::Instant)>,
    /// First-outcome stamp per in-flight batch that streamed one.
    first_out: Vec<(u64, std::time::Instant)>,
    rtt_ewma: Option<f64>,
    serve_ewma: Option<f64>,
}

impl PipelineWindow {
    pub fn new(depth: usize) -> PipelineWindow {
        PipelineWindow {
            depth: depth.max(1),
            inflight: Vec::with_capacity(depth.max(1)),
            sent_at: Vec::new(),
            first_out: Vec::new(),
            rtt_ewma: None,
            serve_ewma: None,
        }
    }

    /// Adaptive depth: the window exists to hide the send→first-
    /// outcome round trip behind the worker's compute, so the depth it
    /// needs is `ceil(rtt / serve_time) + 1` — one batch being served
    /// plus enough queued to cover the next request's flight time.
    /// Both are EWMAs over completed batches; the configured depth is
    /// clamped down to the measurement. A near-zero serve time
    /// (cache-served batches) makes the ratio meaningless: keep the
    /// configured window.
    pub fn effective_depth(&self) -> usize {
        match (self.rtt_ewma, self.serve_ewma) {
            (Some(r), Some(s)) if s > 1e-9 => {
                self.depth.min((r / s).ceil() as usize + 1).max(1)
            }
            _ => self.depth,
        }
    }

    pub fn len(&self) -> usize {
        self.inflight.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inflight.is_empty()
    }

    /// Batch `id` was written for `work`: joins the window, send
    /// stamp recorded.
    pub fn on_sent(&mut self, id: u64, work: usize) {
        self.sent_at.push((id, std::time::Instant::now()));
        self.inflight.push((id, work));
    }

    /// The write for `work` failed after its claim: record the
    /// never-sent batch in the window under pseudo id 0 (real ids
    /// start at 1) so the owed count on loss includes its specs. No
    /// timing stamp — nothing was sent.
    pub fn on_send_failed(&mut self, work: usize) {
        self.inflight.push((0, work));
    }

    /// An `outcome` frame for batch `id`: `Some(work index)` if the
    /// batch is in flight (first-outcome stamp recorded once),
    /// `None` for a stale duplicate from a completed batch — the
    /// caller ignores it, exactly like the ledger would.
    pub fn on_outcome(&mut self, id: u64) -> Option<usize> {
        let &(_, wi) = self.inflight.iter().find(|&&(bid, _)| bid == id)?;
        if !self.first_out.iter().any(|&(bid, _)| bid == id) {
            self.first_out.push((id, std::time::Instant::now()));
        }
        Some(wi)
    }

    /// A `done` frame for batch `id`: the batch leaves the window,
    /// both its timing stamps drain, and the EWMAs fold them in
    /// (α = 1/2) when both were measured. Returns
    /// `Some((work index, rtt_secs, serve_secs))` — `(0.0, 0.0)` when
    /// unmeasured — or `None` for a stale `done`.
    pub fn on_done(&mut self, id: u64) -> Option<(usize, f64, f64)> {
        let pos = self.inflight.iter().position(|&(bid, _)| bid == id)?;
        let (_, wi) = self.inflight.remove(pos);
        let now = std::time::Instant::now();
        let sent = self
            .sent_at
            .iter()
            .position(|&(bid, _)| bid == id)
            .map(|p| self.sent_at.swap_remove(p).1);
        let first = self
            .first_out
            .iter()
            .position(|&(bid, _)| bid == id)
            .map(|p| self.first_out.swap_remove(p).1);
        let (mut rtt, mut serve) = (0.0f64, 0.0f64);
        if let (Some(sent), Some(first)) = (sent, first) {
            rtt = first.duration_since(sent).as_secs_f64();
            serve = now.duration_since(first).as_secs_f64();
            self.rtt_ewma = Some(self.rtt_ewma.map_or(rtt, |e| (e + rtt) / 2.0));
            self.serve_ewma = Some(self.serve_ewma.map_or(serve, |e| (e + serve) / 2.0));
        }
        Some((wi, rtt, serve))
    }

    /// Connection lost: every in-flight batch drains out (the caller
    /// re-injects each ledger's missing specs), and **every timing
    /// stamp drains with them** — a batch whose `done` never arrives
    /// must not leave a stale stamp behind to mis-pair with a later
    /// batch id.
    pub fn on_loss(&mut self) -> Vec<(u64, usize)> {
        self.sent_at.clear();
        self.first_out.clear();
        std::mem::take(&mut self.inflight)
    }

    /// The window contents, send order — the conformance projection.
    pub fn inflight_entries(&self) -> &[(u64, usize)] {
        &self.inflight
    }

    /// Batch ids with a live send stamp — the conformance projection
    /// of the EWMA bookkeeping (must always be ⊆ the in-flight ids).
    pub fn tracked_sends(&self) -> Vec<u64> {
        self.sent_at.iter().map(|&(id, _)| id).collect()
    }

    /// Batch ids with a live first-outcome stamp.
    pub fn tracked_first_outcomes(&self) -> Vec<u64> {
        self.first_out.iter().map(|&(id, _)| id).collect()
    }
}

// --------------------------------------------------------- scheduler

struct Work<'a> {
    layer: &'a ConvLayer,
    quant: LayerQuant,
    /// The job's precomputed cache identity, carried from the
    /// [`EvalJob`] so the sweep's insert never re-hashes the workload.
    key: WorkloadKey,
    ledger: Mutex<BatchLedger>,
}

/// Execute a generation's unique cache-miss jobs across `workers` and
/// the local engine, and record every result in `cache`.
///
/// Remote connection threads and the submitting thread race one claim
/// counter over the priority-ordered job list, so job placement is
/// load-driven and nondeterministic — but each job's result is
/// `merge_shards` over the same deterministic [`mapper::shard_plan`]
/// regardless of who ran it, so the cache ends up bit-identical to
/// local (or serial) execution.
///
/// Each connection keeps a **window** of up to
/// [`Engine::pipeline_depth`](super::Engine::pipeline_depth) batches in
/// flight (ledger slots keyed by `(batch id, shard index)`), so a
/// worker starts the next batch from its socket buffer instead of
/// stalling a round-trip between batches. A worker that cannot be
/// reached, violates the protocol, or disconnects is abandoned: every
/// in-flight batch keeps the outcomes already streamed, the missing
/// specs are re-injected into the local pool, and the remaining queue
/// drains through the other executors.
pub fn eval_jobs(
    engine: &Engine,
    arch: &Arch,
    layers: &[ConvLayer],
    jobs: &[EvalJob],
    cache: &MapperCache,
    cfg: &MapperConfig,
    workers: &[String],
) {
    // same injection order as the local backend: priority by default
    let ordered = super::driver::order_jobs(engine, layers, jobs, cache, cfg);
    let work: Vec<Work> = ordered
        .iter()
        .filter_map(|job| {
            let layer = &layers[job.layer_index];
            // canonicalize once, here: shard seeds, the local-refill
            // LayerContext, and the remote worker (which always
            // canonicalizes) must all see the same quant, or a job's
            // bits would depend on which host ran it. evaluate_genomes
            // already sends canonical quants; this keeps direct
            // callers honest too (and matches search_on_engine). The
            // job's WorkloadKey canonicalized identically when it was
            // built, so key-based probes and seeds agree with this.
            let quant = job.quant.canonical(arch.word_bits, arch.bit_packing);
            if cache.probe_key(job.key, cfg).is_some() {
                return None; // already known (positive or negative)
            }
            let specs = mapper::shard_plan(cfg, cfg.seed ^ job.key.whash);
            Some(Work {
                layer,
                quant,
                key: job.key,
                ledger: Mutex::new(BatchLedger::new(specs)),
            })
        })
        .collect();
    if work.is_empty() {
        return;
    }
    let rendered = render_arch(arch);
    let obj_spec = engine.objectives();
    let objectives = obj_spec.canonical();
    // scopes the worker-side shard-outcome cache: a pure function of
    // the arch text, the mapper budgets, and the objective-spec
    // identity, so every generation of one search maps to the same id
    // and repeated specs hit remotely — while two searches that agree
    // on everything but their objective space never share an identity
    // (mixed-version fleets must fail loudly, not blend)
    let search_id = {
        let mut h = crate::util::Fnv1a::new();
        h.write(rendered.as_bytes());
        h.write_u64(cfg.seed);
        h.write_u64(cfg.valid_target);
        h.write_u64(cfg.max_draws);
        h.write_u64(mapper::effective_shards(cfg) as u64);
        h.write_u64(obj_spec.hash());
        h.finish()
    };
    let next = AtomicUsize::new(0);
    let timeout = worker_timeout();
    let depth = engine.pipeline_depth().max(1);
    // direct callers get the same per-generation stats window the
    // driver opens (harmless double reset when called through it)
    engine.begin_generation();
    std::thread::scope(|sc| {
        for addr in workers {
            let work = &work;
            let next = &next;
            let rendered = &rendered;
            let objectives = &objectives;
            sc.spawn(move || {
                let mut client = match RemoteClient::connect(addr, timeout) {
                    Ok(c) => c,
                    Err(e) => {
                        obs::event_human(
                            obs::Level::Status,
                            "worker_unavailable",
                            vec![
                                ("addr", Json::Str(addr.clone())),
                                ("detail", Json::Str(e.clone())),
                            ],
                            &format!("qmap: worker {addr} unavailable, staying local: {e}"),
                        );
                        engine.note_lost_worker();
                        return;
                    }
                };
                // the window: every batch in flight on this
                // connection, plus the adaptive-depth bookkeeping —
                // the explicit state machine the model suite drives
                let mut win = PipelineWindow::new(depth);
                // the *effective* window this connection settled on
                // (reported to EngineStats at pump exit)
                let eff_cell = std::cell::Cell::new(depth);
                let pump = |client: &mut RemoteClient,
                            win: &mut PipelineWindow|
                 -> Result<(), String> {
                    // Adaptive depth (see `PipelineWindow::
                    // effective_depth`): the configured depth is
                    // clamped down to what the connection's rtt/serve
                    // EWMAs say it needs — a fast LAN needs no
                    // 64-deep queue, and every batch queued behind a
                    // slow connection is a batch no healthy executor
                    // can claim. Placement only: results are
                    // bit-identical at every depth.
                    loop {
                        let eff = win.effective_depth();
                        eff_cell.set(eff);
                        // top the window up from the shared claim queue
                        while win.len() < eff {
                            // near the tail, keep the window shallow: a
                            // claimed batch is never reclaimed from a
                            // healthy-but-slow worker, so stacking the
                            // generation's last jobs behind this
                            // connection would strand them while every
                            // other executor idles — the inverse of the
                            // tail this scheduler exists to shrink.
                            // Beyond the first in-flight batch, only
                            // claim while more unclaimed jobs remain
                            // than there are other executors to feed.
                            if !win.is_empty() {
                                let claimed = next.load(Ordering::SeqCst);
                                if work.len().saturating_sub(claimed) <= workers.len() {
                                    break;
                                }
                            }
                            let i = next.fetch_add(1, Ordering::SeqCst);
                            if i >= work.len() {
                                break;
                            }
                            let w = &work[i];
                            let specs: Vec<ShardSpec> =
                                w.ledger.lock().unwrap().specs().to_vec();
                            let id = match client.send_batch(
                                rendered,
                                search_id,
                                objectives,
                                w.layer,
                                &w.quant,
                                &specs,
                                engine.guide_rate(w.key.whash),
                            ) {
                                Ok(id) => id,
                                Err(e) => {
                                    // the claim already happened:
                                    // record the never-sent batch so
                                    // the owed count on loss includes
                                    // its specs
                                    win.on_send_failed(i);
                                    return Err(e);
                                }
                            };
                            win.on_sent(id, i);
                            metrics::counters().batches_sent.fetch_add(1, Ordering::Relaxed);
                            obs::event(
                                "batch_sent",
                                vec![
                                    ("addr", Json::Str(addr.clone())),
                                    ("batch", Json::Num(id as f64)),
                                    ("whash", Json::hex_u64(w.key.whash)),
                                ],
                            );
                        }
                        if win.is_empty() {
                            return Ok(());
                        }
                        match client.recv_event()? {
                            WorkerEvent::Outcome { id, shard, outcome } => {
                                // an id no longer in flight is a stale
                                // duplicate from a completed batch —
                                // `None`: ignore, exactly like the
                                // ledger would
                                if let Some(wi) = win.on_outcome(id) {
                                    work[wi].ledger.lock().unwrap().deliver(shard, outcome)?;
                                }
                            }
                            WorkerEvent::Done { id } => {
                                // the batch leaves the window; its
                                // timing stamps drain into the EWMAs
                                if let Some((_, rtt, serve)) = win.on_done(id) {
                                    engine.note_remote_job();
                                    metrics::counters()
                                        .batches_done
                                        .fetch_add(1, Ordering::Relaxed);
                                    obs::event(
                                        "batch_done",
                                        vec![
                                            ("addr", Json::Str(addr.clone())),
                                            ("batch", Json::Num(id as f64)),
                                            ("rtt_us", Json::Num(rtt * 1e6)),
                                            ("serve_us", Json::Num(serve * 1e6)),
                                            ("depth_eff", Json::Num(eff_cell.get() as f64)),
                                        ],
                                    );
                                }
                            }
                        }
                    }
                };
                let pumped = pump(&mut client, &mut win);
                engine.note_pipeline_depth(eff_cell.get());
                if let Err(e) = pumped {
                    // every batch still in the window keeps what it
                    // already received; the rest re-runs locally —
                    // and the timing stamps drain with the batches
                    let lost = win.on_loss();
                    let owed: usize = lost
                        .iter()
                        .map(|&(_, wi)| work[wi].ledger.lock().unwrap().missing().len())
                        .sum();
                    let c = metrics::counters();
                    c.batches_lost.fetch_add(lost.len() as u64, Ordering::Relaxed);
                    c.lost_workers.fetch_add(1, Ordering::Relaxed);
                    obs::event_human(
                        obs::Level::Status,
                        "worker_lost",
                        vec![
                            ("addr", Json::Str(addr.clone())),
                            ("batches_inflight", Json::Num(lost.len() as f64)),
                            ("owed_shards", Json::Num(owed as f64)),
                            ("detail", Json::Str(e.clone())),
                        ],
                        &format!(
                            "qmap: worker {addr} lost with {} batch(es) in flight, \
                             re-injecting {owed} shard(s) into the local pool: {e}",
                            lost.len()
                        ),
                    );
                    // the forensics trigger: the ring now holds the
                    // batch_sent/batch_done history leading up to the
                    // loss, including the failing batch's span
                    let _ = ring::dump("worker_lost");
                    engine.note_requeued(owed as u64);
                    engine.note_lost_worker();
                }
            });
        }
        // the submitting thread claims from the same counter and runs
        // jobs on the local work-stealing pool — idle local workers
        // keep stealing shards while remote batches are in flight
        loop {
            let i = next.fetch_add(1, Ordering::SeqCst);
            if i >= work.len() {
                break;
            }
            run_job_local(engine, arch, &work[i]);
        }
    });
    // sweep: re-run anything a lost worker never delivered (on the
    // pool), merge each job in shard-index order, record in the cache
    for w in &work {
        let ledger = {
            let mut guard = w.ledger.lock().unwrap();
            std::mem::replace(&mut *guard, BatchLedger::new(Vec::new()))
        };
        let result = if ledger.is_complete() {
            ledger.finalize(|_, _| unreachable!("complete ledger never fills"))
        } else {
            let specs: Vec<ShardSpec> = ledger.specs().to_vec();
            let missing = ledger.missing();
            let space = MapSpace::of(arch);
            let lctx = LayerContext::new(arch, w.layer, &w.quant);
            let run = |spec: &ShardSpec| {
                let (out, stats) = mapper::run_shard_with_stats(&space, &lctx, spec);
                super::driver::note_shard(&w.layer.name, w.key.whash, &stats);
                out
            };
            let refills = engine.map(&missing, |&i| run(&specs[i]));
            let mut ledger = ledger;
            for (&i, out) in missing.iter().zip(refills) {
                let _ = ledger.deliver(i, out);
            }
            ledger.finalize(|_, spec| run(spec))
        };
        cache.insert_search_key(w.key, cfg, &result);
        // the distributed twin of the fold in
        // `driver::search_on_engine_keyed` — a job runs through exactly
        // one of the two paths, so no outcome is counted twice
        engine.guide_note(w.key.whash, result.valid, result.draws);
    }
}

/// Run one claimed job entirely on the local pool (the same shards a
/// worker would have executed), filling its ledger.
fn run_job_local(engine: &Engine, arch: &Arch, w: &Work) {
    let specs: Vec<ShardSpec> = w.ledger.lock().unwrap().specs().to_vec();
    let space = MapSpace::of(arch);
    let lctx = LayerContext::new(arch, w.layer, &w.quant);
    let outs = engine.map(&specs, |s| {
        let (out, stats) = mapper::run_shard_with_stats(&space, &lctx, s);
        super::driver::note_shard(&w.layer.name, w.key.whash, &stats);
        out
    });
    let mut ledger = w.ledger.lock().unwrap();
    for (i, out) in outs.into_iter().enumerate() {
        let _ = ledger.deliver(i, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;

    fn workload() -> (Arch, ConvLayer, LayerQuant, MapperConfig) {
        let arch = toy();
        let layer = ConvLayer::conv("c1", 3, 8, 3, 16, 1);
        let q = LayerQuant::uniform(4).canonical(arch.word_bits, arch.bit_packing);
        let cfg = MapperConfig {
            valid_target: 30,
            max_draws: 30_000,
            seed: 11,
            shards: 3,
        };
        (arch, layer, q, cfg)
    }

    fn serial_reference(
        arch: &Arch,
        layer: &ConvLayer,
        q: &LayerQuant,
        cfg: &MapperConfig,
    ) -> MapperResult {
        mapper::search(arch, layer, q, cfg)
    }

    fn run_against(opts: WorkerOptions) -> (MapperResult, MapperResult) {
        let (arch, layer, q, cfg) = workload();
        let addr = spawn_local_worker(opts).expect("loopback worker");
        let mut client =
            RemoteClient::connect(&addr, Duration::from_secs(10)).expect("connect");
        let specs = mapper::shard_plan(&cfg, cfg.seed ^ mapper::workload_hash(&layer, &q));
        let mut ledger = BatchLedger::new(specs);
        let rendered = render_arch(&arch);
        let net = client.run_batch(&rendered, &layer, &q, &mut ledger);
        // only the injected drop fault may sever the stream
        assert_eq!(net.is_err(), opts.drop_after.is_some(), "{net:?}");
        let space = MapSpace::of(&arch);
        let lctx = LayerContext::new(&arch, &layer, &q);
        let got = ledger.finalize(|_, spec| mapper::run_shard(&space, &lctx, spec));
        (got, serial_reference(&arch, &layer, &q, &cfg))
    }

    fn assert_bit_identical(got: &MapperResult, want: &MapperResult) {
        assert_eq!(got.valid, want.valid);
        assert_eq!(got.draws, want.draws);
        assert_eq!(
            got.best.as_ref().map(|e| e.edp().to_bits()),
            want.best.as_ref().map(|e| e.edp().to_bits())
        );
        assert_eq!(got.best_mapping, want.best_mapping);
    }

    #[test]
    fn loopback_batch_is_bit_identical_to_serial() {
        let (got, want) = run_against(WorkerOptions::default());
        assert!(want.best.is_some());
        assert_bit_identical(&got, &want);
    }

    #[test]
    fn duplicate_delivery_is_idempotent() {
        let (got, want) = run_against(WorkerOptions {
            duplicate_outcomes: true,
            ..WorkerOptions::default()
        });
        assert_bit_identical(&got, &want);
    }

    #[test]
    fn reordered_delivery_merges_identically() {
        let (got, want) = run_against(WorkerOptions {
            reverse_outcomes: true,
            ..WorkerOptions::default()
        });
        assert_bit_identical(&got, &want);
    }

    #[test]
    fn dropped_connection_refills_locally_and_identically() {
        for drop_after in [0usize, 1, 2] {
            let (got, want) = run_against(WorkerOptions {
                drop_after: Some(drop_after),
                ..WorkerOptions::default()
            });
            assert_bit_identical(&got, &want);
        }
    }

    #[test]
    fn ledger_rejects_out_of_range_and_ignores_duplicates() {
        let (arch, layer, q, cfg) = workload();
        let specs = mapper::shard_plan(&cfg, cfg.seed ^ mapper::workload_hash(&layer, &q));
        let space = MapSpace::of(&arch);
        let lctx = LayerContext::new(&arch, &layer, &q);
        let out0 = mapper::run_shard(&space, &lctx, &specs[0]);
        let mut ledger = BatchLedger::new(specs);
        assert!(ledger.deliver(99, out0.clone()).is_err());
        assert_eq!(ledger.deliver(0, out0.clone()), Ok(true));
        assert_eq!(ledger.deliver(0, out0), Ok(false));
        assert_eq!(ledger.missing(), vec![1, 2]);
        assert!(!ledger.is_complete());
    }

    #[test]
    fn eval_jobs_fills_the_cache_identically_to_serial() {
        let (arch, layer, q, cfg) = workload();
        let addr = spawn_local_worker(WorkerOptions::default()).expect("worker");
        let layers = vec![layer.clone(), ConvLayer::fc("fc", 16, 10)];
        let q8 = LayerQuant::uniform(8).canonical(arch.word_bits, arch.bit_packing);
        let jobs: Vec<EvalJob> = vec![
            EvalJob {
                layer_index: 0,
                quant: q,
                key: WorkloadKey::of(&arch, &layers[0], &q),
            },
            EvalJob {
                layer_index: 1,
                quant: q8,
                key: WorkloadKey::of(&arch, &layers[1], &q8),
            },
        ];
        let engine = Engine::new(2);
        let cache = MapperCache::new();
        eval_jobs(&engine, &arch, &layers, &jobs, &cache, &cfg, &[addr]);
        assert_eq!(cache.len(), 2);
        // every entry matches a from-scratch serial evaluation
        let serial = MapperCache::new();
        for job in &jobs {
            let got = cache.evaluate(&arch, &layers[job.layer_index], &job.quant, &cfg);
            let want = serial.evaluate(&arch, &layers[job.layer_index], &job.quant, &cfg);
            assert_eq!(got, want);
            if let (Some(g), Some(w)) = (got, want) {
                assert_eq!(g.edp.to_bits(), w.edp.to_bits());
            }
        }
    }

    #[test]
    fn pipelined_eval_jobs_is_bit_identical_for_any_depth_and_fault() {
        let (arch, layer, q, cfg) = workload();
        let layers = vec![
            layer.clone(),
            ConvLayer::fc("fc", 16, 10),
            ConvLayer::pw("p1", 8, 16, 16),
        ];
        let jobs: Vec<EvalJob> = (0..layers.len())
            .map(|i| EvalJob {
                layer_index: i,
                quant: q,
                key: WorkloadKey::of(&arch, &layers[i], &q),
            })
            .collect();
        let serial = MapperCache::new();
        for depth in [1usize, 2, 4] {
            for fault in [
                WorkerOptions::default(),
                WorkerOptions {
                    drop_after: Some(1),
                    ..WorkerOptions::default()
                },
                WorkerOptions {
                    duplicate_outcomes: true,
                    ..WorkerOptions::default()
                },
            ] {
                let addr = spawn_local_worker(fault).expect("worker");
                let engine = Engine::new(2).with_pipeline_depth(depth);
                let cache = MapperCache::new();
                eval_jobs(&engine, &arch, &layers, &jobs, &cache, &cfg, &[addr]);
                assert_eq!(cache.len(), layers.len(), "depth={depth} fault={fault:?}");
                // the adaptive window may clamp below the configured
                // depth (RTT-derived), never above it, and is always
                // at least 1 once a connection pumped
                let st = engine.stats();
                assert!(
                    (1..=depth).contains(&st.last_pipeline_depth),
                    "effective depth {} outside [1, {depth}]",
                    st.last_pipeline_depth
                );
                for job in &jobs {
                    let got = cache.evaluate(&arch, &layers[job.layer_index], &job.quant, &cfg);
                    let want =
                        serial.evaluate(&arch, &layers[job.layer_index], &job.quant, &cfg);
                    assert_eq!(got, want, "depth={depth} fault={fault:?}");
                    if let (Some(g), Some(w)) = (got, want) {
                        assert_eq!(g.edp.to_bits(), w.edp.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn worker_cache_serves_repeated_specs_bit_identically() {
        let (arch, layer, q, cfg) = workload();
        let rendered = render_arch(&arch);
        let addr = spawn_local_worker(WorkerOptions::default()).expect("worker");
        let specs = mapper::shard_plan(&cfg, cfg.seed ^ mapper::workload_hash(&layer, &q));
        let space = MapSpace::of(&arch);
        let lctx = LayerContext::new(&arch, &layer, &q);
        let mut results = Vec::new();
        // the same batch under the same search id three times: the
        // second and third are served from the worker's outcome cache
        // and must not change a bit
        let mut client = RemoteClient::connect(&addr, Duration::from_secs(10)).expect("connect");
        for _ in 0..3 {
            let mut ledger = BatchLedger::new(specs.clone());
            let id = client
                .send_batch(&rendered, 0xA5A5, "edp,error", &layer, &q, &specs, Some((5, 500)))
                .expect("send");
            loop {
                match client.recv_event().expect("event") {
                    WorkerEvent::Outcome { id: oid, shard, outcome } => {
                        if oid == id {
                            ledger.deliver(shard, outcome).expect("deliver");
                        }
                    }
                    WorkerEvent::Done { id: did } => {
                        if did == id {
                            break;
                        }
                    }
                }
            }
            results.push(ledger.finalize(|_, spec| mapper::run_shard(&space, &lctx, spec)));
        }
        let want = serial_reference(&arch, &layer, &q, &cfg);
        for got in &results {
            assert_bit_identical(got, &want);
        }
    }

    #[test]
    fn graceful_shutdown_finishes_the_inflight_batch_then_stops_accepting() {
        use std::sync::atomic::AtomicBool;
        let (arch, layer, q, cfg) = workload();
        let flag: &'static AtomicBool = Box::leak(Box::new(AtomicBool::new(false)));
        let opts = WorkerOptions {
            shutdown: Some(flag),
            ..WorkerOptions::default()
        };
        let addr = spawn_local_worker(opts).expect("worker");
        let mut client = RemoteClient::connect(&addr, Duration::from_secs(10)).expect("connect");
        // raise the flag, then submit: the worker must still finish
        // and flush this batch before closing the connection
        flag.store(true, Ordering::SeqCst);
        let specs = mapper::shard_plan(&cfg, cfg.seed ^ mapper::workload_hash(&layer, &q));
        let mut ledger = BatchLedger::new(specs);
        client
            .run_batch(&render_arch(&arch), &layer, &q, &mut ledger)
            .expect("in-flight batch must complete after shutdown request");
        assert!(ledger.is_complete(), "all outcomes must be flushed");
        let space = MapSpace::of(&arch);
        let lctx = LayerContext::new(&arch, &layer, &q);
        let got = ledger.finalize(|_, spec| mapper::run_shard(&space, &lctx, spec));
        assert_bit_identical(&got, &serial_reference(&arch, &layer, &q, &cfg));
        // the accept loop drains and closes the listener: new
        // connections are eventually refused
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match RemoteClient::connect(&addr, Duration::from_millis(250)) {
                Err(_) => break, // listener gone
                Ok(_) => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "worker kept accepting after graceful shutdown"
                    );
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    #[test]
    fn batch_naming_an_unknown_objective_axis_is_refused() {
        // the mixed-version-fleet seam: a worker that cannot parse the
        // driver's objective spec answers with an `error` frame naming
        // the axis instead of executing the batch
        let (arch, layer, q, cfg) = workload();
        let addr = spawn_local_worker(WorkerOptions::default()).expect("worker");
        let mut client = RemoteClient::connect(&addr, Duration::from_secs(10)).expect("connect");
        let specs = mapper::shard_plan(&cfg, cfg.seed ^ mapper::workload_hash(&layer, &q));
        let msg = proto::batch(
            1,
            0,
            "edp,flux_capacitance",
            &render_arch(&arch),
            &layer,
            &q,
            &specs,
            None,
        );
        proto::write_msg(&mut client.writer, &msg).expect("send");
        let err = client.recv_event().expect_err("hostile spec must be refused");
        assert!(err.contains("flux_capacitance"), "{err}");
        // the connection survives: a well-formed spec still executes
        let mut ledger = BatchLedger::new(specs);
        client
            .run_batch(&render_arch(&arch), &layer, &q, &mut ledger)
            .expect("well-formed batch after the refused one");
        assert!(ledger.is_complete());
    }

    #[test]
    fn unreachable_worker_degrades_to_local() {
        let (arch, layer, q, cfg) = workload();
        let layers = vec![layer];
        let jobs = vec![EvalJob {
            layer_index: 0,
            quant: q,
            key: WorkloadKey::of(&arch, &layers[0], &q),
        }];
        let engine = Engine::new(2);
        let cache = MapperCache::new();
        // a loopback port nobody listens on: the connect is refused
        // immediately (no timeout involved) and the jobs run locally
        eval_jobs(
            &engine,
            &arch,
            &layers,
            &jobs,
            &cache,
            &cfg,
            &["127.0.0.1:9".to_string()],
        );
        assert_eq!(cache.len(), 1);
        let serial = MapperCache::new();
        assert_eq!(
            cache.evaluate(&arch, &layers[0], &jobs[0].quant, &cfg),
            serial.evaluate(&arch, &layers[0], &jobs[0].quant, &cfg)
        );
    }

    fn one_outcome() -> ShardOutcome {
        let (arch, layer, q, cfg) = workload();
        let space = MapSpace::of(&arch);
        let lctx = LayerContext::new(&arch, &layer, &q);
        let specs = mapper::shard_plan(&cfg, cfg.seed ^ mapper::workload_hash(&layer, &q));
        mapper::run_shard(&space, &lctx, &specs[0])
    }

    /// Regression: `put` on an already-registered search must refresh
    /// its recency, not push a duplicate `order` entry. Before the fix
    /// eviction was FIFO by *first* registration, so a long-lived
    /// search streaming outcomes was the first one evicted when
    /// one-shot searches churned past the capacity — the exact
    /// opposite of the documented least-recently-active contract.
    #[test]
    fn worker_cache_reregistration_refreshes_instead_of_duplicating() {
        let cache = WorkerCache {
            searches: Mutex::new((VecDeque::new(), FxHashMap::default())),
        };
        let out = one_outcome();
        // hammer one search well past the search capacity: the order
        // queue must stay deduplicated
        for k in 0..(WORKER_CACHE_SEARCHES as u64 + 3) {
            cache.put(1, k, &out);
        }
        {
            let g = cache.searches.lock().unwrap();
            assert_eq!(g.0.len(), 1, "re-registration duplicated the order queue");
            assert_eq!(
                g.1.get(&1).map(|m| m.len()),
                Some(WORKER_CACHE_SEARCHES + 3)
            );
        }
        // the active search survives a churn of one-shot searches
        for s in 2..=(WORKER_CACHE_SEARCHES as u64 + 5) {
            cache.put(s, 0, &out); // a new one-shot search...
            cache.put(1, s, &out); // ...while search 1 stays active
            assert!(
                cache.get(1, 0).is_some(),
                "active search evicted by one-shot search {s}"
            );
        }
        let g = cache.searches.lock().unwrap();
        assert!(g.0.len() <= WORKER_CACHE_SEARCHES, "order queue leaked");
        assert_eq!(g.0.len(), g.1.len(), "order and maps out of sync");
        assert_eq!(g.0.back(), Some(&1), "most recently active sits at the back");
    }

    /// Regression: the EWMA timing stamps ride inside the window now,
    /// so a connection loss drains them with the in-flight entries — a
    /// batch whose `done` never arrived cannot leave a stale stamp
    /// behind to corrupt a later job's RTT/serve estimate after the
    /// work is re-injected.
    #[test]
    fn pipeline_window_drains_timing_stamps_with_the_window() {
        let mut win = PipelineWindow::new(4);
        win.on_sent(1, 0);
        win.on_sent(2, 1);
        assert_eq!(win.len(), 2);
        assert_eq!(win.on_outcome(1), Some(0));
        assert_eq!(win.on_outcome(1), Some(0), "still in flight after an outcome");
        assert_eq!(win.on_outcome(99), None, "stale outcome id is ignored");
        assert_eq!(win.tracked_sends(), vec![1, 2]);
        assert_eq!(win.tracked_first_outcomes(), vec![1]);
        // done drains its own stamps...
        let (wi, _rtt, _serve) = win.on_done(1).expect("in flight");
        assert_eq!(wi, 0);
        assert!(win.on_done(1).is_none(), "stale done id is ignored");
        assert_eq!(win.tracked_sends(), vec![2]);
        assert!(win.tracked_first_outcomes().is_empty());
        // ...and loss drains everything that never completed
        assert_eq!(win.on_outcome(2), Some(1));
        let lost = win.on_loss();
        assert_eq!(lost, vec![(2, 1)]);
        assert!(win.is_empty());
        assert!(win.tracked_sends().is_empty(), "send stamp leaked past the loss");
        assert!(
            win.tracked_first_outcomes().is_empty(),
            "first-outcome stamp leaked past the loss"
        );
    }

    /// A failed send enters the window under pseudo id 0 (no timing
    /// stamp — nothing was written), so the loss path still owes its
    /// shards back to the engine.
    #[test]
    fn pipeline_window_send_failure_is_owed_without_a_stamp() {
        assert_eq!(PipelineWindow::new(0).effective_depth(), 1, "depth floor");
        let mut win = PipelineWindow::new(2);
        win.on_sent(1, 0);
        win.on_send_failed(1);
        assert_eq!(win.tracked_sends(), vec![1], "a failed write has no stamp");
        let lost = win.on_loss();
        assert_eq!(lost, vec![(1, 0), (0, 1)], "pseudo id 0 keeps the claim owed");
        assert!(win.tracked_sends().is_empty());
    }
}
