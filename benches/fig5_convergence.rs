//! Fig. 5: Pareto fronts of the proposed NSGA-II search across
//! generations, MobileNetV1 on Eyeriss (paper: e=10, |Q|=16; most of the
//! improvement lands before generation ~11).
//!
//! Run: `cargo bench --bench fig5_convergence`.

use qmap::coordinator::experiments::fig5_convergence;
use qmap::coordinator::RunConfig;
use qmap::report;
use qmap::util::stats;
use std::time::Instant;

/// 2-D hypervolume (to a reference point) of a front of (edp, error)
/// minimization points — a scalar measure of front quality.
fn hypervolume(front: &[Vec<f64>], ref_pt: (f64, f64)) -> f64 {
    let mut pts: Vec<(f64, f64)> = front
        .iter()
        .filter(|p| p[0] <= ref_pt.0 && p[1] <= ref_pt.1)
        .map(|p| (p[0], p[1]))
        .collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut hv = 0.0;
    let mut prev_y = ref_pt.1;
    for (x, y) in pts {
        if y < prev_y {
            hv += (ref_pt.0 - x) * (prev_y - y);
            prev_y = y;
        }
    }
    hv
}

fn main() {
    let mut rc = RunConfig::from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2)
    });
    if std::env::var("QMAP_PROFILE").is_err() {
        rc.nsga.offspring = 16; // the paper's |Q|=16 run
        rc.nsga.generations = 20;
    }
    let snaps: Vec<usize> = (0..=rc.nsga.generations).collect();

    println!(
        "=== Fig. 5: NSGA-II convergence (|P|={}, |Q|={}, {} gens) ===",
        rc.nsga.population, rc.nsga.offspring, rc.nsga.generations
    );
    let t0 = Instant::now();
    let r = fig5_convergence(&rc, &snaps);
    let dt = t0.elapsed();

    // reference point for hypervolume: worst corner over all snapshots
    let (mut rx, mut ry) = (0.0f64, 0.0f64);
    for (_, front) in &r.fronts {
        for p in front {
            rx = rx.max(p[0] * 1.01);
            ry = ry.max(p[1] * 1.01 + 1e-9);
        }
    }

    let mut hv_series = Vec::new();
    let mut rows = Vec::new();
    for (gen, front) in &r.fronts {
        let hv = hypervolume(front, (rx, ry));
        hv_series.push(hv);
        rows.push(vec![
            gen.to_string(),
            front.len().to_string(),
            format!("{:.4e}", front.iter().map(|p| p[0]).fold(f64::INFINITY, f64::min)),
            format!("{:.4}", 1.0 - front.iter().map(|p| p[1]).fold(f64::INFINITY, f64::min)),
            format!("{:.4e}", hv),
        ]);
    }
    print!(
        "{}",
        report::table(
            &["gen", "front size", "best EDP", "best top-1", "hypervolume"],
            &rows
        )
    );

    // scatter of first/mid/last snapshot fronts
    let mut pts = Vec::new();
    if let Some((_, f0)) = r.fronts.first() {
        pts.extend(f0.iter().map(|p| (p[0], 1.0 - p[1], '0')));
    }
    if r.fronts.len() > 2 {
        let (_, fm) = &r.fronts[r.fronts.len() / 2];
        pts.extend(fm.iter().map(|p| (p[0], 1.0 - p[1], 'm')));
    }
    if let Some((_, fl)) = r.fronts.last() {
        pts.extend(fl.iter().map(|p| (p[0], 1.0 - p[1], 'F')));
    }
    println!("\nfronts: '0' = first gen, 'm' = midpoint, 'F' = final:");
    print!("{}", report::ascii_scatter(&pts, 72, 20, "EDP", "top-1 accuracy"));

    // paper shape: hypervolume grows, most progress in the first half
    let n = hv_series.len();
    let grew = n >= 2 && hv_series[n - 1] >= hv_series[0];
    let first_half_gain = if n >= 3 {
        let total = hv_series[n - 1] - hv_series[0];
        let half = hv_series[n / 2] - hv_series[0];
        if total > 0.0 { half / total } else { 1.0 }
    } else {
        1.0
    };
    println!(
        "\nhypervolume grew: {grew}; share of gain in first half: {:.0}% (paper: most changes before gen 11/20)",
        first_half_gain * 100.0
    );
    println!(
        "paper shape: {}",
        if grew && first_half_gain > 0.5 { "REPRODUCED" } else { "MISMATCH" }
    );
    println!("hv trend (Spearman vs gen): {:+.3}", {
        let gens: Vec<f64> = (0..n).map(|i| i as f64).collect();
        stats::spearman(&gens, &hv_series)
    });

    let csv_rows: Vec<Vec<String>> = r
        .fronts
        .iter()
        .flat_map(|(gen, front)| {
            front
                .iter()
                .map(|p| vec![gen.to_string(), format!("{:.6e}", p[0]), format!("{:.6}", p[1])])
                .collect::<Vec<_>>()
        })
        .collect();
    let path = report::write_results(
        "fig5_fronts.csv",
        &report::csv(&["generation", "edp", "error"], &csv_rows),
    );
    let mut plot = report::svg::Plot::new(
        "Fig 5: Pareto front per generation (MobileNetV1, Eyeriss)",
        "EDP [J*cycles]",
        "top-1 accuracy",
    );
    let picks = [0usize, r.fronts.len() / 4, r.fronts.len() / 2, r.fronts.len().saturating_sub(1)];
    for &pi in &picks {
        if let Some((gen, front)) = r.fronts.get(pi) {
            let pts: Vec<(f64, f64)> = front.iter().map(|p| (p[0], 1.0 - p[1])).collect();
            plot.line(&format!("gen {gen}"), &pts);
        }
    }
    report::write_results("fig5.svg", &plot.render());
    println!("[{dt:.2?}] wrote {} (+ fig5.svg)", path.display());
}
