//! Accelerator architecture model (the "text specification" consumed by
//! the mapping engine, mirroring Timeloop's arch YAML + Accelergy energy
//! tables).
//!
//! An architecture is a linear hierarchy of storage levels, innermost
//! (closest to the MACs) first, DRAM last. Each level may fan out
//! spatially to the level below it (e.g. Eyeriss' global buffer fans out
//! to the 168-PE array), may keep or bypass each of the three data
//! spaces, and carries Accelergy-style per-access energies.

pub mod parser;
pub mod presets;

use crate::workload::{Dim, Tensor, DIMS};

/// Buffer capacity: one shared pool or per-tensor partitions
/// (Eyeriss PEs have separate weight/ifmap/psum scratchpads).
#[derive(Debug, Clone, PartialEq)]
pub enum Capacity {
    /// Unbounded (off-chip DRAM).
    Unbounded,
    /// One shared pool of `words` memory words for all kept tensors.
    Shared(u64),
    /// Separate word budgets per tensor `[Weights, Inputs, Outputs]`.
    PerTensor([u64; 3]),
}

/// One storage level.
#[derive(Debug, Clone, PartialEq)]
pub struct Level {
    pub name: String,
    pub capacity: Capacity,
    /// Per-access energy in pJ for `[Weights, Inputs, Outputs]` accesses
    /// (word-granular; reads and writes priced identically, as in the
    /// Accelergy tables the paper uses at 45 nm).
    pub access_energy_pj: [f64; 3],
    /// Words per cycle this level can source/sink (per instance).
    pub bandwidth_words: f64,
    /// Spatial fanout *below* this level (number of child instances fed
    /// by one instance of this level). 1 = no fanout.
    pub fanout: u64,
    /// Dims allowed in the spatial mapping at this level. Encodes the
    /// dataflow style constraint (e.g. Eyeriss row stationary restricts
    /// the array dims). Ignored when `fanout == 1`.
    pub spatial_dims: Vec<Dim>,
    /// Whether the network below this level can multicast one read to
    /// several children (and reduce partial sums on the way up).
    pub multicast: bool,
    /// Which tensors this level stores (`false` = bypass).
    pub keeps: [bool; 3],
}

impl Level {
    pub fn keeps_tensor(&self, t: Tensor) -> bool {
        self.keeps[t.index()]
    }
    pub fn capacity_for(&self, t: Tensor) -> Option<u64> {
        match &self.capacity {
            Capacity::Unbounded => None,
            Capacity::Shared(w) => Some(*w),
            Capacity::PerTensor(ws) => Some(ws[t.index()]),
        }
    }
}

/// A full accelerator specification.
#[derive(Debug, Clone, PartialEq)]
pub struct Arch {
    pub name: String,
    /// Memory word size in bits (paper: 16 for both accelerators).
    pub word_bits: u32,
    /// Energy of one MAC operation in pJ (kept constant across
    /// bit-widths: the paper leaves compute units untouched).
    pub mac_energy_pj: f64,
    /// Storage hierarchy, innermost first, DRAM last.
    pub levels: Vec<Level>,
    /// Whether the mapping engine applies bit-packing (the paper's
    /// Timeloop extension; `false` reproduces vanilla Timeloop).
    pub bit_packing: bool,
}

impl Arch {
    /// Total PE (MAC-lane) count = product of all fanouts.
    pub fn total_pes(&self) -> u64 {
        self.levels.iter().map(|l| l.fanout).product()
    }

    /// Index of the innermost level at/above `from` that keeps `t`
    /// (DRAM keeps everything, so this always resolves).
    pub fn next_keeper(&self, from: usize, t: Tensor) -> usize {
        for (i, l) in self.levels.iter().enumerate().skip(from) {
            if l.keeps_tensor(t) {
                return i;
            }
        }
        self.levels.len() - 1
    }

    /// Validate structural invariants of a spec.
    pub fn validate(&self) -> Result<(), String> {
        if self.levels.len() < 2 {
            return Err("need at least one on-chip level plus DRAM".into());
        }
        let top = self.levels.last().unwrap();
        if top.capacity != Capacity::Unbounded {
            return Err("top level (DRAM) must be unbounded".into());
        }
        if !top.keeps.iter().all(|&k| k) {
            return Err("top level must keep all tensors".into());
        }
        if self.word_bits == 0 || self.word_bits > 64 {
            return Err(format!("bad word_bits {}", self.word_bits));
        }
        for l in &self.levels {
            if l.fanout == 0 {
                return Err(format!("level {} has zero fanout", l.name));
            }
            if l.fanout > 1 && l.spatial_dims.is_empty() {
                return Err(format!("level {} fans out but allows no spatial dims", l.name));
            }
            for d in &l.spatial_dims {
                if !DIMS.contains(d) {
                    return Err("bad spatial dim".into());
                }
            }
        }
        if !self.levels.iter().any(|l| l.keeps[Tensor::Weights.index()]) {
            return Err("no level keeps weights".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::presets::{eyeriss, simba};
    use super::*;

    #[test]
    fn presets_validate() {
        eyeriss().validate().unwrap();
        simba().validate().unwrap();
    }

    #[test]
    fn pe_counts_match_paper() {
        // paper: "Eyeriss consists of 168 16-bit PEs, Simba employs 256"
        assert_eq!(eyeriss().total_pes(), 168);
        assert_eq!(simba().total_pes(), 256);
        assert_eq!(eyeriss().word_bits, 16);
        assert_eq!(simba().word_bits, 16);
    }

    #[test]
    fn next_keeper_resolves_bypass() {
        let e = eyeriss();
        // Eyeriss GLB bypasses weights: keeper above PE spad is DRAM
        let pe = 0;
        let glb = 1;
        assert!(e.levels[pe].keeps_tensor(Tensor::Weights));
        assert!(!e.levels[glb].keeps_tensor(Tensor::Weights));
        assert_eq!(e.next_keeper(glb, Tensor::Weights), e.levels.len() - 1);
        assert_eq!(e.next_keeper(glb, Tensor::Inputs), glb);
    }

    #[test]
    fn validation_catches_errors() {
        let mut a = eyeriss();
        a.levels.last_mut().unwrap().capacity = Capacity::Shared(10);
        assert!(a.validate().is_err());

        let mut b = simba();
        b.levels[0].fanout = 0;
        assert!(b.validate().is_err());

        let mut c = eyeriss();
        c.word_bits = 0;
        assert!(c.validate().is_err());
    }
}
