//! Accelergy-style energy/latency estimation on top of the nest analysis.
//!
//! Element traffic from `crate::nest` is converted to *memory words*
//! using the per-tensor bit-widths and the bit-packing factor of each
//! level's word size, then priced with the level's per-access energy.
//! This is where the paper's quantization x mapping synergy becomes
//! visible: the same mapping costs less at lower bit-widths, and lower
//! bit-widths admit cheaper mappings.
//!
//! MAC energy is intentionally constant w.r.t. bit-width: the paper
//! "only considers the memory path [...] computational MAC units remain
//! untouched".

use crate::arch::Arch;
use crate::mapping::{LayerContext, Mapping};
use crate::nest::NestAnalysis;
use crate::quant::{pack_factor, LayerQuant};
use crate::workload::{ConvLayer, Tensor, TENSORS};

/// Energy/latency estimate for one layer under one mapping.
#[derive(Debug, Clone, PartialEq)]
pub struct Estimate {
    /// Total energy in picojoules.
    pub energy_pj: f64,
    /// Energy per hierarchy level (same order as `arch.levels`), memory
    /// path only, pJ.
    pub level_energy_pj: Vec<f64>,
    /// MAC (compute) energy, pJ.
    pub mac_energy_pj: f64,
    /// Execution latency in cycles.
    pub cycles: f64,
    /// Word traffic per level (reads+writes, all tensors).
    pub level_words: Vec<f64>,
    /// Utilized MAC lanes.
    pub pes_used: u64,
}

impl Estimate {
    /// Energy-delay product in pJ * cycles (the paper reports J * cycles;
    /// scale is arbitrary but consistent across comparisons).
    pub fn edp(&self) -> f64 {
        self.energy_pj * self.cycles
    }

    /// Memory-subsystem energy (everything except MACs), pJ.
    pub fn memory_energy_pj(&self) -> f64 {
        self.energy_pj - self.mac_energy_pj
    }

    /// An empty estimate to be filled by [`estimate_into`]
    /// (scratch-buffer construction for the allocation-free hot path).
    pub fn empty() -> Self {
        Estimate {
            energy_pj: 0.0,
            level_energy_pj: Vec::new(),
            mac_energy_pj: 0.0,
            cycles: 0.0,
            level_words: Vec::new(),
            pes_used: 0,
        }
    }

    /// Overwrite `self` with `src`, reusing the level vectors' capacity
    /// (no allocation once lengths match).
    pub fn copy_from(&mut self, src: &Estimate) {
        self.energy_pj = src.energy_pj;
        self.level_energy_pj.clone_from(&src.level_energy_pj);
        self.mac_energy_pj = src.mac_energy_pj;
        self.cycles = src.cycles;
        self.level_words.clone_from(&src.level_words);
        self.pes_used = src.pes_used;
    }
}

/// Convert element traffic at a level to word traffic for tensor `t`.
#[inline]
fn words(arch: &Arch, elems: f64, t: Tensor, q: &LayerQuant) -> f64 {
    let bits = q.of(t);
    if arch.bit_packing {
        (elems / pack_factor(arch.word_bits, bits) as f64).ceil()
    } else {
        elems * crate::util::ceil_div(bits as u64, arch.word_bits as u64) as f64
    }
}

/// Price a nest analysis.
pub fn estimate(arch: &Arch, layer: &ConvLayer, q: &LayerQuant, nest: &NestAnalysis) -> Estimate {
    let _ = layer;
    let nl = arch.levels.len();
    let mut level_energy = vec![0.0; nl];
    let mut level_words = vec![0.0; nl];

    for lv in 0..nl {
        let al = &arch.levels[lv];
        for t in TENSORS {
            let a = nest.accesses[lv][t.index()];
            let w = words(arch, a.total(), t, q);
            level_words[lv] += w;
            level_energy[lv] += w * al.access_energy_pj[t.index()];
        }
    }

    let mac_energy = nest.macs as f64 * arch.mac_energy_pj;
    let energy: f64 = level_energy.iter().sum::<f64>() + mac_energy;

    // latency: bound by compute or by the busiest memory interface;
    // machine-total words are spread across a level's parallel instances
    let compute_cycles = nest.macs as f64 / nest.pes_used.max(1) as f64;
    let mut cycles = compute_cycles;
    for lv in 0..nl {
        let al = &arch.levels[lv];
        let level_cycles =
            level_words[lv] / (al.bandwidth_words * instance_count(arch, nest, lv) as f64);
        cycles = cycles.max(level_cycles);
    }

    Estimate {
        energy_pj: energy,
        level_energy_pj: level_energy,
        mac_energy_pj: mac_energy,
        cycles,
        level_words,
        pes_used: nest.pes_used,
    }
}

/// Allocation-free, table-driven [`estimate`]: identical math in the
/// same order (bit-identical results — asserted by
/// `tests/hotpath_equivalence.rs`), with per-level constants read from
/// the precomputed [`LayerContext`] and the result written into `out`
/// without reallocating in steady state.
pub fn estimate_into(lctx: &LayerContext, nest: &NestAnalysis, out: &mut Estimate) {
    let nl = lctx.num_levels;
    out.level_energy_pj.clear();
    out.level_energy_pj.resize(nl, 0.0);
    out.level_words.clear();
    out.level_words.resize(nl, 0.0);

    // energy table read from the contiguous `num_levels * 3` slab —
    // same values as `access_energy[lv][t]`, same accumulation order
    // (TENSORS is index order), so the sums stay bit-identical to the
    // naive path while the inner loop indexes one flat buffer.
    for lv in 0..nl {
        let ae = &lctx.access_energy_flat[lv * 3..lv * 3 + 3];
        for t in TENSORS {
            let a = nest.accesses[lv][t.index()];
            let w = lctx.words_f(t, a.total());
            out.level_words[lv] += w;
            out.level_energy_pj[lv] += w * ae[t.index()];
        }
    }

    out.mac_energy_pj = nest.macs as f64 * lctx.mac_energy_pj;
    out.energy_pj = out.level_energy_pj.iter().sum::<f64>() + out.mac_energy_pj;

    // latency: bound by compute or by the busiest memory interface;
    // machine-total words are spread across a level's parallel instances
    let compute_cycles = nest.macs as f64 / nest.pes_used.max(1) as f64;
    let mut cycles = compute_cycles;
    for lv in 0..nl {
        let inst = lctx.inst_cap[lv].min(nest.pes_used.max(1));
        let level_cycles = out.level_words[lv] / (lctx.bandwidth[lv] * inst as f64);
        cycles = cycles.max(level_cycles);
    }
    out.cycles = cycles;
    out.pes_used = nest.pes_used;
}

/// Reusable scratch for [`edp_lower_bound`] (no allocation in steady
/// state, like the rest of the hot path's buffers).
#[derive(Debug, Clone, Default)]
pub struct BoundScratch {
    reads: Vec<f64>,
    writes: Vec<f64>,
    level_words: Vec<f64>,
    level_energy: Vec<f64>,
}

impl BoundScratch {
    pub fn new() -> Self {
        BoundScratch::default()
    }
}

/// Admissible lower bound on the EDP of a candidate that survived
/// [`LayerContext::check_tiles_into`], computed straight from the
/// recorded tile-footprint slab (`elems[lv * 3 + tensor]`) — no reload
/// or multicast analysis, no instance products.
///
/// The bound under-counts the exact traffic termwise: the innermost
/// keeper of every tensor still moves all `macs` accesses (exact), and
/// each upper keeper below DRAM moves at least its own tile once
/// (`fills = tile x instances x reloads >= tile`, since both factors
/// are `>= 1`); every other term of the exact accumulation (fill
/// cascades into parent levels, output write-back and read-modify-write
/// traffic) is dropped, i.e. replaced by adding zero at its position in
/// the accumulation chain. Because IEEE round-to-nearest addition and
/// multiplication are monotone, each partial sum of this reduced chain
/// is `<=` the exact chain's partial sum, and multiplying by the
/// non-negative energy constants, dividing by the positive bandwidths
/// (both guaranteed by [`LayerContext::bound_safe`]; callers must not
/// prune when that flag is false), and taking `energy x cycles` on
/// non-negative values preserve the ordering — so
/// `edp_lower_bound(..) <= estimate_into(..).edp()` holds *bitwise*,
/// not merely approximately. `tests/hotpath_equivalence.rs` asserts the
/// property over every accepted candidate on the preset arches.
///
/// The latency term reuses the exact divisors: `mapping.pes_used()` is
/// precisely what the nest analysis reports, so the compute-bound term
/// matches the exact estimate and the bandwidth terms divide
/// under-counted words by the same `bandwidth x instances` products.
pub fn edp_lower_bound(
    lctx: &LayerContext,
    mapping: &Mapping,
    elems: &[u64],
    s: &mut BoundScratch,
) -> f64 {
    let nl = lctx.num_levels;
    debug_assert!(elems.len() >= nl * 3);
    s.reads.clear();
    s.reads.resize(nl * 3, 0.0);
    s.writes.clear();
    s.writes.resize(nl * 3, 0.0);
    let macs = lctx.macs as f64;
    for t in TENSORS {
        let ti = t.index();
        let keepers = &lctx.keepers[ti];
        let k0 = keepers[0];
        // innermost keeper: every MAC touches it — exact, not a bound
        s.reads[k0 * 3 + ti] += macs;
        if matches!(t, Tensor::Outputs) {
            s.writes[k0 * 3 + ti] += macs;
        }
        // each upper keeper below DRAM holds its tile at least once;
        // reads for Outputs (drained upward), writes for the others
        // (filled downward) — mirroring which side of the slot the
        // exact `fills` term lands on
        for w in keepers.windows(2) {
            let k = w[0];
            let tile = elems[k * 3 + ti] as f64;
            if matches!(t, Tensor::Outputs) {
                s.reads[k * 3 + ti] += tile;
            } else {
                s.writes[k * 3 + ti] += tile;
            }
        }
    }
    s.level_words.clear();
    s.level_words.resize(nl, 0.0);
    s.level_energy.clear();
    s.level_energy.resize(nl, 0.0);
    // identical accumulation shape to `estimate_into`, term-for-term
    for lv in 0..nl {
        let ae = &lctx.access_energy_flat[lv * 3..lv * 3 + 3];
        for t in TENSORS {
            let ti = t.index();
            let total = s.reads[lv * 3 + ti] + s.writes[lv * 3 + ti];
            let w = lctx.words_f(t, total);
            s.level_words[lv] += w;
            s.level_energy[lv] += w * ae[ti];
        }
    }
    let mac_energy = lctx.macs as f64 * lctx.mac_energy_pj;
    let energy = s.level_energy.iter().sum::<f64>() + mac_energy;
    let pes = mapping.pes_used().max(1);
    let mut cycles = lctx.macs as f64 / pes as f64;
    for lv in 0..nl {
        let inst = lctx.inst_cap[lv].min(pes);
        let level_cycles = s.level_words[lv] / (lctx.bandwidth[lv] * inst as f64);
        cycles = cycles.max(level_cycles);
    }
    energy * cycles
}

/// Number of parallel instances of level `lv`: total PEs divided by the
/// spatial fanout at or below the level. Fanout at level `l` multiplies
/// instances of everything *below* `l`, so instances(lv) = product of
/// fanouts of levels strictly above `lv` that are actually used.
fn instance_count(arch: &Arch, nest: &NestAnalysis, lv: usize) -> u64 {
    // We approximate used-fanout per level by the architecture fanout
    // capped by total PEs used; exact per-level usage would need the
    // mapping, which the nest result no longer carries. The top level has
    // 1 instance; a level below a fanout-F level has up to F instances.
    let mut max_inst: u64 = 1;
    for l in arch.levels.iter().skip(lv + 1) {
        max_inst = max_inst.saturating_mul(l.fanout);
    }
    max_inst.min(nest.pes_used.max(1))
}

/// Convenience: validity check + nest analysis + pricing in one call.
pub fn evaluate_mapping(
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    mapping: &crate::mapping::Mapping,
) -> Result<Estimate, crate::mapping::Violation> {
    crate::mapping::check(arch, layer, q, mapping)?;
    let nest = crate::nest::analyze(arch, layer, mapping);
    Ok(estimate(arch, layer, q, &nest))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{eyeriss, toy};
    use crate::mapping::Mapping;
    use crate::workload::ConvLayer;

    fn dram_heavy(l: &ConvLayer, nl: usize) -> Mapping {
        let mut m = Mapping::unit(nl);
        for d in 0..7 {
            m.levels[nl - 1].temporal[d] = l.dims[d];
        }
        m
    }

    #[test]
    fn lower_bitwidth_lowers_memory_energy() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let m = dram_heavy(&l, a.levels.len());
        let e8 = evaluate_mapping(&a, &l, &LayerQuant::uniform(8), &m).unwrap();
        let e4 = evaluate_mapping(&a, &l, &LayerQuant::uniform(4), &m).unwrap();
        let e2 = evaluate_mapping(&a, &l, &LayerQuant::uniform(2), &m).unwrap();
        assert!(e4.memory_energy_pj() < e8.memory_energy_pj());
        assert!(e2.memory_energy_pj() < e4.memory_energy_pj());
        // MAC energy must be bit-width independent (paper's setup)
        assert_eq!(e8.mac_energy_pj, e4.mac_energy_pj);
        assert_eq!(e8.mac_energy_pj, e2.mac_energy_pj);
    }

    #[test]
    fn packing_plateau_6_to_8_bits() {
        // pack factor is 2 for q in {6,7,8} at word 16: word traffic and
        // hence memory energy must be identical (paper: "for x >= 6 the
        // bit-packing yields no benefit" beyond the 8-bit case)
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let m = dram_heavy(&l, a.levels.len());
        let e8 = evaluate_mapping(&a, &l, &LayerQuant::uniform(8), &m).unwrap();
        let e7 = evaluate_mapping(&a, &l, &LayerQuant::uniform(7), &m).unwrap();
        let e6 = evaluate_mapping(&a, &l, &LayerQuant::uniform(6), &m).unwrap();
        assert_eq!(e8.memory_energy_pj(), e7.memory_energy_pj());
        assert_eq!(e8.memory_energy_pj(), e6.memory_energy_pj());
    }

    #[test]
    fn no_packing_removes_benefit() {
        let mut a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let m = dram_heavy(&l, a.levels.len());
        let packed = evaluate_mapping(&a, &l, &LayerQuant::uniform(4), &m).unwrap();
        a.bit_packing = false;
        let unpacked = evaluate_mapping(&a, &l, &LayerQuant::uniform(4), &m).unwrap();
        assert!(unpacked.memory_energy_pj() > 2.0 * packed.memory_energy_pj());
    }

    #[test]
    fn edp_positive_and_consistent() {
        let a = eyeriss();
        let l = ConvLayer::dw("dw2", 32, 3, 112, 1);
        let m = dram_heavy(&l, a.levels.len());
        let e = evaluate_mapping(&a, &l, &LayerQuant::uniform(8), &m).unwrap();
        assert!(e.energy_pj > 0.0);
        assert!(e.cycles > 0.0);
        assert!((e.edp() - e.energy_pj * e.cycles).abs() < 1e-6);
        assert_eq!(e.level_energy_pj.len(), a.levels.len());
        // DRAM should dominate for a dram-heavy mapping
        let dram = a.levels.len() - 1;
        let on_chip: f64 = e.level_energy_pj[..dram].iter().sum();
        assert!(e.level_energy_pj[dram] > on_chip * 0.1);
    }

    #[test]
    fn invalid_mapping_propagates_violation() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let m = Mapping::unit(a.levels.len());
        assert!(evaluate_mapping(&a, &l, &LayerQuant::uniform(8), &m).is_err());
    }

    #[test]
    fn more_pes_fewer_cycles() {
        use crate::workload::Dim;
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let nl = a.levels.len();
        let serial = dram_heavy(&l, nl);
        let mut parallel = dram_heavy(&l, nl);
        parallel.levels[1].spatial[Dim::K.index()] = 4;
        parallel.levels[nl - 1].temporal[Dim::K.index()] = 2;
        let q = LayerQuant::uniform(4);
        let es = evaluate_mapping(&a, &l, &q, &serial).unwrap();
        let ep = evaluate_mapping(&a, &l, &q, &parallel).unwrap();
        assert!(ep.cycles < es.cycles);
        assert_eq!(ep.pes_used, 4);
    }
}
