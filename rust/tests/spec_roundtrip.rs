//! Text-spec round-trips and shipped spec files: the `.qarch`/`.qnet`
//! formats are a public interface (the paper's "text specification"),
//! so the files in `specs/` must stay loadable and equivalent to the
//! built-in presets.

use qmap::arch::parser::{load_arch, parse_arch, render_arch};
use qmap::arch::presets;
use qmap::workload::parser::{load_net, parse_net, render_net};
use qmap::workload::models;

fn spec_path(name: &str) -> String {
    format!("{}/specs/{name}", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn shipped_qarch_files_match_presets() {
    for (file, preset) in [
        ("eyeriss.qarch", presets::eyeriss()),
        ("simba.qarch", presets::simba()),
        ("toy.qarch", presets::toy()),
    ] {
        let loaded = load_arch(&spec_path(file)).unwrap_or_else(|e| panic!("{file}: {e}"));
        assert_eq!(loaded, preset, "{file} drifted from the built-in preset");
    }
}

#[test]
fn arch_render_parse_roundtrip() {
    for a in [presets::eyeriss(), presets::simba(), presets::toy()] {
        let text = render_arch(&a);
        let back = parse_arch(&text).unwrap();
        assert_eq!(back, a);
    }
}

#[test]
fn shipped_qnet_loads_and_maps() {
    let net = load_net(&spec_path("tinynet.qnet")).unwrap();
    assert_eq!(net.len(), 6);
    // it must actually be mappable on every preset
    let cfg = qmap::mapper::MapperConfig {
        valid_target: 30,
        max_draws: 60_000,
        seed: 1,
        shards: 1,
    };
    for arch in [presets::eyeriss(), presets::simba(), presets::toy()] {
        let cache = qmap::mapper::cache::MapperCache::new();
        let qc = qmap::quant::QuantConfig::uniform(net.len(), 8);
        let e = qmap::eval::evaluate_network(&arch, &net, &qc, &cache, &cfg);
        assert!(e.is_some(), "tinynet failed to map on {}", arch.name);
    }
}

#[test]
fn net_render_parse_roundtrip() {
    for net in [models::mobilenet_v1(), models::mobilenet_v2()] {
        assert_eq!(parse_net(&render_net(&net)).unwrap(), net);
    }
}

#[test]
fn mobilenet_v2_layer_count_matches_paper_genome() {
    // 53 quantizable layers (stem + 17 blocks x (expand,dw,project) with
    // no expand on block 1 + final 1x1 + FC)
    assert_eq!(models::mobilenet_v2().len(), 53);
}

#[test]
fn constraints_ship_for_both_paper_archs() {
    use qmap::mapping::constraints::MapConstraints;
    for a in [presets::eyeriss(), presets::simba()] {
        let c = MapConstraints::for_arch(&a);
        c.validate(&a).unwrap();
        // constrained enumeration must still admit mappings for the
        // paper's Table-I layer
        let layer = &models::mobilenet_v1()[1];
        let space = qmap::mapping::mapspace::MapSpace::of(&a);
        let st = space.enumerate_valid(
            &a,
            layer,
            &qmap::quant::LayerQuant::uniform(8),
            500,
            |_| {},
        );
        assert!(st.valid > 0, "{}: constrained space empty", a.name);
    }
}
