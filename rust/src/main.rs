//! `qmap` — CLI for the quantization x mapping synergy explorer.
//!
//! Subcommands mirror the library's workflow: inspect architectures and
//! workloads, characterize quantized networks through the mapping
//! engine, run the NSGA-II search (proxy or real-QAT accuracy), and
//! regenerate every paper artifact from the terminal.

use qmap::accuracy::{AccuracyModel, ProxyAccuracy, ProxyParams};
use qmap::arch::{presets, Arch};
use qmap::baselines::{naive_search, search_with_objectives, uniform_sweep};
use qmap::coordinator::{experiments, RunConfig};
use qmap::engine::{driver, Backend, Checkpointer, Engine, WorkerSource};
use qmap::eval::evaluate_network;
use qmap::mapper::cache::MapperCache;
use qmap::mapper::{self, MapperConfig};
use qmap::mapping::mapspace::MapSpace;
use qmap::objective::ObjectiveSpec;
use qmap::obs::{self, Level};
use qmap::quant::{LayerQuant, QuantConfig};
use qmap::report;
use qmap::util::cli::Args;
use qmap::util::json::Json;
use qmap::workload::{models, ConvLayer};

const USAGE: &str = "\
qmap — quantization x mapping synergy for DNN accelerators
  (reproduction of Klhufek et al., DDECS 2024)

USAGE: qmap <command> [options]

inspect:
  arch      [--arch eyeriss|simba|toy | --spec file.qarch]   print + validate an accelerator
  layers    [--net v1|v2]                                    print a network's layer table
  map       [--arch A] [--net N] --layer I [--qa 8 --qw 8 --qo 8]
                                                             best mapping for one layer
  enumerate [--arch A] [--net N] --layer I [--qa ... ] [--limit 1e6]
                                                             exhaustive valid-mapping count

characterize:
  eval      [--arch A] [--net N] (--bits 8 | --genome 8/8,6/4,...)
                                                             full-network metrics
  search    [--arch A] [--net N] [--strategy proposed|naive|uniform]
            [--gens 20] [--pop 32] [--offspring 16]
            [--objectives error,energy,weight_words]         NSGA-II / baseline search over a
            [--checkpoint file.json [--resume]]              named k-objective space (default
            [--workers host:port,...|@fleet.txt]             edp,error; or QMAP_OBJECTIVES; axes:
            [--pipeline N] [--svg PREFIX]                    error energy memory_energy edp
            [--cache-dir DIR]                                cycles weight_words model_size).
                                                             Append-only journal checkpoint per
                                                             generation records the spec — resume
                                                             under another spec is refused;
                                                             shards fan out to qmap workers —
                                                             @file is re-read every generation
                                                             for elastic fleets, N batches
                                                             pipelined per connection (window
                                                             auto-clamps to measured RTT) —
                                                             results bit-identical to local.
                                                             --svg writes every 2-D projection
                                                             of the k-D front as PREFIX_*.svg.
                                                             --cache-dir (or QMAP_CACHE_DIR)
                                                             opens a persistent cross-process
                                                             mapper-cache store keyed by
                                                             arch+config identity — mismatch is
                                                             refused; warm runs bit-identical

distributed:
  worker    --listen HOST:PORT [--stdin-close]               serve mapper shard batches to a
            [--metrics HOST:PORT] [--cache-dir DIR]          remote `qmap search --workers`
                                                             driver (stateless; SIGTERM — and
                                                             stdin EOF with --stdin-close —
                                                             finishes the in-flight batch,
                                                             flushes, exits 0). --metrics
                                                             serves Prometheus-style counters
                                                             over HTTP; --cache-dir persists
                                                             shard outcomes so restarts and
                                                             fleets warm-start

observability:
  trace-report FILE                                          summarize a `--trace` JSONL file
                                                             (per-layer shard tables, cache and
                                                             dedup rates, remote batches,
                                                             checkpoint timing, faults)

engine:
  engine-stats [--budget N] [--workers host:port,...|@file]  work-stealing pool self-test:
               [--pipeline N] [--cache-dir DIR]              scaling rows + tail latency +
                                                             steal/split/remote counters,
                                                             bit-identity check; --cache-dir
                                                             reopens the store per row and
                                                             prints store hit/append stats

paper artifacts (same engines as `cargo bench`):
  fig1 [--n 250] | table1 | fig3 | fig4 | fig5 | fig6 | table2

runtime (needs `make artifacts`):
  train     [--steps 200] [--bits 8] [--lr 0.05]             PJRT QAT pre-training + loss curve

global: --threads N, --seed S, --profile fast|default|full (or QMAP_PROFILE),
        --trace FILE (JSONL event trace; bit-identical results, see trace-report),
        --quiet / --progress (suppress / force progress lines on stderr)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        std::process::exit(2);
    };
    let args = match Args::parse(
        &argv[1..],
        &["help", "csv", "no-packing", "emit", "resume", "stdin-close", "progress", "quiet"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") {
        print!("{USAGE}");
        return;
    }
    // flight recorder: the panic hook dumps the event ring for
    // post-mortem forensics; --quiet routes every Progress-level stderr
    // line through one policy (--progress wins when both are given)
    obs::install_panic_hook();
    obs::set_quiet(args.flag("quiet") && !args.flag("progress"));
    if let Some(path) = args.get("trace") {
        if let Err(e) = obs::trace_to(path) {
            eprintln!("error: --trace {path}: {e}");
            std::process::exit(2);
        }
    }
    if let Some(p) = args.get("profile") {
        std::env::set_var("QMAP_PROFILE", p);
    }
    let mut rc = match RunConfig::from_env() {
        Ok(rc) => rc,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    rc.threads = args.usize_or("threads", rc.threads);
    rc.seed = args.u64_or("seed", rc.seed);

    let code = match cmd.as_str() {
        "arch" => cmd_arch(&args),
        "layers" => cmd_layers(&args),
        "map" => cmd_map(&args),
        "enumerate" => cmd_enumerate(&args),
        "eval" => cmd_eval(&args, &rc),
        "search" => cmd_search(&args, &rc),
        "worker" => cmd_worker(&args),
        "engine-stats" => cmd_engine_stats(&args, &rc),
        "trace-report" => cmd_trace_report(&args),
        "fig1" => {
            let r = experiments::fig1_correlation(args.usize_or("n", 250), &rc);
            println!("pearson r size<->words {:+.4}, size<->EDP {:+.4}", r.r_size_words, r.r_size_edp);
            0
        }
        "table1" => {
            let rows = experiments::table1_mappings(args.u64_or("limit", 2_000_000));
            for r in rows {
                println!(
                    "{:7} ({:>2},{:>2},{:>2})  {:>9} mappings{}  min EDP {:.3e}",
                    r.arch, r.setting.0, r.setting.1, r.setting.2,
                    r.valid_mappings, if r.truncated { "+" } else { " " }, r.min_edp
                );
            }
            0
        }
        "fig3" => {
            for (name, r) in [
                ("a", experiments::fig3a_init_model(&rc)),
                ("b", experiments::fig3b_offspring(&rc)),
                ("c", experiments::fig3c_epochs(&rc)),
            ] {
                println!("fig3{name}:");
                for (label, front) in &r.arms {
                    println!("  {label}: {} front points", front.len());
                }
            }
            0
        }
        "fig4" => {
            for r in experiments::fig4_breakdown(&rc) {
                println!(
                    "{:>2}b  spads {:.3e}  buffers {:.3e}  dram {:.3e}  mac {:.3e}  total {:.3e}",
                    r.bits, r.components_pj[0], r.components_pj[1], r.components_pj[2],
                    r.components_pj[3], r.total_pj
                );
            }
            0
        }
        "fig5" => {
            let snaps: Vec<usize> = (0..=rc.nsga.generations).collect();
            let r = experiments::fig5_convergence(&rc, &snaps);
            for (g, front) in &r.fronts {
                println!("gen {g:>3}: {} pareto points", front.len());
            }
            0
        }
        "fig6" => {
            let r = experiments::fig6_tradeoff(&rc);
            print!("{}", report::pareto_table(&r.proposed, r.reference.0, r.reference.1, r.reference.2));
            0
        }
        "table2" => {
            for r in experiments::table2_summary(&rc, 4) {
                println!(
                    "{:8} {:12} {:9}  d_em {:+6.1}%  d_acc {:+5.1}%",
                    r.arch, r.network, r.strategy, r.delta_mem * 100.0, r.delta_acc * 100.0
                );
            }
            0
        }
        "train" => cmd_train(&args),
        _ => {
            eprintln!("unknown command '{cmd}'\n");
            print!("{USAGE}");
            2
        }
    };
    obs::trace_close();
    std::process::exit(code);
}

// ------------------------------------------------------------- helpers

fn load_arch(args: &Args) -> Result<Arch, String> {
    let mut arch = if let Some(path) = args.get("spec") {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        qmap::arch::parser::parse_arch(&src)?
    } else {
        let name = args.str_or("arch", "eyeriss");
        presets::by_name(&name).ok_or(format!("unknown arch '{name}' (try eyeriss|simba|toy)"))?
    };
    if args.flag("no-packing") {
        arch.bit_packing = false;
    }
    arch.validate()?;
    Ok(arch)
}

fn load_net(args: &Args) -> Result<Vec<ConvLayer>, String> {
    let spec = args.str_or("net", "v1");
    match spec.as_str() {
        "v1" | "mobilenetv1" => Ok(models::mobilenet_v1()),
        "v2" | "mobilenetv2" => Ok(models::mobilenet_v2()),
        // anything else is a `.qnet` layer-table file
        path => qmap::workload::parser::load_net(path)
            .map_err(|e| format!("{e} (or pass v1|v2 for the built-in tables)")),
    }
}

fn parse_genome(s: &str, n: usize) -> Result<QuantConfig, String> {
    let mut qc = QuantConfig::uniform(n, 8);
    let parts: Vec<&str> = s.split(',').collect();
    if parts.len() != n {
        return Err(format!("genome has {} entries, net has {n} layers", parts.len()));
    }
    for (i, p) in parts.iter().enumerate() {
        let (a, w) = p
            .split_once('/')
            .ok_or(format!("bad genome entry '{p}' (want qa/qw)"))?;
        qc.layers[i] = (
            a.trim().parse().map_err(|_| format!("bad qa '{a}'"))?,
            w.trim().parse().map_err(|_| format!("bad qw '{w}'"))?,
        );
    }
    Ok(qc)
}

fn fail(e: impl std::fmt::Display) -> i32 {
    eprintln!("error: {e}");
    1
}

/// The persistent cache-store directory: `--cache-dir DIR` beats
/// `QMAP_CACHE_DIR`; absent = no persistent tier.
fn cache_dir(args: &Args) -> Option<String> {
    args.get("cache-dir")
        .map(|s| s.to_string())
        .or_else(|| std::env::var("QMAP_CACHE_DIR").ok())
}

/// Open the search-side cache store under `dir` and attach it to
/// `cache`, or exit loudly: a mismatched identity (different arch or
/// mapper config), corrupt header, or unreadable path is a refusal —
/// silently searching cold (or worse, reusing foreign results) would
/// hide exactly the condition the operator needs to see.
fn attach_search_store(
    cache: &MapperCache,
    dir: &str,
    arch: &Arch,
    cfg: &MapperConfig,
) -> Result<(), String> {
    let store = qmap::mapper::store::open_search_store(dir, arch, cfg)
        .map_err(|e| e.to_string())?;
    obs::event_human(
        Level::Status,
        "store_open",
        vec![
            ("path", Json::Str(store.path().display().to_string())),
            ("entries", Json::Num(store.len() as f64)),
            ("skipped", Json::Num(store.skipped() as f64)),
            ("open_us", Json::Num(store.open_us() as f64)),
        ],
        &format!(
            "cache store {} ({} entries, opened in {} us)",
            store.path().display(),
            store.len(),
            store.open_us()
        ),
    );
    cache.set_backing(store);
    Ok(())
}

/// The end-of-run store summary (Status level: the CI smoke asserts on
/// the hit count). Counters are process-global, so this reports the
/// whole run's read-through/write-behind traffic.
fn store_summary() {
    use std::sync::atomic::Ordering::Relaxed;
    let m = obs::metrics::counters();
    let (h, mi, ap) = (
        m.store_hits.load(Relaxed),
        m.store_misses.load(Relaxed),
        m.store_appends.load(Relaxed),
    );
    obs::event_human(
        Level::Status,
        "store_summary",
        vec![
            ("hits", Json::Num(h as f64)),
            ("misses", Json::Num(mi as f64)),
            ("appends", Json::Num(ap as f64)),
        ],
        &format!("cache store: {h} hit(s), {mi} miss(es), {ap} append(s)"),
    );
}

/// Remote worker source: the `--workers` flag (comma-separated
/// `host:port` list, or `@file` for an elastic fleet file that is
/// re-read at every generation boundary), falling back to the
/// `QMAP_WORKERS` environment variable. An empty static list means
/// local-only.
fn worker_source(args: &Args) -> WorkerSource {
    match args.get("workers") {
        Some(s) => WorkerSource::parse(s),
        None => match std::env::var("QMAP_WORKERS") {
            Ok(s) => WorkerSource::parse(&s),
            Err(_) => WorkerSource::Static(Vec::new()),
        },
    }
}

/// The `--pipeline` override, warning (once, at parse time) on a
/// value that is not a positive integer rather than silently ignoring
/// the flag.
fn pipeline_override(args: &Args) -> Option<usize> {
    let d = args.get("pipeline")?;
    match d.parse::<usize>() {
        Ok(d) if d >= 1 => Some(d),
        _ => {
            obs::event_human(
                Level::Status,
                "warn",
                vec![("detail", Json::Str(format!("bad --pipeline '{d}'")))],
                &format!("warning: ignoring bad --pipeline '{d}' (want an integer >= 1)"),
            );
            None
        }
    }
}

/// Build the engine for a run: local, or distributed across the
/// configured `qmap worker` processes (results are bit-identical
/// either way; workers only add capacity). `--pipeline` overrides the
/// per-connection batch window (default `QMAP_PIPELINE_DEPTH` or 4).
fn build_engine(threads: usize, source: WorkerSource, args: &Args) -> Engine {
    let addrs = source.resolve();
    if !addrs.is_empty() {
        obs::event_human(
            Level::Progress,
            "distribute",
            vec![(
                "workers",
                Json::Arr(addrs.iter().map(|a| Json::Str(a.clone())).collect()),
            )],
            &format!(
                "distributing mapper shards to {} worker(s): {}",
                addrs.len(),
                addrs.join(", ")
            ),
        );
    }
    let mut engine = Engine::distributed_source(threads, source);
    if let Some(d) = pipeline_override(args) {
        engine = engine.with_pipeline_depth(d);
    }
    engine
}

// ------------------------------------------------------------ commands

fn cmd_arch(args: &Args) -> i32 {
    let arch = match load_arch(args) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("emit") {
        // print the round-trippable text specification (see specs/)
        print!("{}", qmap::arch::parser::render_arch(&arch));
        return 0;
    }
    println!(
        "{}: {} PEs, word {} bits, MAC {:.2} pJ, bit-packing {}",
        arch.name,
        arch.total_pes(),
        arch.word_bits,
        arch.mac_energy_pj,
        arch.bit_packing
    );
    let rows: Vec<Vec<String>> = arch
        .levels
        .iter()
        .map(|l| {
            vec![
                l.name.clone(),
                format!("{:?}", l.capacity),
                format!("{:?}", l.access_energy_pj),
                l.fanout.to_string(),
                l.spatial_dims.iter().map(|d| d.name()).collect::<String>(),
                format!("{:?}", l.keeps),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(
            &["level", "capacity [words]", "energy [pJ] W/I/O", "fanout", "spatial dims", "keeps W/I/O"],
            &rows
        )
    );
    0
}

fn cmd_layers(args: &Args) -> i32 {
    let layers = match load_net(args) {
        Ok(l) => l,
        Err(e) => return fail(e),
    };
    let rows: Vec<Vec<String>> = layers
        .iter()
        .map(|l| {
            vec![
                l.name.clone(),
                format!("{:?}", l.kind),
                format!("{:?}", l.dims),
                format!("{}x{}", l.stride.0, l.stride.1),
                l.macs().to_string(),
                l.tensor_elements(qmap::workload::Tensor::Weights).to_string(),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(&["layer", "kind", "[N,K,C,R,S,P,Q]", "stride", "MACs", "weights"], &rows)
    );
    println!(
        "total: {} MACs, {} weights",
        layers.iter().map(|l| l.macs()).sum::<u64>(),
        layers
            .iter()
            .map(|l| l.tensor_elements(qmap::workload::Tensor::Weights))
            .sum::<u64>()
    );
    0
}

fn cmd_map(args: &Args) -> i32 {
    let (arch, layers) = match (load_arch(args), load_net(args)) {
        (Ok(a), Ok(l)) => (a, l),
        (Err(e), _) | (_, Err(e)) => return fail(e),
    };
    let i = args.usize_or("layer", 1);
    if i >= layers.len() {
        return fail(format!("layer {i} out of range (net has {})", layers.len()));
    }
    let q = LayerQuant {
        qa: args.usize_or("qa", 8) as u8,
        qw: args.usize_or("qw", 8) as u8,
        qo: args.usize_or("qo", 8) as u8,
    };
    let cfg = MapperConfig::default();
    let r = mapper::search(&arch, &layers[i], &q, &cfg);
    println!(
        "layer '{}' on {} at (qa,qw,qo)=({},{},{}): {} valid / {} draws",
        layers[i].name, arch.name, q.qa, q.qw, q.qo, r.valid, r.draws
    );
    match (r.best, r.best_mapping) {
        (Some(est), Some(m)) => {
            print!("{}", m.render(&arch));
            println!(
                "energy {:.3e} pJ (memory {:.3e}), {:.0} cycles, EDP {:.3e}, PEs {}/{}",
                est.energy_pj,
                est.memory_energy_pj(),
                est.cycles,
                est.edp(),
                m.pes_used(),
                arch.total_pes()
            );
            0
        }
        _ => fail("no valid mapping found"),
    }
}

fn cmd_enumerate(args: &Args) -> i32 {
    let (arch, layers) = match (load_arch(args), load_net(args)) {
        (Ok(a), Ok(l)) => (a, l),
        (Err(e), _) | (_, Err(e)) => return fail(e),
    };
    let i = args.usize_or("layer", 1);
    let q = LayerQuant {
        qa: args.usize_or("qa", 8) as u8,
        qw: args.usize_or("qw", 8) as u8,
        qo: args.usize_or("qo", 8) as u8,
    };
    let limit = args.u64_or("limit", 2_000_000);
    let space = MapSpace::of(&arch);
    let mut min_edp = f64::INFINITY;
    let st = space.enumerate_valid(&arch, &layers[i], &q, limit, |m| {
        let nest = qmap::nest::analyze(&arch, &layers[i], m);
        let est = qmap::energy::estimate(&arch, &layers[i], &q, &nest);
        min_edp = min_edp.min(est.edp());
    });
    println!(
        "{} valid mappings{} ({} examined), min EDP {:.3e}",
        st.valid,
        if st.truncated { "+ (capped)" } else { "" },
        st.examined,
        min_edp
    );
    0
}

fn cmd_eval(args: &Args, rc: &RunConfig) -> i32 {
    let (arch, layers) = match (load_arch(args), load_net(args)) {
        (Ok(a), Ok(l)) => (a, l),
        (Err(e), _) | (_, Err(e)) => return fail(e),
    };
    let qc = if let Some(g) = args.get("genome") {
        match parse_genome(g, layers.len()) {
            Ok(q) => q,
            Err(e) => return fail(e),
        }
    } else {
        QuantConfig::uniform(layers.len(), args.usize_or("bits", 8) as u8)
    };
    let cache = MapperCache::new();
    match evaluate_network(&arch, &layers, &qc, &cache, &rc.mapper) {
        Some(e) => {
            println!("network on {}:", arch.name);
            println!("  energy        {:.4e} pJ (memory {:.4e}, MAC {:.4e})", e.energy_pj, e.memory_energy_pj, e.mac_energy_pj);
            println!("  breakdown     spads {:.3e} / buffers {:.3e} / dram {:.3e} pJ", e.energy_breakdown_pj[0], e.energy_breakdown_pj[1], e.energy_breakdown_pj[2]);
            println!("  latency       {:.4e} cycles", e.cycles);
            println!("  EDP           {:.4e} J*cycles", e.edp);
            println!("  weight words  {} (packed), model size {} bits", e.weight_words, e.model_size_bits);
            0
        }
        None => fail("some layer failed to map within the draw budget"),
    }
}

fn cmd_search(args: &Args, rc: &RunConfig) -> i32 {
    let (arch, layers) = match (load_arch(args), load_net(args)) {
        (Ok(a), Ok(l)) => (a, l),
        (Err(e), _) | (_, Err(e)) => return fail(e),
    };
    let mut nsga = rc.nsga;
    nsga.generations = args.usize_or("gens", nsga.generations);
    nsga.population = args.usize_or("pop", nsga.population);
    nsga.offspring = args.usize_or("offspring", nsga.offspring);
    // the run's objective space: --objectives beats QMAP_OBJECTIVES
    // (already folded into rc) beats the paper's edp,error default
    let objectives = match args.get("objectives") {
        Some(s) => match ObjectiveSpec::parse(s) {
            Ok(spec) => spec,
            Err(e) => return fail(e),
        },
        None => rc.objectives,
    };

    let engine =
        build_engine(rc.threads, worker_source(args), args).with_objectives(objectives);
    let distributed = matches!(engine.backend(), Backend::Distributed { .. });
    let cache = MapperCache::new();
    // --cache-dir/QMAP_CACHE_DIR: the persistent cross-process mapper
    // cache, keyed by arch + mapper-config identity. Strictly additive:
    // a warm run's front is bit-identical to a cold run's.
    let persistent = cache_dir(args);
    if let Some(dir) = &persistent {
        if let Err(e) = attach_search_store(&cache, dir, &arch, &rc.mapper) {
            return fail(e);
        }
    }
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
    let strategy = args.str_or("strategy", "proposed");
    let axis0 = objectives.axes()[0].name();
    let progress = |g: usize, pop: &[qmap::nsga::Individual]| {
        let best = pop.iter().map(|i| i.objectives[0]).fold(f64::INFINITY, f64::min);
        obs::event_human(
            Level::Progress,
            "gen_progress",
            vec![("gen", Json::Num(g as f64)), ("best", Json::Num(best))],
            &format!("gen {g:>3}: best {axis0} {best:.3e}"),
        );
    };
    if args.flag("resume") && args.get("checkpoint").is_none() {
        return fail("--resume needs --checkpoint FILE");
    }
    if args.get("checkpoint").is_some() && strategy != "proposed" {
        // refuse rather than silently run hours of un-checkpointed search
        return fail(format!(
            "--checkpoint is only supported with --strategy proposed (got '{strategy}')"
        ));
    }
    if strategy != "proposed"
        && (args.get("objectives").is_some() || objectives != ObjectiveSpec::default())
    {
        // naive pins its own (model_size, error) axes and uniform has
        // none — an ignored flag (or a silently dropped
        // QMAP_OBJECTIVES) would be worse than a refusal
        return fail(format!(
            "--objectives / QMAP_OBJECTIVES is only supported with --strategy proposed \
             (got '{strategy}')"
        ));
    }
    if args.get("svg").is_some() && strategy != "proposed" {
        // the projections are drawn in the search's objective space;
        // naive/uniform fronts were not optimized under these axes and
        // would render as false "Pareto fronts"
        return fail(format!(
            "--svg is only supported with --strategy proposed (got '{strategy}')"
        ));
    }
    let cands = match (strategy.as_str(), args.get("checkpoint")) {
        ("proposed", Some(path)) => {
            let ckpt = Checkpointer::new(path);
            let resume = args.flag("resume");
            if resume && ckpt.exists() {
                obs::event_human(
                    Level::Progress,
                    "resume",
                    vec![("path", Json::Str(path.to_string()))],
                    &format!("resuming from checkpoint {path}"),
                );
            }
            match driver::search_resumable(
                &engine, &arch, &layers, &mut acc, &cache, &rc.mapper, &nsga, &objectives,
                &ckpt, resume, progress,
            ) {
                Ok(c) => c,
                Err(e) => return fail(e),
            }
        }
        ("proposed", None) => search_with_objectives(
            &engine, &arch, &layers, &mut acc, &cache, &rc.mapper, &nsga, &objectives, progress,
        ),
        ("naive", _) => naive_search(&engine, &arch, &layers, &mut acc, &cache, &rc.mapper, &nsga),
        ("uniform", _) => {
            uniform_sweep(&engine, &arch, &layers, &mut acc, &cache, &rc.mapper, true)
        }
        (other, _) => return fail(format!("unknown strategy '{other}'")),
    };
    if distributed {
        // positive marker for scripts (the CI smoke asserts on it):
        // "remote job(s) > 0" proves the remote path actually executed
        // rather than silently degrading to local
        let st = engine.stats();
        obs::event_human(
            Level::Status,
            "distributed_summary",
            vec![
                ("remote_jobs", Json::Num(st.remote_jobs as f64)),
                ("requeued_specs", Json::Num(st.requeued_specs as f64)),
                ("lost_workers", Json::Num(st.lost_workers as f64)),
            ],
            &format!(
                "distributed: {} remote job(s), {} requeued spec(s), {} lost worker(s)",
                st.remote_jobs, st.requeued_specs, st.lost_workers
            ),
        );
    }
    let reference = evaluate_network(
        &arch,
        &layers,
        &QuantConfig::uniform(layers.len(), 8),
        &cache,
        &rc.mapper,
    )
    .expect("uniform-8 maps");
    let ref_acc = acc.accuracy(&QuantConfig::uniform(layers.len(), 8));
    print!(
        "{}",
        report::pareto_table(&cands, reference.edp, reference.memory_energy_pj, ref_acc)
    );
    if let Some(prefix) = args.get("svg") {
        // every 2-D projection of the k-D front (k*(k-1)/2 figures)
        let pts: Vec<Vec<f64>> = cands
            .iter()
            .map(|c| objectives.evaluate(Some(&c.hw), c.accuracy).into_values())
            .collect();
        let axis_names: Vec<&str> = objectives.axes().iter().map(|a| a.name()).collect();
        for (stem, svg) in
            report::svg::front_projections("Pareto front", &axis_names, &pts)
        {
            let path = format!("{prefix}_{stem}.svg");
            match std::fs::write(&path, svg) {
                Ok(()) => obs::event_human(
                    Level::Progress,
                    "wrote",
                    vec![("path", Json::Str(path.clone()))],
                    &format!("wrote {path}"),
                ),
                Err(e) => return fail(format!("{path}: {e}")),
            }
        }
    }
    if args.flag("csv") {
        let rows: Vec<Vec<String>> = cands
            .iter()
            .map(|c| {
                vec![
                    format!("{:.5}", c.accuracy),
                    format!("{:.5e}", c.hw.edp),
                    c.genome.layers.iter().map(|&(a, w)| format!("{a}/{w}")).collect::<Vec<_>>().join(","),
                ]
            })
            .collect();
        print!("{}", report::csv(&["accuracy", "edp", "genome"], &rows));
    }
    if persistent.is_some() {
        store_summary();
    }
    0
}

/// The worker's graceful-shutdown flag, raised by SIGTERM/SIGINT (and
/// by stdin EOF when `--stdin-close` asked for it). The handler only
/// performs an atomic store — async-signal-safe. No libc crate is
/// vendored and std exposes no signal API, so this binds the C
/// runtime's `signal(2)` directly (std already links libc).
#[cfg(unix)]
fn install_shutdown_signals() -> &'static std::sync::atomic::AtomicBool {
    use std::sync::atomic::AtomicBool;
    static SHUTDOWN: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
    }
    &SHUTDOWN
}

#[cfg(not(unix))]
fn install_shutdown_signals() -> &'static std::sync::atomic::AtomicBool {
    use std::sync::atomic::AtomicBool;
    static SHUTDOWN: AtomicBool = AtomicBool::new(false);
    &SHUTDOWN
}

/// Serve mapper shard batches to remote drivers: `qmap worker --listen
/// HOST:PORT`. Stateless — every batch carries its full context — so a
/// worker can be killed and restarted at any time; the driver re-runs
/// whatever was in flight. SIGTERM/SIGINT (and stdin EOF, with
/// `--stdin-close`, for supervisors that manage workers by pipe) drain
/// gracefully: the in-flight batch finishes and flushes its outcomes,
/// no new connections are accepted, and the process exits 0.
fn cmd_worker(args: &Args) -> i32 {
    let addr = args.str_or("listen", "127.0.0.1:7070");
    let listener = match std::net::TcpListener::bind(&addr) {
        Ok(l) => l,
        Err(e) => return fail(format!("bind {addr}: {e}")),
    };
    let local = listener
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| addr.clone());
    let shutdown = install_shutdown_signals();
    if args.flag("stdin-close") {
        let spawned = std::thread::Builder::new()
            .name("qmap-stdin-watch".into())
            .spawn(move || {
                use std::io::Read as _;
                let mut buf = [0u8; 256];
                let mut stdin = std::io::stdin();
                loop {
                    match stdin.read(&mut buf) {
                        Ok(0) | Err(_) => break, // EOF: parent is gone
                        Ok(_) => {}
                    }
                }
                eprintln!("qmap worker: stdin closed, draining");
                shutdown.store(true, std::sync::atomic::Ordering::SeqCst);
            });
        if let Err(e) = spawned {
            eprintln!("qmap worker: stdin watcher: {e}");
        }
    }
    if let Some(maddr) = args.get("metrics") {
        match obs::metrics::serve(maddr) {
            Ok(bound) => obs::event_human(
                Level::Status,
                "metrics_serve",
                vec![("addr", Json::Str(bound.clone()))],
                &format!("qmap worker metrics on http://{bound}/metrics"),
            ),
            Err(e) => return fail(format!("metrics {maddr}: {e}")),
        }
    }
    // --cache-dir/QMAP_CACHE_DIR: persist shard outcomes so worker
    // restarts (and whole fleets sharing the directory) warm-start. A
    // bad directory is reported at first use and the worker proceeds
    // cold — a fleet worker must not die over a cache tier.
    if let Some(dir) = cache_dir(args) {
        qmap::engine::remote::set_worker_store_dir(dir);
    }
    // the "listening" line is what scripts (and the CI smoke) wait for
    obs::event_human(
        Level::Status,
        "worker_listen",
        vec![("addr", Json::Str(local.clone()))],
        &format!(
            "qmap worker listening on {local} (protocol v{})",
            qmap::engine::proto::VERSION
        ),
    );
    let opts = qmap::engine::WorkerOptions {
        shutdown: Some(shutdown),
        ..qmap::engine::WorkerOptions::default()
    };
    qmap::engine::remote::serve(listener, opts);
    if shutdown.load(std::sync::atomic::Ordering::SeqCst) {
        eprintln!("qmap worker: drained, exiting");
        return 0;
    }
    fail("worker accept loop ended")
}

/// Summarize a `--trace` JSONL file: per-layer shard tables, dedup and
/// cache rates, remote batch latencies, checkpoint timing, and any
/// recorded faults. Pure text over the recorded events — running it
/// never touches a search.
fn cmd_trace_report(args: &Args) -> i32 {
    let Some(path) = args.positional.first() else {
        return fail("trace-report needs a trace file: qmap trace-report FILE");
    };
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => return fail(format!("{path}: {e}")),
    };
    match obs::report::report(&src) {
        Ok(text) => {
            print!("{text}");
            0
        }
        Err(e) => fail(format!("{path}: {e}")),
    }
}

/// Exercise the work-stealing engine on a small synthetic population and
/// print scaling rows plus the pool's counters — a quick sanity check
/// that parallel evaluation is (a) faster and (b) bit-identical to the
/// 1-worker baseline on this machine. With `--workers host:port,...`
/// the same check runs through the distributed backend.
fn cmd_engine_stats(args: &Args, rc: &RunConfig) -> i32 {
    use std::time::Instant;
    // `--workers N` historically meant the thread budget; keep that
    // reading when the value is a bare integer, now that `--workers`
    // means remote addresses everywhere else (`--budget` is explicit)
    let (legacy_budget, source) = match args.get("workers") {
        Some(s) => match s.parse::<usize>() {
            Ok(n) => (Some(n), WorkerSource::Static(Vec::new())),
            Err(_) => (None, WorkerSource::parse(s)),
        },
        None => (None, worker_source(args)),
    };
    let remote_workers = source.resolve();
    let pipeline = pipeline_override(args);
    let budget = args
        .usize_or("budget", legacy_budget.unwrap_or(rc.threads))
        .max(1);
    let arch = presets::toy();
    let layers = vec![
        ConvLayer::conv("c1", 3, 8, 3, 16, 1),
        ConvLayer::dw("d1", 8, 3, 16, 1),
        ConvLayer::pw("p1", 8, 16, 16),
        ConvLayer::fc("fc", 16, 10),
    ];
    let cfg = MapperConfig {
        valid_target: 200,
        max_draws: 200_000,
        seed: 9,
        shards: 4,
    };
    let mut rng = qmap::util::rng::Rng::new(0xE6);
    let genomes: Vec<QuantConfig> = (0..16)
        .map(|_| {
            let mut g = QuantConfig::uniform(layers.len(), 8);
            for l in g.layers.iter_mut() {
                l.0 = 2 + rng.below(7) as u8;
                l.1 = 2 + rng.below(7) as u8;
            }
            g
        })
        .collect();

    println!(
        "engine self-test: {} genomes x {} layers on '{}', budget {budget} (of {} cores)",
        genomes.len(),
        layers.len(),
        arch.name,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let mut workers: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&w| w <= budget)
        .collect();
    if !workers.contains(&budget) {
        workers.push(budget);
    }
    if !remote_workers.is_empty() {
        println!(
            "  fanning shards out to {} remote worker(s): {}",
            remote_workers.len(),
            remote_workers.join(", ")
        );
    }
    let store_dir = cache_dir(args);
    if let Some(dir) = &store_dir {
        println!("  persistent cache store under {dir} (reopened per row: rows after the first warm-start)");
    }
    let mut reference: Option<Vec<Option<qmap::eval::NetworkEval>>> = None;
    let mut t1 = 0.0f64;
    let mut last_guide = qmap::mapper::guide::GuideState::new();
    for &w in &workers {
        let mut engine = Engine::distributed_source(w, source.clone());
        if let Some(d) = pipeline {
            engine = engine.with_pipeline_depth(d);
        }
        let cache = MapperCache::new();
        // a fresh open per row sees the previous row's appends, so the
        // bit-identity column doubles as the warm == cold assertion
        if let Some(dir) = &store_dir {
            if let Err(e) = attach_search_store(&cache, dir, &arch, &cfg) {
                return fail(e);
            }
        }
        let t0 = Instant::now();
        let evals = driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &cache, &cfg);
        let dt = t0.elapsed().as_secs_f64();
        if w == 1 {
            t1 = dt;
        }
        let identical = match reference.take() {
            None => {
                reference = Some(evals);
                true
            }
            Some(r) => {
                let same = r == evals;
                reference = Some(r);
                same
            }
        };
        let st = engine.stats();
        // the tail metric is recorded by the local scheduling path
        // only; on the distributed backend it was never measured, so
        // print n/a instead of a misleading 0.0
        let tail_cell = match engine.backend() {
            Backend::Local => format!("{:>7.1} ms", st.last_tail_ms),
            Backend::Distributed { .. } => format!("{:>7} ms", "n/a"),
        };
        println!(
            "  workers {w:>2}: {:>8.1} ms  speedup {:>4.2}x  tail {tail_cell}  jobs {:>3}  splits {:>3}  tasks {:>4}  steals {:>4}  remote {:>3}  requeued {:>3}  lost {:>2}  identical {}",
            dt * 1e3,
            if dt > 0.0 && t1 > 0.0 { t1 / dt } else { 1.0 },
            st.jobs,
            st.splits,
            st.tasks,
            st.steals,
            st.remote_jobs,
            st.requeued_specs,
            st.lost_workers,
            identical
        );
        if !identical {
            eprintln!("error: engine results diverged from the 1-worker baseline");
            return 1;
        }
        last_guide = engine.guide_snapshot();
    }
    println!("results bit-identical across all worker counts");
    // validity-rate guidance + admissible-bound pruning summary (see
    // mapper::guide and energy::edp_lower_bound): what the search
    // learned about each workload, and how much pricing the bound
    // skipped. Observational — the rows above already asserted the
    // results cannot move.
    {
        let m = obs::metrics::counters();
        let g = |c: &std::sync::atomic::AtomicU64| c.load(std::sync::atomic::Ordering::Relaxed);
        let valid = g(&m.shard_valid);
        let pruned = g(&m.bound_pruned);
        let rate = if valid > 0 { pruned as f64 / valid as f64 * 100.0 } else { 0.0 };
        println!(
            "guide: {} workload(s) profiled, {} update(s), {} guided reordering(s); \
             bound pruning skipped pricing on {pruned} of {valid} valid candidates ({rate:.1}%)",
            last_guide.len(),
            g(&m.guide_updates),
            g(&m.guided_reorderings),
        );
        for (whash, (v, d)) in last_guide.iter() {
            let expected = last_guide.expected_draws(whash, &cfg);
            println!(
                "  whash {whash:016x}: valid {v} / drawn {d}  expected draws to target {expected}"
            );
        }
    }
    if store_dir.is_some() {
        store_summary();
    }
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> i32 {
    fail(
        "the PJRT training runtime is compiled out: rebuild with \
         `--features pjrt` (runs on the deterministic stub backend; a \
         real PJRT client plugs into runtime::backend::PjrtBackend — \
         see the [features] notes in rust/Cargo.toml)",
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> i32 {
    use qmap::data::SyntheticDataset;
    use qmap::runtime::{default_artifact_dir, Runtime};
    let rt = match Runtime::load(default_artifact_dir()) {
        Ok(r) => r,
        Err(e) => return fail(e),
    };
    println!("platform {}, model {}", rt.platform(), rt.meta.model);
    let data = SyntheticDataset::new(args.u64_or("seed", 0xDA7A));
    let steps = args.u64_or("steps", 200);
    let bits = args.usize_or("bits", 8) as u8;
    let lr = args.f64_or("lr", 0.05) as f32;
    let r = qmap::runtime::qat::QatAccuracy::pretrain(&rt, &data, bits, steps, lr, |s, l| {
        if s % 10 == 0 || s + 1 == steps {
            println!("step {s:>5}  loss {l:.4}");
        }
    });
    match r {
        Ok(_) => 0,
        Err(e) => fail(e),
    }
}
