//! §Perf: micro/meso benchmarks of the L3 hot paths. Not a paper
//! artifact — this is the before/after harness for the performance pass
//! recorded in EXPERIMENTS.md §Perf.
//!
//!   * mapper throughput, naive vs context path: candidate draws priced
//!     per second (draw + validity + nest analysis + energy model). The
//!     naive loop reproduces the pre-refactor hot path with the same
//!     functions it used (`random_mapping`/`check`/`analyze`/
//!     `estimate`), so the speedup is measured in one environment;
//!   * sharded single-layer characterization scaling,
//!   * full-network characterization latency (28 workloads × target
//!     valid mappings), cold and warm cache,
//!   * cache hit latency on the lock-striped cache,
//!   * parallel scaling of population evaluation.
//!
//! Run: `cargo bench --bench perf_hotpath`. Writes the machine-readable
//! trajectory record to `BENCH_perf.json` at the repository root.
//!
//! Both throughput numbers and their ratio are recorded so the >= 3x
//! acceptance bar of the hot-path refactor stays auditable across PRs.

use qmap::arch::presets;
use qmap::coordinator::experiments::parallel_map;
use qmap::energy::estimate_into;
use qmap::eval::evaluate_network;
use qmap::mapper::cache::MapperCache;
use qmap::mapper::{self, EvalContext, MapperConfig};
use qmap::mapping::mapspace::MapSpace;
use qmap::mapping::{check, LayerContext};
use qmap::nest::analyze_into;
use qmap::quant::{LayerQuant, QuantConfig};
use qmap::util::json::Json;
use qmap::util::rng::Rng;
use qmap::workload::models;
use std::time::Instant;

fn time<R>(label: &str, f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("{label:<58} {:>10.3} ms", dt * 1e3);
    (r, dt)
}

fn main() {
    println!("=== §Perf: L3 hot-path benchmarks ===\n");
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cfg = MapperConfig {
        valid_target: 2_000, // the paper's budget
        max_draws: 2_000_000,
        seed: 42,
        shards: 1,
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // 1. raw mapper throughput on the paper's dw-conv layer:
    //    (a) the pre-refactor path, reproduced with the naive per-draw
    //        functions it used (allocates on every draw);
    //    (b) the allocation-free LayerContext/EvalContext path.
    let layer = &layers[1];
    let q = LayerQuant { qa: 8, qw: 8, qo: 8 }.canonical(arch.word_bits, arch.bit_packing);
    let space = MapSpace::of(&arch);
    const PIPELINE_DRAWS: u64 = 200_000;

    let (naive_priced, dt_naive) = time(
        &format!("mapper: naive draw+check+analyze+estimate x {PIPELINE_DRAWS}"),
        || {
            let mut rng = Rng::new(42);
            let mut priced = 0u64;
            for _ in 0..PIPELINE_DRAWS {
                let m = space.random_mapping(layer, &mut rng);
                if check(&arch, layer, &q, &m).is_err() {
                    continue;
                }
                let nest = qmap::nest::analyze(&arch, layer, &m);
                let est = qmap::energy::estimate(&arch, layer, &q, &nest);
                std::hint::black_box(est.edp());
                priced += 1;
            }
            priced
        },
    );
    let naive_rate = PIPELINE_DRAWS as f64 / dt_naive;
    println!("  -> {naive_priced} valid priced, {naive_rate:.0} candidates/s/core (naive)");

    let (ctx_priced, dt_ctx) = time(
        &format!("mapper: ctx   draw+check+analyze+estimate x {PIPELINE_DRAWS}"),
        || {
            let lctx = LayerContext::new(&arch, layer, &q);
            let mut ectx = EvalContext::for_arch(&arch);
            let mut rng = Rng::new(42);
            let mut priced = 0u64;
            for _ in 0..PIPELINE_DRAWS {
                space.random_mapping_into(&lctx, &mut rng, &mut ectx.fbuf, &mut ectx.mapping);
                if lctx.check(&ectx.mapping, &mut ectx.ext).is_err() {
                    continue;
                }
                analyze_into(&lctx, &ectx.mapping, &mut ectx.ext, &mut ectx.nest);
                estimate_into(&lctx, &ectx.nest, &mut ectx.est);
                std::hint::black_box(ectx.est.edp());
                priced += 1;
            }
            priced
        },
    );
    let ctx_rate = PIPELINE_DRAWS as f64 / dt_ctx;
    let speedup = ctx_rate / naive_rate.max(1e-12);
    assert_eq!(
        naive_priced, ctx_priced,
        "naive and ctx paths must price identical candidate streams"
    );
    // `mappings_per_sec_*` = VALID mappings priced per second (the
    // historical meaning of the key); `candidates_per_sec_*` = raw
    // draws per second including invalid candidates. Both paths walk
    // the identical candidate stream, so the two ratios agree.
    let naive_valid_rate = naive_priced as f64 / dt_naive;
    let ctx_valid_rate = ctx_priced as f64 / dt_ctx;
    println!("  -> {ctx_priced} valid priced, {ctx_rate:.0} candidates/s/core (ctx)");
    println!("  -> hot-path speedup {speedup:.2}x (target >= 3x)");

    // 2. random-search characterization of one layer (2000 valid),
    //    1 shard vs all-core sharding
    let cache = MapperCache::new();
    let (_, dt2) = time("mapper: random search, 1 layer, 2000 valid, 1 shard", || {
        cache.evaluate(&arch, layer, &q, &cfg)
    });
    println!("  -> {:.0} layer-characterizations/s possible", 1.0 / dt2);
    let sharded_cfg = MapperConfig { shards: threads, ..cfg };
    let (_, dt2s) = time(
        &format!("mapper: random search, 1 layer, 2000 valid, {threads} shards"),
        || mapper::search(&arch, layer, &q, &sharded_cfg),
    );
    let shard_scaling = dt2 / dt2s.max(1e-12);
    println!("  -> sharded speedup {shard_scaling:.1}x on {threads} shards");

    // 3. full MobileNetV1 characterization, cold vs warm cache
    let cache2 = MapperCache::new();
    let qc = QuantConfig::uniform(layers.len(), 8);
    let (r_cold, dt_cold) = time("network: MobileNetV1 cold-cache characterization", || {
        evaluate_network(&arch, &layers, &qc, &cache2, &cfg)
    });
    assert!(r_cold.is_some());
    let (_, dt_warm) = time("network: MobileNetV1 warm-cache (identical genome)", || {
        evaluate_network(&arch, &layers, &qc, &cache2, &cfg)
    });
    println!(
        "  -> warm/cold speedup {:.0}x; warm per-genome {:.1} µs",
        dt_cold / dt_warm.max(1e-12),
        dt_warm * 1e6
    );

    // 4. cache hit latency (single layer, striped cache)
    let (_, dth) = time("cache: single-workload hit x 100k", || {
        for _ in 0..100_000 {
            std::hint::black_box(cache2.evaluate(&arch, layer, &q, &cfg));
        }
    });
    let cache_hit_ns = dth * 1e9 / 1e5;
    println!("  -> {cache_hit_ns:.0} ns per hit");

    // 5. parallel scaling: 64 random genomes on 1 vs N threads
    let mut rng = Rng::new(7);
    let genomes: Vec<QuantConfig> = (0..64)
        .map(|_| {
            let mut g = QuantConfig::uniform(layers.len(), 8);
            for l in g.layers.iter_mut() {
                l.0 = 2 + rng.below(7) as u8;
                l.1 = 2 + rng.below(7) as u8;
            }
            g
        })
        .collect();
    let fresh = MapperCache::new();
    let (_, dt1) = time("population: 64 genomes, 1 thread, shared cold cache", || {
        for g in &genomes {
            std::hint::black_box(evaluate_network(&arch, &layers, g, &fresh, &cfg));
        }
    });
    let fresh2 = MapperCache::new();
    let (_, dtn) = time(
        &format!("population: 64 genomes, {threads} threads, shared cold cache"),
        || {
            parallel_map(&genomes, threads, |g| {
                evaluate_network(&arch, &layers, g, &fresh2, &cfg).map(|e| e.edp)
            })
        },
    );
    let pop64 = dt1 / dtn.max(1e-12);
    println!("  -> parallel speedup {pop64:.1}x on {threads} threads");

    // summary + machine-readable record for the perf trajectory
    println!("\nsummary:");
    println!("  mappings_per_sec_core        = {ctx_valid_rate:.0}");
    println!("  mappings_per_sec_core_naive  = {naive_valid_rate:.0}");
    println!("  candidates_per_sec_core      = {ctx_rate:.0}");
    println!("  candidates_per_sec_core_naive= {naive_rate:.0}");
    println!("  hotpath_speedup_x            = {speedup:.2}");
    println!("  shard_scaling_x              = {shard_scaling:.2}");
    println!("  network_cold_ms              = {:.1}", dt_cold * 1e3);
    println!("  network_warm_us              = {:.1}", dt_warm * 1e6);
    println!("  cache_hit_ns                 = {cache_hit_ns:.0}");
    println!("  pop64_speedup_x              = {pop64:.1}");

    let record = Json::obj(vec![
        ("bench", Json::Str("perf_hotpath".into())),
        ("pipeline_draws", Json::Num(PIPELINE_DRAWS as f64)),
        // valid mappings priced per second (naive twin measured in the
        // same run on the same candidate stream)
        ("mappings_per_sec_core", Json::Num(ctx_valid_rate)),
        ("mappings_per_sec_core_naive", Json::Num(naive_valid_rate)),
        // raw candidate draws per second, invalid draws included
        ("candidates_per_sec_core", Json::Num(ctx_rate)),
        ("candidates_per_sec_core_naive", Json::Num(naive_rate)),
        ("hotpath_speedup_x", Json::Num(speedup)),
        ("shard_scaling_x", Json::Num(shard_scaling)),
        ("threads", Json::Num(threads as f64)),
        ("network_cold_ms", Json::Num(dt_cold * 1e3)),
        ("network_warm_us", Json::Num(dt_warm * 1e6)),
        ("cache_hit_ns", Json::Num(cache_hit_ns)),
        ("pop64_speedup_x", Json::Num(pop64)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_perf.json");
    match std::fs::write(path, record.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
