//! FSM model of one connection's pipelined batch window — the model
//! twin of `engine::remote::PipelineWindow` plus one
//! [`BatchLedger`](crate::engine::remote::BatchLedger) per job.
//!
//! The state tracks what the adaptive-depth bookkeeping *must* track:
//! `timings` counts the send/first-outcome timestamps the EWMA
//! machinery holds, and the model keeps it equal to
//! `|inflight| + |{batches with an outcome seen}|` at every step. The
//! conformance projection reads the count from the **real**
//! `sent_at`/`first_out` vectors, so a drain leak on loss (stale
//! stamps surviving the window) is a retraction mismatch, not a
//! sampled flake.

use super::Fsm;

/// One connection over `jobs` claimable jobs of `shards` shards each,
/// windowed at configured pipeline depth `depth`.
pub struct WindowModel {
    pub jobs: usize,
    pub shards: usize,
    pub depth: usize,
}

/// The driver's view of one job.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct JobView {
    /// Claimed by this connection (sent, or send-failed).
    pub claimed: bool,
    /// Ledger slots filled.
    pub delivered: Vec<bool>,
    /// `done` consumed — the batch left the window.
    pub completed: bool,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct WindowState {
    /// `(job, first_outcome_seen)` per in-flight batch, send order.
    pub inflight: Vec<(usize, bool)>,
    pub jobs: Vec<JobView>,
    /// Connection condemned (loss, protocol violation, send failure).
    pub lost: bool,
    /// The driver's refill-and-merge sweep ran (terminal).
    pub swept: bool,
    /// Timing entries the adaptive-depth EWMA holds: one send stamp
    /// per in-flight batch plus one first-outcome stamp per in-flight
    /// batch that has streamed at least one outcome. Projected from
    /// the real `sent_at`/`first_out` lengths.
    pub timings: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WindowEvent {
    /// Claim the lowest unclaimed job and ship it.
    Send,
    /// Claim the lowest unclaimed job, fail the write: the claim
    /// stands (pseudo batch id 0 joins the window so the owed count
    /// sees its specs), the connection is condemned and drained.
    SendFail,
    /// One `outcome` frame for an in-flight batch; re-delivery of a
    /// filled shard is the duplicate fault.
    Outcome { job: usize, shard: usize },
    /// `outcome` for a batch already done — stale, ignored.
    StaleOutcome { job: usize },
    /// `done` for an in-flight batch (possibly before every outcome).
    Done { job: usize },
    /// `done` for a batch already done — stale, ignored.
    StaleDone { job: usize },
    /// Connection loss: every in-flight batch drains (keeping what it
    /// already received), all timing stamps drain with them.
    Lose,
    /// The driver's sweep: refill every claimed job's missing shards,
    /// merge each in shard-index order (terminal).
    Sweep,
}

impl WindowModel {
    fn live(&self, s: &WindowState) -> bool {
        !s.lost && !s.swept
    }

    fn next_unclaimed(&self, s: &WindowState) -> Option<usize> {
        s.jobs.iter().position(|j| !j.claimed)
    }

    fn retime(s: &mut WindowState) {
        s.timings = s.inflight.len() + s.inflight.iter().filter(|&&(_, f)| f).count();
    }
}

impl Fsm for WindowModel {
    type State = WindowState;
    type Event = WindowEvent;

    fn name(&self) -> String {
        "window".to_string()
    }

    fn initial(&self) -> WindowState {
        WindowState {
            inflight: Vec::new(),
            jobs: (0..self.jobs)
                .map(|_| JobView {
                    claimed: false,
                    delivered: vec![false; self.shards],
                    completed: false,
                })
                .collect(),
            lost: false,
            swept: false,
            timings: 0,
        }
    }

    fn events(&self, s: &WindowState) -> Vec<WindowEvent> {
        let mut evs = Vec::new();
        if self.live(s) {
            if s.inflight.len() < self.depth && self.next_unclaimed(s).is_some() {
                evs.push(WindowEvent::Send);
                evs.push(WindowEvent::SendFail);
            }
            for &(job, _) in &s.inflight {
                for shard in 0..self.shards {
                    evs.push(WindowEvent::Outcome { job, shard });
                }
                evs.push(WindowEvent::Done { job });
            }
            for (job, j) in s.jobs.iter().enumerate() {
                if j.completed {
                    evs.push(WindowEvent::StaleOutcome { job });
                    evs.push(WindowEvent::StaleDone { job });
                }
            }
            evs.push(WindowEvent::Lose);
        }
        if !s.swept && (s.lost || s.inflight.is_empty()) {
            evs.push(WindowEvent::Sweep);
        }
        evs
    }

    fn step(&self, s: &WindowState, e: &WindowEvent) -> WindowState {
        let mut n = s.clone();
        match e {
            WindowEvent::Send => {
                if self.live(s) && s.inflight.len() < self.depth {
                    if let Some(j) = self.next_unclaimed(s) {
                        n.jobs[j].claimed = true;
                        n.inflight.push((j, false));
                    }
                }
            }
            WindowEvent::SendFail => {
                if self.live(s) && s.inflight.len() < self.depth {
                    if let Some(j) = self.next_unclaimed(s) {
                        n.jobs[j].claimed = true;
                        n.lost = true;
                        n.inflight.clear();
                    }
                }
            }
            WindowEvent::Outcome { job, shard } => {
                if self.live(s) && *shard < self.shards {
                    if let Some(p) = n.inflight.iter().position(|&(j, _)| j == *job) {
                        n.inflight[p].1 = true;
                        n.jobs[*job].delivered[*shard] = true;
                    }
                }
            }
            WindowEvent::StaleOutcome { .. } | WindowEvent::StaleDone { .. } => {}
            WindowEvent::Done { job } => {
                if self.live(s) {
                    if let Some(p) = n.inflight.iter().position(|&(j, _)| j == *job) {
                        n.inflight.remove(p);
                        n.jobs[*job].completed = true;
                    }
                }
            }
            WindowEvent::Lose => {
                if self.live(s) {
                    n.lost = true;
                    n.inflight.clear();
                }
            }
            WindowEvent::Sweep => {
                if !s.swept && (s.lost || s.inflight.is_empty()) {
                    n.swept = true;
                }
            }
        }
        Self::retime(&mut n);
        n
    }

    fn invariant(&self, s: &WindowState) -> Result<(), String> {
        if s.inflight.len() > self.depth {
            return Err(format!(
                "window overflow: {} in flight > depth {}",
                s.inflight.len(),
                self.depth
            ));
        }
        let expect = s.inflight.len() + s.inflight.iter().filter(|&&(_, f)| f).count();
        if s.timings != expect {
            return Err(format!(
                "timing-stamp leak: {} stamps tracked, window accounts for {expect}",
                s.timings
            ));
        }
        for (i, &(job, _)) in s.inflight.iter().enumerate() {
            if s.inflight.iter().skip(i + 1).any(|&(j, _)| j == job) {
                return Err(format!("job {job} in flight twice"));
            }
            let j = &s.jobs[job];
            if !j.claimed || j.completed {
                return Err(format!("in-flight job {job} not claimed-and-open"));
            }
        }
        if s.lost && !s.inflight.is_empty() {
            return Err("lost connection with an undrained window".to_string());
        }
        Ok(())
    }

    fn show_event(&self, e: &WindowEvent) -> String {
        match e {
            WindowEvent::Send => "send".to_string(),
            WindowEvent::SendFail => "sendfail".to_string(),
            WindowEvent::Outcome { job, shard } => format!("out:{job}.{shard}"),
            WindowEvent::StaleOutcome { job } => format!("stale_out:{job}"),
            WindowEvent::Done { job } => format!("done:{job}"),
            WindowEvent::StaleDone { job } => format!("stale_done:{job}"),
            WindowEvent::Lose => "lose".to_string(),
            WindowEvent::Sweep => "sweep".to_string(),
        }
    }

    fn parse_event(&self, line: &str) -> Option<WindowEvent> {
        if let Some(rest) = line.strip_prefix("out:") {
            let (j, s) = rest.split_once('.')?;
            return Some(WindowEvent::Outcome {
                job: j.parse().ok()?,
                shard: s.parse().ok()?,
            });
        }
        if let Some(j) = line.strip_prefix("stale_out:") {
            return j.parse().ok().map(|job| WindowEvent::StaleOutcome { job });
        }
        if let Some(j) = line.strip_prefix("stale_done:") {
            return j.parse().ok().map(|job| WindowEvent::StaleDone { job });
        }
        if let Some(j) = line.strip_prefix("done:") {
            return j.parse().ok().map(|job| WindowEvent::Done { job });
        }
        match line {
            "send" => Some(WindowEvent::Send),
            "sendfail" => Some(WindowEvent::SendFail),
            "lose" => Some(WindowEvent::Lose),
            "sweep" => Some(WindowEvent::Sweep),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{explore, Budget};

    /// The documented small scope: worker loss × pipelining at depth
    /// ≤ 2 is *exhausted* (every interleaving deduped, frontier empty)
    /// well past the acceptance floor of depth 6.
    #[test]
    fn window_model_exhausts_the_small_scope() {
        let m = WindowModel {
            jobs: 3,
            shards: 2,
            depth: 2,
        };
        // a full fault-free run is 13 events (3 sends + 6 outcomes +
        // 3 dones + sweep); depth 14 covers it plus one fault/dup
        let cov = explore(&m, &Budget::new(14, 400_000)).expect("no violation");
        assert!(cov.complete, "small scope must be exhausted");
        assert!(cov.deepest >= 13, "got depth {}", cov.deepest);
        assert!(cov.states >= 400, "got {} states", cov.states);
    }

    #[test]
    fn window_grammar_round_trips() {
        let m = WindowModel {
            jobs: 2,
            shards: 2,
            depth: 2,
        };
        for ev in [
            WindowEvent::Send,
            WindowEvent::SendFail,
            WindowEvent::Outcome { job: 1, shard: 0 },
            WindowEvent::StaleOutcome { job: 0 },
            WindowEvent::Done { job: 1 },
            WindowEvent::StaleDone { job: 1 },
            WindowEvent::Lose,
            WindowEvent::Sweep,
        ] {
            let s = m.show_event(&ev);
            assert_eq!(m.parse_event(&s), Some(ev), "grammar: {s}");
        }
        assert_eq!(m.parse_event("out:1"), None);
    }

    /// The leak the model exists to catch: hand-build a state whose
    /// stamp count disagrees with the window and watch the invariant
    /// reject it.
    #[test]
    fn stale_timing_stamps_violate_the_invariant() {
        let m = WindowModel {
            jobs: 2,
            shards: 2,
            depth: 2,
        };
        let mut s = m.initial();
        s = m.step(&s, &WindowEvent::Send);
        s = m.step(&s, &WindowEvent::Lose);
        assert!(m.invariant(&s).is_ok(), "drained loss is clean");
        s.timings = 1; // a sent_at stamp that survived the drain
        assert!(m.invariant(&s).is_err(), "leaked stamp must be caught");
    }
}
