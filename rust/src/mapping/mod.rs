//! Mapping representation and validity checking.
//!
//! A mapping assigns, for every storage level of the hierarchy:
//! * temporal tiling factors per problem dim,
//! * spatial tiling factors per dim (only at levels with fanout > 1),
//! * a temporal loop permutation (innermost-first).
//!
//! Validity = (1) factor products reproduce the workload dims,
//! (2) spatial factors fit the fanout and allowed-dim constraints,
//! (3) every kept tile fits its buffer **in memory words after
//! bit-packing** — the paper's extension: lower bit-widths shrink word
//! footprints, admitting mappings that are invalid at 16 bits. This is
//! exactly why Table I's mapping counts grow as precision drops.

pub mod constraints;
pub mod context;
pub mod factorize;
pub mod mapspace;

pub use context::LayerContext;

use crate::arch::Arch;
use crate::quant::{packed_words, unpacked_words, LayerQuant};
use crate::workload::{ConvLayer, Dim, Tensor, DIMS, TENSORS};

/// Per-level portion of a mapping.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct LevelMapping {
    /// Temporal tiling factors, indexed by `Dim::index()`.
    pub temporal: [u64; 7],
    /// Spatial factors (fanout below this level), indexed by dim.
    pub spatial: [u64; 7],
    /// Temporal loop order at this level, innermost first.
    pub perm: [Dim; 7],
}

impl LevelMapping {
    pub fn unit() -> Self {
        LevelMapping {
            temporal: [1; 7],
            spatial: [1; 7],
            perm: DIMS,
        }
    }

    pub fn temporal_product(&self) -> u64 {
        self.temporal.iter().product()
    }

    pub fn spatial_product(&self) -> u64 {
        self.spatial.iter().product()
    }
}

/// A complete mapping of one layer onto one architecture
/// (`levels.len() == arch.levels.len()`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Mapping {
    pub levels: Vec<LevelMapping>,
}

impl Mapping {
    pub fn unit(num_levels: usize) -> Self {
        Mapping {
            levels: vec![LevelMapping::unit(); num_levels],
        }
    }

    /// Reset all factors to 1 and permutations to canonical, in place
    /// (the allocation-free analogue of `Mapping::unit`).
    pub fn reset_unit(&mut self) {
        for lm in &mut self.levels {
            *lm = LevelMapping::unit();
        }
    }

    /// Overwrite `self` with `other` without reallocating (level counts
    /// must match).
    pub fn copy_from(&mut self, other: &Mapping) {
        self.levels.clone_from_slice(&other.levels);
    }

    /// Cumulative tile extents at level `lv`: for each dim, the product
    /// of all temporal and spatial factors at levels `<= lv`. This is the
    /// per-instance data footprint boundary of level `lv`.
    pub fn tile_extents(&self, lv: usize) -> [u64; 7] {
        let mut t = [1u64; 7];
        for l in &self.levels[..=lv] {
            for d in 0..7 {
                t[d] *= l.temporal[d] * l.spatial[d];
            }
        }
        t
    }

    /// Per-dim product across all levels (must equal the workload dims).
    pub fn total_extents(&self) -> [u64; 7] {
        self.tile_extents(self.levels.len() - 1)
    }

    /// Number of parallel instances of level `lv` in the machine
    /// (product of spatial factors at strictly higher levels).
    pub fn instances(&self, lv: usize) -> u64 {
        self.levels[lv + 1..]
            .iter()
            .map(|l| l.spatial_product())
            .product()
    }

    /// Total MAC lanes used = product of all spatial factors.
    pub fn pes_used(&self) -> u64 {
        self.levels.iter().map(|l| l.spatial_product()).product()
    }

    /// Compact human-readable rendering (for logs / debugging).
    pub fn render(&self, arch: &Arch) -> String {
        let mut s = String::new();
        for (i, (lm, al)) in self.levels.iter().zip(&arch.levels).enumerate().rev() {
            s.push_str(&format!("L{i} {:<12}", al.name));
            s.push_str(" T[");
            for d in DIMS {
                if lm.temporal[d.index()] > 1 {
                    s.push_str(&format!("{}{} ", d.name(), lm.temporal[d.index()]));
                }
            }
            s.push(']');
            if lm.spatial_product() > 1 {
                s.push_str(" S[");
                for d in DIMS {
                    if lm.spatial[d.index()] > 1 {
                        s.push_str(&format!("{}{} ", d.name(), lm.spatial[d.index()]));
                    }
                }
                s.push(']');
            }
            s.push('\n');
        }
        s
    }
}

/// Why a mapping is invalid (used by tests and the mapper's rejection
/// statistics; mirrors the paper's "checker which checks for mapping
/// violations").
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    /// Factor product along `dim` does not reproduce the workload size.
    FactorProduct(Dim),
    /// Spatial factors exceed the level fanout.
    FanoutExceeded { level: usize },
    /// Spatial factor on a dim the level's dataflow does not allow.
    SpatialDimNotAllowed { level: usize, dim: Dim },
    /// A kept tile does not fit its buffer (in words, after packing).
    CapacityExceeded {
        level: usize,
        tensor: Tensor,
        needed_words: u64,
        available_words: u64,
    },
    /// A `Capacity::Shared` pool overflows in aggregate: no single
    /// tensor is to blame, the *sum* of kept tiles exceeds the pool.
    SharedCapacityExceeded {
        level: usize,
        needed_words: u64,
        available_words: u64,
    },
    /// Spatial factors at a level with no fanout.
    SpatialAtLeafLevel { level: usize },
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::FactorProduct(d) => {
                write!(f, "factor product along {} != workload size", d.name())
            }
            Violation::FanoutExceeded { level } => {
                write!(f, "spatial product exceeds fanout at level {level}")
            }
            Violation::SpatialDimNotAllowed { level, dim } => {
                write!(f, "spatial {} not allowed at level {level}", dim.name())
            }
            Violation::CapacityExceeded { level, tensor, needed_words, available_words } => write!(
                f,
                "{tensor:?} tile needs {needed_words} words at level {level}, only {available_words} available"
            ),
            Violation::SharedCapacityExceeded { level, needed_words, available_words } => write!(
                f,
                "shared pool at level {level} needs {needed_words} words in aggregate, only {available_words} available"
            ),
            Violation::SpatialAtLeafLevel { level } => {
                write!(f, "spatial factors at fanout-1 level {level}")
            }
        }
    }
}

/// Words occupied at `level` by tensor `t`'s tile, given quantization.
pub fn tile_words(
    arch: &Arch,
    layer: &ConvLayer,
    mapping: &Mapping,
    lv: usize,
    t: Tensor,
    q: &LayerQuant,
) -> u64 {
    let tile = mapping.tile_extents(lv);
    let elems = layer.tile_elements(t, &clamp_tile(layer, &tile));
    let bits = q.of(t);
    let wb = arch.word_bits;
    if arch.bit_packing {
        packed_words(elems, wb, bits)
    } else {
        unpacked_words(elems, wb, bits)
    }
}

/// Clamp cumulative tile extents to the workload dims (products can only
/// equal the dim when valid; during partial construction they may not).
fn clamp_tile(layer: &ConvLayer, tile: &[u64; 7]) -> [u64; 7] {
    let mut out = *tile;
    for d in 0..7 {
        out[d] = out[d].min(layer.dims[d]);
    }
    out
}

/// Full validity check. Returns the first violation found, or `Ok`.
pub fn check(
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    mapping: &Mapping,
) -> Result<(), Violation> {
    assert_eq!(mapping.levels.len(), arch.levels.len());

    // (1) factor products
    let totals = mapping.total_extents();
    for d in DIMS {
        if totals[d.index()] != layer.size(d) {
            return Err(Violation::FactorProduct(d));
        }
    }

    // (2) spatial constraints
    for (lv, (lm, al)) in mapping.levels.iter().zip(&arch.levels).enumerate() {
        let sp = lm.spatial_product();
        if al.fanout == 1 {
            if sp != 1 {
                return Err(Violation::SpatialAtLeafLevel { level: lv });
            }
            continue;
        }
        if sp > al.fanout {
            return Err(Violation::FanoutExceeded { level: lv });
        }
        for d in DIMS {
            if lm.spatial[d.index()] > 1 && !al.spatial_dims.contains(&d) {
                return Err(Violation::SpatialDimNotAllowed { level: lv, dim: d });
            }
        }
    }

    // (3) capacity with bit-packing; DRAM (last level) is unbounded
    for lv in 0..arch.levels.len() - 1 {
        let al = &arch.levels[lv];
        let mut shared_needed = 0u64;
        for t in TENSORS {
            if !al.keeps_tensor(t) {
                continue;
            }
            let words = tile_words(arch, layer, mapping, lv, t, q);
            match &al.capacity {
                crate::arch::Capacity::Unbounded => {}
                crate::arch::Capacity::Shared(_) => shared_needed += words,
                crate::arch::Capacity::PerTensor(ws) => {
                    let avail = ws[t.index()];
                    if words > avail {
                        return Err(Violation::CapacityExceeded {
                            level: lv,
                            tensor: t,
                            needed_words: words,
                            available_words: avail,
                        });
                    }
                }
            }
        }
        if let crate::arch::Capacity::Shared(avail) = al.capacity {
            if shared_needed > avail {
                return Err(Violation::SharedCapacityExceeded {
                    level: lv,
                    needed_words: shared_needed,
                    available_words: avail,
                });
            }
        }
    }

    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{eyeriss, toy};
    use crate::quant::LayerQuant;
    use crate::workload::ConvLayer;

    fn small_layer() -> ConvLayer {
        ConvLayer::conv("t", 4, 8, 3, 8, 1)
    }

    /// A hand-built valid mapping for `small_layer` on `toy`:
    /// everything at DRAM except a tiny inner tile.
    fn dram_heavy_mapping(arch_levels: usize, layer: &ConvLayer) -> Mapping {
        let mut m = Mapping::unit(arch_levels);
        // put all factors at the top level temporally
        let top = arch_levels - 1;
        for d in 0..7 {
            m.levels[top].temporal[d] = layer.dims[d];
        }
        m
    }

    #[test]
    fn unit_tile_fits_everywhere() {
        let a = toy();
        let l = small_layer();
        let m = dram_heavy_mapping(a.levels.len(), &l);
        check(&a, &l, &LayerQuant::uniform(8), &m).unwrap();
    }

    #[test]
    fn factor_product_violation() {
        let a = toy();
        let l = small_layer();
        let m = Mapping::unit(a.levels.len()); // products are all 1 != dims
        assert!(matches!(
            check(&a, &l, &LayerQuant::uniform(8), &m),
            Err(Violation::FactorProduct(_))
        ));
    }

    #[test]
    fn capacity_depends_on_bitwidth() {
        // toy spad = 16 shared words. A 3x3x4-input-channel weight tile =
        // 36 elems: needs 18 words @8b packed (invalid), 9 words @4b ...
        // wait: 36/2=18 > 16 invalid at 8b; 36/4=9 + inputs/outputs.
        let mut a = toy();
        a.levels[0].capacity = crate::arch::Capacity::PerTensor([16, 64, 64]);
        let l = small_layer();
        let mut m = dram_heavy_mapping(a.levels.len(), &l);
        // pull a K=1,C=4,R=3,S=3 weight tile into the spad
        m.levels[0].temporal[Dim::C.index()] = 4;
        m.levels[0].temporal[Dim::R.index()] = 3;
        m.levels[0].temporal[Dim::S.index()] = 3;
        m.levels[2].temporal[Dim::C.index()] = 1;
        m.levels[2].temporal[Dim::R.index()] = 1;
        m.levels[2].temporal[Dim::S.index()] = 1;

        let q8 = LayerQuant::uniform(8); // 36 elems / 2 per word = 18 > 16
        assert!(matches!(
            check(&a, &l, &q8, &m),
            Err(Violation::CapacityExceeded { tensor: Tensor::Weights, .. })
        ));
        let q4 = LayerQuant::uniform(4); // 36 / 4 = 9 <= 16
        check(&a, &l, &q4, &m).unwrap();

        // without bit-packing even 4-bit stays invalid (1 elem/word)
        a.bit_packing = false;
        assert!(check(&a, &l, &q4, &m).is_err());
    }

    #[test]
    fn spatial_constraints() {
        let a = toy(); // buf level: fanout 4, dims {K, C, P}
        let l = small_layer();
        let mut m = dram_heavy_mapping(a.levels.len(), &l);

        // spatial on a forbidden dim (R not allowed)
        m.levels[1].spatial[Dim::R.index()] = 3;
        m.levels[2].temporal[Dim::R.index()] = 1;
        assert!(matches!(
            check(&a, &l, &LayerQuant::uniform(8), &m),
            Err(Violation::SpatialDimNotAllowed { dim: Dim::R, .. })
        ));

        // fanout exceeded: K=8 spatial > 4
        let mut m2 = dram_heavy_mapping(a.levels.len(), &l);
        m2.levels[1].spatial[Dim::K.index()] = 8;
        m2.levels[2].temporal[Dim::K.index()] = 1;
        assert!(matches!(
            check(&a, &l, &LayerQuant::uniform(8), &m2),
            Err(Violation::FanoutExceeded { level: 1 })
        ));

        // valid spatial K=4
        let mut m3 = dram_heavy_mapping(a.levels.len(), &l);
        m3.levels[1].spatial[Dim::K.index()] = 4;
        m3.levels[2].temporal[Dim::K.index()] = 2;
        check(&a, &l, &LayerQuant::uniform(8), &m3).unwrap();
        assert_eq!(m3.pes_used(), 4);
    }

    #[test]
    fn spatial_at_leaf_rejected() {
        let a = toy();
        let l = small_layer();
        let mut m = dram_heavy_mapping(a.levels.len(), &l);
        m.levels[0].spatial[Dim::K.index()] = 2;
        m.levels[2].temporal[Dim::K.index()] = 4;
        assert!(matches!(
            check(&a, &l, &LayerQuant::uniform(8), &m),
            Err(Violation::SpatialAtLeafLevel { level: 0 })
        ));
    }

    #[test]
    fn eyeriss_shared_glb_pool() {
        // GLB keeps inputs+outputs in one shared pool: a tile that fits
        // each alone but not together must be rejected.
        let a = eyeriss();
        let l = ConvLayer::pw("pw", 256, 256, 28);
        let mut m = dram_heavy_mapping(a.levels.len(), &l);
        // full ifmap + ofmap at GLB: 256*28*28 = 200k elems each @8b ->
        // 100k words each > 55k shared
        for d in [Dim::C, Dim::K, Dim::P, Dim::Q] {
            m.levels[1].temporal[d.index()] = l.size(d);
            m.levels[2].temporal[d.index()] = 1;
        }
        let v = check(&a, &l, &LayerQuant::uniform(8), &m).unwrap_err();
        assert!(
            matches!(v, Violation::SharedCapacityExceeded { level: 1, .. }),
            "aggregate overflow must not blame a single tensor: {v:?}"
        );
        // the diagnostic names the pool, not a scapegoat tensor
        assert!(v.to_string().contains("shared pool"), "{v}");
        // at 2 bits it fits: 200k/8 = 25k words each, 50k total < 55k
        check(&a, &l, &LayerQuant::uniform(2), &m).unwrap();
    }

    #[test]
    fn tile_extents_compose() {
        let l = small_layer();
        let a = toy();
        let mut m = Mapping::unit(a.levels.len());
        m.levels[0].temporal[Dim::K.index()] = 2;
        m.levels[1].spatial[Dim::K.index()] = 2;
        m.levels[1].temporal[Dim::K.index()] = 1;
        m.levels[2].temporal[Dim::K.index()] = 2;
        assert_eq!(m.tile_extents(0)[Dim::K.index()], 2);
        assert_eq!(m.tile_extents(1)[Dim::K.index()], 4);
        assert_eq!(m.total_extents()[Dim::K.index()], 8);
        assert_eq!(m.instances(0), 2);
        assert_eq!(m.instances(1), 1);
        let _ = l;
    }
}
