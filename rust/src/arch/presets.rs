//! Built-in accelerator presets: Eyeriss and Simba, mirroring the
//! Timeloop/Accelergy 45 nm characterizations the paper evaluates on.
//!
//! Geometry follows the published designs (Eyeriss ISSCC'17: 168-PE
//! 14x12 array, 108 KB global buffer, per-PE weight/ifmap/psum
//! scratchpads, row-stationary dataflow with weights bypassing the GLB;
//! Simba MICRO'19: 16 PEs x 16 distributed MAC lanes, shared global
//! buffer, per-PE weight/input/accumulation buffers). Energy-per-access
//! values are 45 nm Accelergy-style orders of magnitude; absolute pJ are
//! not the authors' tables, ratios across levels are (DESIGN.md §3).

use super::{Arch, Capacity, Level};
use crate::workload::Dim;

/// Eyeriss-like: DRAM -> 108 KB global buffer (ifmaps + psums only,
/// weights bypass) -> 168 PEs, each with separate weight (224 w),
/// ifmap (12 w), psum (24 w) scratchpads.
///
/// The row-stationary dataflow is encoded as the array's spatial-dim
/// constraint {R, P, C, K}: filter rows and output rows spread across
/// the physical array (plus channel folding), never the full loop nest —
/// this is why Eyeriss gains fewer extra mappings than Simba in Table I.
pub fn eyeriss() -> Arch {
    Arch {
        name: "eyeriss".into(),
        word_bits: 16,
        mac_energy_pj: 2.2,
        bit_packing: true,
        levels: vec![
            Level {
                name: "pe_spad".into(),
                capacity: Capacity::PerTensor([224, 12, 24]),
                access_energy_pj: [0.96, 0.48, 0.72],
                bandwidth_words: 2.0,
                fanout: 1,
                spatial_dims: vec![],
                multicast: false,
                keeps: [true, true, true],
            },
            Level {
                name: "shared_glb".into(),
                // 108 KB @ 16-bit words = 55,296 words, shared by
                // ifmaps + psums (weights bypass to DRAM).
                capacity: Capacity::Shared(55_296),
                access_energy_pj: [6.0, 6.0, 6.0],
                bandwidth_words: 16.0,
                fanout: 168,
                spatial_dims: vec![Dim::R, Dim::P, Dim::C, Dim::K],
                multicast: true,
                keeps: [false, true, true],
            },
            Level {
                name: "dram".into(),
                capacity: Capacity::Unbounded,
                access_energy_pj: [200.0, 200.0, 200.0],
                bandwidth_words: 4.0,
                fanout: 1,
                spatial_dims: vec![],
                multicast: false,
                keeps: [true, true, true],
            },
        ],
    }
}

/// Simba-like: DRAM -> 64 KB global buffer -> 16 PEs (each with weight /
/// input / accumulation buffers) -> 16 distributed MAC lanes per PE
/// (weight-stationary-ish, much freer spatial mapping than Eyeriss).
pub fn simba() -> Arch {
    Arch {
        name: "simba".into(),
        word_bits: 16,
        mac_energy_pj: 1.8,
        bit_packing: true,
        levels: vec![
            Level {
                // per-lane operand registers
                name: "lane_reg".into(),
                capacity: Capacity::PerTensor([8, 8, 8]),
                access_energy_pj: [0.12, 0.12, 0.12],
                bandwidth_words: 2.0,
                fanout: 1,
                spatial_dims: vec![],
                multicast: false,
                keeps: [true, true, true],
            },
            Level {
                // per-PE buffers: weights 4 KB, inputs 2 KB, psums 1 KB
                name: "pe_buf".into(),
                capacity: Capacity::PerTensor([2048, 1024, 512]),
                access_energy_pj: [1.2, 0.9, 1.1],
                bandwidth_words: 4.0,
                fanout: 16, // 16 MAC lanes below each PE
                spatial_dims: vec![Dim::K, Dim::C],
                multicast: true,
                keeps: [true, true, true],
            },
            Level {
                // 64 KB global buffer @ 16-bit words; weights bypass (they
                // stream DRAM -> PE weight buffers, as in Simba).
                name: "global_buf".into(),
                capacity: Capacity::Shared(32_768),
                access_energy_pj: [4.0, 4.0, 4.0],
                bandwidth_words: 16.0,
                fanout: 16, // 16 PEs
                spatial_dims: vec![Dim::K, Dim::C, Dim::P, Dim::Q, Dim::R, Dim::S, Dim::N],
                multicast: true,
                keeps: [false, true, true],
            },
            Level {
                name: "dram".into(),
                capacity: Capacity::Unbounded,
                access_energy_pj: [200.0, 200.0, 200.0],
                bandwidth_words: 4.0,
                fanout: 1,
                spatial_dims: vec![],
                multicast: false,
                keeps: [true, true, true],
            },
        ],
    }
}

/// A deliberately tiny architecture for unit tests and exhaustive-search
/// sanity checks: DRAM -> 256-word buffer -> 4 PEs with 16-word spads.
pub fn toy() -> Arch {
    Arch {
        name: "toy".into(),
        word_bits: 16,
        mac_energy_pj: 1.0,
        bit_packing: true,
        levels: vec![
            Level {
                name: "spad".into(),
                capacity: Capacity::Shared(16),
                access_energy_pj: [0.5, 0.5, 0.5],
                bandwidth_words: 2.0,
                fanout: 1,
                spatial_dims: vec![],
                multicast: false,
                keeps: [true, true, true],
            },
            Level {
                name: "buf".into(),
                capacity: Capacity::Shared(256),
                access_energy_pj: [5.0, 5.0, 5.0],
                bandwidth_words: 4.0,
                fanout: 4,
                spatial_dims: vec![Dim::K, Dim::C, Dim::P],
                multicast: true,
                keeps: [true, true, true],
            },
            Level {
                name: "dram".into(),
                capacity: Capacity::Unbounded,
                access_energy_pj: [100.0, 100.0, 100.0],
                bandwidth_words: 2.0,
                fanout: 1,
                spatial_dims: vec![],
                multicast: false,
                keeps: [true, true, true],
            },
        ],
    }
}

/// Look up a preset by name.
pub fn by_name(name: &str) -> Option<Arch> {
    match name {
        "eyeriss" => Some(eyeriss()),
        "simba" => Some(simba()),
        "toy" => Some(toy()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert_eq!(by_name("eyeriss").unwrap().name, "eyeriss");
        assert_eq!(by_name("simba").unwrap().name, "simba");
        assert!(by_name("tpu").is_none());
    }

    #[test]
    fn toy_validates() {
        toy().validate().unwrap();
        assert_eq!(toy().total_pes(), 4);
    }

    #[test]
    fn energy_hierarchy_is_monotone() {
        // sanity: accessing DRAM must dominate on-chip accesses
        for a in [eyeriss(), simba()] {
            let inner = a.levels[0].access_energy_pj[0];
            let outer = a.levels.last().unwrap().access_energy_pj[0];
            assert!(outer > 20.0 * inner, "{}", a.name);
        }
    }
}
