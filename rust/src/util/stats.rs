//! Small statistics helpers for experiment reporting (Fig. 1 correlations,
//! Pareto summaries).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Pearson correlation coefficient; 0.0 when either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..n {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Fractional ranks (average ties), 1-based, for Spearman.
fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Spearman rank correlation (Pearson over fractional ranks).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// Linear-regression R^2 of y on x.
pub fn r_squared(xs: &[f64], ys: &[f64]) -> f64 {
    let r = pearson(xs, ys);
    r * r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn = [-2.0, -4.0, -6.0, -8.0];
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone, nonlinear
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
        assert!(pearson(&x, &y) < 1.0);
    }

    #[test]
    fn spearman_ties() {
        let x = [1.0, 2.0, 2.0, 3.0];
        let y = [1.0, 2.0, 2.0, 3.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }
}
