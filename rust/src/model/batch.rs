//! FSM model of one driver↔worker batch (`batch_sent` → interleaved
//! `outcome` frames → `done`), with the fault events the stateful
//! suites inject: duplicated outcomes, out-of-range shard indices,
//! early `done`, connection loss, and the driver's refill sweep.
//!
//! Reordering needs no dedicated event: the explorer's BFS covers
//! *every* delivery order of [`BatchEvent::Deliver`], which is exactly
//! what `Fault::Reorder` sampled.
//!
//! The conformance SUT (`tests/model_conformance.rs`) is a real
//! [`BatchLedger`](crate::engine::remote::BatchLedger) fed real
//! [`ShardOutcome`](crate::mapper::ShardOutcome)s; `Finalize` pins the
//! merged result bit-identical to the serial reference in every
//! interleaving.

use super::Fsm;

/// One batch with `shards` shard slots.
pub struct BatchModel {
    pub shards: usize,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchState {
    /// Per-shard slot filled (delivery order is deliberately absent:
    /// the ledger is order-free, so the model must be too).
    pub delivered: Vec<bool>,
    /// `done` frame consumed while the connection was live.
    pub done: bool,
    /// Connection condemned: loss, or a protocol violation.
    pub lost: bool,
    /// Driver sweep ran: missing slots refilled, result merged.
    pub finalized: bool,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BatchEvent {
    /// `outcome` frame for shard `i`; a second delivery of the same
    /// shard is the duplicate fault.
    Deliver(usize),
    /// `outcome` frame with an out-of-range shard index — the peer is
    /// condemned.
    DeliverBogus,
    /// `done` frame; may arrive before every shard (a buggy or
    /// fault-injected worker) — the sweep owns the rest.
    Done,
    /// Connection loss mid-stream.
    Lose,
    /// The driver's sweep: refill whatever is missing, merge.
    Finalize,
}

impl BatchModel {
    fn live(&self, s: &BatchState) -> bool {
        !s.done && !s.lost && !s.finalized
    }
}

impl Fsm for BatchModel {
    type State = BatchState;
    type Event = BatchEvent;

    fn name(&self) -> String {
        "batch".to_string()
    }

    fn initial(&self) -> BatchState {
        BatchState {
            delivered: vec![false; self.shards],
            done: false,
            lost: false,
            finalized: false,
        }
    }

    fn events(&self, s: &BatchState) -> Vec<BatchEvent> {
        let mut evs = Vec::new();
        if self.live(s) {
            for i in 0..self.shards {
                evs.push(BatchEvent::Deliver(i));
            }
            evs.push(BatchEvent::DeliverBogus);
            evs.push(BatchEvent::Done);
            evs.push(BatchEvent::Lose);
        }
        if (s.done || s.lost) && !s.finalized {
            evs.push(BatchEvent::Finalize);
        }
        evs
    }

    fn step(&self, s: &BatchState, e: &BatchEvent) -> BatchState {
        let mut n = s.clone();
        match e {
            BatchEvent::Deliver(i) => {
                if self.live(s) && *i < self.shards {
                    n.delivered[*i] = true;
                }
            }
            BatchEvent::DeliverBogus => {
                if self.live(s) {
                    n.lost = true;
                }
            }
            BatchEvent::Done => {
                if self.live(s) {
                    n.done = true;
                }
            }
            BatchEvent::Lose => {
                if self.live(s) {
                    n.lost = true;
                }
            }
            BatchEvent::Finalize => {
                if (s.done || s.lost) && !s.finalized {
                    n.finalized = true;
                }
            }
        }
        n
    }

    fn invariant(&self, s: &BatchState) -> Result<(), String> {
        if s.delivered.len() != self.shards {
            return Err(format!(
                "slot count changed: {} != {}",
                s.delivered.len(),
                self.shards
            ));
        }
        if s.finalized && !(s.done || s.lost) {
            return Err("finalized a batch still streaming".to_string());
        }
        Ok(())
    }

    fn show_event(&self, e: &BatchEvent) -> String {
        match e {
            BatchEvent::Deliver(i) => format!("deliver:{i}"),
            BatchEvent::DeliverBogus => "bogus".to_string(),
            BatchEvent::Done => "done".to_string(),
            BatchEvent::Lose => "lose".to_string(),
            BatchEvent::Finalize => "finalize".to_string(),
        }
    }

    fn parse_event(&self, line: &str) -> Option<BatchEvent> {
        if let Some(i) = line.strip_prefix("deliver:") {
            return i.parse().ok().map(BatchEvent::Deliver);
        }
        match line {
            "bogus" => Some(BatchEvent::DeliverBogus),
            "done" => Some(BatchEvent::Done),
            "lose" => Some(BatchEvent::Lose),
            "finalize" => Some(BatchEvent::Finalize),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{explore, Budget};

    #[test]
    fn batch_model_explores_exhaustively() {
        let m = BatchModel { shards: 3 };
        let cov = explore(&m, &Budget::new(12, 100_000)).expect("no violation");
        assert!(cov.complete, "small scope must be exhausted");
        // delivered ∈ 2^3, × {streaming, done, lost} × finalized-or-not
        // for the ended ones; terminal states are absorbing
        assert!(cov.states >= 8 * 3, "got {} states", cov.states);
        // deepest full run: 3 deliveries + a duplicate + done + finalize
        assert!(cov.deepest >= 5, "got depth {}", cov.deepest);
    }

    #[test]
    fn batch_grammar_round_trips() {
        let m = BatchModel { shards: 2 };
        for ev in [
            BatchEvent::Deliver(1),
            BatchEvent::DeliverBogus,
            BatchEvent::Done,
            BatchEvent::Lose,
            BatchEvent::Finalize,
        ] {
            let s = m.show_event(&ev);
            assert_eq!(m.parse_event(&s), Some(ev), "grammar: {s}");
        }
        assert_eq!(m.parse_event("deliver:x"), None);
    }
}
