//! Deterministic PRNGs used across the whole stack.
//!
//! No external `rand` crate is available offline, so we implement
//! SplitMix64 (seeding / stream-splitting) and a thin layer of sampling
//! helpers on top. The same SplitMix64 recurrence is re-implemented in
//! `python/compile/data.py` so build-time Python and run-time Rust can
//! generate bit-identical integer streams when needed.

/// SplitMix64: tiny, fast, passes BigCrush; ideal for reproducible
/// experiment streams. Reference: Steele, Lea, Flood (OOPSLA'14).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Derive an independent stream for a sub-experiment; mixes the tag
    /// into the state so `split(a) != split(b)` for `a != b`.
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64();
        Rng::new(s ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The raw generator state, for checkpointing: `Rng::new(state)`
    /// resumes the exact stream (SplitMix64's whole state is one word).
    pub fn state(&self) -> u64 {
        self.state
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (n > 0), via Lemire's multiply-shift with
    /// rejection to remove modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mulwide(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (single value; second discarded —
    /// simplicity over throughput, this is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-12 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick a reference into a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mulwide(a: u64, b: u64) -> (u64, u64) {
    let w = (a as u128) * (b as u128);
    ((w >> 64) as u64, w as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_reference_vector() {
        // Known-good SplitMix64 outputs for seed 0 (cross-checked with the
        // canonical C implementation); the Python twin asserts the same.
        let mut r = Rng::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(r.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn below_is_in_range_and_unbiased_ish() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(1);
        let mut a = root.split(1);
        let mut b = root.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
