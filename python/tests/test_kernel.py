"""Kernel-vs-reference correctness: the CORE L1 signal.

The Pallas fake-quant matmul must match the pure-jnp oracle across
shapes, bit-widths, value ranges, and block boundaries (hypothesis
sweeps + directed edge cases).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qmatmul import _qmatmul_impl, qmatmul
from compile.kernels.ref import ref_qmatmul

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, lo=-3.0, hi=3.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


def assert_matches_ref(x, w, qa, qw, **kw):
    got = _qmatmul_impl(x, w, jnp.float32(qa), jnp.float32(qw), **kw)
    want = ref_qmatmul(x, w, jnp.float32(qa), jnp.float32(qw))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    qa=st.integers(2, 8),
    qw=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shape_and_bit_sweep(m, k, n, qa, qw, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    assert_matches_ref(x, w, qa, qw, block_m=32)


@pytest.mark.parametrize("m", [1, 127, 128, 129, 256])
def test_block_boundaries(m):
    """Padding/slicing around the BLOCK_M stripe edge must be exact."""
    x = _rand(7, (m, 16))
    w = _rand(8, (16, 12))
    assert_matches_ref(x, w, 4, 4)


@pytest.mark.parametrize("qa,qw", [(2, 2), (2, 8), (8, 2), (8, 8), (16, 16)])
def test_bitwidth_corners(qa, qw):
    x = _rand(3, (33, 20))
    w = _rand(4, (20, 10))
    assert_matches_ref(x, w, qa, qw)


def test_constant_tensor_no_nan():
    """Zero-span tensors must not divide by zero."""
    x = jnp.ones((8, 8), jnp.float32) * 0.5
    w = _rand(5, (8, 8))
    out = _qmatmul_impl(x, w, jnp.float32(4), jnp.float32(4))
    assert np.isfinite(np.asarray(out)).all()


def test_asymmetric_range():
    """Strictly-positive and strictly-negative ranges (asymmetric zp)."""
    x = _rand(9, (16, 8), lo=2.0, hi=5.0)
    w = _rand(10, (8, 8), lo=-7.0, hi=-1.0)
    assert_matches_ref(x, w, 3, 5)


def test_quantization_actually_quantizes():
    """At 2 bits the result must differ from the unquantized matmul."""
    x = _rand(11, (32, 16))
    w = _rand(12, (16, 16))
    q2 = _qmatmul_impl(x, w, jnp.float32(2), jnp.float32(2))
    exact = jnp.matmul(x, w)
    assert not np.allclose(np.asarray(q2), np.asarray(exact), atol=1e-3)
    # and at 16 bits it is numerically indistinguishable
    q16 = _qmatmul_impl(x, w, jnp.float32(16), jnp.float32(16))
    np.testing.assert_allclose(np.asarray(q16), np.asarray(exact), rtol=1e-3, atol=1e-3)


def test_traced_bitwidths_under_jit():
    """Bit-widths are runtime tensors: one jitted fn, many genomes."""
    x = _rand(13, (24, 12))
    w = _rand(14, (12, 6))
    f = jax.jit(lambda qa, qw: qmatmul(x, w, qa, qw))
    for qa in [2.0, 5.0, 8.0]:
        got = f(jnp.float32(qa), jnp.float32(4.0))
        want = ref_qmatmul(x, w, jnp.float32(qa), jnp.float32(4.0))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_gradients_ste():
    """custom_vjp: gradients flow through as STE (match the ref grads)."""
    x = _rand(15, (16, 8))
    w = _rand(16, (8, 4))
    qa, qw = jnp.float32(4), jnp.float32(4)

    def loss_kernel(x, w):
        return jnp.sum(qmatmul(x, w, qa, qw) ** 2)

    def loss_ref(x, w):
        # same STE structure: forward quantized, grads via dequantized
        xq = x + jax.lax.stop_gradient(
            ref_qmatmul(jnp.eye(x.shape[0]), x, jnp.float32(32), qa) - x
        )
        del xq
        return jnp.sum(ref_qmatmul(x, w, qa, qw) ** 2)

    gx_k, gw_k = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
    # reference STE gradients computed by hand: dL/dO = 2*O
    out = ref_qmatmul(x, w, qa, qw)
    from compile.quantize import quant_dequant

    g = 2.0 * out
    gx_r = g @ quant_dequant(w, qw).T
    gw_r = quant_dequant(x, qa).T @ g
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gw_k), np.asarray(gw_r), rtol=1e-4, atol=1e-3)
    del loss_ref


def test_no_gradient_into_bitwidths():
    x = _rand(17, (8, 8))
    w = _rand(18, (8, 8))

    def loss(qa):
        return jnp.sum(qmatmul(x, w, qa, jnp.float32(4)))

    g = jax.grad(loss)(jnp.float32(4))
    assert float(g) == 0.0


def test_single_row_and_column():
    x = _rand(19, (1, 5))
    w = _rand(20, (5, 1))
    assert_matches_ref(x, w, 6, 3)
