//! One driver per paper artifact (DESIGN.md §5 experiment index).
//!
//! | fn                  | paper artifact                      |
//! |---------------------|-------------------------------------|
//! | `fig1_correlation`  | Fig. 1(a,b) size vs words / EDP     |
//! | `table1_mappings`   | Table I mapping counts + min EDP    |
//! | `fig3_ablations`    | Fig. 3(a,b,c) NSGA-II ablations     |
//! | `fig4_breakdown`    | Fig. 4 energy breakdown             |
//! | `fig5_convergence`  | Fig. 5 Pareto front per generation  |
//! | `fig6_tradeoff`     | Fig. 6 strategy comparison          |
//! | `table2_summary`    | Table II Δ memory-energy / Δ acc    |

use super::RunConfig;
use crate::accuracy::{AccuracyModel, InitModel, ProxyAccuracy, ProxyParams};
use crate::arch::presets;
use crate::arch::Arch;
use crate::baselines::{naive_search, proposed_search, proposed_search3, uniform_sweep, Candidate};
use crate::engine::{driver, Engine};
use crate::eval::{evaluate_network, NetworkEval};
use crate::mapper::cache::MapperCache;
use crate::mapping::mapspace::MapSpace;
use crate::nsga::{pareto_front_of_points, NsgaConfig};
use crate::objective::{Axis, ObjectiveSpec};
use crate::quant::{LayerQuant, QuantConfig, QMAX, QMIN};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::workload::models;

// ---------------------------------------------------------------- fig 1

/// One random quantization configuration's three Fig. 1 metrics.
#[derive(Debug, Clone, Copy)]
pub struct Fig1Point {
    pub model_size_bits: u64,
    pub weight_words: u64,
    pub edp: f64,
}

pub struct Fig1Result {
    pub points: Vec<Fig1Point>,
    pub uniform8: Fig1Point,
    /// Pearson r: size vs words, size vs EDP.
    pub r_size_words: f64,
    pub r_size_edp: f64,
}

/// Fig. 1: `n` random mixed configurations of MobileNetV1 on Eyeriss;
/// correlation of naïve model size against packed word count and EDP.
pub fn fig1_correlation(n: usize, rc: &RunConfig) -> Fig1Result {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    let engine = Engine::new(rc.threads);
    let mut rng = Rng::new(rc.seed ^ 0xF161);

    let mut genomes: Vec<QuantConfig> = Vec::with_capacity(n);
    for _ in 0..n {
        let mut qc = QuantConfig::uniform(layers.len(), 8);
        for l in qc.layers.iter_mut() {
            l.0 = QMIN + rng.below((QMAX - QMIN + 1) as u64) as u8;
            l.1 = QMIN + rng.below((QMAX - QMIN + 1) as u64) as u8;
        }
        genomes.push(qc);
    }

    let evals = driver::evaluate_genomes(&engine, &arch, &layers, &genomes, &cache, &rc.mapper);
    let points: Vec<Fig1Point> = evals
        .into_iter()
        .flatten()
        .map(|e| Fig1Point {
            model_size_bits: e.model_size_bits,
            weight_words: e.weight_words,
            edp: e.edp,
        })
        .collect();

    let u8e = evaluate_network(
        &arch,
        &layers,
        &QuantConfig::uniform(layers.len(), 8),
        &cache,
        &rc.mapper,
    )
    .expect("uniform-8 must map");

    let size: Vec<f64> = points.iter().map(|p| p.model_size_bits as f64).collect();
    let words: Vec<f64> = points.iter().map(|p| p.weight_words as f64).collect();
    let edp: Vec<f64> = points.iter().map(|p| p.edp).collect();
    Fig1Result {
        r_size_words: stats::pearson(&size, &words),
        r_size_edp: stats::pearson(&size, &edp),
        points,
        uniform8: Fig1Point {
            model_size_bits: u8e.model_size_bits,
            weight_words: u8e.weight_words,
            edp: u8e.edp,
        },
    }
}

// --------------------------------------------------------------- table 1

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub setting: (u8, u8, u8), // (qa, qw, qo)
    pub arch: String,
    pub valid_mappings: u64,
    pub truncated: bool,
    pub min_edp: f64,
}

/// Table I: exhaustively enumerate valid mappings of MobileNet conv
/// layer #2 (the 3x3 depthwise over 112x112) for the paper's six
/// bit-width settings on both accelerators; report count + min EDP.
pub fn table1_mappings(limit: u64) -> Vec<Table1Row> {
    let layer = &models::mobilenet_v1()[1]; // dw1: the paper's "conv layer #2"
    let settings: [(u8, u8, u8); 6] = [
        (16, 16, 16),
        (8, 8, 8),
        (8, 4, 8),
        (8, 2, 8),
        (4, 4, 4),
        (2, 2, 2),
    ];
    let mut rows = Vec::new();
    for arch in [presets::eyeriss(), presets::simba()] {
        let space = MapSpace::of(&arch);
        for &(qa, qw, qo) in &settings {
            let q = LayerQuant { qa, qw, qo };
            // price every visited mapping through the allocation-free
            // context path (same numbers as analyze/estimate, much
            // faster on exhaustive sweeps)
            let lctx = crate::mapping::LayerContext::new(&arch, layer, &q);
            let mut ectx = crate::mapper::EvalContext::for_arch(&arch);
            let mut min_edp = f64::INFINITY;
            let st = space.enumerate_valid(&arch, layer, &q, limit, |m| {
                crate::nest::analyze_into(&lctx, m, &mut ectx.ext, &mut ectx.nest);
                crate::energy::estimate_into(&lctx, &ectx.nest, &mut ectx.est);
                if ectx.est.edp() < min_edp {
                    min_edp = ectx.est.edp();
                }
            });
            rows.push(Table1Row {
                setting: (qa, qw, qo),
                arch: arch.name.clone(),
                valid_mappings: st.valid,
                truncated: st.truncated,
                min_edp,
            });
        }
    }
    rows
}

// ---------------------------------------------------------------- fig 4

#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub bits: u8,
    /// `[spads, buffers, dram, mac]` energy in pJ.
    pub components_pj: [f64; 4],
    pub total_pj: f64,
}

/// Fig. 4: energy breakdown of uniformly quantized MobileNetV1 on
/// Eyeriss for x in {16, 8, 6, 5, 4, 3, 2}.
pub fn fig4_breakdown(rc: &RunConfig) -> Vec<Fig4Row> {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    [16u8, 8, 6, 5, 4, 3, 2]
        .iter()
        .filter_map(|&bits| {
            let qc = QuantConfig::uniform(layers.len(), bits);
            let e = evaluate_network(&arch, &layers, &qc, &cache, &rc.mapper)?;
            Some(Fig4Row {
                bits,
                components_pj: [
                    e.energy_breakdown_pj[0],
                    e.energy_breakdown_pj[1],
                    e.energy_breakdown_pj[2],
                    e.mac_energy_pj,
                ],
                total_pj: e.energy_pj,
            })
        })
        .collect()
}

// ---------------------------------------------------------------- fig 5

pub struct Fig5Result {
    /// (generation, pareto front of (EDP, error)) snapshots.
    pub fronts: Vec<(usize, Vec<Vec<f64>>)>,
    pub initial_uniform: Vec<Vec<f64>>,
}

/// Fig. 5: Pareto-front advance of the proposed NSGA-II search across
/// generations (MobileNetV1 on Eyeriss, e=10, |Q|=16 in the paper).
pub fn fig5_convergence(rc: &RunConfig, snapshot_gens: &[usize]) -> Fig5Result {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    let engine = Engine::new(rc.threads);
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());

    let mut fronts = Vec::new();
    let mut initial = Vec::new();
    {
        let snapshot_gens = snapshot_gens.to_vec();
        let fronts_ref = &mut fronts;
        let initial_ref = &mut initial;
        proposed_search(
            &engine,
            &arch,
            &layers,
            &mut acc,
            &cache,
            &rc.mapper,
            &rc.nsga,
            |gen, pop| {
                let pts: Vec<Vec<f64>> =
                    pop.iter().map(|i| i.objectives.values().to_vec()).collect();
                if gen == 0 {
                    *initial_ref = pareto_front_of_points(&pts);
                }
                if snapshot_gens.contains(&gen) {
                    fronts_ref.push((gen, pareto_front_of_points(&pts)));
                }
            },
        );
    }
    Fig5Result {
        fronts,
        initial_uniform: initial,
    }
}

// ---------------------------------------------------------------- fig 3

pub struct Fig3Result {
    /// (label, front of (EDP, error)) per ablation arm.
    pub arms: Vec<(String, Vec<Vec<f64>>)>,
}

/// Fig. 3a: FP32-init (e=10) vs QAT-8-init (e=5) fine-tuning.
pub fn fig3a_init_model(rc: &RunConfig) -> Fig3Result {
    let arms = [
        ("FP32 init, e=10", InitModel::Fp32, 10u32),
        ("QAT-8 init, e=5", InitModel::Qat8, 5u32),
    ];
    ablation_arms(rc, arms.iter().map(|&(label, init, epochs)| {
        (
            label.to_string(),
            ProxyParams {
                init,
                epochs,
                ..ProxyParams::default()
            },
            rc.nsga,
        )
    }))
}

/// Fig. 3b: offspring size |Q| in {8, 16, 32} at a fixed evaluation
/// budget (|Q| x generations = const).
pub fn fig3b_offspring(rc: &RunConfig) -> Fig3Result {
    let budget = rc.nsga.offspring * rc.nsga.generations;
    let arms = [8usize, 16, 32].iter().map(|&q| {
        let mut cfg = rc.nsga;
        cfg.offspring = q;
        cfg.generations = (budget / q).max(1);
        (
            format!("|Q|={q} ({} gens)", cfg.generations),
            ProxyParams::default(),
            cfg,
        )
    });
    ablation_arms(rc, arms)
}

/// Fig. 3c: epochs e in {10, 20}; higher e costs generations
/// (paper: 28 gens at e=10 vs 14 at e=20) but recovers accuracy better.
pub fn fig3c_epochs(rc: &RunConfig) -> Fig3Result {
    let arms = [(10u32, 1.0f64), (20, 0.5)].iter().map(|&(e, gen_scale)| {
        let mut cfg = rc.nsga;
        cfg.generations = ((cfg.generations as f64 * gen_scale) as usize).max(1);
        (
            format!("e={e} ({} gens)", cfg.generations),
            ProxyParams {
                epochs: e,
                ..ProxyParams::default()
            },
            cfg,
        )
    });
    ablation_arms(rc, arms)
}

fn ablation_arms(
    rc: &RunConfig,
    arms: impl Iterator<Item = (String, ProxyParams, NsgaConfig)>,
) -> Fig3Result {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    let engine = Engine::new(rc.threads);
    // the arms' front axes come from the run's objective spec — named,
    // not positional: reordering or extending the spec reorders these
    // points with it instead of silently swapping EDP for error
    let spec = rc.objectives;
    let mut out = Vec::new();
    for (label, params, nsga_cfg) in arms {
        let mut acc = ProxyAccuracy::new(&layers, params);
        let cands = crate::baselines::search_with_objectives(
            &engine,
            &arch,
            &layers,
            &mut acc,
            &cache,
            &rc.mapper,
            &nsga_cfg,
            &spec,
            |_, _| {},
        );
        let pts: Vec<Vec<f64>> = cands
            .iter()
            .map(|c| spec.evaluate(Some(&c.hw), c.accuracy).into_values())
            .collect();
        out.push((label, pareto_front_of_points(&pts)));
    }
    Fig3Result { arms: out }
}

// ---------------------------------------------------------------- fig 6

pub struct Fig6Result {
    pub uniform: Vec<Candidate>,
    pub naive: Vec<Candidate>,
    pub proposed: Vec<Candidate>,
    /// "Proposed for Simba": optimized against Simba, evaluated on the
    /// target (Eyeriss) — the paper's unseen-accelerator arm.
    pub cross: Vec<Candidate>,
    /// uniform-8 reference for relative axes.
    pub reference: (f64, f64, f64), // (edp, mem_energy, accuracy)
}

/// Fig. 6: accuracy-vs-EDP trade-off on Eyeriss running MobileNetV1,
/// comparing Proposed / Uniform / Naïve / Proposed-for-Simba.
pub fn fig6_tradeoff(rc: &RunConfig) -> Fig6Result {
    let target = presets::eyeriss();
    let other = presets::simba();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    let cache_other = MapperCache::new();
    let engine = Engine::new(rc.threads);

    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
    let uniform = uniform_sweep(&engine, &target, &layers, &mut acc, &cache, &rc.mapper, false);
    let naive = naive_search(&engine, &target, &layers, &mut acc, &cache, &rc.mapper, &rc.nsga);
    let proposed = proposed_search(
        &engine,
        &target,
        &layers,
        &mut acc,
        &cache,
        &rc.mapper,
        &rc.nsga,
        |_, _| {},
    );
    // search against Simba, then re-price winners on Eyeriss
    let cross_on_simba = proposed_search(
        &engine,
        &other,
        &layers,
        &mut acc,
        &cache_other,
        &rc.mapper,
        &rc.nsga,
        |_, _| {},
    );
    let cross: Vec<Candidate> = cross_on_simba
        .into_iter()
        .filter_map(|c| {
            let hw = evaluate_network(&target, &layers, &c.genome, &cache, &rc.mapper)?;
            Some(Candidate {
                accuracy: c.accuracy,
                genome: c.genome,
                hw,
                strategy: "proposed-for-simba",
            })
        })
        .collect();

    let u8c = uniform
        .iter()
        .find(|c| c.genome.layers[0] == (8, 8))
        .expect("uniform sweep includes 8-bit");
    Fig6Result {
        reference: (u8c.hw.edp, u8c.hw.memory_energy_pj, u8c.accuracy),
        uniform,
        naive,
        proposed,
        cross,
    }
}

// --------------------------------------------------------------- table 2

#[derive(Debug, Clone)]
pub struct Table2Row {
    pub arch: String,
    pub network: String,
    pub strategy: &'static str,
    /// Δ memory energy vs uniform-8 (negative = saving), fraction.
    pub delta_mem: f64,
    /// Δ accuracy vs uniform-8 (positive = better), fraction.
    pub delta_acc: f64,
}

/// Table II: memory-energy reduction and accuracy delta of Uniform /
/// Naïve / Proposed for both CNNs on both accelerators, relative to the
/// uniform 8-bit implementation. Reports up to `per_cell` Pareto points
/// per (arch, net, strategy) cell, as the paper does.
pub fn table2_summary(rc: &RunConfig, per_cell: usize) -> Vec<Table2Row> {
    let mut rows = Vec::new();
    let engine = Engine::new(rc.threads);
    for arch in [presets::eyeriss(), presets::simba()] {
        for (net_name, layers) in [
            ("MobileNetV1", models::mobilenet_v1()),
            ("MobileNetV2", models::mobilenet_v2()),
        ] {
            let cache = MapperCache::new();
            let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
            let reference = evaluate_network(
                &arch,
                &layers,
                &QuantConfig::uniform(layers.len(), 8),
                &cache,
                &rc.mapper,
            )
            .expect("uniform-8 must map");
            let ref_acc = acc.accuracy(&QuantConfig::uniform(layers.len(), 8));

            let uniform =
                uniform_sweep(&engine, &arch, &layers, &mut acc, &cache, &rc.mapper, false);
            let naive =
                naive_search(&engine, &arch, &layers, &mut acc, &cache, &rc.mapper, &rc.nsga);
            // Table II reports the memory-energy axis, so use the
            // paper's full 3-objective search (memory, energy, error)
            let proposed =
                proposed_search3(&engine, &arch, &layers, &mut acc, &cache, &rc.mapper, &rc.nsga);
            for cands in [uniform, naive, proposed] {
                rows.extend(best_cells(
                    &cands, &arch, net_name, &reference, ref_acc, per_cell,
                ));
            }
        }
    }
    rows
}

fn best_cells(
    cands: &[Candidate],
    arch: &Arch,
    net: &str,
    reference: &NetworkEval,
    ref_acc: f64,
    per_cell: usize,
) -> Vec<Table2Row> {
    // keep the Pareto subset by the named (memory_energy, error) axes,
    // then the `per_cell` with the largest savings at acceptable
    // accuracy
    let table_spec = ObjectiveSpec::new(&[Axis::MemoryEnergy, Axis::Error])
        .expect("table 2 axes are valid");
    let pts: Vec<Vec<f64>> = cands
        .iter()
        .map(|c| table_spec.evaluate(Some(&c.hw), c.accuracy).into_values())
        .collect();
    let front = pareto_front_of_points(&pts);
    let pareto: Vec<Table2Row> = cands
        .iter()
        .filter(|c| {
            front.contains(&table_spec.evaluate(Some(&c.hw), c.accuracy).into_values())
        })
        .map(|c| Table2Row {
            arch: arch.name.clone(),
            network: net.to_string(),
            strategy: c.strategy,
            delta_mem: c.hw.memory_energy_pj / reference.memory_energy_pj - 1.0,
            delta_acc: c.accuracy - ref_acc,
        })
        .collect();
    // the paper prints a handful of representative trade-offs per cell,
    // spanning "no accuracy drop" to "deep saving at visible drop": for
    // each accuracy-drop bin, keep the deepest memory saving available
    let bins = [0.0, -0.005, -0.01, -0.03, -0.09];
    let mut rows: Vec<Table2Row> = Vec::new();
    for &floor in bins.iter().take(per_cell.max(1)) {
        let best = pareto
            .iter()
            .filter(|r| r.delta_acc >= floor)
            .min_by(|a, b| a.delta_mem.partial_cmp(&b.delta_mem).unwrap());
        if let Some(b) = best {
            if !rows
                .iter()
                .any(|r| (r.delta_mem - b.delta_mem).abs() < 1e-12)
            {
                rows.push(b.clone());
            }
        }
    }
    rows.sort_by(|a, b| b.delta_acc.partial_cmp(&a.delta_acc).unwrap());
    rows
}

// NOTE: the old `parallel_map` helper (scoped threads, one pool per
// call site) is retired — ordered fan-out is `Engine::map`, and genome
// batches go through `engine::driver::evaluate_genomes`, so one
// scheduler owns the core budget instead of three mechanisms competing
// for it.

#[cfg(test)]
mod tests {
    use super::*;

    fn rc() -> RunConfig {
        RunConfig::fast()
    }

    #[test]
    fn fig1_shapes_and_correlations() {
        let r = fig1_correlation(40, &rc());
        assert_eq!(r.points.len(), 40);
        // strong size<->words, weaker size<->EDP (the paper's core claim)
        assert!(r.r_size_words > 0.85, "r_sw={}", r.r_size_words);
        assert!(
            r.r_size_edp < r.r_size_words,
            "edp correlation should be weaker: {} vs {}",
            r.r_size_edp,
            r.r_size_words
        );
    }

    #[test]
    fn table1_counts_grow_with_lower_bits() {
        // bounded enumeration keeps the test fast; relative order of the
        // *unbounded* counts is asserted in the bench
        let rows = table1_mappings(3_000);
        assert_eq!(rows.len(), 12);
        for r in &rows {
            assert!(r.valid_mappings > 0, "{r:?}");
            assert!(r.min_edp.is_finite());
        }
        // per-arch: 2-bit setting admits >= mappings than 16-bit
        for arch in ["eyeriss", "simba"] {
            let get = |s: (u8, u8, u8)| {
                rows.iter()
                    .find(|r| r.arch == arch && r.setting == s)
                    .unwrap()
            };
            let m16 = get((16, 16, 16));
            let m2 = get((2, 2, 2));
            assert!(
                m2.valid_mappings >= m16.valid_mappings,
                "{arch}: {} vs {}",
                m2.valid_mappings,
                m16.valid_mappings
            );
        }
    }

    #[test]
    fn fig4_memory_energy_monotone() {
        let rows = fig4_breakdown(&rc());
        assert_eq!(rows.len(), 7);
        // memory components shrink as bits shrink; MAC constant
        let mem = |r: &Fig4Row| r.components_pj[0] + r.components_pj[1] + r.components_pj[2];
        assert!(mem(&rows[1]) <= mem(&rows[0])); // 8 <= 16
        assert!(mem(&rows[6]) < mem(&rows[1])); // 2 < 8
        for w in rows.windows(2) {
            assert_eq!(w[0].components_pj[3], w[1].components_pj[3]); // MAC
        }
        // packing plateau: 6-bit == 8-bit memory energy at word 16
        assert!((mem(&rows[1]) - mem(&rows[2])).abs() < 1e-6);
    }

    #[test]
    fn fig5_front_advances() {
        let mut c = rc();
        c.nsga.generations = 5;
        let r = fig5_convergence(&c, &[0, 5]);
        assert_eq!(r.fronts.len(), 2);
        assert!(!r.initial_uniform.is_empty());
    }

    #[test]
    fn fig1_engine_eval_matches_serial_reference() {
        // the engine fan-out behind fig1 must price genomes exactly as
        // the serial evaluator does
        let rc = rc();
        let arch = presets::eyeriss();
        let layers = models::mobilenet_v1();
        let engine = Engine::new(rc.threads);
        let cache_e = MapperCache::new();
        let cache_s = MapperCache::new();
        let qc = QuantConfig::uniform(layers.len(), 5);
        let from_engine =
            driver::evaluate_genomes(&engine, &arch, &layers, &[qc.clone()], &cache_e, &rc.mapper);
        let serial = evaluate_network(&arch, &layers, &qc, &cache_s, &rc.mapper);
        assert_eq!(from_engine[0], serial);
    }
}
