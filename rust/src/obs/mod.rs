//! §Observability: structured tracing, metrics, and fault forensics.
//!
//! A std-only, zero-dependency flight recorder for the search engine.
//! Three sinks hang off one event stream:
//!
//! * an **always-on bounded ring buffer** ([`ring`]) holding the last
//!   [`ring::RING_CAPACITY`] rendered events, dumped to a JSONL file on
//!   panic, lost worker, or protocol error ([`ring::dump`]) for
//!   post-mortem forensics;
//! * an **optional JSONL trace file** (`qmap search --trace FILE`):
//!   schema-versioned ([`SCHEMA_VERSION`]), one event per line, with a
//!   deterministic field order for free — events render through
//!   [`Json::obj`], whose `BTreeMap` sorts keys;
//! * the **console**: events carrying a human rendering print to
//!   stderr under the single `--progress`/`--quiet` policy
//!   ([`set_quiet`]), so human output and trace output come from one
//!   stream instead of scattered `eprintln!`s.
//!
//! Aggregated hot-path statistics (cascade stage rejects, cache probe
//! outcomes, steals/splits, journal fsync time) live in [`metrics`] as
//! plain relaxed atomics and are served Prometheus-style by
//! `qmap worker --metrics ADDR`; `qmap trace-report FILE` ([`report`])
//! summarizes a trace into per-layer tables.
//!
//! **Non-perturbation is the load-bearing constraint**: the recorder
//! only observes. No event or counter feeds back into the RNG, the
//! candidate evaluation, scheduling, or the wire — tracing on vs off
//! yields bit-identical Pareto fronts (pinned by `tests/obs_trace.rs`
//! and the CI loopback smoke), and the cost of an enabled trace is
//! ceiling-guarded in BENCH_baseline.json (`trace_overhead_pct`).

pub mod metrics;
pub mod report;
pub mod ring;

use crate::util::json::Json;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Instant;

/// Version stamped into every trace header and flight-recorder dump.
/// Bump when an event kind's fields change incompatibly.
pub const SCHEMA_VERSION: u64 = 1;

/// Console policy for an event's human rendering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Informational progress — suppressed by `--quiet`.
    Progress,
    /// Load-bearing status (worker loss, fallback warnings, lines that
    /// scripts wait for) — always printed.
    Status,
}

struct Tracer {
    start: Instant,
    seq: AtomicU64,
    enabled: AtomicBool,
    file: Mutex<Option<BufWriter<File>>>,
}

static TRACER: OnceLock<Tracer> = OnceLock::new();
static QUIET: AtomicBool = AtomicBool::new(false);

fn tracer() -> &'static Tracer {
    TRACER.get_or_init(|| Tracer {
        start: Instant::now(),
        seq: AtomicU64::new(0),
        enabled: AtomicBool::new(false),
        file: Mutex::new(None),
    })
}

/// `--quiet`: suppress [`Level::Progress`] console lines.
/// [`Level::Status`] lines always print.
pub fn set_quiet(quiet: bool) {
    QUIET.store(quiet, Ordering::SeqCst);
}

pub fn quiet() -> bool {
    QUIET.load(Ordering::SeqCst)
}

/// Is a JSONL trace file currently attached?
pub fn trace_enabled() -> bool {
    tracer().enabled.load(Ordering::Relaxed)
}

/// Attach a JSONL trace file and write the schema header event. Every
/// subsequent [`event`] is appended as one line until [`trace_close`].
pub fn trace_to(path: &str) -> std::io::Result<()> {
    let t = tracer();
    let file = BufWriter::new(File::create(path)?);
    *t.file.lock().unwrap() = Some(file);
    t.enabled.store(true, Ordering::SeqCst);
    event(
        "trace_start",
        vec![
            ("schema", Json::Num(SCHEMA_VERSION as f64)),
            ("pid", Json::Num(std::process::id() as f64)),
        ],
    );
    Ok(())
}

/// Flush and detach the trace file (idempotent; the ring stays live).
pub fn trace_close() {
    let t = tracer();
    t.enabled.store(false, Ordering::SeqCst);
    if let Some(mut f) = t.file.lock().unwrap().take() {
        let _ = f.flush();
    }
}

/// Render one event line: caller fields plus the envelope (`event`,
/// `seq`, `t_us`). Field order is deterministic because `Json::obj`
/// stores keys in a `BTreeMap` — serialization is sorted-key.
fn render(kind: &'static str, mut fields: Vec<(&'static str, Json)>) -> String {
    let t = tracer();
    fields.push(("event", Json::Str(kind.into())));
    fields.push(("seq", Json::Num(t.seq.fetch_add(1, Ordering::Relaxed) as f64)));
    fields.push(("t_us", Json::Num(t.start.elapsed().as_micros() as f64)));
    Json::obj(fields).to_string()
}

/// Record one structured event: always into the flight-recorder ring,
/// and into the trace file when one is attached. Never prints.
pub fn event(kind: &'static str, fields: Vec<(&'static str, Json)>) {
    let line = render(kind, fields);
    if trace_enabled() {
        if let Some(f) = tracer().file.lock().unwrap().as_mut() {
            let _ = writeln!(f, "{line}");
        }
    }
    ring::push(line);
}

/// Record one structured event *and* print its human rendering to
/// stderr under the console policy ([`Level`], `--quiet`).
pub fn event_human(
    level: Level,
    kind: &'static str,
    fields: Vec<(&'static str, Json)>,
    human: &str,
) {
    if level == Level::Status || !quiet() {
        eprintln!("{human}");
    }
    event(kind, fields);
}

/// Install a chaining panic hook that records a `panic` event and
/// dumps the flight-recorder ring before the previous hook runs.
/// Idempotent; `main` installs it once at startup.
pub fn install_panic_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            event("panic", vec![("detail", Json::Str(info.to_string()))]);
            if let Some(path) = ring::dump("panic") {
                eprintln!("qmap: flight recorder dumped to {}", path.display());
            }
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn events_render_one_sorted_json_line() {
        let line = render(
            "unit_test",
            vec![("zeta", Json::Num(1.0)), ("alpha", Json::Str("x".into()))],
        );
        assert!(!line.contains('\n'));
        let v = parse(&line).expect("event line parses");
        assert_eq!(v.get("event").as_str(), Some("unit_test"));
        assert!(v.get("seq").as_f64().is_some());
        assert!(v.get("t_us").as_f64().is_some());
        // deterministic field order: sorted keys
        let a = line.find("\"alpha\"").unwrap();
        let e = line.find("\"event\"").unwrap();
        let s = line.find("\"seq\"").unwrap();
        let z = line.find("\"zeta\"").unwrap();
        assert!(a < e && e < s && s < z, "{line}");
    }

    #[test]
    fn seq_is_monotonic() {
        let a = parse(&render("a", vec![])).unwrap().get("seq").as_f64().unwrap();
        let b = parse(&render("b", vec![])).unwrap().get("seq").as_f64().unwrap();
        assert!(b > a);
    }

    #[test]
    fn ring_keeps_the_newest_events_in_order() {
        for i in 0..(ring::RING_CAPACITY + 10) {
            ring::push(format!("{{\"i\":{i}}}"));
        }
        let snap = ring::snapshot();
        assert_eq!(snap.len(), ring::RING_CAPACITY);
        // oldest..newest, and the newest is the last push
        let last = parse(snap.last().unwrap()).unwrap().get("i").as_f64().unwrap();
        let first = parse(&snap[0]).unwrap().get("i").as_f64().unwrap();
        assert!(last >= (ring::RING_CAPACITY + 9) as f64);
        assert!(first <= last);
        let idx: Vec<f64> = snap
            .iter()
            .map(|l| parse(l).unwrap().get("i").as_f64().unwrap())
            .collect();
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "ring must stay ordered");
    }

    #[test]
    fn dump_writes_valid_jsonl_with_header() {
        event("dump_unit_probe", vec![("tag", Json::Num(7.0))]);
        let path = ring::dump("unit_test").expect("dump path");
        let src = std::fs::read_to_string(&path).expect("dump readable");
        let mut lines = src.lines();
        let head = parse(lines.next().expect("header")).expect("header parses");
        assert_eq!(head.get("event").as_str(), Some("flightrec_dump"));
        assert_eq!(head.get("reason").as_str(), Some("unit_test"));
        assert_eq!(head.get("schema").as_f64(), Some(SCHEMA_VERSION as f64));
        let mut seen = false;
        for l in lines {
            let v = parse(l).expect("every dump line is JSON");
            if v.get("event").as_str() == Some("dump_unit_probe") {
                seen = true;
            }
        }
        assert!(seen, "dump must contain the probe event");
        assert!(ring::recent_dumps().iter().any(|p| p == &path));
        let _ = std::fs::remove_file(&path);
    }
}
