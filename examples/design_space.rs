//! Accelerator design-space exploration — the use-case from the paper's
//! conclusion: "Our method can also help in designing new hardware
//! accelerators for CNN because it can cheaply estimate the impact of
//! complex quantization schemes on the resulting performance ... without
//! the need to implement the accelerator."
//!
//! We sweep Eyeriss-like variants (global-buffer size, PE-array size,
//! DRAM cost, bit-packing on/off), and for each variant report the best
//! mixed-precision configuration found by a short hardware-aware search.
//! The interesting output is how the *preferred bit-width profile* shifts
//! with the memory subsystem.
//!
//! Run: `cargo run --release --example design_space`

use qmap::accuracy::{AccuracyModel, ProxyAccuracy, ProxyParams};
use qmap::arch::{presets, Arch, Capacity};
use qmap::baselines::proposed_search;
use qmap::coordinator::RunConfig;
use qmap::engine::Engine;
use qmap::mapper::cache::MapperCache;
use qmap::quant::QuantConfig;
use qmap::report;
use qmap::workload::models;

/// One architecture variant to explore.
struct Variant {
    label: &'static str,
    arch: Arch,
}

fn variants() -> Vec<Variant> {
    let base = presets::eyeriss();

    let mut small_glb = base.clone();
    small_glb.name = "eyeriss-glb/4".into();
    if let Capacity::Shared(w) = small_glb.levels[1].capacity {
        small_glb.levels[1].capacity = Capacity::Shared(w / 4);
    }

    let mut big_array = base.clone();
    big_array.name = "eyeriss-336pe".into();
    big_array.levels[1].fanout = 336;

    let mut pricey_dram = base.clone();
    pricey_dram.name = "eyeriss-2xDRAM-cost".into();
    for e in pricey_dram.levels.last_mut().unwrap().access_energy_pj.iter_mut() {
        *e *= 2.0;
    }

    let mut no_packing = base.clone();
    no_packing.name = "eyeriss-no-packing".into();
    no_packing.bit_packing = false;

    vec![
        Variant { label: "baseline Eyeriss", arch: base },
        Variant { label: "1/4 global buffer", arch: small_glb },
        Variant { label: "2x PE array", arch: big_array },
        Variant { label: "2x DRAM energy", arch: pricey_dram },
        Variant { label: "vanilla Timeloop (no packing)", arch: no_packing },
    ]
}

fn main() {
    let layers = models::mobilenet_v1();
    let mut rc = RunConfig::fast();
    rc.nsga.generations = 8;

    println!("=== design-space exploration: Eyeriss variants x mixed-precision search ===\n");
    let engine = Engine::new(rc.threads);
    let mut rows = Vec::new();
    for v in variants() {
        v.arch.validate().expect("variant must be a legal arch");
        let cache = MapperCache::new();
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());

        let reference = qmap::eval::evaluate_network(
            &arch_ref(&v),
            &layers,
            &QuantConfig::uniform(layers.len(), 8),
            &cache,
            &rc.mapper,
        )
        .expect("uniform-8 must map on every variant");

        let front = proposed_search(
            &engine, &v.arch, &layers, &mut acc, &cache, &rc.mapper, &rc.nsga, |_, _| {},
        );

        // best candidate with <= 1% accuracy drop vs uniform-8
        let ref_acc = acc.accuracy(&QuantConfig::uniform(layers.len(), 8));
        let best = front
            .iter()
            .filter(|c| c.accuracy >= ref_acc - 0.01)
            .min_by(|a, b| a.hw.edp.partial_cmp(&b.hw.edp).unwrap());

        if let Some(b) = best {
            let mean_bits = b
                .genome
                .layers
                .iter()
                .map(|&(a, w)| (a + w) as f64 / 2.0)
                .sum::<f64>()
                / b.genome.layers.len() as f64;
            rows.push(vec![
                v.label.to_string(),
                v.arch.name.clone(),
                format!("{:+.1}%", (b.hw.edp / reference.edp - 1.0) * 100.0),
                format!(
                    "{:+.1}%",
                    (b.hw.memory_energy_pj / reference.memory_energy_pj - 1.0) * 100.0
                ),
                format!("{mean_bits:.1}"),
                profile(&b.genome),
            ]);
        } else {
            rows.push(vec![
                v.label.to_string(),
                v.arch.name.clone(),
                "-".into(),
                "-".into(),
                "-".into(),
                "(no candidate within 1% accuracy)".into(),
            ]);
        }
    }

    print!(
        "{}",
        report::table(
            &["variant", "arch", "ΔEDP vs u8", "Δmem-E vs u8", "mean bits", "bit profile (qa/qw per layer-group)"],
            &rows
        )
    );
    println!(
        "\nreading: smaller buffers / pricier DRAM push the search to lower bit-widths;\n\
         disabling bit-packing removes most of the incentive (the paper's extension\n\
         is what turns lower precision into fewer memory words)."
    );
}

fn arch_ref(v: &Variant) -> Arch {
    v.arch.clone()
}

/// Summarize the 28-layer bit profile as 4 layer-group means "a/w".
fn profile(qc: &QuantConfig) -> String {
    let n = qc.layers.len();
    let g = 4;
    (0..g)
        .map(|i| {
            let lo = i * n / g;
            let hi = ((i + 1) * n / g).max(lo + 1);
            let sl = &qc.layers[lo..hi.min(n)];
            let ma = sl.iter().map(|&(a, _)| a as f64).sum::<f64>() / sl.len() as f64;
            let mw = sl.iter().map(|&(_, w)| w as f64).sum::<f64>() / sl.len() as f64;
            format!("{ma:.0}/{mw:.0}")
        })
        .collect::<Vec<_>>()
        .join("  ")
}
