//! Synthetic image-classification dataset (the repo's stand-in for the
//! paper's ImageNet-100 subset; DESIGN.md §3).
//!
//! Each class is a deterministic composition of colored Gaussian blobs
//! whose positions/colors derive from the class index through SplitMix64;
//! samples add per-image jitter (blob displacement, amplitude, pixel
//! noise). The task is easy enough for the 0.4M-param scaled MobileNet
//! to learn in a few hundred CPU steps, yet hard enough that aggressive
//! quantization visibly costs accuracy — the property the paper's
//! accuracy/EDP trade-off experiments need.
//!
//! Generation is pure Rust (the Python side never needs the data: QAT
//! runs through the AOT artifacts driven from Rust).

use crate::util::rng::Rng;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const NUM_CLASSES: usize = 10;
const BLOBS_PER_CLASS: usize = 3;

/// One batch: NHWC f32 pixels in [0,1] and i32 labels.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub batch: usize,
}

/// Class blueprint: blob centers (row, col), radii, and RGB amplitudes.
#[derive(Debug, Clone)]
struct ClassSpec {
    blobs: [(f32, f32, f32, [f32; 3]); BLOBS_PER_CLASS],
}

/// Deterministic synthetic dataset generator.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    classes: Vec<ClassSpec>,
    seed: u64,
}

impl SyntheticDataset {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDA7A5E7);
        let classes = (0..NUM_CLASSES)
            .map(|c| {
                let mut r = rng.split(c as u64);
                let mut blobs = [(0.0, 0.0, 0.0, [0.0; 3]); BLOBS_PER_CLASS];
                for b in blobs.iter_mut() {
                    *b = (
                        4.0 + r.f32() * (IMG as f32 - 8.0), // center row
                        4.0 + r.f32() * (IMG as f32 - 8.0), // center col
                        2.0 + r.f32() * 4.0,                // radius
                        [
                            0.3 + 0.7 * r.f32(),
                            0.3 + 0.7 * r.f32(),
                            0.3 + 0.7 * r.f32(),
                        ],
                    );
                }
                ClassSpec { blobs }
            })
            .collect();
        SyntheticDataset { classes, seed }
    }

    /// Render one sample of class `label` with index-determined jitter.
    pub fn sample(&self, label: usize, index: u64, x: &mut [f32]) {
        assert_eq!(x.len(), IMG * IMG * CHANNELS);
        assert!(label < NUM_CLASSES);
        let mut r = Rng::new(self.seed ^ (label as u64) << 32 ^ index.wrapping_mul(0x9E37));
        // per-image jitter
        let dx = (r.f32() - 0.5) * 4.0;
        let dy = (r.f32() - 0.5) * 4.0;
        let amp = 0.8 + 0.4 * r.f32();
        x.fill(0.05);
        for &(cr, cc, rad, color) in &self.classes[label].blobs {
            let (cr, cc) = (cr + dy, cc + dx);
            let inv2r2 = 1.0 / (2.0 * rad * rad);
            for i in 0..IMG {
                for j in 0..IMG {
                    let d2 = (i as f32 - cr).powi(2) + (j as f32 - cc).powi(2);
                    let g = amp * (-d2 * inv2r2).exp();
                    if g > 1e-3 {
                        let base = (i * IMG + j) * CHANNELS;
                        for ch in 0..CHANNELS {
                            x[base + ch] += g * color[ch];
                        }
                    }
                }
            }
        }
        // pixel noise and clamp
        for v in x.iter_mut() {
            *v += (r.f32() - 0.5) * 0.08;
            *v = v.clamp(0.0, 1.0);
        }
    }

    /// Deterministic batch `index` of size `batch` with balanced-ish
    /// random labels.
    pub fn batch(&self, batch: usize, index: u64) -> Batch {
        let mut x = vec![0.0f32; batch * IMG * IMG * CHANNELS];
        let mut y = vec![0i32; batch];
        let mut r = Rng::new(self.seed ^ 0xBA7C4 ^ index.wrapping_mul(0x2545F4914F6CDD1D));
        for b in 0..batch {
            let label = r.below(NUM_CLASSES as u64) as usize;
            y[b] = label as i32;
            let off = b * IMG * IMG * CHANNELS;
            self.sample(label, index * 100_000 + b as u64, &mut x[off..off + IMG * IMG * CHANNELS]);
        }
        Batch { x, y, batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let d1 = SyntheticDataset::new(7);
        let d2 = SyntheticDataset::new(7);
        let b1 = d1.batch(8, 3);
        let b2 = d2.batch(8, 3);
        assert_eq!(b1.x, b2.x);
        assert_eq!(b1.y, b2.y);
    }

    #[test]
    fn seeds_differ() {
        let b1 = SyntheticDataset::new(1).batch(8, 0);
        let b2 = SyntheticDataset::new(2).batch(8, 0);
        assert_ne!(b1.x, b2.x);
    }

    #[test]
    fn pixels_in_unit_range() {
        let d = SyntheticDataset::new(3);
        let b = d.batch(16, 0);
        assert!(b.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(b.y.iter().all(|&l| (0..NUM_CLASSES as i32).contains(&l)));
    }

    #[test]
    fn classes_are_separable() {
        // mean image of class A must differ from class B clearly
        let d = SyntheticDataset::new(5);
        let n = 20;
        let mut mean = vec![vec![0.0f32; IMG * IMG * CHANNELS]; 2];
        let mut buf = vec![0.0f32; IMG * IMG * CHANNELS];
        for cls in 0..2 {
            for i in 0..n {
                d.sample(cls, i as u64, &mut buf);
                for (m, v) in mean[cls].iter_mut().zip(&buf) {
                    *m += v / n as f32;
                }
            }
        }
        let dist: f32 = mean[0]
            .iter()
            .zip(&mean[1])
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        assert!(dist > 1.0, "class means too close: {dist}");
    }

    #[test]
    fn intra_class_variance_nonzero() {
        let d = SyntheticDataset::new(5);
        let mut a = vec![0.0f32; IMG * IMG * CHANNELS];
        let mut b = vec![0.0f32; IMG * IMG * CHANNELS];
        d.sample(0, 1, &mut a);
        d.sample(0, 2, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn batches_are_label_diverse() {
        let d = SyntheticDataset::new(9);
        let b = d.batch(64, 0);
        let distinct: std::collections::BTreeSet<i32> = b.y.iter().copied().collect();
        assert!(distinct.len() >= 5, "labels: {distinct:?}");
    }
}
