//! §Perf: micro/meso benchmarks of the L3 hot paths. Not a paper
//! artifact — this is the before/after harness for the performance pass
//! recorded in EXPERIMENTS.md §Perf.
//!
//!   * mapper throughput: candidate mappings evaluated per second
//!     (draw + validity + nest analysis + energy model),
//!   * full-network characterization latency (28 workloads × target
//!     valid mappings), cold and warm cache,
//!   * cache hit latency,
//!   * NSGA-II generation step cost (proxy accuracy),
//!   * parallel scaling of network evaluation.
//!
//! Run: `cargo bench --bench perf_hotpath`.

use qmap::arch::presets;
use qmap::coordinator::experiments::parallel_map;
use qmap::eval::evaluate_network;
use qmap::mapper::cache::MapperCache;
use qmap::mapper::MapperConfig;
use qmap::mapping::mapspace::MapSpace;
use qmap::quant::{LayerQuant, QuantConfig};
use qmap::util::rng::Rng;
use qmap::workload::models;
use std::time::Instant;

fn time<R>(label: &str, f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("{label:<58} {:>10.3} ms", dt * 1e3);
    (r, dt)
}

fn main() {
    println!("=== §Perf: L3 hot-path benchmarks ===\n");
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cfg = MapperConfig {
        valid_target: 2_000, // the paper's budget
        max_draws: 2_000_000,
        seed: 42,
    };

    // 1. raw mapper throughput on the paper's dw-conv layer
    let layer = &layers[1];
    let q = LayerQuant { qa: 8, qw: 8, qo: 8 };
    let space = MapSpace::of(&arch);
    let mut evaluated = 0u64;
    let (st, dt) = time("mapper: enumerate+price dw-conv2 (capped 100k valid)", || {
        space.enumerate_valid(&arch, layer, &q, 100_000, |m| {
            let nest = qmap::nest::analyze(&arch, layer, m);
            let est = qmap::energy::estimate(&arch, layer, &q, &nest);
            std::hint::black_box(est.edp());
            evaluated += 1;
        })
    });
    println!(
        "  -> {} valid mappings priced, {:.0} mappings/s/core",
        st.valid,
        evaluated as f64 / dt
    );

    // 2. random-search characterization of one layer (2000 valid)
    let cache = MapperCache::new();
    let (_, dt2) = time("mapper: random search, 1 layer, 2000 valid", || {
        cache.evaluate(&arch, layer, &q, &cfg)
    });
    println!("  -> {:.0} layer-characterizations/s possible", 1.0 / dt2);

    // 3. full MobileNetV1 characterization, cold vs warm cache
    let cache2 = MapperCache::new();
    let qc = QuantConfig::uniform(layers.len(), 8);
    let (r_cold, dt_cold) = time("network: MobileNetV1 cold-cache characterization", || {
        evaluate_network(&arch, &layers, &qc, &cache2, &cfg)
    });
    assert!(r_cold.is_some());
    let (_, dt_warm) = time("network: MobileNetV1 warm-cache (identical genome)", || {
        evaluate_network(&arch, &layers, &qc, &cache2, &cfg)
    });
    println!(
        "  -> warm/cold speedup {:.0}x; warm per-genome {:.1} µs",
        dt_cold / dt_warm.max(1e-12),
        dt_warm * 1e6
    );

    // 4. cache hit latency (single layer)
    let (_, dth) = time("cache: single-workload hit x 100k", || {
        for _ in 0..100_000 {
            std::hint::black_box(cache2.evaluate(&arch, layer, &q, &cfg));
        }
    });
    println!("  -> {:.0} ns per hit", dth * 1e9 / 1e5);

    // 5. parallel scaling: 64 random genomes on 1 vs N threads
    let mut rng = Rng::new(7);
    let genomes: Vec<QuantConfig> = (0..64)
        .map(|_| {
            let mut g = QuantConfig::uniform(layers.len(), 8);
            for l in g.layers.iter_mut() {
                l.0 = 2 + rng.below(7) as u8;
                l.1 = 2 + rng.below(7) as u8;
            }
            g
        })
        .collect();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let fresh = MapperCache::new();
    let (_, dt1) = time("population: 64 genomes, 1 thread, shared cold cache", || {
        for g in &genomes {
            std::hint::black_box(evaluate_network(&arch, &layers, g, &fresh, &cfg));
        }
    });
    let fresh2 = MapperCache::new();
    let (_, dtn) = time(
        &format!("population: 64 genomes, {threads} threads, shared cold cache"),
        || {
            parallel_map(&genomes, threads, |g| {
                evaluate_network(&arch, &layers, g, &fresh2, &cfg).map(|e| e.edp)
            })
        },
    );
    println!("  -> parallel speedup {:.1}x on {threads} threads", dt1 / dtn.max(1e-12));

    // summary line for EXPERIMENTS.md §Perf
    println!("\nsummary:");
    println!("  mappings_per_sec_core = {:.0}", evaluated as f64 / dt);
    println!("  network_cold_ms       = {:.1}", dt_cold * 1e3);
    println!("  network_warm_us       = {:.1}", dt_warm * 1e6);
    println!("  cache_hit_ns          = {:.0}", dth * 1e9 / 1e5);
    println!("  pop64_speedup_x       = {:.1}", dt1 / dtn.max(1e-12));
}
