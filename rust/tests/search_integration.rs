//! Integration tests: the full search stack (NSGA-II x mapping engine x
//! proxy accuracy) on the real presets, plus cache behaviour across a
//! whole search — everything short of the PJRT runtime (see
//! `runtime_integration.rs`).

use qmap::accuracy::{AccuracyModel, ProxyAccuracy, ProxyParams};
use qmap::arch::presets;
use qmap::baselines::{naive_search, proposed_search, uniform_sweep};
use qmap::coordinator::RunConfig;
use qmap::engine::Engine;
use qmap::eval::evaluate_network;
use qmap::mapper::cache::MapperCache;
use qmap::quant::QuantConfig;
use qmap::workload::models;

fn rc() -> RunConfig {
    RunConfig::fast()
}

#[test]
fn proposed_search_improves_over_uniform8() {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    let c = rc();
    let engine = Engine::new(c.threads);
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());

    let reference = evaluate_network(
        &arch,
        &layers,
        &QuantConfig::uniform(layers.len(), 8),
        &cache,
        &c.mapper,
    )
    .unwrap();
    let ref_acc = acc.accuracy(&QuantConfig::uniform(layers.len(), 8));

    let front = proposed_search(&engine, &arch, &layers, &mut acc, &cache, &c.mapper, &c.nsga, |_, _| {});
    assert!(!front.is_empty());

    // some candidate must save EDP at tolerable accuracy loss
    let best = front
        .iter()
        .filter(|cand| cand.accuracy >= ref_acc - 0.02)
        .map(|cand| cand.hw.edp / reference.edp)
        .fold(f64::INFINITY, f64::min);
    assert!(
        best < 0.95,
        "no candidate saved >=5% EDP at <=2% accuracy loss (best rel EDP {best})"
    );
}

#[test]
fn search_is_deterministic_given_seed() {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let c = rc();
    let engine = Engine::new(c.threads);

    let run = || {
        let cache = MapperCache::new();
        let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
        let front =
            proposed_search(&engine, &arch, &layers, &mut acc, &cache, &c.mapper, &c.nsga, |_, _| {});
        front
            .iter()
            .map(|cand| (cand.genome.encode(), cand.hw.edp.to_bits()))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run(), "two identically-seeded searches diverged");
}

#[test]
fn uniform_sweep_covers_all_bitwidths() {
    let arch = presets::simba();
    let layers = models::mobilenet_v2();
    let cache = MapperCache::new();
    let c = rc();
    let engine = Engine::new(c.threads);
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
    let cands = uniform_sweep(&engine, &arch, &layers, &mut acc, &cache, &c.mapper, true);
    // 2..=8 plus 16-bit reference
    assert_eq!(cands.len(), 8);
    // accuracy should be non-decreasing with bits up to the proxy's
    // small evaluation noise
    let accs: Vec<f64> = cands.iter().map(|cand| cand.accuracy).collect();
    for w in accs.windows(2) {
        assert!(w[0] <= w[1] + 0.01, "uniform accuracy not monotone: {accs:?}");
    }
    // memory energy must be non-decreasing with bits too
    let mems: Vec<f64> = cands.iter().map(|cand| cand.hw.memory_energy_pj).collect();
    for w in mems.windows(2) {
        assert!(w[0] <= w[1] + 1e-6, "uniform mem energy not monotone: {mems:?}");
    }
}

#[test]
fn naive_search_prices_winners_on_real_hardware() {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    let c = rc();
    let engine = Engine::new(c.threads);
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
    let cands = naive_search(&engine, &arch, &layers, &mut acc, &cache, &c.mapper, &c.nsga);
    assert!(!cands.is_empty());
    for cand in &cands {
        assert!(cand.hw.edp.is_finite() && cand.hw.edp > 0.0);
        assert_eq!(cand.strategy, "naive");
    }
}

#[test]
fn cache_deduplicates_across_a_whole_search() {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    let c = rc();
    let engine = Engine::new(c.threads);
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
    let _ = proposed_search(&engine, &arch, &layers, &mut acc, &cache, &c.mapper, &c.nsga, |_, _| {});
    // an NSGA-II run evaluates |P| + |Q|*gens genomes x 28 layers;
    // without the cache that is thousands of mapper searches. With it,
    // the distinct-workload count stays small and hits dominate.
    assert!(
        cache.hits() > cache.misses(),
        "cache ineffective: {} hits / {} misses",
        cache.hits(),
        cache.misses()
    );
    // canonicalization bounds distinct workloads: 28 layers x pack
    // classes (16/8/4/2 -> 4 classes per tensor triple) is the true
    // upper bound; allow slack
    assert!(
        cache.len() < 28 * 64,
        "cache grew implausibly: {} entries",
        cache.len()
    );
}

#[test]
fn cache_persistence_roundtrip() {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    let c = rc();
    let qc = QuantConfig::uniform(layers.len(), 6);
    let before = evaluate_network(&arch, &layers, &qc, &cache, &c.mapper).unwrap();

    let json = cache.to_json();
    let restored = MapperCache::new();
    let n = restored.load_json(&json).unwrap();
    assert_eq!(n, cache.len());

    // the restored cache must produce identical results without misses
    let after = evaluate_network(&arch, &layers, &qc, &restored, &c.mapper).unwrap();
    assert_eq!(before, after);
    assert_eq!(restored.misses(), 0, "restored cache re-evaluated workloads");
}

#[test]
fn generation_callback_sees_monotone_progress() {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();
    let cache = MapperCache::new();
    let mut c = rc();
    let engine = Engine::new(c.threads);
    c.nsga.generations = 8;
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());

    let mut best_edp_per_gen: Vec<f64> = Vec::new();
    proposed_search(&engine, &arch, &layers, &mut acc, &cache, &c.mapper, &c.nsga, |_, pop| {
        let best = pop
            .iter()
            .map(|i| i.objectives[0])
            .fold(f64::INFINITY, f64::min);
        best_edp_per_gen.push(best);
    });
    assert!(best_edp_per_gen.len() >= 8);
    // elitism: the best EDP in the population can never get worse
    for w in best_edp_per_gen.windows(2) {
        assert!(w[1] <= w[0] + 1e-9, "elite lost: {best_edp_per_gen:?}");
    }
}

#[test]
fn cross_architecture_evaluation_is_consistent() {
    // a genome tuned on Simba must still be evaluable on Eyeriss and
    // produce finite, positive metrics (the Fig. 6 cross arm)
    let eyeriss = presets::eyeriss();
    let simba = presets::simba();
    let layers = models::mobilenet_v1();
    let c = rc();
    let engine = Engine::new(c.threads);
    let cache_s = MapperCache::new();
    let cache_e = MapperCache::new();
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
    let front = proposed_search(&engine, &simba, &layers, &mut acc, &cache_s, &c.mapper, &c.nsga, |_, _| {});
    let mut priced = 0;
    for cand in front.iter().take(6) {
        if let Some(e) = evaluate_network(&eyeriss, &layers, &cand.genome, &cache_e, &c.mapper) {
            assert!(e.edp.is_finite() && e.edp > 0.0);
            assert!(e.memory_energy_pj > 0.0);
            priced += 1;
        }
    }
    assert!(priced > 0, "no Simba winner was mappable on Eyeriss");
}
