//! Typed, pluggable objective space for the multi-objective search.
//!
//! The paper's central claim is a *three-way* trade-off among accuracy,
//! energy, and memory (Fig. 1 plots model size on its own axis), and
//! HAQ / Hardware-Centric AutoML show that *which* hardware signal you
//! optimize against materially changes the chosen per-layer bit-widths.
//! Before this module the search pipeline hardcoded an anonymous
//! two-element `Vec<f64>` of `(EDP, error)` from `eval::NetworkEval`
//! through `nsga`, the engine driver, the checkpoint journal, the wire
//! protocol, and the reports. Now the objective space is a first-class
//! value:
//!
//! * an [`Axis`] is one named minimized quantity, a **total** function
//!   of the hardware characterization ([`NetworkEval`]) plus the
//!   accuracy model (an unmappable genome prices every hardware axis at
//!   `+inf`, never a panic);
//! * an [`ObjectiveSpec`] is an ordered, duplicate-free list of axes,
//!   selectable per run (`qmap search --objectives
//!   error,energy,weight_words` / `QMAP_OBJECTIVES`), with a canonical
//!   string form and an FNV-1a identity hash that rides checkpoint
//!   headers and distributed batch messages so a resume or a
//!   mixed-version fleet under a *different* spec fails loudly instead
//!   of silently mixing incomparable fronts;
//! * an [`ObjectiveVec`] is one genome's objective values stamped with
//!   the spec identity they were computed under — the payload
//!   `nsga::Individual` carries.
//!
//! [`ObjectiveSpec::evaluate`] is the **single evaluation site**: every
//! former inline `1.0 - accuracy` / `e.edp` computation in the driver,
//! the baselines, and the experiment arms now routes through it.

use crate::eval::NetworkEval;

/// One named, minimized objective axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    /// CNN classification error, `1 - accuracy` (the accuracy model's
    /// axis; defined even for unmappable genomes).
    Error,
    /// Total inference energy on the target accelerator, pJ.
    Energy,
    /// Memory-subsystem energy (spads + buffers + DRAM), pJ.
    MemoryEnergy,
    /// Sum of per-layer energy-delay products (the paper's headline
    /// hardware metric).
    Edp,
    /// Total inference latency, cycles.
    Cycles,
    /// Packed weight-memory word count (Fig. 1a metric).
    WeightWords,
    /// Naïve model size in bits (Fig. 1 x-axis; the hardware-unaware
    /// baseline's proxy).
    ModelSize,
}

impl Axis {
    /// Every known axis, in canonical declaration order.
    pub const ALL: [Axis; 7] = [
        Axis::Error,
        Axis::Energy,
        Axis::MemoryEnergy,
        Axis::Edp,
        Axis::Cycles,
        Axis::WeightWords,
        Axis::ModelSize,
    ];

    /// The axis name as it appears in spec strings and reports.
    pub fn name(self) -> &'static str {
        match self {
            Axis::Error => "error",
            Axis::Energy => "energy",
            Axis::MemoryEnergy => "memory_energy",
            Axis::Edp => "edp",
            Axis::Cycles => "cycles",
            Axis::WeightWords => "weight_words",
            Axis::ModelSize => "model_size",
        }
    }

    /// Parse one axis name; unknown names list the valid axes.
    pub fn parse(s: &str) -> Result<Axis, String> {
        Axis::ALL
            .into_iter()
            .find(|a| a.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = Axis::ALL.iter().map(|a| a.name()).collect();
                format!(
                    "unknown objective axis '{s}' (valid axes: {})",
                    names.join(", ")
                )
            })
    }

    /// Price this axis for one genome: the hardware characterization
    /// (when the genome mapped) plus its accuracy. Total by
    /// construction — an unmappable genome (`hw == None`) prices every
    /// hardware axis at `+inf`, exactly how the old inline code treated
    /// dead genomes, while `error` stays defined.
    pub fn compute(self, hw: Option<&NetworkEval>, accuracy: f64) -> f64 {
        if self == Axis::Error {
            return 1.0 - accuracy;
        }
        let Some(e) = hw else {
            return f64::INFINITY;
        };
        match self {
            Axis::Error => unreachable!("handled above"),
            Axis::Energy => e.energy_pj,
            Axis::MemoryEnergy => e.memory_energy_pj,
            Axis::Edp => e.edp,
            Axis::Cycles => e.cycles,
            Axis::WeightWords => e.weight_words as f64,
            Axis::ModelSize => e.model_size_bits as f64,
        }
    }
}

/// Most axes a spec can name (each at most once).
pub const MAX_AXES: usize = Axis::ALL.len();

/// An ordered, duplicate-free set of objective axes — the type-level
/// identity of a search's objective space. `Copy` on purpose: it rides
/// inside `RunConfig` and `Engine` without ceremony.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObjectiveSpec {
    axes: [Axis; MAX_AXES],
    len: u8,
}

impl Default for ObjectiveSpec {
    /// The paper's two-objective formulation, `(EDP, error)` — exactly
    /// the pre-refactor hardcoded convention, including the order.
    fn default() -> Self {
        ObjectiveSpec::new(&[Axis::Edp, Axis::Error]).expect("default spec is valid")
    }
}

impl std::fmt::Display for ObjectiveSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, a) in self.axes().iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            f.write_str(a.name())?;
        }
        Ok(())
    }
}

impl ObjectiveSpec {
    /// A spec from an explicit axis list. At least one axis; at least
    /// two to make dominance meaningful is *not* required (a 1-axis
    /// spec degenerates to plain minimization, which is legitimate);
    /// duplicates are rejected — a repeated axis would double-weight it
    /// in crowding distance while adding no information to dominance.
    pub fn new(axes: &[Axis]) -> Result<ObjectiveSpec, String> {
        if axes.is_empty() {
            return Err("objective spec: at least one axis is required".into());
        }
        if axes.len() > MAX_AXES {
            return Err(format!(
                "objective spec: at most {MAX_AXES} axes ({} given)",
                axes.len()
            ));
        }
        let mut packed = [Axis::Error; MAX_AXES];
        for (i, &a) in axes.iter().enumerate() {
            if axes[..i].contains(&a) {
                return Err(format!("objective spec: duplicate axis '{}'", a.name()));
            }
            packed[i] = a;
        }
        Ok(ObjectiveSpec {
            axes: packed,
            len: axes.len() as u8,
        })
    }

    /// Parse the comma-separated grammar of `--objectives` /
    /// `QMAP_OBJECTIVES`: `error,energy,weight_words`. Whitespace
    /// around names is tolerated; empty entries, unknown names, and
    /// duplicates are errors.
    pub fn parse(s: &str) -> Result<ObjectiveSpec, String> {
        let mut axes = Vec::new();
        for part in s.split(',') {
            let name = part.trim();
            if name.is_empty() {
                return Err(format!("objective spec '{s}': empty axis name"));
            }
            axes.push(Axis::parse(name)?);
        }
        ObjectiveSpec::new(&axes)
    }

    /// The spec selected by `QMAP_OBJECTIVES`, if any (unset or empty
    /// means "caller's default"); a malformed value is an error, not a
    /// silent fallback.
    pub fn from_env() -> Result<Option<ObjectiveSpec>, String> {
        match std::env::var("QMAP_OBJECTIVES") {
            Ok(s) if !s.trim().is_empty() => {
                ObjectiveSpec::parse(&s).map(Some).map_err(|e| format!("QMAP_OBJECTIVES: {e}"))
            }
            _ => Ok(None),
        }
    }

    pub fn axes(&self) -> &[Axis] {
        &self.axes[..self.len as usize]
    }

    /// Number of objectives (k).
    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        false // a spec always has at least one axis
    }

    /// The canonical comma-separated string (what [`std::fmt::Display`]
    /// prints, what checkpoints store, what the wire carries).
    pub fn canonical(&self) -> String {
        self.to_string()
    }

    /// FNV-1a identity over the canonical string: equal hashes iff the
    /// same axes in the same order. Folded into the distributed batch
    /// identity and compared on checkpoint resume.
    pub fn hash(&self) -> u64 {
        crate::util::fnv1a(self.canonical().as_bytes())
    }

    /// Position of `axis` in this spec, if present — named (not
    /// positional) access for reports and experiment arms.
    pub fn index_of(&self, axis: Axis) -> Option<usize> {
        self.axes().iter().position(|&a| a == axis)
    }

    /// **The** evaluation site: price one genome's objective vector
    /// from its (optional) hardware characterization and its accuracy.
    pub fn evaluate(&self, hw: Option<&NetworkEval>, accuracy: f64) -> ObjectiveVec {
        ObjectiveVec {
            spec: self.hash(),
            values: self.axes().iter().map(|a| a.compute(hw, accuracy)).collect(),
        }
    }
}

/// One genome's objective values, stamped with the [`ObjectiveSpec`]
/// identity they were computed under. Derefs to `[f64]`, so dominance
/// and crowding code reads it as a plain slice; the stamp exists so
/// layers that *persist or transport* objectives (checkpoint, wire)
/// can refuse to mix incomparable spaces.
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectiveVec {
    /// [`ObjectiveSpec::hash`] of the producing spec; `0` for raw
    /// vectors (tests, generic point utilities) that never cross a
    /// persistence boundary.
    spec: u64,
    values: Vec<f64>,
}

impl ObjectiveVec {
    /// A vector bound to `spec` (lengths must agree).
    pub fn new(spec: &ObjectiveSpec, values: Vec<f64>) -> ObjectiveVec {
        assert_eq!(values.len(), spec.len(), "objective arity");
        ObjectiveVec {
            spec: spec.hash(),
            values,
        }
    }

    /// An unbound vector (spec id 0) for tests and generic utilities.
    pub fn raw(values: Vec<f64>) -> ObjectiveVec {
        ObjectiveVec { spec: 0, values }
    }

    /// Rebind persisted values to the spec they were checkpointed
    /// under (the loader validated arity against the stored ident).
    pub fn rebound(spec: &ObjectiveSpec, values: Vec<f64>) -> ObjectiveVec {
        ObjectiveVec::new(spec, values)
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn into_values(self) -> Vec<f64> {
        self.values
    }

    /// The producing spec's identity hash (0 = unbound).
    pub fn spec_hash(&self) -> u64 {
        self.spec
    }
}

impl std::ops::Deref for ObjectiveVec {
    type Target = [f64];
    fn deref(&self) -> &[f64] {
        &self.values
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> NetworkEval {
        NetworkEval {
            energy_pj: 10.0,
            memory_energy_pj: 6.0,
            mac_energy_pj: 4.0,
            cycles: 100.0,
            edp: 1e-3,
            energy_breakdown_pj: [1.0, 2.0, 3.0],
            weight_words: 42,
            model_size_bits: 1024,
        }
    }

    #[test]
    fn default_spec_is_the_papers_edp_error_convention() {
        let spec = ObjectiveSpec::default();
        assert_eq!(spec.canonical(), "edp,error");
        let v = spec.evaluate(Some(&hw()), 0.9);
        assert_eq!(v.values(), &[1e-3, 1.0 - 0.9]);
    }

    #[test]
    fn every_axis_prices_its_networkeval_field() {
        let spec = ObjectiveSpec::new(&Axis::ALL).unwrap();
        let e = hw();
        let v = spec.evaluate(Some(&e), 0.75);
        assert_eq!(
            v.values(),
            &[0.25, e.energy_pj, e.memory_energy_pj, e.edp, e.cycles, 42.0, 1024.0]
        );
    }

    #[test]
    fn unmappable_genomes_price_hardware_axes_at_infinity_only() {
        let spec = ObjectiveSpec::parse("error,energy,weight_words").unwrap();
        let v = spec.evaluate(None, 0.6);
        assert_eq!(v[0], 0.4);
        assert!(v[1].is_infinite() && v[2].is_infinite());
    }

    #[test]
    fn parse_roundtrips_and_tolerates_whitespace() {
        for s in ["edp,error", "error,energy,weight_words", "model_size , error"] {
            let spec = ObjectiveSpec::parse(s).unwrap();
            let again = ObjectiveSpec::parse(&spec.canonical()).unwrap();
            assert_eq!(spec, again);
            assert_eq!(spec.hash(), again.hash());
        }
    }

    #[test]
    fn bad_specs_are_rejected_with_names() {
        let err = ObjectiveSpec::parse("edp,warp").unwrap_err();
        assert!(err.contains("warp") && err.contains("weight_words"), "{err}");
        assert!(ObjectiveSpec::parse("").is_err());
        assert!(ObjectiveSpec::parse("edp,,error").is_err());
        let err = ObjectiveSpec::parse("edp,edp").unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
        assert!(ObjectiveSpec::new(&[]).is_err());
    }

    #[test]
    fn hash_separates_axis_order_and_content() {
        let a = ObjectiveSpec::parse("edp,error").unwrap();
        let b = ObjectiveSpec::parse("error,edp").unwrap();
        let c = ObjectiveSpec::parse("edp,error,cycles").unwrap();
        assert_ne!(a.hash(), b.hash());
        assert_ne!(a.hash(), c.hash());
        assert_eq!(a.hash(), ObjectiveSpec::default().hash());
    }

    #[test]
    fn named_axis_lookup() {
        let spec = ObjectiveSpec::parse("error,energy,weight_words").unwrap();
        assert_eq!(spec.index_of(Axis::Energy), Some(1));
        assert_eq!(spec.index_of(Axis::Edp), None);
    }

    #[test]
    fn objective_vec_carries_its_spec_identity() {
        let spec = ObjectiveSpec::parse("error,energy").unwrap();
        let v = spec.evaluate(Some(&hw()), 0.5);
        assert_eq!(v.spec_hash(), spec.hash());
        assert_eq!(ObjectiveVec::raw(vec![1.0]).spec_hash(), 0);
        // deref: plain slice reads for the nsga internals
        assert_eq!(v.len(), 2);
        assert_eq!(v[0], 0.5);
    }

    #[test]
    #[should_panic(expected = "objective arity")]
    fn binding_wrong_arity_panics() {
        let spec = ObjectiveSpec::default();
        let _ = ObjectiveVec::new(&spec, vec![1.0, 2.0, 3.0]);
    }
}
